"""L1 kernels vs the pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes (n, k, d, kn, block sizes) and dtypes; every
kernel must match ref.py to f32 accumulation tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import argmin, candidate, pairwise, ref, update

RTOL = 3e-4
ATOL = 3e-4


def _data(seed, n, k, d, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n, d)) * scale).astype(dtype)
    c = (rng.normal(size=(k, d)) * scale).astype(dtype)
    return jnp.array(x), jnp.array(c)


# ----------------------------------------------------------- pairwise ---
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 200),
    k=st.integers(1, 64),
    d=st.integers(1, 96),
    bn=st.sampled_from([16, 64, 256]),
    bk=st.sampled_from([8, 32, 256]),
    bd=st.sampled_from([16, 64, 512]),
)
def test_pairwise_matches_ref(seed, n, k, d, bn, bk, bd):
    x, c = _data(seed, n, k, d)
    got = pairwise.pairwise_sqdist(x, c, bn=bn, bk=bk, bd=bd)
    want = ref.pairwise_sqdist(x, c)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=RTOL, atol=ATOL)


def test_pairwise_bf16_inputs_accumulate_f32():
    x, c = _data(7, 64, 16, 32, dtype=np.float32)
    xb = x.astype(jnp.bfloat16)
    cb = c.astype(jnp.bfloat16)
    got = pairwise.pairwise_sqdist(xb, cb, bn=32, bk=16, bd=16)
    assert got.dtype == jnp.float32
    want = ref.pairwise_sqdist(xb, cb)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=3e-2, atol=3e-2)


def test_pairwise_zero_distance_diagonal():
    x, _ = _data(3, 40, 1, 24)
    d = pairwise.pairwise_sqdist(x, x, bn=16, bk=16, bd=8)
    np.testing.assert_allclose(np.diag(np.array(d)), np.zeros(40), atol=1e-3)


def test_pairwise_exact_tile_multiple():
    # n, k, d exactly divisible by tiles — no padding path at all.
    x, c = _data(11, 128, 32, 64)
    got = pairwise.pairwise_sqdist(x, c, bn=64, bk=32, bd=32)
    want = ref.pairwise_sqdist(x, c)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=RTOL, atol=ATOL)


# ------------------------------------------------------------- argmin ---
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 200),
    k=st.integers(1, 64),
    d=st.integers(1, 96),
    bn=st.sampled_from([16, 64, 256]),
    bk=st.sampled_from([8, 32, 256]),
)
def test_argmin_matches_ref(seed, n, k, d, bn, bk):
    x, c = _data(seed, n, k, d)
    lab, val = argmin.assign_argmin(x, c, bn=bn, bk=bk)
    rl, rv = ref.assign_argmin(x, c)
    # Distance ties across tile boundaries could differ in index; with
    # continuous gaussian data ties have measure zero.
    assert (np.array(lab) == np.array(rl)).all()
    np.testing.assert_allclose(np.array(val), np.array(rv), rtol=RTOL, atol=ATOL)
    assert lab.dtype == jnp.int32


def test_argmin_ghost_centers_never_win():
    # k=3 padded to bk=256: 253 ghost centers must never be selected.
    x, c = _data(5, 100, 3, 20)
    lab, _ = argmin.assign_argmin(x, c, bn=64, bk=256)
    assert np.array(lab).max() < 3


def test_argmin_single_point_single_center():
    x, c = _data(9, 1, 1, 8)
    lab, val = argmin.assign_argmin(x, c)
    assert np.array(lab)[0] == 0
    want = float(np.sum((np.array(x)[0] - np.array(c)[0]) ** 2))
    np.testing.assert_allclose(np.array(val)[0], want, rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------- candidate ---
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 200),
    k=st.integers(2, 64),
    kn=st.integers(1, 16),
    d=st.integers(1, 96),
    bn=st.sampled_from([16, 64, 256]),
)
def test_candidate_matches_ref(seed, n, k, kn, d, bn):
    kn = min(kn, k)
    x, c = _data(seed, n, k, d)
    rng = np.random.default_rng(seed + 1)
    cand = jnp.array(rng.integers(0, k, size=(n, kn)).astype(np.int32))
    lab, val = candidate.candidate_assign(x, c, cand, bn=bn)
    rl, rv = ref.candidate_assign(x, c, cand)
    assert (np.array(lab) == np.array(rl)).all()
    np.testing.assert_allclose(np.array(val), np.array(rv), rtol=RTOL, atol=ATOL)


def test_candidate_equals_full_when_all_centers_offered():
    # cand = [0..k) for every point => must equal the full assignment.
    x, c = _data(21, 120, 12, 30)
    cand = jnp.tile(jnp.arange(12, dtype=jnp.int32)[None, :], (120, 1))
    lab, val = candidate.candidate_assign(x, c, cand, bn=64)
    rl, rv = ref.assign_argmin(x, c)
    assert (np.array(lab) == np.array(rl)).all()
    np.testing.assert_allclose(np.array(val), np.array(rv), rtol=RTOL, atol=ATOL)


def test_candidate_duplicate_candidates_ok():
    x, c = _data(23, 50, 8, 16)
    cand = jnp.zeros((50, 4), dtype=jnp.int32) + 3  # all slots = center 3
    lab, val = candidate.candidate_assign(x, c, cand, bn=32)
    assert (np.array(lab) == 3).all()
    want = np.sum((np.array(x) - np.array(c)[3]) ** 2, axis=1)
    np.testing.assert_allclose(np.array(val), want, rtol=RTOL, atol=ATOL)


# -------------------------------------------------------------- update ---
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 300),
    k=st.integers(1, 48),
    d=st.integers(1, 64),
    bn=st.sampled_from([16, 64, 256]),
)
def test_update_matches_ref(seed, n, k, d, bn):
    x, _ = _data(seed, n, 1, d)
    rng = np.random.default_rng(seed + 2)
    labels = jnp.array(rng.integers(0, k, size=(n,)).astype(np.int32))
    s, cnt = update.center_update(x, labels, k, bn=bn)
    rs, rcnt = ref.center_update(x, labels, k)
    np.testing.assert_allclose(np.array(s), np.array(rs), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.array(cnt), np.array(rcnt))


def test_update_counts_sum_to_n():
    x, _ = _data(31, 257, 1, 10)  # deliberately not a block multiple
    rng = np.random.default_rng(31)
    labels = jnp.array(rng.integers(0, 7, size=(257,)).astype(np.int32))
    _, cnt = update.center_update(x, labels, 7, bn=64)
    assert float(np.array(cnt).sum()) == 257.0


def test_update_empty_cluster_zero():
    x, _ = _data(33, 64, 1, 8)
    labels = jnp.zeros((64,), dtype=jnp.int32)  # everything in cluster 0
    s, cnt = update.center_update(x, labels, 5, bn=32)
    assert np.array(cnt)[1:].sum() == 0.0
    np.testing.assert_allclose(np.array(s)[1:], 0.0)
    np.testing.assert_allclose(
        np.array(s)[0], np.array(x).sum(axis=0), rtol=RTOL, atol=ATOL
    )


# ---------------------------------------------------------- ref vs numpy --
def test_ref_pairwise_vs_numpy_direct():
    rng = np.random.default_rng(41)
    x = rng.normal(size=(50, 13)).astype(np.float32)
    c = rng.normal(size=(9, 13)).astype(np.float32)
    want = ((x[:, None, :] - c[None, :, :]) ** 2).sum(axis=2)
    got = ref.pairwise_sqdist(jnp.array(x), jnp.array(c))
    np.testing.assert_allclose(np.array(got), want, rtol=1e-4, atol=1e-4)


def test_ref_split_scan_vs_direct():
    rng = np.random.default_rng(43)
    x = np.sort(rng.normal(size=(40, 1)), axis=0).astype(np.float32)
    x = np.hstack([x, rng.normal(size=(40, 3)).astype(np.float32)])
    got = np.array(ref.split_scan(jnp.array(x)))

    def phi(a):
        m = a.mean(axis=0)
        return ((a - m) ** 2).sum()

    want = np.array([phi(x[:l]) + phi(x[l:]) for l in range(1, 40)])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
