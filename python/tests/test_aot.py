"""AOT emission: every menu entry lowers to parseable HLO text and the
manifest contract (line format consumed by rust/src/runtime/manifest.rs)
holds."""

import os

import pytest

from compile import aot


def test_to_hlo_text_smoke():
    import jax
    import jax.numpy as jnp

    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
        jax.ShapeDtypeStruct((4, 4), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "dot" in text


def test_default_menu_entries_lower(tmp_path):
    # Lower a trimmed menu (one entry per op) and check HLO well-formedness.
    menu = {op: entries[:1] for op, entries in aot.DEFAULT_MENU.items()}
    count = 0
    for name, lowered, meta in aot.build_entries(menu):
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert meta["op"] in {
            "assign_full",
            "assign_candidates",
            "center_knn",
            "update_stats",
            "split_scan",
        }
        count += 1
    assert count == 5


def test_manifest_line_format(tmp_path):
    """The rust manifest parser's contract: space-separated key=value."""
    import subprocess
    import sys

    out = tmp_path / "arts"
    # Run the real entrypoint on the default menu.
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    lines = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(lines) == 18
    for line in lines:
        kv = dict(f.split("=", 1) for f in line.split())
        assert "op" in kv and "file" in kv and "name" in kv
        assert (out / kv["file"]).exists()
        head = (out / kv["file"]).read_text()[:200]
        assert head.startswith("HloModule")
