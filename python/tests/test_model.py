"""L2 graph semantics: model.* vs numpy compositions + clustering-level
invariants (one Lloyd iteration through the graphs never increases
energy, the center kn-NN graph is symmetric-consistent, etc.)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _blobs(seed, n, k, d, spread=5.0):
    """Gaussian blobs with known structure."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d)) * spread
    lab = rng.integers(0, k, size=n)
    x = centers[lab] + rng.normal(size=(n, d))
    return x.astype(np.float32), centers.astype(np.float32), lab


def test_assign_full_matches_ref():
    x, c, _ = _blobs(0, 300, 10, 24)
    lab, val = model.assign_full(jnp.array(x), jnp.array(c))
    rl, rv = ref.assign_argmin(jnp.array(x), jnp.array(c))
    assert (np.array(lab) == np.array(rl)).all()
    np.testing.assert_allclose(np.array(val), np.array(rv), rtol=3e-4, atol=3e-4)


def test_assign_full_large_d_fallback():
    # d above _FUSED_ASSIGN_MAX_D exercises the pairwise+argmin fallback.
    old = model._FUSED_ASSIGN_MAX_D
    try:
        model._FUSED_ASSIGN_MAX_D = 16
        x, c, _ = _blobs(1, 100, 6, 32)
        lab, val = model.assign_full(jnp.array(x), jnp.array(c))
        rl, rv = ref.assign_argmin(jnp.array(x), jnp.array(c))
        assert (np.array(lab) == np.array(rl)).all()
        np.testing.assert_allclose(np.array(val), np.array(rv), rtol=3e-4, atol=3e-4)
    finally:
        model._FUSED_ASSIGN_MAX_D = old


def test_assign_recovers_blob_structure():
    x, c, true_lab = _blobs(2, 500, 8, 16, spread=20.0)
    lab, _ = model.assign_full(jnp.array(x), jnp.array(c))
    # With well-separated blobs and true centers, assignment = generation.
    assert (np.array(lab) == true_lab).mean() > 0.99


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(2, 40),
    kn=st.integers(1, 12),
    d=st.integers(1, 48),
)
def test_center_knn_properties(seed, k, kn, d):
    kn = min(kn, k)
    rng = np.random.default_rng(seed)
    c = jnp.array(rng.normal(size=(k, d)).astype(np.float32))
    nbrs, dists = model.center_knn(c, kn)
    nbrs = np.array(nbrs)
    dists = np.array(dists)
    assert nbrs.shape == (k, kn)
    # Self is the nearest neighbour (distance 0).
    assert (nbrs[:, 0] == np.arange(k)).all()
    np.testing.assert_allclose(dists[:, 0], 0.0, atol=1e-3)
    # Distances are sorted ascending.
    assert (np.diff(dists, axis=1) >= -1e-3).all()
    # Against brute force.
    full = np.array(ref.pairwise_sqdist(c, c))
    want = np.sort(full, axis=1)[:, :kn]
    np.testing.assert_allclose(np.sort(dists, axis=1), want, rtol=1e-3, atol=1e-3)


def test_update_centers_means_and_empty_preserved():
    x, c, _ = _blobs(3, 200, 5, 12)
    lab = np.random.default_rng(3).integers(0, 3, size=200).astype(np.int32)
    # clusters 3, 4 are empty
    new_c, counts = model.update_centers(jnp.array(x), jnp.array(lab), jnp.array(c))
    new_c, counts = np.array(new_c), np.array(counts)
    for j in range(3):
        np.testing.assert_allclose(
            new_c[j], x[lab == j].mean(axis=0), rtol=1e-4, atol=1e-4
        )
        assert counts[j] == (lab == j).sum()
    np.testing.assert_allclose(new_c[3:], c[3:], atol=1e-6)
    assert (counts[3:] == 0).all()


def test_one_lloyd_iteration_decreases_energy():
    x, c, _ = _blobs(4, 400, 6, 10)
    xj, cj = jnp.array(x), jnp.array(c[: 6])
    lab0, _ = model.assign_full(xj, cj)
    e0 = float(model.energy(xj, cj, lab0))
    c1, _ = model.update_centers(xj, lab0, cj)
    e1 = float(model.energy(xj, c1, lab0))
    assert e1 <= e0 + 1e-3
    lab1, _ = model.assign_full(xj, c1)
    e2 = float(model.energy(xj, c1, lab1))
    assert e2 <= e1 + 1e-3


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(3, 120), d=st.integers(1, 32))
def test_split_scan_matches_direct(seed, n, d):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    v = rng.normal(size=(d,)).astype(np.float32)
    order = np.argsort(x @ v)
    xs = jnp.array(x[order])
    energies, best = model.split_scan(xs)
    energies, best = np.array(energies), int(best)

    def phi(a):
        if len(a) == 0:
            return 0.0
        m = a.mean(axis=0)
        return float(((a - m) ** 2).sum())

    want = np.array([phi(x[order][:l]) + phi(x[order][l:]) for l in range(1, n)])
    np.testing.assert_allclose(energies, want, rtol=2e-3, atol=2e-3)
    assert 1 <= best <= n - 1
    # best is a true argmin up to float noise
    assert want[best - 1] <= want.min() + 1e-2 + 1e-3 * abs(want.min())


def test_split_scan_two_separated_blobs_finds_gap():
    rng = np.random.default_rng(9)
    a = rng.normal(size=(30, 4)) - 10.0
    b = rng.normal(size=(50, 4)) + 10.0
    x = np.vstack([a, b]).astype(np.float32)
    v = np.ones(4, dtype=np.float32)
    order = np.argsort(x @ v)
    _, best = model.split_scan(jnp.array(x[order]))
    assert int(best) == 30  # splits exactly between the blobs


def test_project_matches_numpy():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(64, 20)).astype(np.float32)
    v = rng.normal(size=(20,)).astype(np.float32)
    got = np.array(model.project(jnp.array(x), jnp.array(v)))
    np.testing.assert_allclose(got, x @ v, rtol=1e-4, atol=1e-4)


def test_energy_matches_numpy():
    x, c, _ = _blobs(13, 150, 4, 8)
    lab = np.random.default_rng(13).integers(0, 4, size=150).astype(np.int32)
    got = float(model.energy(jnp.array(x), jnp.array(c), jnp.array(lab)))
    want = float(((x - c[lab]) ** 2).sum())
    np.testing.assert_allclose(got, want, rtol=1e-4)
