"""L2 — the JAX compute graphs for the k²-means engine.

Each function here is a complete, jit-lowerable graph for one step of the
clustering loop, calling the L1 Pallas kernels for the distance hot spots.
``aot.py`` lowers them for a menu of static shapes to HLO text; the rust
runtime (rust/src/runtime/) loads and executes them on the request path.

Graphs:
  assign_full(x, c)                -> labels, dists   (Lloyd/Elkan step)
  assign_candidates(x, c, cand)    -> labels, dists   (k²-means step)
  center_knn(c)                    -> nbrs, nbr_dists (the kn-NN center graph)
  update_centers(x, labels, c_old) -> new_c, counts   (update step)
  split_scan(x_sorted)             -> energies, best  (Projective Split scan)
  energy(x, c, labels)             -> total energy    (convergence metric)
"""

import jax
import jax.numpy as jnp

from .kernels import argmin as _argmin
from .kernels import candidate as _candidate
from .kernels import pairwise as _pairwise
from .kernels import update as _update

# yale-sized d (32256) would need (BN, d) tiles past VMEM; above this the
# assignment falls back to the d-blocked pairwise kernel + argmin in-graph.
_FUSED_ASSIGN_MAX_D = 8192


def assign_full(x, c):
    """Nearest-center assignment (the Lloyd/Elkan assignment step).

    Returns (labels int32 (n,), sqdists f32 (n,)).
    """
    d = x.shape[1]
    if d <= _FUSED_ASSIGN_MAX_D:
        return _argmin.assign_argmin(x, c)
    dist = _pairwise.pairwise_sqdist(x, c)
    return jnp.argmin(dist, axis=1).astype(jnp.int32), jnp.min(dist, axis=1)


def assign_candidates(x, c, cand):
    """k²-means assignment step over per-point candidate sets."""
    return _candidate.candidate_assign(x, c, cand)


def center_knn(c, kn):
    """The kn-NN graph over centers (paper Alg. 1 line 6).

    Self-distances are zero so each center's neighbourhood includes itself
    (column 0), matching the paper's definition of N_kn(c_l).

    Returns:
      nbrs:      (k, kn) int32 — indices of the kn nearest centers
      nbr_dists: (k, kn) f32  — squared distances to them
    """
    dist = _pairwise.pairwise_sqdist(c, c)  # (k, k)
    # Sort-based top-k: jax.lax.top_k lowers to a `topk(..., largest=true)`
    # HLO op that xla_extension 0.5.1's text parser rejects; a full sort
    # lowers to plain `sort`, which round-trips. k <= 1024 so the extra
    # log-factor is noise.
    k = dist.shape[0]
    idx = jnp.argsort(dist, axis=1)[:, :kn]
    nd = jnp.take_along_axis(dist, idx, axis=1)
    return idx.astype(jnp.int32), nd


def update_centers(x, labels, c_old):
    """Update step: new centers = member means; empty clusters keep their
    previous center (the rust coordinator may also re-seed them).

    Returns (new_c (k, d) f32, counts (k,) f32).
    """
    k = c_old.shape[0]
    sums, counts = _update.center_update(x, labels, k)
    safe = jnp.maximum(counts, 1.0)[:, None]
    means = sums / safe
    new_c = jnp.where(counts[:, None] > 0.0, means, c_old.astype(jnp.float32))
    return new_c, counts


def update_stats(x, labels, k):
    """Update-step sufficient statistics only (sums, counts).

    This is the artifact the rust engine executes: it processes `n` in
    fixed-size slabs and needs *combinable* statistics across slabs —
    means don't combine, sums and counts do. Ghost rows (n-padding) carry
    label == k, which falls outside every one-hot column.
    """
    return _update.center_update(x, labels, k)


def split_scan(x_sorted):
    """Projective-Split minimum-energy 1-D scan (paper Alg. 3 lines 4-8).

    Given cluster rows pre-sorted along the projection direction (the sort
    itself lives in L3 — see DESIGN.md §Hardware-Adaptation), computes the
    two-sided prefix energies with the Lemma-1 identity

        phi(S) = sum_i ||s_i||^2 - ||sum_i s_i||^2 / |S|

    via two cumsums, and returns every split's total energy plus the
    argmin split position.

    Returns:
      energies: (n-1,) f32 — phi(x[:l]) + phi(x[l:]) for l = 1..n-1
      best:     ()    int32 — argmin l (number of points in the left part)
    """
    x = x_sorted.astype(jnp.float32)
    n = x.shape[0]

    def phi_prefix(y):
        csum = jnp.cumsum(y, axis=0)
        csq = jnp.cumsum(jnp.sum(y * y, axis=1))
        ls = jnp.arange(1, n + 1, dtype=jnp.float32)
        return csq - jnp.sum(csum * csum, axis=1) / ls

    fwd = phi_prefix(x)
    bwd = phi_prefix(x[::-1])[::-1]
    energies = fwd[:-1] + bwd[1:]
    best = (jnp.argmin(energies) + 1).astype(jnp.int32)
    return energies, best


def project(x, v):
    """Projection of cluster points onto the split direction c_a - c_b."""
    return (x.astype(jnp.float32) @ v.astype(jnp.float32)).astype(jnp.float32)


def energy(x, c, labels):
    """Total clustering energy sum_i ||x_i - c_{a(i)}||^2 (paper eq. 1)."""
    diff = x.astype(jnp.float32) - c.astype(jnp.float32)[labels]
    return jnp.sum(diff * diff)
