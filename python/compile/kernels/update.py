"""Center-update (segment-sum) Pallas kernel.

The update step computes per-cluster sums and counts. The TPU-idiomatic
form is a one-hot matmul: ``sums = onehot(labels)^T @ X`` — an
``(k, BN) @ (BN, d)`` MXU contraction per point block, accumulated across
blocks, instead of a scatter-add (which TPUs do poorly).

Grid: ``(n/BN,)`` with both outputs revisited every step (accumulation
pattern). The one-hot tile is (BN, k) f32 — at BN=256, k≤1024 that is
1 MB, fine for VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256


def _update_kernel(x_ref, lab_ref, sums_ref, counts_ref, *, k):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...]  # (BN, d)
    lab = lab_ref[...]  # (BN,)
    onehot = (lab[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(
        jnp.float32
    )  # (BN, k)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (k, d)
    counts_ref[...] += jnp.sum(onehot, axis=0)


def _pad_to(a, axis, mult, value=0):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad, constant_values=value)


@functools.partial(jax.jit, static_argnames=("k", "bn"))
def center_update(x, labels, k, *, bn=BN):
    """Per-cluster sums (k, d) and counts (k,).

    Ghost rows from n-padding are labelled ``k`` (one past the last real
    cluster) so they fall outside every one-hot column and contribute
    nothing.
    """
    n, d = x.shape
    x = x.astype(jnp.float32)
    labels = labels.astype(jnp.int32)

    xp = _pad_to(x, 0, bn)
    labp = _pad_to(labels, 0, bn, value=k)  # ghost label -> no column
    npad = xp.shape[0]
    grid = (npad // bn,)

    sums, counts = pl.pallas_call(
        functools.partial(_update_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,
    )(xp, labp)
    return sums, counts
