"""Fused Pallas distance + argmin kernel (the Lloyd assignment step).

Rather than materializing the full (n, k) distance matrix in HBM and
argmin-ing it, this kernel keeps a *running* (min-distance, argmin-index)
pair per point in VMEM while streaming center blocks through, which is the
memory-optimal form of the assignment step: HBM traffic is O(nd + kd + n)
instead of O(nd + kd + nk).

Grid: ``(n/BN, k/BK)`` with the k-axis innermost so the output row block
stays resident while all center blocks stream by. The full d extent is
kept per block (d-blocking combined with running argmin would need a
second cross-term accumulator pass; for the d ranges the paper uses —
50..4096 — a (BN, d) tile fits VMEM, and the huge yale d=32256 case is
handled by the L2 graph falling back to pairwise+argmin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256
BK = 256


def _assign_kernel(x_ref, c_ref, x2_ref, c2_ref, idx_ref, val_ref, *, bk):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full_like(val_ref, jnp.inf)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    x = x_ref[...]  # (BN, d)
    c = c_ref[...]  # (BK, d)
    cross = jax.lax.dot_general(
        x, c, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BN, BK)
    dist = x2_ref[...] + c2_ref[...] - 2.0 * cross  # (BN, BK)

    local_idx = jnp.argmin(dist, axis=1).astype(jnp.int32)  # (BN,)
    local_val = jnp.min(dist, axis=1)  # (BN,)
    better = local_val < val_ref[...]
    val_ref[...] = jnp.where(better, local_val, val_ref[...])
    idx_ref[...] = jnp.where(better, local_idx + j * bk, idx_ref[...])


def _pad_to(a, axis, mult, value=0.0):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad, constant_values=value)


# Padded (ghost) centers must never win the argmin.
_PAD_NORM = jnp.float32(3.0e38)


@functools.partial(jax.jit, static_argnames=("bn", "bk"))
def assign_argmin(x, c, *, bn=BN, bk=BK):
    """Nearest-center assignment for every point.

    Returns ``(labels int32 (n,), dists f32 (n,))``. Ghost centers from
    k-padding are excluded by giving them a ~3e38 squared norm, which
    dominates any real distance.
    """
    n, d = x.shape
    k, _ = c.shape
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1)[None, :]

    xp = _pad_to(x, 0, bn)
    cp = _pad_to(c, 0, bk)
    x2p = _pad_to(x2, 0, bn)
    c2p = _pad_to(c2, 1, bk, value=_PAD_NORM)
    npad = xp.shape[0]
    kpad = cp.shape[0]
    grid = (npad // bn, kpad // bk)

    idx, val = pl.pallas_call(
        functools.partial(_assign_kernel, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bk), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=True,
    )(xp, cp, x2p, c2p)
    return idx[:n], val[:n]
