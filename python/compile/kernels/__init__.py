"""L1 Pallas kernels for the k²-means hot paths.

Every kernel here is authored as a tiled Pallas kernel (BlockSpec over an
(n-block, k-block, d-block) grid where applicable) and lowered with
``interpret=True`` so the emitted HLO runs on any PJRT backend, including
the rust CPU client on the request path. On a real TPU the same kernels
compile to Mosaic; the tiling is chosen for VMEM residency (see
DESIGN.md §Hardware-Adaptation).

Kernels:
  pairwise.pairwise_sqdist   — full (n,k) squared-distance matrix
  argmin.assign_argmin       — fused distance + running argmin (Lloyd step)
  candidate.candidate_assign — kn-candidate restricted assignment (k²-means)
  update.center_update       — one-hot-matmul segment-sum center update
  ref                        — pure-jnp oracles for all of the above
"""

from . import argmin, candidate, pairwise, ref, update  # noqa: F401
