"""Tiled Pallas pairwise squared-distance kernel.

The hot spot of every k-means variant is ``dist(x_i, c_j)`` for a block of
points against a block of centers. We compute it in the MXU-friendly form

    ||x - c||^2 = ||x||^2 + ||c||^2 - 2 * <x, c>

where the cross term is a ``(BN, BD) @ (BD, BK)`` matmul per grid step and
the norms are precomputed in the surrounding L2 graph (they cost O(nd),
amortized over the whole iteration).

Grid: ``(n/BN, k/BK, d/BD)``. The output block is indexed by ``(i, j)``
only, so successive ``kd`` steps revisit the same VMEM tile and accumulate
the cross term into it; the final ``kd`` step fuses in the norm combine.
This is the canonical TPU accumulation pattern (the d-axis is the
innermost, "arbitrary"-semantics grid dimension).

VMEM budget per step (f32): BN*BD + BK*BD + BN*BK + BN + BK floats.
With the default BN=256, BK=256, BD=512 that is ~0.9 MB — comfortably
inside a 16 MB VMEM with double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (see module docstring for the VMEM budget).
BN = 256
BK = 256
BD = 512


def _pairwise_kernel(x_ref, c_ref, x2_ref, c2_ref, o_ref, *, nsteps_d):
    """One (i, j, kd) grid step: accumulate -2*x@c^T, fuse norms at the end."""
    kd = pl.program_id(2)

    @pl.when(kd == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # (BN, BD)
    c = c_ref[...]  # (BK, BD)
    # Cross-term on the MXU; accumulate in f32 regardless of input dtype.
    o_ref[...] += jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kd == nsteps_d - 1)
    def _combine():
        x2 = x2_ref[...]  # (BN, 1)
        c2 = c2_ref[...]  # (1, BK)
        o_ref[...] = x2 + c2 - 2.0 * o_ref[...]


def _pad_to(a, axis, mult):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("bn", "bk", "bd"))
def pairwise_sqdist(x, c, *, bn=BN, bk=BK, bd=BD):
    """Full (n, k) squared-distance matrix via the tiled Pallas kernel.

    Inputs of any f32-castable dtype; output f32. Shapes need not be
    multiples of the tile sizes — we pad here and slice the result (the
    rust runtime additionally pads to the artifact menu, see
    rust/src/runtime/).
    """
    n, d = x.shape
    k, _ = c.shape
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    # Norms in the L2 graph — cheap, and padding rows contribute 0.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (n, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, k)

    xp = _pad_to(_pad_to(x, 0, bn), 1, bd)
    cp = _pad_to(_pad_to(c, 0, bk), 1, bd)
    x2p = _pad_to(x2, 0, bn)
    c2p = _pad_to(c2, 1, bk)
    npad, dpad = xp.shape
    kpad = cp.shape[0]
    grid = (npad // bn, kpad // bk, dpad // bd)

    out = pl.pallas_call(
        functools.partial(_pairwise_kernel, nsteps_d=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, kd: (i, kd)),
            pl.BlockSpec((bk, bd), lambda i, j, kd: (j, kd)),
            pl.BlockSpec((bn, 1), lambda i, j, kd: (i, 0)),
            pl.BlockSpec((1, bk), lambda i, j, kd: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j, kd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((npad, kpad), jnp.float32),
        interpret=True,
    )(xp, cp, x2p, c2p)
    return out[:n, :k]
