"""Candidate-restricted assignment kernel — the k²-means hot step.

The paper's core iteration-speedup idea: a point assigned to center ``l``
only needs distances to the ``kn`` nearest neighbours of ``c_l``. On TPU
this becomes a *gather* of the kn candidate center rows into VMEM followed
by per-point small contractions — shrinking both HBM traffic and MXU work
by a factor ``kn/k`` versus the full assignment (see DESIGN.md
§Hardware-Adaptation).

Grid: ``(n/BN,)``. Each step gathers ``(BN, KN, d)`` candidate rows from
the full center table (kept in ANY/HBM memory space; the gather streams
rows into VMEM) and reduces over d with an elementwise-square sum. The
candidate table is small (KN ≤ 200 in the paper), so (BN, KN) fits VMEM
at every d the paper uses.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 256


def _candidate_kernel(x_ref, c_ref, cand_ref, lab_ref, val_ref):
    x = x_ref[...]  # (BN, d)
    cand = cand_ref[...]  # (BN, KN) int32
    c = c_ref[...]  # (k, d) — full table
    cg = c[cand]  # (BN, KN, d) gathered candidates
    diff = x[:, None, :] - cg
    dist = jnp.sum(diff * diff, axis=2)  # (BN, KN)
    j = jnp.argmin(dist, axis=1)  # (BN,) local candidate slot
    lab_ref[...] = jnp.take_along_axis(cand, j[:, None], axis=1)[:, 0].astype(
        jnp.int32
    )
    val_ref[...] = jnp.take_along_axis(dist, j[:, None], axis=1)[:, 0]


def _pad_to(a, axis, mult, value=0):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad, constant_values=value)


@functools.partial(jax.jit, static_argnames=("bn",))
def candidate_assign(x, c, cand, *, bn=BN):
    """Nearest candidate center per point.

    Args:
      x:    (n, d) points.
      c:    (k, d) centers.
      cand: (n, kn) int32 candidate indices (must include the current
            center; the rust coordinator guarantees this).
    Returns:
      labels (n,) int32 global indices, dists (n,) f32.
    """
    n, d = x.shape
    k = c.shape[0]
    kn = cand.shape[1]
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    cand = cand.astype(jnp.int32)

    xp = _pad_to(x, 0, bn)
    candp = _pad_to(cand, 0, bn)  # ghost rows point at center 0 — sliced off
    npad = xp.shape[0]
    grid = (npad // bn,)

    lab, val = pl.pallas_call(
        _candidate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # full center table
            pl.BlockSpec((bn, kn), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((bn,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.int32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=True,
    )(xp, c, candp)
    return lab[:n], val[:n]
