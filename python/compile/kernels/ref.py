"""Pure-jnp correctness oracles for the Pallas kernels.

These define the semantics the kernels must match up to float association
order (the kernels accumulate the cross term over d-blocks, so we compare
with ``assert_allclose`` at ~1e-4 relative for f32).
"""

import jax.numpy as jnp


def pairwise_sqdist(x, c):
    """Full squared euclidean distance matrix.

    Args:
      x: (n, d) points.
      c: (k, d) centers.
    Returns:
      (n, k) squared distances, f32.
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (n, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]  # (1, k)
    cross = x @ c.T  # (n, k)
    return x2 + c2 - 2.0 * cross


def assign_argmin(x, c):
    """Lloyd assignment step: nearest center index + its squared distance.

    Returns:
      labels: (n,) int32
      dists:  (n,) f32 squared distance to the nearest center
    """
    d = pairwise_sqdist(x, c)
    labels = jnp.argmin(d, axis=1).astype(jnp.int32)
    dists = jnp.min(d, axis=1)
    return labels, dists


def candidate_assign(x, c, cand):
    """k²-means assignment step: nearest center among per-point candidates.

    Args:
      x:    (n, d) points.
      c:    (k, d) centers.
      cand: (n, kn) int32 candidate center indices per point (the kn-NN
            neighbourhood of the point's current center; always contains
            the current center itself).
    Returns:
      labels: (n,) int32 — *global* center index of the nearest candidate
      dists:  (n,) f32 squared distance to it
    """
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    cg = c[cand]  # (n, kn, d) gathered candidate centers
    diff2 = jnp.sum((x[:, None, :] - cg) ** 2, axis=2)  # (n, kn)
    j = jnp.argmin(diff2, axis=1)  # (n,) local index
    labels = jnp.take_along_axis(cand, j[:, None], axis=1)[:, 0].astype(jnp.int32)
    dists = jnp.take_along_axis(diff2, j[:, None], axis=1)[:, 0]
    return labels, dists


def center_update(x, labels, k):
    """Update-step sufficient statistics: per-cluster sums and counts.

    Returns:
      sums:   (k, d) f32 — sum of member points per cluster
      counts: (k,)  f32 — member count per cluster
    """
    x = x.astype(jnp.float32)
    onehot = (labels[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def split_scan(x_sorted):
    """Projective-Split 1-D scan oracle (paper Alg. 3, lines 4-8).

    Given the rows of a cluster already sorted along the projection
    direction, return for every split position l in [1, n-1] the total
    energy phi(x[:l]) + phi(x[l:]).

    Returns:
      energies: (n-1,) f32 — total two-cluster energy per split position.
    """
    x = x_sorted.astype(jnp.float32)
    n = x.shape[0]

    def phi_prefix(y):
        # phi(y[:l]) for l = 1..n  via  sum ||y_i||^2 - ||sum y_i||^2 / l
        csum = jnp.cumsum(y, axis=0)  # (n, d)
        csq = jnp.cumsum(jnp.sum(y * y, axis=1))  # (n,)
        ls = jnp.arange(1, n + 1, dtype=jnp.float32)
        return csq - jnp.sum(csum * csum, axis=1) / ls

    fwd = phi_prefix(x)  # phi of x[:l], l=1..n
    bwd = phi_prefix(x[::-1])[::-1]  # phi of x[l:], l=0..n-1
    return fwd[:-1] + bwd[1:]
