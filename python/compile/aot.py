"""AOT lowering: L2 graphs -> HLO text artifacts + manifest.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published ``xla`` 0.1.6 crate links) rejects
(``proto.id() <= INT_MAX``). The text parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Every graph is lowered for a static *shape menu*; the rust runtime pads a
request up to the nearest menu entry (ghost centers get huge norms, ghost
points get an out-of-range label — see the kernels' docstrings) and slices
the result. Two manifests are written:

  manifest.json — human-readable inventory
  manifest.txt  — one ``key=value`` line per artifact, parsed by
                  rust/src/runtime/manifest.rs (no serde in the offline
                  vendor set, so the line format is the contract)

Usage: cd python && python -m compile.aot --out ../artifacts [--menu big]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ----------------------------------------------------------------------
# Shape menus. NB is the fixed point-block row count per executable call;
# the rust engine loops n in NB-row slabs. k/d/kn are padded up to the
# nearest menu entry. The default menu covers the e2e example + the
# integration tests; ``--menu big`` adds the larger dense workloads.
# ----------------------------------------------------------------------
NB = 2048

DEFAULT_MENU = {
    "assign_full": [  # (k, d)
        (256, 64),
        (256, 512),
        (1024, 64),
        (1024, 512),
    ],
    "assign_candidates": [  # (k, kn, d)
        (256, 32, 64),
        (256, 32, 512),
        (1024, 32, 64),
        (1024, 32, 512),
    ],
    "center_knn": [  # (k, kn, d)
        (256, 32, 64),
        (256, 32, 512),
        (1024, 32, 64),
        (1024, 32, 512),
    ],
    "update_stats": [  # (k, d)
        (256, 64),
        (256, 512),
        (1024, 64),
        (1024, 512),
    ],
    "split_scan": [  # (n, d)
        (2048, 64),
        (2048, 512),
    ],
}

BIG_EXTRA = {
    "assign_full": [(256, 3072)],
    "assign_candidates": [(256, 64, 3072), (1024, 64, 512)],
    "center_knn": [(256, 64, 3072), (1024, 64, 512)],
    "update_stats": [(256, 3072)],
    "split_scan": [(2048, 3072)],
}


def build_entries(menu):
    """Yield (name, lowered, meta) for every artifact in the menu."""
    for k, d in menu["assign_full"]:
        name = f"assign_full_nb{NB}_k{k}_d{d}"
        lowered = jax.jit(model.assign_full).lower(spec((NB, d)), spec((k, d)))
        yield name, lowered, {"op": "assign_full", "nb": NB, "k": k, "d": d}

    for k, kn, d in menu["assign_candidates"]:
        name = f"assign_cand_nb{NB}_k{k}_kn{kn}_d{d}"
        lowered = jax.jit(model.assign_candidates).lower(
            spec((NB, d)), spec((k, d)), spec((NB, kn), I32)
        )
        yield name, lowered, {
            "op": "assign_candidates", "nb": NB, "k": k, "kn": kn, "d": d,
        }

    for k, kn, d in menu["center_knn"]:
        name = f"center_knn_k{k}_kn{kn}_d{d}"
        lowered = jax.jit(model.center_knn, static_argnums=1).lower(
            spec((k, d)), kn
        )
        yield name, lowered, {"op": "center_knn", "k": k, "kn": kn, "d": d}

    for k, d in menu["update_stats"]:
        name = f"update_nb{NB}_k{k}_d{d}"
        lowered = jax.jit(model.update_stats, static_argnums=2).lower(
            spec((NB, d)), spec((NB,), I32), k
        )
        yield name, lowered, {"op": "update_stats", "nb": NB, "k": k, "d": d}

    for n, d in menu["split_scan"]:
        name = f"split_scan_n{n}_d{d}"
        lowered = jax.jit(model.split_scan).lower(spec((n, d)))
        yield name, lowered, {"op": "split_scan", "n": n, "d": d}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--menu", choices=["default", "big"], default="default")
    args = ap.parse_args()

    menu = {k: list(v) for k, v in DEFAULT_MENU.items()}
    if args.menu == "big":
        for op, extra in BIG_EXTRA.items():
            menu[op].extend(extra)

    os.makedirs(args.out, exist_ok=True)
    entries = []
    for name, lowered, meta in build_entries(menu):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        meta = dict(meta, name=name, file=fname, bytes=len(text))
        entries.append(meta)
        print(f"  {fname:48s} {len(text):>10,d} bytes")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"nb": NB, "artifacts": entries}, f, indent=2)
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        for e in entries:
            fields = " ".join(
                f"{k}={v}" for k, v in sorted(e.items()) if k != "bytes"
            )
            f.write(fields + "\n")
    print(f"wrote {len(entries)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
