"""Build-path package: JAX/Pallas authoring + AOT lowering for k²-means.

Nothing in here runs at request time. ``python -m compile.aot`` lowers the
L2 graphs (which call the L1 Pallas kernels) to HLO text artifacts that the
rust coordinator loads via the PJRT C API.
"""
