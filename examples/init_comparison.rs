//! Initialization shoot-out (paper Tables 4/7 in miniature): random vs
//! k-means++ vs GDI on one dataset, several k — converged Lloyd energy
//! and init cost, relative to k-means++.
//!
//! ```bash
//! cargo run --release --example init_comparison
//! ```

use k2m::cluster::{lloyd, Config};
use k2m::core::OpCounter;
use k2m::coordinator::inits::InitMethod;
use k2m::data;

fn main() {
    let ds = data::usps_like(0.3, 0xD5);
    println!("dataset {} n={} d={}", ds.name, ds.n(), ds.d());
    println!(
        "{:<6}{:<12}{:>14}{:>16}{:>16}",
        "k", "init", "energy/++", "init ops/++", "init ops"
    );

    for k in [50, 100, 200] {
        // k-means++ reference values (seed-averaged).
        let seeds = [0u64, 1, 2];
        let mut results: Vec<Vec<(f64, f64)>> = vec![Vec::new(); 3];
        for &seed in &seeds {
            for (mi, method) in InitMethod::ALL.iter().enumerate() {
                let mut counter = OpCounter::default();
                let init = method.run(&ds.x, k, seed, &mut counter);
                let init_ops = counter.total();
                let cfg = Config { k, record_trace: false, ..Default::default() };
                let run = lloyd(&ds.x, &init, &cfg, &mut counter);
                results[mi].push((run.energy, init_ops));
            }
        }
        let avg = |v: &[(f64, f64)], f: fn(&(f64, f64)) -> f64| {
            v.iter().map(f).sum::<f64>() / v.len() as f64
        };
        let e_pp = avg(&results[1], |r| r.0);
        let ops_pp = avg(&results[1], |r| r.1);
        for (mi, method) in InitMethod::ALL.iter().enumerate() {
            let e = avg(&results[mi], |r| r.0);
            let ops = avg(&results[mi], |r| r.1);
            println!(
                "{:<6}{:<12}{:>14.4}{:>16.4}{:>16.3e}",
                k,
                method.name(),
                e / e_pp,
                if ops_pp > 0.0 { ops / ops_pp } else { 0.0 },
                ops
            );
        }
    }
    println!("\n(expect: GDI energy ≈ ++ energy, GDI init cost ≪ ++ as k grows — paper Table 7)");
}
