//! Projective Split vs standard 2-means (paper Figure 1): on two
//! overlapping gaussians whose initial centers land in the *same* blob,
//! the standard 2-means midpoint split needs several iterations, while
//! Projective Split finds the minimum-energy cut along the center
//! direction almost immediately.
//!
//! Emits `out/fig1_split_demo.csv` with the point cloud and both
//! methods' assignments after 1 and 2 iterations, plus a console summary.
//!
//! ```bash
//! cargo run --release --example projective_split_demo
//! ```

use k2m::core::{ops, Matrix, NumericsMode, OpCounter};
use k2m::init::split::{projective_split, sqnorms};
use k2m::metrics::phi;
use k2m::rng::Pcg32;

/// One assignment+update round of standard 2-means from given centers.
fn two_means_round(x: &Matrix, c_a: &mut Vec<f32>, c_b: &mut Vec<f32>) -> Vec<u8> {
    let mut sides = vec![0u8; x.rows()];
    for i in 0..x.rows() {
        let da = ops::sqdist_raw(x.row(i), c_a);
        let db = ops::sqdist_raw(x.row(i), c_b);
        sides[i] = u8::from(db < da);
    }
    for (target, side) in [(&mut *c_a, 0u8), (&mut *c_b, 1u8)] {
        let members: Vec<usize> = (0..x.rows()).filter(|&i| sides[i] == side).collect();
        if members.is_empty() {
            continue;
        }
        let mut mean = vec![0.0f64; x.cols()];
        for &i in &members {
            for (m, &v) in mean.iter_mut().zip(x.row(i)) {
                *m += v as f64;
            }
        }
        for (t, m) in target.iter_mut().zip(&mean) {
            *t = (m / members.len() as f64) as f32;
        }
    }
    sides
}

fn split_energy(x: &Matrix, sides: &[u8]) -> f64 {
    let left: Vec<u32> = (0..x.rows() as u32).filter(|&i| sides[i as usize] == 0).collect();
    let right: Vec<u32> = (0..x.rows() as u32).filter(|&i| sides[i as usize] == 1).collect();
    phi(x, &left) + phi(x, &right)
}

fn main() {
    // Figure-1 setup: two 2-D gaussians, both initial centers in blob A.
    let mut rng = Pcg32::seeded(11);
    let n = 400;
    let mut x = Matrix::zeros(n, 2);
    for i in 0..n {
        let (cx, cy) = if i < n / 2 { (-4.0, 0.0) } else { (4.0, 1.5) };
        let r = x.row_mut(i);
        r[0] = cx + rng.gaussian_f32() * 1.2;
        r[1] = cy + rng.gaussian_f32() * 1.2;
    }
    // Both seeds inside blob A (indices < n/2).
    let ia = 3usize;
    let ib = 57usize;

    // Standard 2-means for 2 rounds.
    let mut ca = x.row(ia).to_vec();
    let mut cb = x.row(ib).to_vec();
    let km_r1 = two_means_round(&x, &mut ca, &mut cb);
    let e_km1 = split_energy(&x, &km_r1);
    let km_r2 = two_means_round(&x, &mut ca, &mut cb);
    let e_km2 = split_energy(&x, &km_r2);

    // Projective Split (1 and 2 scan iterations) from the same seeds.
    let members: Vec<u32> = (0..n as u32).collect();
    let mut counter = OpCounter::default();
    let sq = sqnorms(&x, &mut counter);
    // Seeded rng replays the same (ia, ib)-style draw; we simply let it
    // pick its own pair — the point is convergence speed, shown below.
    let nm = NumericsMode::Strict;
    let mut srng = Pcg32::seeded(11);
    let ps1 = projective_split(&x, &members, 1, &sq, &mut counter, &mut srng, 0, nm).unwrap();
    let e_ps1 = ps1.phi_left + ps1.phi_right;
    let mut srng = Pcg32::seeded(11);
    let ps2 = projective_split(&x, &members, 2, &sq, &mut counter, &mut srng, 0, nm).unwrap();
    let e_ps2 = ps2.phi_left + ps2.phi_right;

    println!("two-cluster energy after each iteration (lower = better):");
    println!("  standard 2-means : iter1 {e_km1:.1}   iter2 {e_km2:.1}");
    println!("  projective split : iter1 {e_ps1:.1}   iter2 {e_ps2:.1}");
    println!(
        "  (true blob split  : {:.1})",
        phi(&x, &(0..(n / 2) as u32).collect::<Vec<_>>())
            + phi(&x, &((n / 2) as u32..n as u32).collect::<Vec<_>>())
    );

    // CSV for plotting.
    std::fs::create_dir_all("out").unwrap();
    let mut csv = String::from("x,y,blob,km_iter1,km_iter2,ps_iter2\n");
    let ps_side: Vec<u8> = {
        let mut side = vec![0u8; n];
        for &i in &ps2.right {
            side[i as usize] = 1;
        }
        side
    };
    for i in 0..n {
        csv.push_str(&format!(
            "{},{},{},{},{},{}\n",
            x.row(i)[0],
            x.row(i)[1],
            u8::from(i >= n / 2),
            km_r1[i],
            km_r2[i],
            ps_side[i]
        ));
    }
    std::fs::write("out/fig1_split_demo.csv", csv).unwrap();
    println!("wrote out/fig1_split_demo.csv");

    assert!(
        e_ps1 <= e_km2 * 1.02,
        "projective split's first iteration should match 2-means' second"
    );
}
