//! Visual-vocabulary construction — the workload that motivates the
//! paper's large-k regime (Philbin et al.'s object retrieval needs
//! vocabularies of 10⁴–10⁶ visual words; the paper's intro cites exactly
//! this use case for fast large-scale clustering).
//!
//! We build a codebook over cnnvoc-like CNN descriptors with k=500 words
//! and compare the three practical options end to end:
//!   * AKM (what Philbin used),
//!   * Lloyd++ (the accuracy yardstick),
//!   * k²-means + GDI (the paper's method),
//! then quantize a held-out query set against the codebook and report
//! quantization error + op budgets.
//!
//! ```bash
//! cargo run --release --example visual_codebook
//! ```

use k2m::cluster::{akm, k2means, lloyd, Config};
use k2m::core::{ops, Matrix, OpCounter};
use k2m::data;
use k2m::init::{gdi, kmeans_pp, random_init, GdiOpts};

/// Mean squared quantization error of queries against a codebook.
fn quantization_error(queries: &Matrix, codebook: &Matrix) -> f64 {
    let mut total = 0.0f64;
    for i in 0..queries.rows() {
        let mut best = f32::INFINITY;
        for j in 0..codebook.rows() {
            best = best.min(ops::sqdist_raw(queries.row(i), codebook.row(j)));
        }
        total += best as f64;
    }
    total / queries.rows() as f64
}

fn main() {
    let train = data::cnnvoc_like(0.2, 0xBEEF); // n≈3100 descriptors
    let queries = data::cnnvoc_like(0.02, 0xCAFE); // held-out set
    // Project to a manageable dimension for the demo (JL-preserving).
    let train_x = data::random_projection(&train.x, 256, 1);
    let queries_x = data::random_projection(&queries.x, 256, 1);
    let k = 500;
    println!(
        "codebook training: n={} d={} k={k}; queries n={}",
        train_x.rows(),
        train_x.cols(),
        queries_x.rows()
    );

    // Lloyd++ (yardstick).
    let mut c1 = OpCounter::default();
    let init = kmeans_pp(&train_x, k, &mut c1, 3);
    let lpp = lloyd(&train_x, &init, &Config { k, ..Default::default() }, &mut c1);

    // AKM with m=30 checks.
    let mut c2 = OpCounter::default();
    let akm_run = akm(
        &train_x,
        &random_init(&train_x, k, 3),
        &Config { k, m: 30, ..Default::default() },
        &mut c2,
    );

    // k²-means + GDI with kn=30.
    let mut c3 = OpCounter::default();
    let init_gdi = gdi(&train_x, k, &mut c3, 3, &GdiOpts::default());
    let k2 = k2means(&train_x, &init_gdi, &Config { k, kn: 30, ..Default::default() }, &mut c3);

    println!(
        "\n{:<12}{:>14}{:>14}{:>16}{:>12}",
        "method", "train energy", "vector ops", "quant. error", "iters"
    );
    for (name, run, counter) in
        [("Lloyd++", &lpp, &c1), ("AKM", &akm_run, &c2), ("k2-means", &k2, &c3)]
    {
        let qe = quantization_error(&queries_x, &run.centers);
        println!(
            "{:<12}{:>14.4e}{:>14.3e}{:>16.4e}{:>12}",
            name,
            run.energy,
            counter.total(),
            qe,
            run.iters
        );
    }

    let gap = k2.energy / lpp.energy - 1.0;
    let speedup = c1.total() / c3.total();
    println!(
        "\nk2-means lands {:+.2}% from Lloyd++ at {:.1}x fewer vector ops",
        gap * 100.0,
        speedup
    );
}
