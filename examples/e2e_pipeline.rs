//! End-to-end driver: the full three-layer system on a real small
//! workload, proving every layer composes (DESIGN.md §5, mandated e2e
//! validation; the run is recorded in EXPERIMENTS.md §E2E).
//!
//! Pipeline:
//! 1. build an mnist50-like workload (n≈6000, d=50 — a real clustering
//!    problem with ground-truth digit structure);
//! 2. GDI initialization (the paper's Alg. 2/3) in the L3 coordinator;
//! 3. k²-means through **both** execution backends — the native rust
//!    engine and the PJRT engine running the AOT JAX+Pallas artifacts —
//!    cross-checking energies;
//! 4. the op-counted k²-means (triangle-inequality variant) against the
//!    Lloyd++ reference, reporting the paper's headline metric:
//!    algorithmic speedup at the 1% energy band.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_pipeline
//! ```

use k2m::cluster::{k2means, lloyd, Config};
use k2m::core::OpCounter;
use k2m::data;
use k2m::init::{gdi, kmeans_pp, GdiOpts};
use k2m::runtime::{k2means_engine, RustEngine, XlaEngine};

fn main() -> anyhow::Result<()> {
    let t_total = std::time::Instant::now();
    println!("=== k2m end-to-end pipeline ===");

    // ---- 1. workload ----------------------------------------------------
    let ds = data::mnist50_like(0.1, 0xD5);
    let k = 200;
    let kn = 30;
    println!("[1] workload: {} n={} d={} k={k} kn={kn}", ds.name, ds.n(), ds.d());

    // ---- 2. GDI init (L3) -----------------------------------------------
    let mut counter = OpCounter::default();
    let t = std::time::Instant::now();
    let init = gdi(&ds.x, k, &mut counter, 7, &GdiOpts::default());
    println!(
        "[2] GDI: {} centers, {:.3e} vector ops, {:?}",
        init.k(),
        counter.total(),
        t.elapsed()
    );

    // ---- 3. engine cross-check (native vs PJRT/AOT) ----------------------
    let mut rust_engine = RustEngine::default();
    let t = std::time::Instant::now();
    let r_native = k2means_engine(
        &ds.x, &init.centers, init.labels.as_deref(), kn, 100, &mut rust_engine,
    )?;
    let t_native = t.elapsed();

    let artifact_dir = k2m::runtime::default_artifact_dir();
    let mut xla_engine = XlaEngine::new(&artifact_dir)?;
    let t = std::time::Instant::now();
    let r_xla = k2means_engine(
        &ds.x, &init.centers, init.labels.as_deref(), kn, 100, &mut xla_engine,
    )?;
    let t_xla = t.elapsed();

    let gap = (r_native.energy - r_xla.energy).abs() / r_native.energy;
    println!(
        "[3] engines: native {:.6e} ({} iters, {t_native:?})  |  \
         xla-pjrt {:.6e} ({} iters, {t_xla:?})  |  gap {gap:.2e}",
        r_native.energy, r_native.iters, r_xla.energy, r_xla.iters
    );
    anyhow::ensure!(gap < 1e-3, "engine mismatch");

    // ---- 4. headline metric: speedup at the 1% band ----------------------
    let mut ops_ref = OpCounter::default();
    let init_pp = kmeans_pp(&ds.x, k, &mut ops_ref, 7);
    let reference = lloyd(&ds.x, &init_pp, &Config { k, ..Default::default() }, &mut ops_ref);
    let target = reference.energy * 1.01;
    let ref_ops = reference
        .trace
        .ops_to_reach(target)
        .unwrap_or(ops_ref.total());

    let mut ops_k2 = OpCounter::default();
    let init2 = gdi(&ds.x, k, &mut ops_k2, 7, &GdiOpts::default());
    let cfg = Config { k, kn, target_energy: Some(target), ..Default::default() };
    let r_k2 = k2means(&ds.x, &init2, &cfg, &mut ops_k2);
    let k2_ops = r_k2
        .trace
        .ops_to_reach(target)
        .ok_or_else(|| anyhow::anyhow!("k2-means missed the 1% band"))?;

    let speedup = ref_ops / k2_ops;
    println!(
        "[4] headline: Lloyd++ {:.3e} ops to 1% band | k2-means {:.3e} ops | speedup {speedup:.1}x",
        ref_ops, k2_ops
    );
    println!(
        "    energies: Lloyd++ {:.6e} | k2-means {:.6e} ({:+.2}%)",
        reference.energy,
        r_k2.energy,
        (r_k2.energy / reference.energy - 1.0) * 100.0
    );
    anyhow::ensure!(speedup > 3.0, "expected a clear speedup, got {speedup:.2}");

    println!("=== all layers compose; total wall {:?} ===", t_total.elapsed());
    Ok(())
}
