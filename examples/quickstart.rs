//! Quickstart: cluster a 60k-point-class dataset with k²-means + GDI and
//! compare against Lloyd with k-means++ — the library's 30-second tour.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use k2m::cluster::{k2means, lloyd, Config};
use k2m::core::OpCounter;
use k2m::data;
use k2m::init::{gdi, kmeans_pp, GdiOpts};

fn main() {
    // A scaled mnist50-like workload (paper: n=60000, d=50).
    let ds = data::mnist50_like(0.05, 42);
    let k = 100;
    println!("dataset {} n={} d={} k={k}", ds.name, ds.n(), ds.d());

    // Reference: Lloyd from k-means++ (the paper's accuracy yardstick).
    let mut ops_ref = OpCounter::default();
    let init_pp = kmeans_pp(&ds.x, k, &mut ops_ref, 0);
    let cfg = Config { k, ..Default::default() };
    let reference = lloyd(&ds.x, &init_pp, &cfg, &mut ops_ref);
    println!(
        "Lloyd++  : energy {:.4e}  iters {:>3}  vector ops {:.3e}",
        reference.energy,
        reference.iters,
        ops_ref.total()
    );

    // k²-means from GDI with kn = 30 candidates per point.
    let mut ops_k2 = OpCounter::default();
    let init_gdi = gdi(&ds.x, k, &mut ops_k2, 0, &GdiOpts::default());
    let cfg = Config { k, kn: 30, ..Default::default() };
    let result = k2means(&ds.x, &init_gdi, &cfg, &mut ops_k2);
    println!(
        "k2-means : energy {:.4e}  iters {:>3}  vector ops {:.3e}",
        result.energy,
        result.iters,
        ops_k2.total()
    );

    let rel = result.energy / reference.energy - 1.0;
    let speedup = ops_ref.total() / ops_k2.total();
    println!("energy gap vs Lloyd++: {:+.3}%   op speedup: {speedup:.1}x", rel * 100.0);
    assert!(rel < 0.05, "k2-means should land within 5% of Lloyd++");
}
