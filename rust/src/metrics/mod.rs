//! Measurement: clustering energy, per-iteration convergence traces, and
//! run summaries. Everything here is *uncounted* (paper methodology:
//! evaluation work is not part of a method's op budget).

use crate::core::{ops, Matrix};

/// Total clustering energy `Σ_i ||x_i − c_{a(i)}||²` (paper eq. 1).
pub fn energy(x: &Matrix, centers: &Matrix, labels: &[u32]) -> f64 {
    assert_eq!(x.rows(), labels.len());
    let mut e = 0.0f64;
    for (i, &l) in labels.iter().enumerate() {
        e += ops::sqdist_raw(x.row(i), centers.row(l as usize)) as f64;
    }
    e
}

/// Energy of a subset of points around its own mean — `φ(X_j)` in the
/// paper's notation. Used by GDI's split priority.
pub fn phi(x: &Matrix, members: &[u32]) -> f64 {
    if members.is_empty() {
        return 0.0;
    }
    let d = x.cols();
    let mut mean = vec![0.0f64; d];
    for &i in members {
        for (m, &v) in mean.iter_mut().zip(x.row(i as usize)) {
            *m += v as f64;
        }
    }
    let inv = 1.0 / members.len() as f64;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    let mut e = 0.0f64;
    for &i in members {
        for (&m, &v) in mean.iter().zip(x.row(i as usize)) {
            let dlt = v as f64 - m;
            e += dlt * dlt;
        }
    }
    e
}

/// One point on a convergence curve: cumulative counted vector ops vs the
/// energy at that moment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    pub ops: f64,
    pub energy: f64,
    pub iter: usize,
}

/// A convergence trace — the raw material of the paper's Figures 2–4.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub points: Vec<TracePoint>,
}

impl Trace {
    pub fn push(&mut self, ops: f64, energy: f64, iter: usize) {
        self.points.push(TracePoint { ops, energy, iter });
    }

    /// Earliest cumulative op count at which the trace's energy reaches
    /// `target` (energies are monotone for exact methods but *not* for
    /// MiniBatch — we therefore take the first crossing). `None` if never.
    pub fn ops_to_reach(&self, target: f64) -> Option<f64> {
        self.points.iter().find(|p| p.energy <= target).map(|p| p.ops)
    }

    /// Final (converged) energy; +inf for an empty trace.
    pub fn final_energy(&self) -> f64 {
        self.points.last().map_or(f64::INFINITY, |p| p.energy)
    }

    /// Minimum energy seen anywhere on the trace.
    pub fn min_energy(&self) -> f64 {
        self.points.iter().fold(f64::INFINITY, |m, p| m.min(p.energy))
    }
}

/// Summary of one clustering run.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub method: String,
    pub dataset: String,
    pub k: usize,
    pub seed: u64,
    /// Method parameter (m for AKM, kn for k²-means), 0 if n/a.
    pub param: usize,
    pub energy: f64,
    pub iters: usize,
    pub total_ops: f64,
    pub init_ops: f64,
    pub trace: Trace,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Matrix, Matrix, Vec<u32>) {
        // 4 points, 2 centers.
        let x = Matrix::from_vec(vec![0., 0., 1., 0., 10., 0., 11., 0.], 4, 2);
        let c = Matrix::from_vec(vec![0.5, 0., 10.5, 0.], 2, 2);
        let labels = vec![0, 0, 1, 1];
        (x, c, labels)
    }

    #[test]
    fn energy_hand_computed() {
        let (x, c, l) = tiny();
        // each point is 0.5 away from its center -> 4 * 0.25 = 1.0
        assert!((energy(&x, &c, &l) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn phi_equals_energy_at_own_mean() {
        let (x, _, _) = tiny();
        let members = vec![0u32, 1];
        // mean (0.5, 0); each point 0.5 away -> 0.5
        assert!((phi(&x, &members) - 0.5).abs() < 1e-9);
        assert_eq!(phi(&x, &[]), 0.0);
        assert_eq!(phi(&x, &[2]), 0.0); // singleton has zero energy
    }

    #[test]
    fn phi_total_decomposition() {
        // phi over all points >= sum of per-cluster phis (clustering helps).
        let (x, _, _) = tiny();
        let all: Vec<u32> = (0..4).collect();
        let split = phi(&x, &[0, 1]) + phi(&x, &[2, 3]);
        assert!(phi(&x, &all) > split);
    }

    #[test]
    fn trace_ops_to_reach() {
        let mut t = Trace::default();
        t.push(10.0, 100.0, 0);
        t.push(20.0, 50.0, 1);
        t.push(30.0, 49.0, 2);
        assert_eq!(t.ops_to_reach(60.0), Some(20.0));
        assert_eq!(t.ops_to_reach(49.0), Some(30.0));
        assert_eq!(t.ops_to_reach(10.0), None);
        assert_eq!(t.final_energy(), 49.0);
        assert_eq!(t.min_energy(), 49.0);
    }

    #[test]
    fn trace_first_crossing_for_nonmonotone() {
        let mut t = Trace::default();
        t.push(1.0, 5.0, 0);
        t.push(2.0, 3.0, 1);
        t.push(3.0, 4.0, 2); // minibatch-style bounce
        t.push(4.0, 2.0, 3);
        assert_eq!(t.ops_to_reach(3.5), Some(2.0));
    }
}
