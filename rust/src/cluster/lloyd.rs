//! Standard k-means (Lloyd's algorithm): full `n*k` counted distance
//! computations per assignment step — the paper's reference baseline and
//! the cost model everything else is measured against.

use super::common::{update_means, Config, KmeansResult};
use crate::core::{ops, Matrix, OpCounter};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// Run Lloyd's algorithm from the given initialization.
pub fn lloyd(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let mut centers = init.centers.clone();
    let mut labels: Vec<u32> = vec![u32::MAX; n];
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    for it in 0..cfg.max_iters {
        iters = it + 1;
        // Assignment step: n*k counted distances.
        let mut changed = 0usize;
        for i in 0..n {
            let xi = x.row(i);
            let mut best = (0u32, f32::INFINITY);
            for j in 0..k {
                let dist = ops::sqdist(xi, centers.row(j), counter);
                if dist < best.1 {
                    best = (j as u32, dist);
                }
            }
            if labels[i] != best.0 {
                labels[i] = best.0;
                changed += 1;
            }
        }

        // Measurement (uncounted): energy w.r.t. current centers.
        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Update step.
        let (new_centers, _) = update_means(x, &labels, &centers, counter);
        centers = new_centers;
    }

    let final_e = energy(x, &centers, &labels);
    KmeansResult { centers, labels, energy: final_e, iters, converged, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::{kmeans_pp, random_init};
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn converges_on_separated_blobs_to_near_zero_mismatch() {
        let (x, true_labels) = blobs(300, 4, 6, 50.0, 1);
        let mut c = OpCounter::default();
        let init = kmeans_pp(&x, 4, &mut c, 2);
        let cfg = Config { k: 4, ..Default::default() };
        let r = lloyd(&x, &init, &cfg, &mut c);
        assert!(r.converged);
        // Cluster purity: every found cluster maps to one true blob.
        for j in 0..4u32 {
            let blob_ids: std::collections::HashSet<u32> = (0..300)
                .filter(|&i| r.labels[i] == j)
                .map(|i| true_labels[i])
                .collect();
            assert_eq!(blob_ids.len(), 1);
        }
    }

    #[test]
    fn energy_monotone_along_trace() {
        let x = random_matrix(200, 8, 3);
        let mut c = OpCounter::default();
        let init = random_init(&x, 10, 4);
        let cfg = Config { k: 10, ..Default::default() };
        let r = lloyd(&x, &init, &cfg, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(
                w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()),
                "energy increased: {} -> {}",
                w[0].energy,
                w[1].energy
            );
        }
    }

    #[test]
    fn counts_nk_distances_per_iteration() {
        let x = random_matrix(50, 4, 5);
        let mut c = OpCounter::default();
        let init = random_init(&x, 5, 6);
        let cfg = Config { k: 5, max_iters: 1, ..Default::default() };
        let _ = lloyd(&x, &init, &cfg, &mut c);
        assert_eq!(c.distances, 50 * 5);
    }

    #[test]
    fn target_energy_stops_early() {
        let x = random_matrix(300, 6, 7);
        let mut c = OpCounter::default();
        let init = random_init(&x, 8, 8);
        let full = lloyd(&x, &init, &Config { k: 8, ..Default::default() }, &mut c);
        // Re-run with a loose target: must stop in fewer iterations.
        let mut c2 = OpCounter::default();
        let loose = full.trace.points[0].energy * 0.999;
        let cfg = Config { k: 8, target_energy: Some(loose), ..Default::default() };
        let r = lloyd(&x, &init, &cfg, &mut c2);
        assert!(r.iters <= full.iters);
    }

    #[test]
    fn one_cluster_converges_to_mean_immediately() {
        let x = random_matrix(40, 3, 9);
        let mut c = OpCounter::default();
        let init = random_init(&x, 1, 10);
        let r = lloyd(&x, &init, &Config { k: 1, max_iters: 10, ..Default::default() }, &mut c);
        assert!(r.converged);
        assert!(r.iters <= 2);
    }
}
