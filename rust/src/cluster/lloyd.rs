//! Standard k-means (Lloyd's algorithm): full `n*k` counted distance
//! computations per assignment step — the paper's reference baseline and
//! the cost model everything else is measured against.
//!
//! The assignment step runs on the sharded execution engine
//! (`cfg.threads` contiguous point shards; each point's argmin reads
//! only shared immutable centers, so labels are bit-identical for any
//! thread count), and the update step uses the cluster-sharded
//! [`update_means_threaded`]. Each point's argmin is one blocked
//! [`crate::core::kernels::nearest_sq_rows`] scan on the configured
//! numerics tier ([`Config::numerics`]) — the query row loads once and
//! centers stream through register tiles; the Strict tier is
//! bit-identical to the scalar loop it replaced, the Fast tier is the
//! lane-striped variant (deterministic, same op count), and the
//! Quantized tier prunes the scan with 1-bit codes before a strict
//! re-rank (identical labels, exact-distance bill ≤ Strict's).

use super::common::{finish_run, moved_rows, update_means_threaded, Config, KmeansResult, QuantState};
use crate::coordinator::pool;
use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// One assignment pass over the shard `labels[.. ]` starting at global
/// point index `start`: blocked full argmin over all centers on the
/// configured numerics tier, counting `k` distances per point into the
/// shard-local counter. Returns the number of changed labels.
fn assign_shard(
    x: &Matrix,
    centers: &Matrix,
    start: usize,
    labels: &mut [u32],
    nm: NumericsMode,
    qs: Option<&QuantState>,
    ctr: &mut OpCounter,
) -> usize {
    let mut changed = 0usize;
    for (off, lab) in labels.iter_mut().enumerate() {
        let xi = x.row(start + off);
        let qp = qs.map(|q| q.pair(start + off));
        let (best, _) = nm.nearest_sq_rows_q(xi, centers, qp.as_ref(), ctr);
        if *lab != best {
            *lab = best;
            changed += 1;
        }
    }
    changed
}

/// Run Lloyd's algorithm from the given initialization.
pub fn lloyd(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let threads = pool::resolve_threads(cfg.threads, n);
    let nm = cfg.numerics;
    let mut centers = init.centers.clone();
    // Quantized tier only: packed codes for prune-before-rerank scans.
    let mut qs = QuantState::new(x, &centers, cfg, counter);
    let mut labels: Vec<u32> = vec![u32::MAX; n];
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    for it in 0..cfg.max_iters {
        iters = it + 1;
        // Assignment step: n*k counted distances, sharded over points on
        // the execution engine (single shard runs inline when serial).
        let changed: usize = {
            let chunk = pool::chunk_len(n, threads);
            let centers_ref = &centers;
            let qs_ref = qs.as_ref();
            pool::sharded_reduce(labels.chunks_mut(chunk), counter, |si, lab_c, ctr| {
                assign_shard(x, centers_ref, si * chunk, lab_c, nm, qs_ref, ctr)
            })
            .into_iter()
            .sum()
        };

        // Measurement (uncounted): energy w.r.t. current centers.
        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Update step (cluster-sharded; bit-identical for any threads).
        let (new_centers, _) =
            update_means_threaded(x, &labels, &centers, counter, cfg.threads);
        // Bitwise moved set for the incremental code repack — only
        // derived when the Quantized tier's codes exist to refresh.
        let moved = qs.as_ref().map(|_| moved_rows(&centers, &new_centers));
        centers = new_centers;
        if let Some(q) = qs.as_mut() {
            q.refresh(&centers, moved.as_deref(), counter);
        }
    }

    let final_e = energy(x, &centers, &labels);
    finish_run(centers, labels, final_e, iters, converged, trace, None, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::{kmeans_pp, random_init};
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn converges_on_separated_blobs_to_near_zero_mismatch() {
        let (x, true_labels) = blobs(300, 4, 6, 50.0, 1);
        let mut c = OpCounter::default();
        let init = kmeans_pp(&x, 4, &mut c, 2);
        let cfg = Config { k: 4, ..Default::default() };
        let r = lloyd(&x, &init, &cfg, &mut c);
        assert!(r.converged);
        // Cluster purity: every found cluster maps to one true blob.
        for j in 0..4u32 {
            let blob_ids: std::collections::HashSet<u32> = (0..300)
                .filter(|&i| r.labels[i] == j)
                .map(|i| true_labels[i])
                .collect();
            assert_eq!(blob_ids.len(), 1);
        }
    }

    #[test]
    fn energy_monotone_along_trace() {
        let x = random_matrix(200, 8, 3);
        let mut c = OpCounter::default();
        let init = random_init(&x, 10, 4);
        let cfg = Config { k: 10, ..Default::default() };
        let r = lloyd(&x, &init, &cfg, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(
                w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()),
                "energy increased: {} -> {}",
                w[0].energy,
                w[1].energy
            );
        }
    }

    #[test]
    fn counts_nk_distances_per_iteration() {
        let x = random_matrix(50, 4, 5);
        let mut c = OpCounter::default();
        let init = random_init(&x, 5, 6);
        let cfg = Config { k: 5, max_iters: 1, ..Default::default() };
        let _ = lloyd(&x, &init, &cfg, &mut c);
        assert_eq!(c.distances, 50 * 5);
    }

    #[test]
    fn target_energy_stops_early() {
        let x = random_matrix(300, 6, 7);
        let mut c = OpCounter::default();
        let init = random_init(&x, 8, 8);
        let full = lloyd(&x, &init, &Config { k: 8, ..Default::default() }, &mut c);
        // Re-run with a loose target: must stop in fewer iterations.
        let mut c2 = OpCounter::default();
        let loose = full.trace.points[0].energy * 0.999;
        let cfg = Config { k: 8, target_energy: Some(loose), ..Default::default() };
        let r = lloyd(&x, &init, &cfg, &mut c2);
        assert!(r.iters <= full.iters);
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        let (x, _) = blobs(600, 12, 10, 12.0, 21);
        let init = random_init(&x, 12, 22);
        let mut c1 = OpCounter::default();
        let want =
            lloyd(&x, &init, &Config { k: 12, threads: 1, ..Default::default() }, &mut c1);
        for threads in [2usize, 7, 32] {
            let mut c2 = OpCounter::default();
            let got =
                lloyd(&x, &init, &Config { k: 12, threads, ..Default::default() }, &mut c2);
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.iters, want.iters, "threads={threads}");
            assert_eq!(c1.distances, c2.distances, "threads={threads}");
        }
    }

    #[test]
    fn one_cluster_converges_to_mean_immediately() {
        let x = random_matrix(40, 3, 9);
        let mut c = OpCounter::default();
        let init = random_init(&x, 1, 10);
        let r = lloyd(&x, &init, &Config { k: 1, max_iters: 10, ..Default::default() }, &mut c);
        assert!(r.converged);
        assert!(r.iters <= 2);
    }
}
