//! Hamerly's accelerated k-means (SDM'10) — cited by the paper as the
//! lighter cousin of Elkan: ONE lower bound per point (distance to the
//! second-closest center) instead of k, trading pruning power for O(n)
//! bound memory. Exact: produces Lloyd's trajectory. Per-iteration cost
//! is `O(n·k·d)` worst case, decaying toward `O(n·d)` once centers
//! settle and the `max(s, l)` prune holds.
//!
//! Included as an extension baseline (the paper compares against Elkan;
//! Hamerly completes the bounds-family picture in the ablation bench).
//!
//! Runs on the sharded execution engine ([`pool::sharded_reduce`]): the
//! bootstrap, bounded assignment and drift-shift passes shard over
//! contiguous point ranges (`cfg.threads`; each point touches only its
//! own `labels`/`u`/`l` slots plus shared immutable state, so labels are
//! **bit-identical for any thread count**); the update step is the
//! cluster-sharded [`update_means_threaded`].

use super::common::{
    finish_run, moved_rows, sharded_bound_pass, update_means_threaded, with_tile_scratch,
    BoundShard, Config, KmeansResult, QuantState,
};
use crate::coordinator::pool;
use crate::core::kernels::quant;
use crate::core::{Matrix, OpCounter, RefreshMode, ScanMode};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// Run Hamerly's algorithm (exact accelerated Lloyd).
pub fn hamerly(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let threads = pool::resolve_threads(cfg.threads, n);
    let nm = cfg.numerics;
    let mut centers = init.centers.clone();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // Bootstrap: full assignment establishing u (closest) and l (second
    // closest) — both plain distances — sharded over points.
    let mut labels = vec![0u32; n];
    let mut u = vec![0.0f32; n];
    let mut l = vec![0.0f32; n];
    {
        let centers_ref = &centers;
        sharded_bound_pass(
            threads,
            1,
            &mut labels,
            &mut u,
            &mut l,
            counter,
            |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                // One blocked scan per point into a shard-local buffer,
                // then the same two-best fold over identical values.
                let mut dbuf = vec![0.0f32; k];
                for off in 0..st.labels.len() {
                    let xi = x.row(start + off);
                    nm.dist_rows(xi, centers_ref, 0, &mut dbuf, ctr);
                    let (mut b1, mut b2) = ((0u32, f32::INFINITY), f32::INFINITY);
                    for (j, &dist) in dbuf.iter().enumerate() {
                        if dist < b1.1 {
                            b2 = b1.1;
                            b1 = (j as u32, dist);
                        } else if dist < b2 {
                            b2 = dist;
                        }
                    }
                    st.labels[off] = b1.0;
                    st.u[off] = b1.1;
                    st.lb[off] = b2;
                }
                0
            },
        );
    }

    let mut s = vec![0.0f32; k];
    // Persistent **squared** center-center table behind s(c), so the
    // moved-set refresh can reuse unmoved-pair rows bitwise; `moved` is
    // the bitwise moved set of the previous update step (None on the
    // first iteration — always a full build).
    let mut cc = vec![0.0f32; k * k];
    let mut cc_row = vec![0.0f32; k];
    let mut moved: Option<Vec<bool>> = None;

    // Center codes for the batched rescan's estimator prune
    // (`QuantState::new` is `None` off the Quantized tier). Hamerly's
    // rescan is already one blocked scan over all k rows, so on the
    // Strict and Fast tiers Batched and Gated share every instruction —
    // the codes are the only thing `ScanMode::Batched` adds here.
    let mut qs = if cfg.scan == ScanMode::Batched {
        QuantState::new(x, &centers, cfg, counter)
    } else {
        None
    };
    for it in 0..cfg.max_iters {
        iters = it + 1;
        // s(c) = half distance to the nearest other center (O(k²),
        // serial — negligible next to the point passes). Full build:
        // each row is one blocked scan; the self distance comes out of
        // the same pass for free and is skipped by the fold, and the
        // bill stays the scalar loop's k-1 per row (Hamerly recomputes
        // both orientations of every pair — preserved for op-count
        // parity). Incremental (`cfg.refresh`, default): only *moved*
        // rows rescan (k-1 billed each, same per-row convention);
        // unmoved rows keep their cached entries and receive moved
        // columns by mirroring (bitwise-symmetric kernels, so the
        // table matches a full rebuild bit for bit), logging the
        // (k-|M|)·(k-1) avoided row scans to `refresh_saved`.
        match (cfg.refresh, moved.as_deref()) {
            (RefreshMode::Incremental, Some(mv)) => {
                let m_count = mv.iter().filter(|&&b| b).count();
                counter.refresh_saved += ((k - m_count) * (k - 1)) as u64;
                for j in 0..k {
                    if !mv[j] {
                        continue;
                    }
                    nm.sqdist_rows_raw(centers.row(j), &centers, 0, &mut cc_row);
                    counter.distances += (k - 1) as u64;
                    cc[j * k..(j + 1) * k].copy_from_slice(&cc_row);
                    for (i, &sq) in cc_row.iter().enumerate() {
                        if i != j {
                            cc[i * k + j] = sq;
                        }
                    }
                }
            }
            _ => {
                for j in 0..k {
                    nm.sqdist_rows_raw(centers.row(j), &centers, 0, &mut cc_row);
                    counter.distances += (k - 1) as u64;
                    cc[j * k..(j + 1) * k].copy_from_slice(&cc_row);
                }
            }
        }
        for j in 0..k {
            let mut m = f32::INFINITY;
            for (j2, &sq) in cc[j * k..(j + 1) * k].iter().enumerate() {
                if j2 != j {
                    m = m.min(sq.sqrt());
                }
            }
            s[j] = 0.5 * m;
        }

        // Bounded assignment, sharded over points: every read is shared
        // immutable (centers, s) or the point's own slots, so labels are
        // bit-identical for any thread count.
        let changed = {
            let centers_ref = &centers;
            let s_ref = &s;
            let qs_ref = qs.as_ref();
            sharded_bound_pass(
                threads,
                1,
                &mut labels,
                &mut u,
                &mut l,
                counter,
                |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                    with_tile_scratch(|scratch| {
                        let mut changed = 0usize;
                        let mut dbuf = vec![0.0f32; k];
                        for off in 0..st.labels.len() {
                            let a = st.labels[off] as usize;
                            let bound = s_ref[a].max(st.lb[off]);
                            if st.u[off] <= bound {
                                continue;
                            }
                            let xi = x.row(start + off);
                            // Tighten u; re-test.
                            st.u[off] = nm.dist_one(xi, centers_ref.row(a), ctr);
                            if st.u[off] <= bound {
                                continue;
                            }
                            // Full rescan (Hamerly's fallback): one blocked
                            // scan. On the Strict and Fast tiers it covers
                            // all k rows — the slot for the current center
                            // recomputes the distance just tightened above,
                            // bit-identical bits for free, so the bill
                            // stays the scalar path's k-1 fresh distances.
                            // Under `ScanMode::Batched` on the Quantized
                            // tier the top-2-safe estimator prune first
                            // drops centers certified outside the running
                            // two best: survivors still contain every
                            // center whose exact distance can reach b1 or
                            // b2 (and every min attainer), so the fold
                            // lands bitwise where the full scan does, with
                            // the current center's slot still free if it
                            // survived.
                            let (mut b1, mut b2) = ((0u32, f32::INFINITY), f32::INFINITY);
                            if let Some(q) = qs_ref {
                                let qp = q.pair(start + off);
                                scratch.ids.clear();
                                scratch.ids.extend(0..k as u32);
                                quant::prune_survivors_top2(
                                    qp.query,
                                    qp.cands,
                                    &mut scratch.ids,
                                    None,
                                    ctr,
                                );
                                let m = scratch.ids.len();
                                scratch.dists.resize(m, 0.0);
                                nm.sqdist_block_raw(
                                    xi,
                                    centers_ref,
                                    &scratch.ids,
                                    &mut scratch.dists,
                                );
                                let survived_a =
                                    scratch.ids.iter().any(|&j| j as usize == a);
                                ctr.distances += (m - usize::from(survived_a)) as u64;
                                for (r, &j) in scratch.ids.iter().enumerate() {
                                    let dist = scratch.dists[r].sqrt();
                                    if dist < b1.1 {
                                        b2 = b1.1;
                                        b1 = (j, dist);
                                    } else if dist < b2 {
                                        b2 = dist;
                                    }
                                }
                            } else {
                                nm.sqdist_rows_raw(xi, centers_ref, 0, &mut dbuf);
                                for v in dbuf.iter_mut() {
                                    *v = v.sqrt();
                                }
                                ctr.distances += (k - 1) as u64;
                                for (j, &dist) in dbuf.iter().enumerate() {
                                    if dist < b1.1 {
                                        b2 = b1.1;
                                        b1 = (j as u32, dist);
                                    } else if dist < b2 {
                                        b2 = dist;
                                    }
                                }
                            }
                            st.u[off] = b1.1;
                            st.lb[off] = b2;
                            if b1.0 != st.labels[off] {
                                st.labels[off] = b1.0;
                                changed += 1;
                            }
                        }
                        changed
                    })
                },
            )
        };

        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Update step (cluster-sharded, bit-identical for any thread
        // count), then shift the bounds by the center drift.
        let (new_centers, _) =
            update_means_threaded(x, &labels, &centers, counter, cfg.threads);
        let mut drift = vec![0.0f32; k];
        nm.dist_rowwise(&centers, &new_centers, &mut drift, counter);
        let max_drift = drift.iter().fold(0.0f32, |m, &dj| m.max(dj));
        {
            let drift_ref = &drift;
            sharded_bound_pass(
                threads,
                1,
                &mut labels,
                &mut u,
                &mut l,
                counter,
                |_start, st: BoundShard<'_>, _ctr: &mut OpCounter| {
                    for off in 0..st.labels.len() {
                        st.u[off] += drift_ref[st.labels[off] as usize];
                        st.lb[off] = (st.lb[off] - max_drift).max(0.0);
                    }
                    0
                },
            );
        }
        // Bitwise moved set for the next iteration's s-table refresh
        // (exact row compare — an f32 drift can underflow to 0.0 for a
        // center that moved, so only the bitwise test is sound).
        moved = Some(moved_rows(&centers, &new_centers));
        centers = new_centers;
        if let Some(q) = qs.as_mut() {
            q.refresh(&centers, moved.as_deref(), counter);
        }
    }

    let final_e = energy(x, &centers, &labels);
    finish_run(centers, labels, final_e, iters, converged, trace, None, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::random_init;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn matches_lloyd_exactly() {
        let x = random_matrix(220, 10, 1);
        let init = random_init(&x, 12, 2);
        let cfg = Config { k: 12, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let rh = hamerly(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, rh.labels);
    }

    #[test]
    fn fewer_distances_than_lloyd_on_clustered_data() {
        let (x, _) = blobs(500, 8, 16, 15.0, 3);
        let init = random_init(&x, 8, 4);
        let cfg = Config { k: 8, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let _ = lloyd(&x, &init, &cfg, &mut c1);
        let _ = hamerly(&x, &init, &cfg, &mut c2);
        assert!(c2.distances < c1.distances, "{} vs {}", c2.distances, c1.distances);
    }

    #[test]
    fn energy_monotone() {
        let x = random_matrix(150, 6, 5);
        let init = random_init(&x, 9, 6);
        let mut c = OpCounter::default();
        let r = hamerly(&x, &init, &Config { k: 9, ..Default::default() }, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()));
        }
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        let (x, _) = blobs(600, 12, 10, 10.0, 11);
        let init = random_init(&x, 14, 12);
        let mut c1 = OpCounter::default();
        let want =
            hamerly(&x, &init, &Config { k: 14, threads: 1, ..Default::default() }, &mut c1);
        for threads in [2usize, 5, 19] {
            let mut c2 = OpCounter::default();
            let got =
                hamerly(&x, &init, &Config { k: 14, threads, ..Default::default() }, &mut c2);
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.iters, want.iters, "threads={threads}");
            assert_eq!(c1.distances, c2.distances, "threads={threads}");
            assert_eq!(c1.additions, c2.additions, "threads={threads}");
        }
    }
}
