//! Hamerly's accelerated k-means (SDM'10) — cited by the paper as the
//! lighter cousin of Elkan: ONE lower bound per point (distance to the
//! second-closest center) instead of k, trading pruning power for O(n)
//! bound memory. Exact: produces Lloyd's trajectory.
//!
//! Included as an extension baseline (the paper compares against Elkan;
//! Hamerly completes the bounds-family picture in the ablation bench).

use super::common::{update_means, Config, KmeansResult};
use crate::core::{ops, Matrix, OpCounter};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// Run Hamerly's algorithm (exact accelerated Lloyd).
pub fn hamerly(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let mut centers = init.centers.clone();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // Bootstrap: full assignment establishing u (closest) and l (second
    // closest) — both plain distances.
    let mut labels = vec![0u32; n];
    let mut u = vec![0.0f32; n];
    let mut l = vec![0.0f32; n];
    for i in 0..n {
        let xi = x.row(i);
        let (mut b1, mut b2) = ((0u32, f32::INFINITY), f32::INFINITY);
        for j in 0..k {
            let dist = ops::dist(xi, centers.row(j), counter);
            if dist < b1.1 {
                b2 = b1.1;
                b1 = (j as u32, dist);
            } else if dist < b2 {
                b2 = dist;
            }
        }
        labels[i] = b1.0;
        u[i] = b1.1;
        l[i] = b2;
    }

    let mut s = vec![0.0f32; k];
    for it in 0..cfg.max_iters {
        iters = it + 1;
        // s(c) = half distance to the nearest other center.
        for j in 0..k {
            let mut m = f32::INFINITY;
            for j2 in 0..k {
                if j2 != j {
                    m = m.min(ops::dist(centers.row(j), centers.row(j2), counter));
                }
            }
            s[j] = 0.5 * m;
        }

        let mut changed = 0usize;
        for i in 0..n {
            let a = labels[i] as usize;
            let bound = s[a].max(l[i]);
            if u[i] <= bound {
                continue;
            }
            let xi = x.row(i);
            // Tighten u; re-test.
            u[i] = ops::dist(xi, centers.row(a), counter);
            if u[i] <= bound {
                continue;
            }
            // Full rescan (Hamerly's fallback).
            let (mut b1, mut b2) = ((0u32, f32::INFINITY), f32::INFINITY);
            for j in 0..k {
                let dist = if j == a {
                    u[i]
                } else {
                    ops::dist(xi, centers.row(j), counter)
                };
                if dist < b1.1 {
                    b2 = b1.1;
                    b1 = (j as u32, dist);
                } else if dist < b2 {
                    b2 = dist;
                }
            }
            u[i] = b1.1;
            l[i] = b2;
            if b1.0 != labels[i] {
                labels[i] = b1.0;
                changed += 1;
            }
        }

        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        let (new_centers, _) = update_means(x, &labels, &centers, counter);
        let mut drift = vec![0.0f32; k];
        let mut max_drift = 0.0f32;
        for j in 0..k {
            drift[j] = ops::dist(centers.row(j), new_centers.row(j), counter);
            max_drift = max_drift.max(drift[j]);
        }
        for i in 0..n {
            u[i] += drift[labels[i] as usize];
            l[i] = (l[i] - max_drift).max(0.0);
        }
        centers = new_centers;
    }

    let final_e = energy(x, &centers, &labels);
    KmeansResult { centers, labels, energy: final_e, iters, converged, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::random_init;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn matches_lloyd_exactly() {
        let x = random_matrix(220, 10, 1);
        let init = random_init(&x, 12, 2);
        let cfg = Config { k: 12, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let rh = hamerly(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, rh.labels);
    }

    #[test]
    fn fewer_distances_than_lloyd_on_clustered_data() {
        let (x, _) = blobs(500, 8, 16, 15.0, 3);
        let init = random_init(&x, 8, 4);
        let cfg = Config { k: 8, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let _ = lloyd(&x, &init, &cfg, &mut c1);
        let _ = hamerly(&x, &init, &cfg, &mut c2);
        assert!(c2.distances < c1.distances, "{} vs {}", c2.distances, c1.distances);
    }

    #[test]
    fn energy_monotone() {
        let x = random_matrix(150, 6, 5);
        let init = random_init(&x, 9, 6);
        let mut c = OpCounter::default();
        let r = hamerly(&x, &init, &Config { k: 9, ..Default::default() }, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()));
        }
    }
}
