//! The clustering algorithms of the paper's evaluation:
//!
//! | method | module | paper role | per-iteration cost |
//! |---|---|---|---|
//! | Lloyd           | [`fn@lloyd`]     | the baseline (standard k-means) | `O(n·k·d)` |
//! | Elkan           | [`fn@elkan`]     | exact acceleration via triangle-inequality bounds | `O(n·k·d)` worst case, decaying; `O(n·k)` bound memory |
//! | MiniBatch       | [`fn@minibatch`] | Sculley's web-scale online k-means | `O(b·k·d)` per step, `b = 100` |
//! | AKM             | [`fn@akm`]       | Philbin's approximate k-means (kd-tree, m checks) | `O(n·m·(d + log k))` |
//! | **k²-means**    | [`fn@k2means`]   | **the paper's contribution** (Alg. 1) | `O(n·kn·d + k²·d)`, decaying toward `O(n·d)` |
//!
//! Extension baselines beyond the paper's roster (for the ablation
//! bench; both are cited in the paper's related work):
//!
//! | Hamerly         | [`fn@hamerly`]   | single-lower-bound exact accelerator | `O(n·k·d)` worst case; `O(n)` bound memory |
//! | Yinyang         | [`fn@yinyang`]   | group-filtering exact accelerator | `O(n·k·d)` worst case; `O(n·k/10)` bound memory |
//!
//! Above the roster sits [`fn@bigmeans`], the big-means **global
//! search**: fixed-size sample subproblems solved by any roster
//! algorithm (k²-means by default), warm-started from a shared
//! incumbent, over an in-RAM or out-of-core
//! [`crate::data::DatasetSource`] — the driver for data too large to
//! iterate in full.
//!
//! # Bound invariants
//!
//! Every accelerated method maintains sound triangle-inequality bounds
//! between update steps — the invariants each module's passes preserve:
//!
//! * **Elkan**: `u[i] >= d(x_i, c_a(i))` and `lb[i][j] <= d(x_i, c_j)`
//!   for *all* k centers; after an update step `u` grows by the assigned
//!   center's drift, every `lb` shrinks by its center's drift.
//! * **Hamerly**: same `u`, but a *single* `l[i] <=` distance to the
//!   second-closest center; `l` shrinks by the *maximum* drift.
//! * **Yinyang**: `u` plus one lower bound per center *group* (`k/10`
//!   groups); each group bound shrinks by that group's max drift.
//! * **k²-means**: `u` plus `kn` bounds covering only the assigned
//!   center's neighbourhood `N_kn(c_a)` — sound *within* the
//!   neighbourhood, which is exactly the paper's restricted fixed point
//!   (`kn = k` recovers Elkan's exactness; see [`fn@k2means`]).
//!
//! All algorithms share [`Config`]/[`KmeansResult`], count every vector
//! operation through [`crate::core::OpCounter`], and record per-iteration
//! `(ops, energy)` convergence traces (the raw material of the paper's
//! tables and figures). Energy evaluation for traces is *uncounted*
//! measurement, computed with raw ops.
//!
//! # Sharded execution
//!
//! The per-point hot paths of every algorithm in this module —
//! [`fn@lloyd`], [`fn@elkan`], [`fn@hamerly`], [`fn@yinyang`],
//! [`fn@k2means`], [`fn@minibatch`]'s batch assignment and [`fn@akm`]'s
//! kd-tree queries — and the cluster-sharded update step
//! [`update_means_threaded`] run on the persistent-pool execution
//! engine ([`crate::coordinator::pool::sharded_reduce`]) under
//! [`Config::threads`], with **bit-identical** output at any thread
//! count (`rust/tests/sharding.rs`). See `EXPERIMENTS.md` §Perf for the
//! measured 1→N scaling and the pool-vs-scoped-spawn protocol.
//!
//! # The train/serve artifact
//!
//! Every trainer finishes through a single tail
//! (`common::finish_run`), which packages the final centers into a
//! [`ClusterModel`] — centers + exact kn-NN center graph + per-center
//! squared norms + the [`Config`] provenance — carried on
//! [`KmeansResult::model`]. k²-means donates the graph it already
//! built when it matches the returned centers; every other algorithm
//! builds it once post-hoc (uncounted — packaging, not part of the op
//! bill). The model is what [`crate::runtime::serve`] serves and what
//! `data::io::save_model` / `load_model` round-trip to disk ([`model`]
//! has the full contract).

mod akm;
mod bigmeans;
mod common;
mod elkan;
mod hamerly;
mod k2means;
mod lloyd;
mod minibatch;
pub mod model;
mod yinyang;

pub use akm::akm;
pub use bigmeans::{
    bigmeans, job_seed, sample_indices, BigMeansOpts, BigMeansOutcome, SampleOutcome,
};
pub use common::{update_means, update_means_threaded, Config, KmeansResult};
pub use model::ClusterModel;
pub use elkan::elkan;
pub use hamerly::hamerly;
pub use k2means::k2means;
pub use lloyd::lloyd;
pub use minibatch::{minibatch, MiniBatchOpts};
pub use yinyang::yinyang;
