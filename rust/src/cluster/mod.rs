//! The clustering algorithms of the paper's evaluation:
//!
//! | method | module | paper role |
//! |---|---|---|
//! | Lloyd           | [`lloyd`]     | the baseline (standard k-means) |
//! | Elkan           | [`elkan`]     | exact acceleration via triangle-inequality bounds |
//! | MiniBatch       | [`minibatch`] | Sculley's web-scale online k-means |
//! | AKM             | [`akm`]       | Philbin's approximate k-means (kd-tree, m checks) |
//! | **k²-means**    | [`k2means`]   | **the paper's contribution** (Alg. 1) |
//!
//! Extension baselines beyond the paper's roster (for the ablation
//! bench; both are cited in the paper's related work):
//!
//! | Hamerly         | [`hamerly`]   | single-lower-bound exact accelerator |
//! | Yinyang         | [`yinyang`]   | group-filtering exact accelerator |
//!
//! All algorithms share [`Config`]/[`KmeansResult`], count every vector
//! operation through [`crate::core::OpCounter`], and record per-iteration
//! `(ops, energy)` convergence traces (the raw material of the paper's
//! tables and figures). Energy evaluation for traces is *uncounted*
//! measurement, computed with raw ops.

mod akm;
mod common;
mod elkan;
mod hamerly;
mod k2means;
mod lloyd;
mod minibatch;
mod yinyang;

pub use akm::akm;
pub use common::{update_means, update_means_threaded, Config, KmeansResult};
pub use elkan::elkan;
pub use hamerly::hamerly;
pub use k2means::k2means;
pub use lloyd::lloyd;
pub use minibatch::{minibatch, MiniBatchOpts};
pub use yinyang::yinyang;
