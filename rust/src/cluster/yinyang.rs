//! Yinyang k-means (Ding et al., ICML'15) — cited by the paper as the
//! state-of-the-art exact accelerator ("typically 2-3x faster than
//! Elkan"). Centers are grouped once at start (k/10 groups via a short
//! k-means over the centers); each point keeps one upper bound and one
//! lower bound *per group*, so a whole group of centers is skipped with
//! one comparison. Exact: produces Lloyd's trajectory.
//!
//! Included as an extension baseline for the ablation bench — the paper
//! positions k²-means against this family (its bounds are per-
//! neighbourhood instead of per-group, plus the kn candidate
//! restriction that makes it approximate-but-sublinear).

use super::common::{update_means, Config, KmeansResult};
use crate::core::{ops, Matrix, OpCounter};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// Group centers with a short (5-iteration) uncounted k-means over the
/// center table — Yinyang's own prescription; grouping cost is O(k²·t)
/// on k points, negligible and done once.
fn group_centers(centers: &Matrix, groups: usize, seed: u64) -> Vec<u32> {
    let k = centers.rows();
    let groups = groups.clamp(1, k);
    let mut rng = crate::rng::Pcg32::new(seed, 0x79696e);
    let idx = rng.sample_distinct(k, groups);
    let mut gcenters = Matrix::gather(centers, &idx);
    let mut assign = vec![0u32; k];
    for _ in 0..5 {
        for j in 0..k {
            let mut best = (0u32, f32::INFINITY);
            for g in 0..groups {
                let dist = ops::sqdist_raw(centers.row(j), gcenters.row(g));
                if dist < best.1 {
                    best = (g as u32, dist);
                }
            }
            assign[j] = best.0;
        }
        let mut sums = vec![0.0f64; groups * centers.cols()];
        let mut counts = vec![0usize; groups];
        let d = centers.cols();
        for j in 0..k {
            let g = assign[j] as usize;
            counts[g] += 1;
            for (s, &v) in sums[g * d..(g + 1) * d].iter_mut().zip(centers.row(j)) {
                *s += v as f64;
            }
        }
        for g in 0..groups {
            if counts[g] > 0 {
                let inv = 1.0 / counts[g] as f64;
                for (c, &s) in
                    gcenters.row_mut(g).iter_mut().zip(&sums[g * d..(g + 1) * d])
                {
                    *c = (s * inv) as f32;
                }
            }
        }
    }
    assign
}

/// Run Yinyang k-means with `max(1, k/10)` center groups.
pub fn yinyang(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let ngroups = (k / 10).max(1);
    let mut centers = init.centers.clone();
    let group_of = group_centers(&centers, ngroups, cfg.seed);
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // Bootstrap full assignment: u + per-group lower bounds.
    let mut labels = vec![0u32; n];
    let mut u = vec![0.0f32; n];
    let mut lb = vec![f32::INFINITY; n * ngroups];
    for i in 0..n {
        let xi = x.row(i);
        let mut best = (0u32, f32::INFINITY);
        for j in 0..k {
            let dist = ops::dist(xi, centers.row(j), counter);
            let g = group_of[j] as usize;
            if dist < best.1 {
                // Previous best falls back into its group's lower bound.
                if best.1 < lb[i * ngroups + group_of[best.0 as usize] as usize] {
                    lb[i * ngroups + group_of[best.0 as usize] as usize] = best.1;
                }
                best = (j as u32, dist);
                // (its own group's lb must exclude the closest itself —
                // handled by the fall-back above on replacement)
            } else if dist < lb[i * ngroups + g] {
                lb[i * ngroups + g] = dist;
            }
        }
        labels[i] = best.0;
        u[i] = best.1;
    }

    for it in 0..cfg.max_iters {
        iters = it + 1;
        let mut changed = 0usize;
        for i in 0..n {
            let global_lb = (0..ngroups)
                .map(|g| lb[i * ngroups + g])
                .fold(f32::INFINITY, f32::min);
            if u[i] <= global_lb {
                continue;
            }
            let xi = x.row(i);
            u[i] = ops::dist(xi, centers.row(labels[i] as usize), counter);
            if u[i] <= global_lb {
                continue;
            }
            // Group filtering: rescan only groups whose bound is beaten.
            let mut best = (labels[i], u[i]);
            let mut second_per_group = vec![f32::INFINITY; ngroups];
            for g in 0..ngroups {
                if u[i] <= lb[i * ngroups + g] {
                    continue;
                }
                for j in 0..k {
                    if group_of[j] as usize != g || j == best.0 as usize {
                        continue;
                    }
                    let dist = ops::dist(xi, centers.row(j), counter);
                    if dist < best.1 {
                        let old_g = group_of[best.0 as usize] as usize;
                        if best.1 < second_per_group[old_g] {
                            second_per_group[old_g] = best.1;
                        }
                        best = (j as u32, dist);
                    } else if dist < second_per_group[g] {
                        second_per_group[g] = dist;
                    }
                }
                lb[i * ngroups + g] = second_per_group[g].min(lb[i * ngroups + g]);
            }
            u[i] = best.1;
            if best.0 != labels[i] {
                labels[i] = best.0;
                changed += 1;
            }
        }

        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        let (new_centers, _) = update_means(x, &labels, &centers, counter);
        // Per-group max drift shifts that group's lower bounds.
        let mut gdrift = vec![0.0f32; ngroups];
        for j in 0..k {
            let dist = ops::dist(centers.row(j), new_centers.row(j), counter);
            let g = group_of[j] as usize;
            gdrift[g] = gdrift[g].max(dist);
        }
        for i in 0..n {
            u[i] += gdrift[group_of[labels[i] as usize] as usize];
            for g in 0..ngroups {
                lb[i * ngroups + g] = (lb[i * ngroups + g] - gdrift[g]).max(0.0);
            }
        }
        centers = new_centers;
    }

    let final_e = energy(x, &centers, &labels);
    KmeansResult { centers, labels, energy: final_e, iters, converged, trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::random_init;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn matches_lloyd_exactly() {
        let x = random_matrix(200, 8, 1);
        let init = random_init(&x, 20, 2);
        let cfg = Config { k: 20, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let ry = yinyang(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, ry.labels);
    }

    #[test]
    fn fewer_distances_than_lloyd() {
        let (x, _) = blobs(600, 20, 16, 15.0, 3);
        let init = random_init(&x, 20, 4);
        let cfg = Config { k: 20, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let _ = lloyd(&x, &init, &cfg, &mut c1);
        let _ = yinyang(&x, &init, &cfg, &mut c2);
        assert!(c2.distances < c1.distances, "{} vs {}", c2.distances, c1.distances);
    }

    #[test]
    fn single_group_degenerates_gracefully() {
        // k < 10 -> one group; still exact.
        let x = random_matrix(120, 5, 5);
        let init = random_init(&x, 5, 6);
        let cfg = Config { k: 5, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let ry = yinyang(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, ry.labels);
    }

    #[test]
    fn grouping_covers_all_centers() {
        let c = random_matrix(50, 4, 7);
        let assign = group_centers(&c, 5, 0);
        assert_eq!(assign.len(), 50);
        assert!(assign.iter().all(|&g| g < 5));
    }
}
