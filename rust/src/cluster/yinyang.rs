//! Yinyang k-means (Ding et al., ICML'15) — cited by the paper as the
//! state-of-the-art exact accelerator ("typically 2-3x faster than
//! Elkan"). Centers are grouped once at start (k/10 groups via a short
//! k-means over the centers); each point keeps one upper bound and one
//! lower bound *per group*, so a whole group of centers is skipped with
//! one comparison. Exact: produces Lloyd's trajectory. Per-iteration
//! cost is `O(n·k·d)` worst case with `O(n·k/10)` bound memory.
//!
//! Included as an extension baseline for the ablation bench — the paper
//! positions k²-means against this family (its bounds are per-
//! neighbourhood instead of per-group, plus the kn candidate
//! restriction that makes it approximate-but-sublinear).
//!
//! Runs on the sharded execution engine ([`pool::sharded_reduce`]): the
//! bootstrap, group-filtered assignment and drift-shift passes shard
//! over contiguous point ranges (`cfg.threads`; each point touches only
//! its own `labels`/`u`/`lb` slots plus shared immutable state —
//! centers, the group map, per-group drifts — so labels are
//! **bit-identical for any thread count**); the update step is the
//! cluster-sharded [`update_means_threaded`].
//!
//! # No per-iteration `O(k²)` state — nothing for the moved-set refresh
//!
//! Unlike k²-means (center kNN graph), Elkan (`cc` table) and Hamerly
//! (`s` table), Yinyang keeps **no** pairwise center structure across
//! iterations: groups are built once up front and the per-iteration
//! bound maintenance only needs the per-group max drift, already a
//! row-wise `O(k·d)` pass. `Config::refresh` therefore has nothing to
//! refresh here — both modes run identically (the roster parity tests
//! in `tests/refresh.rs` cover Yinyang to pin exactly that).

use super::common::{
    finish_run, moved_rows, sharded_bound_pass, update_means_threaded, with_tile_scratch,
    BoundShard, Config, KmeansResult, QuantState,
};
use crate::coordinator::pool;
use crate::core::kernels::{quant, tile_scan_gated};
use crate::core::{Matrix, NumericsMode, OpCounter, ScanMode};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// Per-point fold state the batched group scan threads through
/// [`tile_scan_gated`]: the running global best plus the per-group
/// second-minimum accumulators the fold maintains (displaced bests fall
/// back into their group's slot, losers into their own).
struct YinFold<'a> {
    best: (u32, f32),
    second: &'a mut [f32],
    group_of: &'a [u32],
}

/// Group centers with a short (5-iteration) uncounted k-means over the
/// center table — Yinyang's own prescription; grouping cost is O(k²·t)
/// on k points, negligible and done once. Runs on the caller's numerics
/// tier so a fast-mode run is fast (and deterministic) end to end.
fn group_centers(centers: &Matrix, groups: usize, seed: u64, nm: NumericsMode) -> Vec<u32> {
    let k = centers.rows();
    let groups = groups.clamp(1, k);
    let mut rng = crate::rng::Pcg32::new(seed, 0x79696e);
    let idx = rng.sample_distinct(k, groups);
    let mut gcenters = Matrix::gather(centers, &idx);
    let mut assign = vec![0u32; k];
    for _ in 0..5 {
        for j in 0..k {
            let (g, _) = nm.nearest_sq_rows_raw(centers.row(j), &gcenters);
            assign[j] = g;
        }
        let mut sums = vec![0.0f64; groups * centers.cols()];
        let mut counts = vec![0usize; groups];
        let d = centers.cols();
        for j in 0..k {
            let g = assign[j] as usize;
            counts[g] += 1;
            for (s, &v) in sums[g * d..(g + 1) * d].iter_mut().zip(centers.row(j)) {
                *s += v as f64;
            }
        }
        for g in 0..groups {
            if counts[g] > 0 {
                let inv = 1.0 / counts[g] as f64;
                for (c, &s) in
                    gcenters.row_mut(g).iter_mut().zip(&sums[g * d..(g + 1) * d])
                {
                    *c = (s * inv) as f32;
                }
            }
        }
    }
    assign
}

/// Run Yinyang k-means with `max(1, k/10)` center groups.
pub fn yinyang(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let ngroups = (k / 10).max(1);
    let threads = pool::resolve_threads(cfg.threads, n);
    let nm = cfg.numerics;
    let mut centers = init.centers.clone();
    let group_of = group_centers(&centers, ngroups, cfg.seed, nm);
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // Bootstrap full assignment: u + per-group lower bounds, sharded
    // over points.
    let mut labels = vec![0u32; n];
    let mut u = vec![0.0f32; n];
    let mut lb = vec![f32::INFINITY; n * ngroups];
    {
        let centers_ref = &centers;
        let group_of_ref = &group_of;
        sharded_bound_pass(
            threads,
            ngroups,
            &mut labels,
            &mut u,
            &mut lb,
            counter,
            |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                // Blocked full scan into a shard-local buffer; the
                // group-bound bookkeeping below folds over identical
                // values in the identical order.
                let mut dbuf = vec![0.0f32; k];
                for off in 0..st.labels.len() {
                    let xi = x.row(start + off);
                    nm.dist_rows(xi, centers_ref, 0, &mut dbuf, ctr);
                    let mut best = (0u32, f32::INFINITY);
                    for (j, &dist) in dbuf.iter().enumerate() {
                        let g = group_of_ref[j] as usize;
                        if dist < best.1 {
                            // Previous best falls back into its group's
                            // lower bound.
                            let old_g = group_of_ref[best.0 as usize] as usize;
                            if best.1 < st.lb[off * ngroups + old_g] {
                                st.lb[off * ngroups + old_g] = best.1;
                            }
                            best = (j as u32, dist);
                            // (its own group's lb must exclude the closest
                            // itself — handled by the fall-back above on
                            // replacement)
                        } else if dist < st.lb[off * ngroups + g] {
                            st.lb[off * ngroups + g] = dist;
                        }
                    }
                    st.labels[off] = best.0;
                    st.u[off] = best.1;
                }
                0
            },
        );
    }

    // Ascending member list per group (the gated loop's `0..k` filter,
    // precomputed) and center codes for the batched scan's estimator
    // prune (`QuantState::new` is `None` off the Quantized tier) — both
    // only consumed under `ScanMode::Batched`.
    let members: Vec<Vec<u32>> = {
        let mut m = vec![Vec::new(); ngroups];
        for (j, &g) in group_of.iter().enumerate() {
            m[g as usize].push(j as u32);
        }
        m
    };
    let mut qs = if cfg.scan == ScanMode::Batched {
        QuantState::new(x, &centers, cfg, counter)
    } else {
        None
    };

    for it in 0..cfg.max_iters {
        iters = it + 1;
        // Group-filtered assignment, sharded over points: every read is
        // shared immutable (centers, group map) or the point's own
        // slots, so labels are bit-identical for any thread count.
        let changed = {
            let centers_ref = &centers;
            let group_of_ref = &group_of;
            if cfg.scan == ScanMode::Gated {
                sharded_bound_pass(
                    threads,
                    ngroups,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    counter,
                    |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                        let mut changed = 0usize;
                        for off in 0..st.labels.len() {
                            let global_lb = (0..ngroups)
                                .map(|g| st.lb[off * ngroups + g])
                                .fold(f32::INFINITY, f32::min);
                            if st.u[off] <= global_lb {
                                continue;
                            }
                            let xi = x.row(start + off);
                            st.u[off] =
                                nm.dist_one(xi, centers_ref.row(st.labels[off] as usize), ctr);
                            if st.u[off] <= global_lb {
                                continue;
                            }
                            // Group filtering: rescan only groups whose
                            // bound is beaten.
                            let mut best = (st.labels[off], st.u[off]);
                            let mut second_per_group = vec![f32::INFINITY; ngroups];
                            for g in 0..ngroups {
                                if st.u[off] <= st.lb[off * ngroups + g] {
                                    continue;
                                }
                                for j in 0..k {
                                    if group_of_ref[j] as usize != g
                                        || j == best.0 as usize
                                    {
                                        continue;
                                    }
                                    // One evaluation per admitted member
                                    // (the batched twin gathers these into
                                    // tiles instead).
                                    let dist = nm.dist_one(xi, centers_ref.row(j), ctr);
                                    if dist < best.1 {
                                        let old_g =
                                            group_of_ref[best.0 as usize] as usize;
                                        if best.1 < second_per_group[old_g] {
                                            second_per_group[old_g] = best.1;
                                        }
                                        best = (j as u32, dist);
                                    } else if dist < second_per_group[g] {
                                        second_per_group[g] = dist;
                                    }
                                }
                                st.lb[off * ngroups + g] =
                                    second_per_group[g].min(st.lb[off * ngroups + g]);
                            }
                            st.u[off] = best.1;
                            if best.0 != st.labels[off] {
                                st.labels[off] = best.0;
                                changed += 1;
                            }
                        }
                        changed
                    },
                )
            } else {
                // `ScanMode::Batched`: group admission is already a
                // bounds-only filter against the *static* tightened u,
                // so phase 1 is the precomputed member list of each
                // admitted group. Within a group the gated loop has no
                // per-candidate bound — its only skip is the current
                // best itself, which the driver's gate replays (a
                // candidate not yet folded can never *be* the running
                // best, so the replay never fires late and
                // `batch_extra` stays 0 here; skipping the old label
                // under a stale gather-state only drops a re-evaluation
                // whose value the displacement fall-back already
                // min-folded — state-neutral, strictly fewer
                // distances). Under the Quantized tier the top-2-safe
                // estimator prune drops members certified outside the
                // group's two best first: survivors still contain
                // every min attainer and every value that can reach
                // the group's second-minimum accumulator, so labels
                // *and* the written lb land bitwise where gated puts
                // them.
                let members_ref = &members;
                let qs_ref = qs.as_ref();
                sharded_bound_pass(
                    threads,
                    ngroups,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    counter,
                    |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                        with_tile_scratch(|scratch| {
                            let mut changed = 0usize;
                            for off in 0..st.labels.len() {
                                let global_lb = (0..ngroups)
                                    .map(|g| st.lb[off * ngroups + g])
                                    .fold(f32::INFINITY, f32::min);
                                if st.u[off] <= global_lb {
                                    continue;
                                }
                                let xi = x.row(start + off);
                                st.u[off] = nm.dist_one(
                                    xi,
                                    centers_ref.row(st.labels[off] as usize),
                                    ctr,
                                );
                                if st.u[off] <= global_lb {
                                    continue;
                                }
                                let mut best = (st.labels[off], st.u[off]);
                                let mut second_per_group =
                                    vec![f32::INFINITY; ngroups];
                                for g in 0..ngroups {
                                    if st.u[off] <= st.lb[off * ngroups + g] {
                                        continue;
                                    }
                                    scratch.ids.clear();
                                    scratch.ids.extend_from_slice(&members_ref[g]);
                                    if let Some(q) = qs_ref {
                                        let qp = q.pair(start + off);
                                        quant::prune_survivors_top2(
                                            qp.query,
                                            qp.cands,
                                            &mut scratch.ids,
                                            None,
                                            ctr,
                                        );
                                    }
                                    let mut fold = YinFold {
                                        best,
                                        second: &mut second_per_group,
                                        group_of: group_of_ref,
                                    };
                                    tile_scan_gated(
                                        nm,
                                        xi,
                                        centers_ref,
                                        &scratch.ids,
                                        &scratch.ids,
                                        &mut fold,
                                        ctr,
                                        |f, j| j != f.best.0,
                                        |f, j, dist| {
                                            if dist < f.best.1 {
                                                let old_g = f.group_of
                                                    [f.best.0 as usize]
                                                    as usize;
                                                if f.best.1 < f.second[old_g] {
                                                    f.second[old_g] = f.best.1;
                                                }
                                                f.best = (j, dist);
                                            } else {
                                                let jg =
                                                    f.group_of[j as usize] as usize;
                                                if dist < f.second[jg] {
                                                    f.second[jg] = dist;
                                                }
                                            }
                                        },
                                    );
                                    best = fold.best;
                                    st.lb[off * ngroups + g] = second_per_group[g]
                                        .min(st.lb[off * ngroups + g]);
                                }
                                st.u[off] = best.1;
                                if best.0 != st.labels[off] {
                                    st.labels[off] = best.0;
                                    changed += 1;
                                }
                            }
                            changed
                        })
                    },
                )
            }
        };

        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Update step (cluster-sharded, bit-identical for any thread
        // count); per-group max drift then shifts that group's lower
        // bounds in a sharded point pass.
        let (new_centers, _) =
            update_means_threaded(x, &labels, &centers, counter, cfg.threads);
        let mut drift = vec![0.0f32; k];
        nm.dist_rowwise(&centers, &new_centers, &mut drift, counter);
        let mut gdrift = vec![0.0f32; ngroups];
        for (j, &dist) in drift.iter().enumerate() {
            let g = group_of[j] as usize;
            gdrift[g] = gdrift[g].max(dist);
        }
        {
            let gdrift_ref = &gdrift;
            let group_of_ref = &group_of;
            sharded_bound_pass(
                threads,
                ngroups,
                &mut labels,
                &mut u,
                &mut lb,
                counter,
                |_start, st: BoundShard<'_>, _ctr: &mut OpCounter| {
                    for off in 0..st.labels.len() {
                        let g = group_of_ref[st.labels[off] as usize] as usize;
                        st.u[off] += gdrift_ref[g];
                        for (gi, &dg) in gdrift_ref.iter().enumerate() {
                            let slot = &mut st.lb[off * ngroups + gi];
                            *slot = (*slot - dg).max(0.0);
                        }
                    }
                    0
                },
            );
        }
        if let Some(q) = qs.as_mut() {
            // Yinyang keeps no pairwise center structure, so the center
            // codes are the one batched-mode artifact to refresh; the
            // bitwise moved set keeps the incremental repack exact.
            let mv = moved_rows(&centers, &new_centers);
            centers = new_centers;
            q.refresh(&centers, Some(&mv), counter);
        } else {
            centers = new_centers;
        }
    }

    let final_e = energy(x, &centers, &labels);
    finish_run(centers, labels, final_e, iters, converged, trace, None, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::random_init;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn matches_lloyd_exactly() {
        let x = random_matrix(200, 8, 1);
        let init = random_init(&x, 20, 2);
        let cfg = Config { k: 20, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let ry = yinyang(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, ry.labels);
    }

    #[test]
    fn fewer_distances_than_lloyd() {
        let (x, _) = blobs(600, 20, 16, 15.0, 3);
        let init = random_init(&x, 20, 4);
        let cfg = Config { k: 20, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let _ = lloyd(&x, &init, &cfg, &mut c1);
        let _ = yinyang(&x, &init, &cfg, &mut c2);
        assert!(c2.distances < c1.distances, "{} vs {}", c2.distances, c1.distances);
    }

    #[test]
    fn single_group_degenerates_gracefully() {
        // k < 10 -> one group; still exact.
        let x = random_matrix(120, 5, 5);
        let init = random_init(&x, 5, 6);
        let cfg = Config { k: 5, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let ry = yinyang(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, ry.labels);
    }

    #[test]
    fn grouping_covers_all_centers() {
        let c = random_matrix(50, 4, 7);
        let assign = group_centers(&c, 5, 0, NumericsMode::Strict);
        assert_eq!(assign.len(), 50);
        assert!(assign.iter().all(|&g| g < 5));
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        let (x, _) = blobs(600, 12, 10, 10.0, 11);
        let init = random_init(&x, 24, 12);
        let mut c1 = OpCounter::default();
        let want =
            yinyang(&x, &init, &Config { k: 24, threads: 1, ..Default::default() }, &mut c1);
        for threads in [2usize, 5, 19] {
            let mut c2 = OpCounter::default();
            let got =
                yinyang(&x, &init, &Config { k: 24, threads, ..Default::default() }, &mut c2);
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.iters, want.iters, "threads={threads}");
            assert_eq!(c1.distances, c2.distances, "threads={threads}");
            assert_eq!(c1.additions, c2.additions, "threads={threads}");
        }
    }
}
