//! **k²-means** (paper Algorithm 1) — the paper's core contribution.
//!
//! Two ideas compose:
//!
//! 1. *Neighbourhood-restricted assignment*: cluster centers move slowly,
//!    so a point assigned to center `l` only needs to consider the `kn`
//!    nearest centers of `c_l` as candidates next iteration. The kn-NN
//!    center graph is rebuilt every iteration (`O(k²d)`) and the
//!    assignment step drops from `O(nkd)` to `O(n·kn·d)`.
//! 2. *Elkan-style triangle-inequality bounds within the neighbourhood*:
//!    one upper bound per point and `kn` (not `k`) lower bounds per point
//!    skip most of the remaining candidate distances — empirically the
//!    `O(n·kn·d)` term decays toward `O(nd)` at convergence (paper §2.2).
//!
//! The energy is monotonically non-increasing (each point only moves to a
//! closer center; the update step is the usual mean), so the method
//! converges — but, unlike Elkan, to a *restricted* fixed point: a point
//! never sees centers outside its current neighbourhood. `kn` controls
//! that accuracy/speed trade-off (paper Figure 4); `kn = k` recovers
//! exact Lloyd/Elkan behaviour (verified by property tests).

use super::common::{update_means, Config, KmeansResult};
use crate::core::{ops, Matrix, OpCounter};
use crate::init::InitResult;
use crate::knn::{knn_graph, NeighborGraph};
use crate::metrics::{energy, Trace};

/// Run k²-means with neighbourhood size `cfg.kn`.
///
/// When the initialization carries labels (GDI, k-means++), they seed the
/// assignment and only `n` tightening distances are spent; otherwise one
/// full `n*k` assignment bootstraps the state (counted, like Elkan's
/// first iteration).
pub fn k2means(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let kn = cfg.kn.clamp(1, k);
    let mut centers = init.centers.clone();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // --- Bootstrap labels and upper bounds -----------------------------
    let mut labels: Vec<u32>;
    let mut u = vec![0.0f32; n]; // upper bound on d(x, c_a(x)), plain distance
    match &init.labels {
        Some(l0) => {
            labels = l0.clone();
            for i in 0..n {
                u[i] = ops::dist(x.row(i), centers.row(labels[i] as usize), counter);
            }
        }
        None => {
            labels = vec![0u32; n];
            for i in 0..n {
                let xi = x.row(i);
                let mut best = (0u32, f32::INFINITY);
                for j in 0..k {
                    let dist = ops::dist(xi, centers.row(j), counter);
                    if dist < best.1 {
                        best = (j as u32, dist);
                    }
                }
                labels[i] = best.0;
                u[i] = best.1;
            }
        }
    }

    // lb[i*kn + t]: lower bound on d(x_i, c_j) where j is slot t of the
    // *current* graph's neighbour list of x_i's current center. Starts at
    // 0 (always sound, never prunes wrongly).
    let mut lb = vec![0.0f32; n * kn];
    let mut lb_next = vec![0.0f32; n * kn];
    let mut graph: Option<NeighborGraph> = None;

    for it in 0..cfg.max_iters {
        iters = it + 1;

        // Line 6: rebuild the kn-NN center graph (O(k²) counted distances
        // + the selection counted under the sort convention).
        let new_graph = knn_graph(&centers, kn, counter);
        if let Some(old) = &graph {
            remap_bounds(&lb, &mut lb_next, &labels, old, &new_graph, kn);
            std::mem::swap(&mut lb, &mut lb_next);
        }
        let graph_now = new_graph;

        // s[l] = half distance to the nearest *other* candidate of c_l —
        // the Elkan step-2 prune restricted to the neighbourhood.
        let s: Vec<f32> = (0..k)
            .map(|l| {
                if graph_now.dists[l].len() > 1 {
                    0.5 * graph_now.dists[l][1].sqrt()
                } else {
                    f32::INFINITY
                }
            })
            .collect();

        // Lines 7–12: bounded assignment over the candidate sets.
        // (`use_bounds = false` is the ablation path: plain argmin over
        // all kn candidates — isolates the kn-restriction's contribution
        // from the triangle-inequality pruning's.)
        let mut changed = 0usize;
        if !cfg.use_bounds {
            for i in 0..n {
                let l = labels[i] as usize;
                let xi = x.row(i);
                let nbrs = &graph_now.nbrs[l];
                let mut best = (l as u32, f32::INFINITY);
                for &j in nbrs.iter() {
                    let dist = ops::dist(xi, centers.row(j as usize), counter);
                    if dist < best.1 {
                        best = (j, dist);
                    }
                }
                u[i] = best.1;
                if best.0 as usize != l {
                    labels[i] = best.0;
                    changed += 1;
                }
            }
        } else {
        for i in 0..n {
            let l = labels[i] as usize;
            if u[i] <= s[l] {
                continue;
            }
            let xi = x.row(i);
            // Tighten the upper bound once.
            let d_a = ops::dist(xi, centers.row(l), counter);
            u[i] = d_a;
            lb[i * kn] = d_a;
            if u[i] <= s[l] {
                continue;
            }
            let nbrs = &graph_now.nbrs[l];
            let ccd = &graph_now.dists[l];
            let mut best_t = 0usize;
            let mut best_j = l as u32;
            let mut best_d = d_a;
            for t in 1..nbrs.len() {
                // Elkan step-3 prunes, neighbourhood-local. The
                // center-center prune is only sound while the running
                // best is still the original center l (ccd holds
                // distances *from l*); the lb prune is always sound.
                if best_d <= lb[i * kn + t]
                    || (best_j as usize == l && best_d <= 0.5 * ccd[t].sqrt())
                {
                    continue;
                }
                let j = nbrs[t];
                let dist = ops::dist(xi, centers.row(j as usize), counter);
                lb[i * kn + t] = dist;
                if dist < best_d {
                    best_t = t;
                    best_j = j;
                    best_d = dist;
                }
            }
            u[i] = best_d;
            if best_j as usize != l {
                // Re-align the point's lb slots to the new center's list.
                realign_point(&mut lb, i, kn, &graph_now, l, best_j as usize, best_t);
                labels[i] = best_j;
                changed += 1;
            }
        }
        }

        // Trace + termination (uncounted measurement).
        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        // Converged = assignments stable *after* at least one update step
        // (seeded labels can already be the argmin of the seed centers —
        // the update step still lowers the energy by moving to means).
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Lines 13–15: update step, then shift bounds by center drift.
        let (new_centers, _) = update_means(x, &labels, &centers, counter);
        let mut drift = vec![0.0f32; k];
        for j in 0..k {
            drift[j] = ops::dist(centers.row(j), new_centers.row(j), counter);
        }
        for i in 0..n {
            let l = labels[i] as usize;
            u[i] += drift[l];
            let nbrs = &graph_now.nbrs[l];
            let row = &mut lb[i * kn..i * kn + nbrs.len()];
            for (t, b) in row.iter_mut().enumerate() {
                *b = (*b - drift[nbrs[t] as usize]).max(0.0);
            }
        }
        centers = new_centers;
        graph = Some(graph_now);
    }

    let final_e = energy(x, &centers, &labels);
    KmeansResult { centers, labels, energy: final_e, iters, converged, trace }
}

/// Re-slot every point's lower bounds when the center graph is rebuilt:
/// bounds for centers present in both the old and new neighbour list of
/// the point's center carry over; new centers start at 0 (sound).
/// Pure bookkeeping — uncounted.
fn remap_bounds(
    lb: &[f32],
    lb_next: &mut [f32],
    labels: &[u32],
    old: &NeighborGraph,
    new: &NeighborGraph,
    kn: usize,
) {
    let k = new.k();
    // Per center: map new slot -> old slot (or usize::MAX).
    let mut slot_map = vec![usize::MAX; k * kn];
    for l in 0..k {
        let old_n = &old.nbrs[l];
        let new_n = &new.nbrs[l];
        for (t_new, &j) in new_n.iter().enumerate() {
            if let Some(t_old) = old_n.iter().position(|&o| o == j) {
                slot_map[l * kn + t_new] = t_old;
            }
        }
    }
    for (i, &l) in labels.iter().enumerate() {
        let l = l as usize;
        let map = &slot_map[l * kn..l * kn + new.nbrs[l].len()];
        for (t_new, &t_old) in map.iter().enumerate() {
            lb_next[i * kn + t_new] =
                if t_old == usize::MAX { 0.0 } else { lb[i * kn + t_old] };
        }
        for t in map.len()..kn {
            lb_next[i * kn + t] = 0.0;
        }
    }
}

/// When point `i` switches from center `from` to `to` (slot `to_slot` of
/// `from`'s list), re-align its lb row to `to`'s neighbour list, carrying
/// over the bounds we hold for shared centers.
fn realign_point(
    lb: &mut [f32],
    i: usize,
    kn: usize,
    graph: &NeighborGraph,
    from: usize,
    to: usize,
    _to_slot: usize,
) {
    let old_list = &graph.nbrs[from];
    let new_list = &graph.nbrs[to];
    let old_row: Vec<f32> = lb[i * kn..i * kn + old_list.len()].to_vec();
    for (t_new, &j) in new_list.iter().enumerate() {
        let carried = old_list
            .iter()
            .position(|&o| o == j)
            .map(|t_old| old_row[t_old])
            .unwrap_or(0.0);
        lb[i * kn + t_new] = carried;
    }
    for t in new_list.len()..kn {
        lb[i * kn + t] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::{gdi, kmeans_pp, random_init, GdiOpts};
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn kn_equals_k_matches_lloyd_labels() {
        let x = random_matrix(200, 8, 1);
        let init = kmeans_pp(&x, 12, &mut OpCounter::default(), 2);
        let cfg_k2 = Config { k: 12, kn: 12, ..Default::default() };
        let cfg_l = Config { k: 12, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let r2 = k2means(&x, &init, &cfg_k2, &mut c1);
        let rl = lloyd(&x, &init, &cfg_l, &mut c2);
        assert_eq!(r2.labels, rl.labels);
        assert!((r2.energy - rl.energy).abs() <= 1e-4 * (1.0 + rl.energy));
    }

    #[test]
    fn energy_monotone_along_trace() {
        let x = random_matrix(300, 10, 3);
        let mut c = OpCounter::default();
        let init = gdi(&x, 20, &mut c, 4, &GdiOpts::default());
        let cfg = Config { k: 20, kn: 5, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(
                w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()),
                "energy increased: {} -> {}",
                w[0].energy,
                w[1].energy
            );
        }
    }

    #[test]
    fn far_fewer_ops_than_lloyd_at_moderate_kn() {
        let (x, _) = blobs(800, 32, 16, 8.0, 5);
        let mut c_init = OpCounter::default();
        let init = gdi(&x, 32, &mut c_init, 6, &GdiOpts::default());
        let mut c2 = OpCounter::default();
        let cfg = Config { k: 32, kn: 6, ..Default::default() };
        let _ = k2means(&x, &init, &cfg, &mut c2);
        let mut cl = OpCounter::default();
        let _ = lloyd(&x, &init, &Config { k: 32, ..Default::default() }, &mut cl);
        assert!(
            c2.total() < 0.5 * cl.total(),
            "k2means {} vs lloyd {}",
            c2.total(),
            cl.total()
        );
    }

    #[test]
    fn reaches_near_lloyd_energy_on_blobs() {
        let (x, _) = blobs(600, 20, 12, 15.0, 7);
        let mut c = OpCounter::default();
        let init = gdi(&x, 20, &mut c, 8, &GdiOpts::default());
        let cfg = Config { k: 20, kn: 8, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        // Reference: Lloyd from k-means++.
        let mut cl = OpCounter::default();
        let initpp = kmeans_pp(&x, 20, &mut cl, 9);
        let rl = lloyd(&x, &initpp, &Config { k: 20, ..Default::default() }, &mut cl);
        assert!(
            r.energy <= 1.05 * rl.energy,
            "k2means {} vs lloyd++ {}",
            r.energy,
            rl.energy
        );
    }

    #[test]
    fn works_without_init_labels() {
        let x = random_matrix(150, 6, 9);
        let init = random_init(&x, 10, 10);
        assert!(init.labels.is_none());
        let mut c = OpCounter::default();
        let cfg = Config { k: 10, kn: 4, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        assert!(r.labels.iter().all(|&l| l < 10));
        assert!(r.energy.is_finite());
    }

    #[test]
    fn kn_one_freezes_assignments() {
        let x = random_matrix(100, 4, 11);
        let mut c = OpCounter::default();
        let init = gdi(&x, 8, &mut c, 12, &GdiOpts::default());
        let before = init.labels.clone().unwrap();
        let cfg = Config { k: 8, kn: 1, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        // Only candidate is the current center: labels can never change.
        assert_eq!(r.labels, before);
    }

    #[test]
    fn bounds_do_not_change_the_trajectory() {
        // The triangle-inequality pruning is sound: with and without it,
        // k²-means must produce identical assignments — only the op
        // count differs (that difference is the `k2m ablation` headline).
        let (x, _) = blobs(400, 16, 10, 12.0, 21);
        let mut c0 = OpCounter::default();
        let init = gdi(&x, 16, &mut c0, 22, &GdiOpts::default());
        let with = Config { k: 16, kn: 6, ..Default::default() };
        let without = Config { k: 16, kn: 6, use_bounds: false, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let a = k2means(&x, &init, &with, &mut c1);
        let b = k2means(&x, &init, &without, &mut c2);
        assert_eq!(a.labels, b.labels);
        assert!(
            c1.distances < c2.distances,
            "bounds should save distances: {} vs {}",
            c1.distances,
            c2.distances
        );
    }

    #[test]
    fn converges() {
        let (x, _) = blobs(400, 10, 8, 25.0, 13);
        let mut c = OpCounter::default();
        let init = gdi(&x, 10, &mut c, 14, &GdiOpts::default());
        let cfg = Config { k: 10, kn: 5, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        assert!(r.converged, "did not converge in {} iters", r.iters);
    }
}
