//! **k²-means** (paper Algorithm 1) — the paper's core contribution.
//!
//! Two ideas compose:
//!
//! 1. *Neighbourhood-restricted assignment*: cluster centers move slowly,
//!    so a point assigned to center `l` only needs to consider the `kn`
//!    nearest centers of `c_l` as candidates next iteration. The kn-NN
//!    center graph is refreshed every iteration — a full `O(k²d)` build
//!    the first time, then a moved-set refresh under the default
//!    [`crate::core::RefreshMode::Incremental`] that recomputes only
//!    pairs touching a center that actually moved (`O(|M|·k·d)`,
//!    bitwise-identical graph; see [`KnnGraphCache`]) — and the
//!    assignment step drops from `O(nkd)` to `O(n·kn·d)`.
//! 2. *Elkan-style triangle-inequality bounds within the neighbourhood*:
//!    one upper bound per point and `kn` (not `k`) lower bounds per point
//!    skip most of the remaining candidate distances — empirically the
//!    `O(n·kn·d)` term decays toward `O(nd)` at convergence (paper §2.2).
//!
//! The energy is monotonically non-increasing (each point only moves to a
//! closer center; the update step is the usual mean), so the method
//! converges — but, unlike Elkan, to a *restricted* fixed point: a point
//! never sees centers outside its current neighbourhood. `kn` controls
//! that accuracy/speed trade-off (paper Figure 4); `kn = k` recovers
//! exact Lloyd/Elkan behaviour (verified by property tests).
//!
//! # Sharded execution
//!
//! Every per-point pass (bootstrap, bounded assignment, bound remap,
//! drift shift) runs over contiguous point shards on the execution
//! engine ([`pool::sharded_reduce`]; `cfg.threads`, 0 = auto). Each
//! point's work reads only shared immutable state (centers, graph, `s`)
//! plus its own `labels[i]`, `u[i]`, `lb[i·kn..]` slots, so shard
//! outputs are independent of the shard layout and labels are
//! **bit-identical for any thread count**. Per-shard [`OpCounter`]s are
//! merged in shard order; the update step reduces per-cluster in a
//! thread-count-invariant order ([`update_means_threaded`]).
//!
//! # Distance conventions
//!
//! `u`/`lb` hold **plain** distances (triangle-inequality arithmetic);
//! the center graph holds **squared** distances. Conversions go through
//! [`NeighborGraph::plain_dist`] only — see `knn::brute`.
//!
//! # Blocked candidate scans
//!
//! The kn-candidate scans run on [`crate::core::kernels`], on the tier
//! picked by [`Config::numerics`]: the graph's flat neighbour rows are
//! contiguous candidate lists, so the ablation path is one
//! `nearest_in_block` per point and the unlabeled bootstrap one
//! `nearest_rows`. The bounded path dispatches per [`Config::scan`]:
//! [`ScanMode::Gated`] keeps the historical per-candidate `dist_one`
//! calls, each gated on the bounds the previous evaluation tightened;
//! [`ScanMode::Batched`] (the default) filters the neighbour list on
//! cached bounds first, then evaluates the survivors in `TILE`-wide
//! blocks through [`tile_scan_gated`], replaying the gate between folds
//! — labels bitwise equal to gated at an exact-distance bill within
//! `TILE − 1` per scan of the gated bill (the overshoot tallied on
//! `OpCounter::batch_extra`), and with the Quantized tier's estimator
//! finally pruning *inside* the loop, not just at bootstrap. Either
//! way every evaluation dispatches through the same numerics tier, so
//! bounds, graph distances and candidate evaluations share one
//! arithmetic per run.

use super::common::{
    finish_run, moved_rows, update_means_threaded, with_tile_scratch, Config, KmeansResult,
    QuantState,
};
use crate::coordinator::pool;
use crate::core::kernels::{quant, tile_scan_gated};
use crate::core::{Matrix, OpCounter, ScanMode};
use crate::init::InitResult;
use crate::knn::{KnnGraphCache, NeighborGraph};
use crate::metrics::{energy, Trace};

/// One shard's view of the per-point mutable state: the shard's slice of
/// every array, all covering the same contiguous point range.
struct ShardState<'a> {
    labels: &'a mut [u32],
    u: &'a mut [f32],
    lb: &'a mut [f32],
    lb_next: &'a mut [f32],
}

/// Per-point fold state the batched bounded scan threads through
/// [`tile_scan_gated`]: the running best plus everything the replayed
/// gate reads — the point's lb slots and the graph row (center-center
/// distances *from l*, valid for the half-distance prune only while the
/// running best is still `l`).
struct ScanFold<'a> {
    best_j: u32,
    best_d: f32,
    l: usize,
    lb_row: &'a mut [f32],
    nbrs: &'a [u32],
    graph: &'a NeighborGraph,
}

/// Run `pass(shard_start, shard_state, shard_counter)` over contiguous
/// point shards on [`pool::sharded_reduce`], summing the per-shard
/// returns (used for `changed` counts); the engine merges the per-shard
/// counters in shard order. With `threads <= 1` the engine runs the
/// identical closure inline on the full range — the serial and sharded
/// paths share every instruction that matters.
fn sharded_pass<F>(
    threads: usize,
    kn: usize,
    labels: &mut [u32],
    u: &mut [f32],
    lb: &mut [f32],
    lb_next: &mut [f32],
    counter: &mut OpCounter,
    pass: F,
) -> usize
where
    F: Fn(usize, ShardState<'_>, &mut OpCounter) -> usize + Sync,
{
    let chunk = pool::chunk_len(labels.len(), threads);
    let shards = labels
        .chunks_mut(chunk)
        .zip(u.chunks_mut(chunk))
        .zip(lb.chunks_mut(chunk * kn))
        .zip(lb_next.chunks_mut(chunk * kn))
        .map(|(((labels, u), lb), lb_next)| ShardState { labels, u, lb, lb_next });
    pool::sharded_reduce(shards, counter, |si, st, ctr| pass(si * chunk, st, ctr))
        .into_iter()
        .sum()
}

/// Run k²-means with neighbourhood size `cfg.kn`.
///
/// When the initialization carries labels (GDI, k-means++), they seed the
/// assignment and only `n` tightening distances are spent; otherwise one
/// full `n*k` assignment bootstraps the state (counted, like Elkan's
/// first iteration).
pub fn k2means(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let kn = cfg.kn.clamp(1, k);
    let threads = pool::resolve_threads(cfg.threads, n);
    let nm = cfg.numerics;
    let mut centers = init.centers.clone();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // Per-point state. lb[i*kn + t]: lower bound on d(x_i, c_j) where j
    // is slot t of the *current* graph's neighbour list of x_i's current
    // center — a PLAIN distance, like u. Starts at 0 (always sound,
    // never prunes wrongly).
    let mut labels: Vec<u32>;
    let mut u = vec![0.0f32; n]; // upper bound on d(x, c_a(x)), plain distance
    let mut lb = vec![0.0f32; n * kn];
    let mut lb_next = vec![0.0f32; n * kn];

    // Quantized tier only, and only where a *scan* exists to prune: the
    // unlabeled bootstrap (full argmin over all centers), the ablation
    // path (plain argmin over the kn candidates), and — under
    // `ScanMode::Batched` — the bounded loop itself, whose phase-1
    // survivor list is exactly such a scan (gathered before any exact
    // evaluation, so the estimator can drop certified non-improvers
    // first). Only the gated bounded loop needs no codes: its
    // per-candidate `dist_one` evaluations are interleaved with the
    // bound tightening, so there is never a gathered list to estimate.
    let keep_codes = cfg.scan == ScanMode::Batched;
    let mut qs = if init.labels.is_none() || !cfg.use_bounds || keep_codes {
        QuantState::new(x, &centers, cfg, counter)
    } else {
        None
    };

    // --- Bootstrap labels and upper bounds -----------------------------
    match &init.labels {
        Some(l0) => {
            labels = l0.clone();
            let centers_ref = &centers;
            sharded_pass(
                threads,
                kn,
                &mut labels,
                &mut u,
                &mut lb,
                &mut lb_next,
                counter,
                |start, st: ShardState<'_>, ctr: &mut OpCounter| {
                    for (off, ui) in st.u.iter_mut().enumerate() {
                        let i = start + off;
                        *ui = nm.dist_one(x.row(i), centers_ref.row(st.labels[off] as usize), ctr);
                    }
                    0
                },
            );
        }
        None => {
            labels = vec![0u32; n];
            let centers_ref = &centers;
            let qs_ref = qs.as_ref();
            sharded_pass(
                threads,
                kn,
                &mut labels,
                &mut u,
                &mut lb,
                &mut lb_next,
                counter,
                |start, st: ShardState<'_>, ctr: &mut OpCounter| {
                    for (off, (lab, ui)) in
                        st.labels.iter_mut().zip(st.u.iter_mut()).enumerate()
                    {
                        let xi = x.row(start + off);
                        // Blocked full scan, plain distances (establishes
                        // the bound domain), lowest index wins ties.
                        let qp = qs_ref.map(|q| q.pair(start + off));
                        let (j, dist) = nm.nearest_rows_q(xi, centers_ref, qp.as_ref(), ctr);
                        *lab = j;
                        *ui = dist;
                    }
                    0
                },
            );
        }
    }
    if cfg.use_bounds && !keep_codes {
        // Codes were only for the bootstrap scan; the gated bounded
        // loop has nothing to prune with them.
        qs = None;
    }

    // The center kNN graph lives in a [`KnnGraphCache`] so the
    // per-iteration rebuild (Alg. 1 line 6) can refresh incrementally:
    // under the default `RefreshMode::Incremental` only pairs touching a
    // *moved* center are recomputed — bitwise-identical graph, counted
    // bill `C(k,2) - C(k-m,2)` instead of `C(k,2)` (see the cache's
    // incremental-update contract). `moved` is the bitwise moved set of
    // the previous update step; `prev_graph` feeds the lb slot remap;
    // `graph_stale` records whether the final update step outran the
    // cache (max_iters fallthrough), so the donation below can bring it
    // current and donate on *every* exit arm.
    let mut cache: Option<KnnGraphCache> = None;
    let mut moved: Option<Vec<bool>> = None;
    let mut prev_graph: Option<NeighborGraph> = None;
    let mut graph_stale = false;

    for it in 0..cfg.max_iters {
        iters = it + 1;

        // Line 6: refresh the kn-NN center graph. First iteration: full
        // build (C(k,2) counted distances + selection under the sort
        // convention), rows sharded over the engine's workers;
        // afterwards: moved-set refresh per `cfg.refresh`.
        if cache.is_none() {
            cache = Some(KnnGraphCache::new(
                &centers,
                kn,
                counter,
                cfg.threads,
                nm,
                cfg.refresh,
            ));
        } else {
            let c = cache.as_mut().unwrap();
            prev_graph = Some(c.graph().clone());
            c.update(&centers, moved.as_deref(), counter, cfg.threads, nm);
        }
        graph_stale = false;
        let graph_now = cache.as_ref().unwrap().graph();
        if let Some(old) = &prev_graph {
            // Re-slot every point's lower bounds onto the new graph:
            // bounds for centers present in both the old and new
            // neighbour list of the point's center carry over; new
            // centers start at 0 (sound). Pure bookkeeping — uncounted.
            let slot_map = build_slot_map(old, graph_now, kn);
            let slot_map_ref = &slot_map;
            let graph_ref = graph_now;
            sharded_pass(
                threads,
                kn,
                &mut labels,
                &mut u,
                &mut lb,
                &mut lb_next,
                counter,
                |_start, st: ShardState<'_>, _ctr: &mut OpCounter| {
                    for off in 0..st.labels.len() {
                        let l = st.labels[off] as usize;
                        let used = graph_ref.kn();
                        let map = &slot_map_ref[l * kn..l * kn + used];
                        for (t_new, &t_old) in map.iter().enumerate() {
                            st.lb_next[off * kn + t_new] = if t_old == usize::MAX {
                                0.0
                            } else {
                                st.lb[off * kn + t_old]
                            };
                        }
                        for t in used..kn {
                            st.lb_next[off * kn + t] = 0.0;
                        }
                    }
                    0
                },
            );
            std::mem::swap(&mut lb, &mut lb_next);
        }

        // s[l] = half distance to the nearest *other* candidate of c_l —
        // the Elkan step-2 prune restricted to the neighbourhood. The
        // graph stores squared distances; the bound domain is plain.
        let s: Vec<f32> = (0..k)
            .map(|l| {
                if graph_now.kn() > 1 {
                    0.5 * graph_now.plain_dist(l, 1)
                } else {
                    f32::INFINITY
                }
            })
            .collect();

        // Lines 7–12: bounded assignment over the candidate sets, sharded
        // over contiguous point ranges — every read is either shared
        // immutable (centers, graph, s) or the point's own slots, so the
        // labels are bit-identical for any thread count.
        // (`use_bounds = false` is the ablation path: plain argmin over
        // all kn candidates — isolates the kn-restriction's contribution
        // from the triangle-inequality pruning's.)
        let changed = {
            let centers_ref = &centers;
            let graph_ref = graph_now;
            let s_ref = &s;
            let qs_ref = qs.as_ref();
            if !cfg.use_bounds {
                sharded_pass(
                    threads,
                    kn,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    &mut lb_next,
                    counter,
                    |start, st: ShardState<'_>, ctr: &mut OpCounter| {
                        let mut changed = 0usize;
                        for (off, (lab, ui)) in
                            st.labels.iter_mut().zip(st.u.iter_mut()).enumerate()
                        {
                            let l = *lab as usize;
                            let xi = x.row(start + off);
                            // Blocked argmin over the candidate list —
                            // slot 0 is the current center, so the
                            // lowest-slot tie-break keeps it exactly
                            // like the serial loop did.
                            let nbrs = graph_ref.nbrs_row(l);
                            let qp = qs_ref.map(|q| q.pair(start + off));
                            let (slot, dist) =
                                nm.nearest_in_block_q(xi, centers_ref, nbrs, qp.as_ref(), ctr);
                            let best = nbrs[slot];
                            *ui = dist;
                            if best as usize != l {
                                *lab = best;
                                changed += 1;
                            }
                        }
                        changed
                    },
                )
            } else if cfg.scan == ScanMode::Gated {
                sharded_pass(
                    threads,
                    kn,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    &mut lb_next,
                    counter,
                    |start, st: ShardState<'_>, ctr: &mut OpCounter| {
                        let mut changed = 0usize;
                        for off in 0..st.labels.len() {
                            let l = st.labels[off] as usize;
                            if st.u[off] <= s_ref[l] {
                                continue;
                            }
                            let xi = x.row(start + off);
                            // Tighten the upper bound once.
                            let d_a = nm.dist_one(xi, centers_ref.row(l), ctr);
                            st.u[off] = d_a;
                            let lb_row = &mut st.lb[off * kn..(off + 1) * kn];
                            lb_row[0] = d_a;
                            if d_a <= s_ref[l] {
                                continue;
                            }
                            let nbrs = graph_ref.nbrs_row(l);
                            let mut best_j = l as u32;
                            let mut best_d = d_a;
                            for t in 1..nbrs.len() {
                                // Elkan step-3 prunes, neighbourhood-local.
                                // The center-center prune is only sound
                                // while the running best is still the
                                // original center l (the graph row holds
                                // distances *from l*); the lb prune is
                                // always sound.
                                if best_d <= lb_row[t]
                                    || (best_j as usize == l
                                        && best_d <= 0.5 * graph_ref.plain_dist(l, t))
                                {
                                    continue;
                                }
                                let j = nbrs[t];
                                let dist = nm.dist_one(xi, centers_ref.row(j as usize), ctr);
                                lb_row[t] = dist;
                                if dist < best_d {
                                    best_j = j;
                                    best_d = dist;
                                }
                            }
                            st.u[off] = best_d;
                            if best_j as usize != l {
                                // Re-align the point's lb slots to the new
                                // center's list.
                                realign_point(lb_row, kn, graph_ref, l, best_j as usize);
                                st.labels[off] = best_j;
                                changed += 1;
                            }
                        }
                        changed
                    },
                )
            } else {
                // `ScanMode::Batched`: same gates, two phases. Phase 1
                // walks the neighbour list with *zero* distance
                // evaluations, keeping every slot the initial bound
                // state cannot prune — a superset of whatever the gated
                // loop evaluates, since its running best only shrinks
                // from `d_a`. (The center-center prune depends on the
                // running best, so it is replayed inside the driver
                // rather than used for admission.) Under the Quantized
                // tier the estimator then drops survivors certified
                // farther than the tightened upper bound before any
                // exact evaluation is spent — certified non-improvers
                // cannot change the strict-< argmin, so labels stay
                // bitwise. Phase 2 hands the survivors to
                // [`tile_scan_gated`], which re-gathers under the live
                // gate, evaluates `TILE`-wide blocks, and replays the
                // gate per candidate in slot order.
                sharded_pass(
                    threads,
                    kn,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    &mut lb_next,
                    counter,
                    |start, st: ShardState<'_>, ctr: &mut OpCounter| {
                        with_tile_scratch(|scratch| {
                            let mut changed = 0usize;
                            for off in 0..st.labels.len() {
                                let l = st.labels[off] as usize;
                                if st.u[off] <= s_ref[l] {
                                    continue;
                                }
                                let xi = x.row(start + off);
                                // Tighten the upper bound once.
                                let d_a = nm.dist_one(xi, centers_ref.row(l), ctr);
                                st.u[off] = d_a;
                                let lb_row = &mut st.lb[off * kn..(off + 1) * kn];
                                lb_row[0] = d_a;
                                if d_a <= s_ref[l] {
                                    continue;
                                }
                                let nbrs = graph_ref.nbrs_row(l);
                                scratch.tags.clear();
                                scratch.ids.clear();
                                for t in 1..nbrs.len() {
                                    if d_a > lb_row[t] {
                                        scratch.tags.push(t as u32);
                                        scratch.ids.push(nbrs[t]);
                                    }
                                }
                                if let Some(q) = qs_ref {
                                    let qp = q.pair(start + off);
                                    quant::prune_survivors(
                                        qp.query,
                                        qp.cands,
                                        &mut scratch.ids,
                                        Some(&mut scratch.tags),
                                        quant::plain_threshold_sq(d_a),
                                        ctr,
                                    );
                                }
                                let mut fold = ScanFold {
                                    best_j: l as u32,
                                    best_d: d_a,
                                    l,
                                    lb_row,
                                    nbrs,
                                    graph: graph_ref,
                                };
                                tile_scan_gated(
                                    nm,
                                    xi,
                                    centers_ref,
                                    &scratch.tags,
                                    &scratch.ids,
                                    &mut fold,
                                    ctr,
                                    |f, t| {
                                        let t = t as usize;
                                        f.best_d > f.lb_row[t]
                                            && !(f.best_j as usize == f.l
                                                && f.best_d
                                                    <= 0.5 * f.graph.plain_dist(f.l, t))
                                    },
                                    |f, t, dist| {
                                        let t = t as usize;
                                        f.lb_row[t] = dist;
                                        if dist < f.best_d {
                                            f.best_j = f.nbrs[t];
                                            f.best_d = dist;
                                        }
                                    },
                                );
                                let (best_j, best_d) = (fold.best_j, fold.best_d);
                                st.u[off] = best_d;
                                if best_j as usize != l {
                                    // Re-align the point's lb slots to the
                                    // new center's list.
                                    let lb_row = &mut st.lb[off * kn..(off + 1) * kn];
                                    realign_point(lb_row, kn, graph_ref, l, best_j as usize);
                                    st.labels[off] = best_j;
                                    changed += 1;
                                }
                            }
                            changed
                        })
                    },
                )
            }
        };

        // Trace + termination (uncounted measurement).
        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        // Converged = assignments stable *after* at least one update step
        // (seeded labels can already be the argmin of the seed centers —
        // the update step still lowers the energy by moving to means).
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Lines 13–15: update step (cluster-sharded, bit-identical for
        // any thread count), then shift bounds by center drift.
        let (new_centers, _) =
            update_means_threaded(x, &labels, &centers, counter, cfg.threads);
        let mut drift = vec![0.0f32; k];
        nm.dist_rowwise(&centers, &new_centers, &mut drift, counter);
        {
            let drift_ref = &drift;
            let graph_ref = graph_now;
            sharded_pass(
                threads,
                kn,
                &mut labels,
                &mut u,
                &mut lb,
                &mut lb_next,
                counter,
                |_start, st: ShardState<'_>, _ctr: &mut OpCounter| {
                    for off in 0..st.labels.len() {
                        let l = st.labels[off] as usize;
                        st.u[off] += drift_ref[l];
                        let nbrs = graph_ref.nbrs_row(l);
                        let row = &mut st.lb[off * kn..off * kn + nbrs.len()];
                        for (t, b) in row.iter_mut().enumerate() {
                            *b = (*b - drift_ref[nbrs[t] as usize]).max(0.0);
                        }
                    }
                    0
                },
            );
        }
        // Bitwise moved set for the next iteration's refreshes (graph
        // cache + center codes). Derived by exact row comparison rather
        // than `drift[j] != 0.0`: an f32 drift can underflow to exactly
        // 0.0 for a center that *did* move, and the refresh contract is
        // bitwise, so only a bitwise test is unconditionally sound.
        moved = Some(moved_rows(&centers, &new_centers));
        centers = new_centers;
        if let Some(q) = qs.as_mut() {
            q.refresh(&centers, moved.as_deref(), counter);
        }
        graph_stale = true;
    }

    let final_e = energy(x, &centers, &labels);
    // Donate the maintained graph on every exit arm (the early breaks
    // leave the cache already matching `centers`). On the max_iters
    // fallthrough the final update step moved the centers past the last
    // refresh, so bring the cache current first — uncounted (throwaway
    // counter), like every other piece of model packaging; both refresh
    // modes produce the identical graph, so the donated artifact is
    // mode-invariant. `None` only for the degenerate `max_iters == 0`,
    // where `finish_run` still rebuilds post-hoc.
    let donated = cache.map(|mut c| {
        if graph_stale {
            c.update(&centers, moved.as_deref(), &mut OpCounter::default(), cfg.threads, nm);
        }
        c.into_graph()
    });
    finish_run(centers, labels, final_e, iters, converged, trace, donated, cfg)
}

/// Per center: map new slot -> old slot (or `usize::MAX` when the
/// neighbour is new to the list). `O(k·kn²)` serial bookkeeping shared
/// by every point shard of the remap pass.
fn build_slot_map(old: &NeighborGraph, new: &NeighborGraph, kn: usize) -> Vec<usize> {
    let k = new.k();
    let mut slot_map = vec![usize::MAX; k * kn];
    for l in 0..k {
        let old_n = old.nbrs_row(l);
        let new_n = new.nbrs_row(l);
        for (t_new, &j) in new_n.iter().enumerate() {
            if let Some(t_old) = old_n.iter().position(|&o| o == j) {
                slot_map[l * kn + t_new] = t_old;
            }
        }
    }
    slot_map
}

/// When a point switches from center `from` to `to`, re-align its lb
/// row (`lb_row`, length `kn`) to `to`'s neighbour list, carrying over
/// the bounds we hold for shared centers.
fn realign_point(lb_row: &mut [f32], kn: usize, graph: &NeighborGraph, from: usize, to: usize) {
    let old_list = graph.nbrs_row(from);
    let new_list = graph.nbrs_row(to);
    let old_row: Vec<f32> = lb_row[..old_list.len()].to_vec();
    for (t_new, &j) in new_list.iter().enumerate() {
        let carried = old_list
            .iter()
            .position(|&o| o == j)
            .map(|t_old| old_row[t_old])
            .unwrap_or(0.0);
        lb_row[t_new] = carried;
    }
    for t in new_list.len()..kn {
        lb_row[t] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::{gdi, kmeans_pp, random_init, GdiOpts};
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn kn_equals_k_matches_lloyd_labels() {
        let x = random_matrix(200, 8, 1);
        let init = kmeans_pp(&x, 12, &mut OpCounter::default(), 2);
        let cfg_k2 = Config { k: 12, kn: 12, ..Default::default() };
        let cfg_l = Config { k: 12, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let r2 = k2means(&x, &init, &cfg_k2, &mut c1);
        let rl = lloyd(&x, &init, &cfg_l, &mut c2);
        assert_eq!(r2.labels, rl.labels);
        assert!((r2.energy - rl.energy).abs() <= 1e-4 * (1.0 + rl.energy));
    }

    #[test]
    fn energy_monotone_along_trace() {
        let x = random_matrix(300, 10, 3);
        let mut c = OpCounter::default();
        let init = gdi(&x, 20, &mut c, 4, &GdiOpts::default());
        let cfg = Config { k: 20, kn: 5, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(
                w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()),
                "energy increased: {} -> {}",
                w[0].energy,
                w[1].energy
            );
        }
    }

    #[test]
    fn far_fewer_ops_than_lloyd_at_moderate_kn() {
        let (x, _) = blobs(800, 32, 16, 8.0, 5);
        let mut c_init = OpCounter::default();
        let init = gdi(&x, 32, &mut c_init, 6, &GdiOpts::default());
        let mut c2 = OpCounter::default();
        let cfg = Config { k: 32, kn: 6, ..Default::default() };
        let _ = k2means(&x, &init, &cfg, &mut c2);
        let mut cl = OpCounter::default();
        let _ = lloyd(&x, &init, &Config { k: 32, ..Default::default() }, &mut cl);
        assert!(
            c2.total() < 0.5 * cl.total(),
            "k2means {} vs lloyd {}",
            c2.total(),
            cl.total()
        );
    }

    #[test]
    fn reaches_near_lloyd_energy_on_blobs() {
        let (x, _) = blobs(600, 20, 12, 15.0, 7);
        let mut c = OpCounter::default();
        let init = gdi(&x, 20, &mut c, 8, &GdiOpts::default());
        let cfg = Config { k: 20, kn: 8, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        // Reference: Lloyd from k-means++.
        let mut cl = OpCounter::default();
        let initpp = kmeans_pp(&x, 20, &mut cl, 9);
        let rl = lloyd(&x, &initpp, &Config { k: 20, ..Default::default() }, &mut cl);
        assert!(
            r.energy <= 1.05 * rl.energy,
            "k2means {} vs lloyd++ {}",
            r.energy,
            rl.energy
        );
    }

    #[test]
    fn works_without_init_labels() {
        let x = random_matrix(150, 6, 9);
        let init = random_init(&x, 10, 10);
        assert!(init.labels.is_none());
        let mut c = OpCounter::default();
        let cfg = Config { k: 10, kn: 4, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        assert!(r.labels.iter().all(|&l| l < 10));
        assert!(r.energy.is_finite());
    }

    #[test]
    fn kn_one_freezes_assignments() {
        let x = random_matrix(100, 4, 11);
        let mut c = OpCounter::default();
        let init = gdi(&x, 8, &mut c, 12, &GdiOpts::default());
        let before = init.labels.clone().unwrap();
        let cfg = Config { k: 8, kn: 1, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        // Only candidate is the current center: labels can never change.
        assert_eq!(r.labels, before);
    }

    #[test]
    fn bounds_do_not_change_the_trajectory() {
        // The triangle-inequality pruning is sound: with and without it,
        // k²-means must produce identical assignments — only the op
        // count differs (that difference is the `k2m ablation` headline).
        let (x, _) = blobs(400, 16, 10, 12.0, 21);
        let mut c0 = OpCounter::default();
        let init = gdi(&x, 16, &mut c0, 22, &GdiOpts::default());
        let with = Config { k: 16, kn: 6, ..Default::default() };
        let without = Config { k: 16, kn: 6, use_bounds: false, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let a = k2means(&x, &init, &with, &mut c1);
        let b = k2means(&x, &init, &without, &mut c2);
        assert_eq!(a.labels, b.labels);
        assert!(
            c1.distances < c2.distances,
            "bounds should save distances: {} vs {}",
            c1.distances,
            c2.distances
        );
    }

    #[test]
    fn converges() {
        let (x, _) = blobs(400, 10, 8, 25.0, 13);
        let mut c = OpCounter::default();
        let init = gdi(&x, 10, &mut c, 14, &GdiOpts::default());
        let cfg = Config { k: 10, kn: 5, ..Default::default() };
        let r = k2means(&x, &init, &cfg, &mut c);
        assert!(r.converged, "did not converge in {} iters", r.iters);
    }

    #[test]
    fn sharded_runs_match_serial_bit_for_bit() {
        // The engine's core guarantee on a workload small enough for a
        // unit test; the full-size version lives in tests/sharding.rs.
        let (x, _) = blobs(700, 24, 12, 10.0, 31);
        let mut c0 = OpCounter::default();
        let init = gdi(&x, 24, &mut c0, 32, &GdiOpts::default());
        let serial_cfg = Config { k: 24, kn: 8, threads: 1, ..Default::default() };
        let mut cs = OpCounter::default();
        let want = k2means(&x, &init, &serial_cfg, &mut cs);
        for threads in [2usize, 3, 8, 16] {
            let cfg = Config { k: 24, kn: 8, threads, ..Default::default() };
            let mut c = OpCounter::default();
            let got = k2means(&x, &init, &cfg, &mut c);
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.energy, want.energy, "threads={threads}");
            assert_eq!(got.iters, want.iters, "threads={threads}");
            assert_eq!(c.distances, cs.distances, "threads={threads}");
        }
    }

    #[test]
    fn more_shards_than_points_is_fine() {
        // n < threads: every shard holds at most one point.
        let x = random_matrix(5, 3, 40);
        let mut c0 = OpCounter::default();
        let init = gdi(&x, 3, &mut c0, 41, &GdiOpts::default());
        let mut c1 = OpCounter::default();
        let serial =
            k2means(&x, &init, &Config { k: 3, kn: 2, threads: 1, ..Default::default() }, &mut c1);
        let mut c2 = OpCounter::default();
        let wide =
            k2means(&x, &init, &Config { k: 3, kn: 2, threads: 64, ..Default::default() }, &mut c2);
        assert_eq!(serial.labels, wide.labels);
        assert_eq!(serial.centers, wide.centers);
    }
}
