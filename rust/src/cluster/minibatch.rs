//! MiniBatch k-means (Sculley, WWW'10, Algorithm 1): per iteration, draw
//! `b` points, assign them against the current centers, then take
//! per-center gradient steps with learning rate `1/counts[c]`. The paper
//! runs it with `b = 100` and `t = n/2` iterations; it trades converged
//! energy for speed and (per the paper's Tables 5/6) mostly fails the
//! 1%-band targets — reproducing that failure is part of the benchmark.
//!
//! The batch assignment shards over batch slots on the execution engine
//! (`cfg.threads`; bit-identical at any thread count). The gradient
//! steps stay serial — each step's learning rate `1/counts[c]` depends
//! on every step before it. Note the paper's `b = 100` is too narrow to
//! shard profitably: auto (`threads = 0`) correctly keeps it serial,
//! while an explicit count is honored exactly (engine contract) and
//! pays a per-iteration spawn that only large batches amortize.

use super::common::{finish_run, moved_rows, Config, KmeansResult, QuantState};
use crate::coordinator::pool;
use crate::core::{kernels, Matrix, OpCounter};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};
use crate::rng::Pcg32;

/// MiniBatch-specific knobs.
#[derive(Clone, Debug)]
pub struct MiniBatchOpts {
    /// Total iterations; the paper uses `n/2`. `None` = n/2.
    pub iterations: Option<usize>,
    /// Evaluate the (uncounted) energy trace every this many iterations,
    /// keeping trace size bounded.
    pub eval_every: Option<usize>,
}

impl Default for MiniBatchOpts {
    fn default() -> Self {
        MiniBatchOpts { iterations: None, eval_every: None }
    }
}

/// Run MiniBatch k-means. `cfg.batch` is `b`; iterations default to `n/2`.
pub fn minibatch(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    opts: &MiniBatchOpts,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let b = cfg.batch.max(1).min(n);
    let t = opts.iterations.unwrap_or(n / 2).max(1);
    let eval_every = opts.eval_every.unwrap_or_else(|| (t / 200).max(1));
    let mut rng = Pcg32::new(cfg.seed, 0x6d696e69);

    let mut centers = init.centers.clone();
    // Quantized tier only: packed codes for the batch assignment scans.
    let mut qs = QuantState::new(x, &centers, cfg, counter);
    let mut counts = vec![0u64; k];
    let mut trace = Trace::default();
    let mut batch_labels = vec![0u32; b];
    let mut iters = 0;

    // Batch assignment shards over batch slots (`cfg.threads`; the
    // paper's b=100 stays serial under auto — see
    // `pool::resolve_threads` — but large batches parallelize). The
    // sampling and the gradient steps stay serial: the sample stream
    // must follow one RNG, and each step's learning rate depends on the
    // running per-center counts. Labels are bit-identical at any thread
    // count (each slot reads only shared immutable centers).
    let threads = pool::resolve_threads(cfg.threads, b);
    let chunk = pool::chunk_len(b, threads);
    let nm = cfg.numerics;

    for it in 0..t {
        iters = it + 1;
        // Sample the batch and cache nearest centers (b*k counted).
        let batch: Vec<usize> = (0..b).map(|_| rng.gen_below(n)).collect();
        {
            let centers_ref = &centers;
            let qs_ref = qs.as_ref();
            pool::sharded_reduce(
                batch.chunks(chunk).zip(batch_labels.chunks_mut(chunk)),
                counter,
                |_si, (idx_c, lab_c): (&[usize], &mut [u32]), ctr| {
                    for (&i, lab) in idx_c.iter().zip(lab_c.iter_mut()) {
                        let qp = qs_ref.map(|q| q.pair(i));
                        let (best, _) =
                            nm.nearest_sq_rows_q(x.row(i), centers_ref, qp.as_ref(), ctr);
                        *lab = best;
                    }
                },
            );
        }
        // Snapshot before the gradient steps (only when codes exist to
        // refresh) so the incremental repack can diff rows bitwise —
        // the steps mutate `centers` in place.
        let pre = qs.as_ref().map(|_| centers.clone());
        // Gradient steps (one counted vector addition per sample).
        for (bi, &i) in batch.iter().enumerate() {
            let c = batch_labels[bi] as usize;
            counts[c] += 1;
            let eta = 1.0f32 / counts[c] as f32;
            let row = centers.row_mut(c);
            for (cv, &xv) in row.iter_mut().zip(x.row(i)) {
                *cv = (1.0 - eta) * *cv + eta * xv;
            }
            counter.additions += 1;
        }
        // Center rows drifted under the gradient steps: re-pack their
        // codes before the next batch's pruned scans — under the
        // incremental refresh, only rows a step actually changed
        // bitwise (a batch touches at most b of the k centers).
        if let Some(q) = qs.as_mut() {
            let moved = moved_rows(pre.as_ref().unwrap(), &centers);
            q.refresh(&centers, Some(&moved), counter);
        }

        if cfg.record_trace && (it % eval_every == 0 || it + 1 == t) {
            let (lab, e) = full_eval(x, &centers);
            trace.push(counter.total(), e, it);
            let _ = lab;
            if cfg.target_energy.is_some_and(|t| e <= t) {
                break;
            }
        }
    }

    let (labels, final_e) = full_eval(x, &centers);
    // converged stays false: online method, no assignment-stability notion.
    finish_run(centers, labels, final_e, iters, false, trace, None, cfg)
}

/// Uncounted full assignment + energy (measurement only; blocked scan).
/// Stays on the strict reference tier in both numerics modes — like
/// [`energy`], evaluation work is measurement, and keeping it fixed
/// makes strict-vs-fast energy comparisons apples to apples.
fn full_eval(x: &Matrix, centers: &Matrix) -> (Vec<u32>, f64) {
    let n = x.rows();
    let mut labels = vec![0u32; n];
    for (i, lab) in labels.iter_mut().enumerate() {
        let (best, _) = kernels::nearest_sq_rows_raw(x.row(i), centers);
        *lab = best;
    }
    let e = energy(x, centers, &labels);
    (labels, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::init::random_init;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn improves_energy_on_blobs() {
        let (x, _) = blobs(600, 6, 10, 20.0, 1);
        let init = random_init(&x, 6, 2);
        let e0 = full_eval(&x, &init.centers).1;
        let mut c = OpCounter::default();
        let cfg = Config { k: 6, batch: 50, seed: 3, ..Default::default() };
        let r = minibatch(&x, &init, &cfg, &MiniBatchOpts::default(), &mut c);
        assert!(r.energy < e0, "no improvement: {} vs {e0}", r.energy);
    }

    #[test]
    fn op_count_is_t_times_bk_plus_b() {
        let x = random_matrix(100, 4, 4);
        let init = random_init(&x, 5, 5);
        let mut c = OpCounter::default();
        let cfg = Config { k: 5, batch: 10, seed: 6, ..Default::default() };
        let opts = MiniBatchOpts { iterations: Some(7), eval_every: Some(100) };
        let _ = minibatch(&x, &init, &cfg, &opts, &mut c);
        assert_eq!(c.distances, 7 * 10 * 5);
        assert_eq!(c.additions, 7 * 10);
    }

    #[test]
    fn trace_is_bounded() {
        let x = random_matrix(2000, 4, 7);
        let init = random_init(&x, 8, 8);
        let mut c = OpCounter::default();
        let cfg = Config { k: 8, ..Default::default() };
        let r = minibatch(&x, &init, &cfg, &MiniBatchOpts::default(), &mut c);
        assert!(r.trace.points.len() <= 220, "{}", r.trace.points.len());
        assert!(r.iters == 1000);
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        let x = random_matrix(400, 6, 11);
        let init = random_init(&x, 8, 12);
        let opts = MiniBatchOpts { iterations: Some(30), eval_every: Some(10) };
        let cfg1 = Config { k: 8, batch: 120, seed: 13, threads: 1, ..Default::default() };
        let mut c1 = OpCounter::default();
        let want = minibatch(&x, &init, &cfg1, &opts, &mut c1);
        for threads in [3usize, 8] {
            let cfg = Config { threads, ..cfg1.clone() };
            let mut c2 = OpCounter::default();
            let got = minibatch(&x, &init, &cfg, &opts, &mut c2);
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.energy.to_bits(), want.energy.to_bits(), "threads={threads}");
            assert_eq!(c1.distances, c2.distances, "threads={threads}");
            assert_eq!(c1.additions, c2.additions, "threads={threads}");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let x = random_matrix(150, 3, 9);
        let init = random_init(&x, 4, 10);
        let cfg = Config { k: 4, seed: 42, ..Default::default() };
        let opts = MiniBatchOpts { iterations: Some(20), eval_every: Some(5) };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let a = minibatch(&x, &init, &cfg, &opts, &mut c1);
        let b = minibatch(&x, &init, &cfg, &opts, &mut c2);
        assert_eq!(a.centers, b.centers);
    }
}
