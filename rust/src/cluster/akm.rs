//! Approximate k-means (Philbin et al., CVPR'07): each iteration rebuilds
//! a randomized kd-tree over the centers and answers every point's
//! assignment with a best-bin-first search bounded to `m` distance
//! checks — `O(nmd)` per iteration (paper Table 2). `m` trades accuracy
//! for speed exactly like `kn` does for k²-means, which is the comparison
//! the paper's Figure 4 sweeps.
//!
//! # Sharded execution
//!
//! The per-point query pass runs over contiguous label shards on the
//! execution engine ([`pool::sharded_reduce`]; `cfg.threads`, 0 = auto):
//! each query reads only the shared immutable tree and centers plus its
//! own label slot, so labels — and the integer op-count categories — are
//! **bit-identical for any thread count** (the tree build itself is
//! serial `O(k log k)` bookkeeping on the caller's counter). Pinned by
//! `rust/tests/sharding.rs`. The per-leaf distance checks run on the
//! configured numerics tier ([`Config::numerics`] →
//! [`crate::knn::KdTree::nearest_mode`]); descent and build stay on the
//! scalar reference arithmetic, whose per-leaf candidate sets are too
//! small and irregular to benefit.
//!
//! Like Yinyang, AKM keeps no pairwise `O(k²)` center state across
//! iterations (the kd-tree is uncounted bookkeeping rebuilt from
//! scratch), so `Config::refresh` has nothing to refresh here — both
//! modes run identically (pinned by the roster parity tests in
//! `tests/refresh.rs`).

use super::common::{finish_run, update_means, Config, KmeansResult};
use crate::coordinator::pool;
use crate::core::{Matrix, OpCounter};
use crate::init::InitResult;
use crate::knn::KdTree;
use crate::metrics::{energy, Trace};

/// Run AKM with `cfg.m` distance checks per query.
pub fn akm(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let m = cfg.m.max(1);
    let nm = cfg.numerics;
    let threads = pool::resolve_threads(cfg.threads, n);
    let chunk = pool::chunk_len(n, threads);
    let mut centers = init.centers.clone();
    let mut labels: Vec<u32> = vec![u32::MAX; n];
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    for it in 0..cfg.max_iters {
        iters = it + 1;
        // Rebuild the randomized tree over the moved centers (build
        // comparisons counted under the sort convention inside).
        let tree = KdTree::build(&centers, cfg.seed ^ (it as u64) << 8, counter);

        // The query pass: every point asks the shared tree for its
        // bounded-BBF nearest center, writing only its own label slot.
        let tree_ref = &tree;
        let changed: usize = pool::sharded_reduce(
            labels.chunks_mut(chunk),
            counter,
            |si, shard: &mut [u32], ctr: &mut OpCounter| {
                let start = si * chunk;
                let mut changed = 0usize;
                for (off, lab) in shard.iter_mut().enumerate() {
                    let (j, _dist) = tree_ref.nearest_mode(x.row(start + off), m, ctr, nm);
                    if *lab != j {
                        *lab = j;
                        changed += 1;
                    }
                }
                changed
            },
        )
        .into_iter()
        .sum();

        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        let (new_centers, _) = update_means(x, &labels, &centers, counter);
        centers = new_centers;
    }

    let final_e = energy(x, &centers, &labels);
    finish_run(centers, labels, final_e, iters, converged, trace, None, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::random_init;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn large_m_approaches_lloyd_energy() {
        let (x, _) = blobs(300, 10, 8, 15.0, 1);
        let init = random_init(&x, 10, 2);
        let cfg_exact = Config { k: 10, m: usize::MAX >> 1, ..Default::default() };
        let mut c1 = OpCounter::default();
        let r_akm = akm(&x, &init, &cfg_exact, &mut c1);
        let mut c2 = OpCounter::default();
        let r_lloyd = lloyd(&x, &init, &Config { k: 10, ..Default::default() }, &mut c2);
        // Unbounded BBF search is exact => identical trajectory to Lloyd.
        assert_eq!(r_akm.labels, r_lloyd.labels);
    }

    #[test]
    fn small_m_uses_fewer_ops_per_iteration() {
        // Compare a single iteration (convergence speed differs between
        // m values, so total-run ops are confounded).
        let (x, _) = blobs(400, 16, 12, 10.0, 3);
        let init = random_init(&x, 16, 4);
        let mut c_small = OpCounter::default();
        let mut c_big = OpCounter::default();
        let cfg_small = Config { k: 16, m: 4, max_iters: 1, ..Default::default() };
        let cfg_big = Config { k: 16, m: 64, max_iters: 1, ..Default::default() };
        let _ = akm(&x, &init, &cfg_small, &mut c_small);
        let _ = akm(&x, &init, &cfg_big, &mut c_big);
        assert!(
            c_small.total() < c_big.total(),
            "m=4: {} vs m=64: {}",
            c_small.total(),
            c_big.total()
        );
    }

    #[test]
    fn energy_reasonable_on_blobs() {
        let (x, _) = blobs(500, 8, 10, 30.0, 5);
        let init = random_init(&x, 8, 6);
        let mut c = OpCounter::default();
        let cfg = Config { k: 8, m: 16, ..Default::default() };
        let r = akm(&x, &init, &cfg, &mut c);
        // Within 2x of a converged Lloyd run (approximation is lossy but sane).
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &Config { k: 8, ..Default::default() }, &mut c2);
        assert!(r.energy <= 2.0 * rl.energy + 1e-9, "{} vs {}", r.energy, rl.energy);
    }

    #[test]
    fn labels_all_valid() {
        let x = random_matrix(120, 5, 7);
        let init = random_init(&x, 9, 8);
        let mut c = OpCounter::default();
        let r = akm(&x, &init, &Config { k: 9, m: 5, max_iters: 5, ..Default::default() }, &mut c);
        assert!(r.labels.iter().all(|&l| l < 9));
    }
}
