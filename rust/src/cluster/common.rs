//! Shared configuration, result type, and the update step used by every
//! Lloyd-family algorithm — including the sharded-parallel update step
//! of the execution engine.

use crate::coordinator::pool;
use crate::core::{Matrix, OpCounter};
use crate::metrics::Trace;

/// Common knobs for all algorithms (a method reads only what it needs:
/// `kn` is k²-means', `m` is AKM's, `batch` is MiniBatch's).
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of clusters.
    pub k: usize,
    /// k²-means neighbourhood size (candidate centers per point).
    pub kn: usize,
    /// AKM distance checks per query.
    pub m: usize,
    /// MiniBatch batch size (paper §3.2: b = 100).
    pub batch: usize,
    /// Iteration cap (paper §3.2: 100 for all but MiniBatch).
    pub max_iters: usize,
    /// Seed for the algorithm's internal randomness (kd-tree axes,
    /// minibatch sampling).
    pub seed: u64,
    /// Record per-iteration `(ops, energy)` trace points.
    pub record_trace: bool,
    /// Early-stop as soon as the trace energy reaches this value — used
    /// by the speedup experiments so oracle runs don't waste work.
    pub target_energy: Option<f64>,
    /// k²-means ablation: `false` disables the triangle-inequality
    /// bounds, leaving only the kn-candidate restriction (quantifies how
    /// much each of the paper's two ideas contributes — `k2m ablation`).
    pub use_bounds: bool,
    /// Worker threads for the sharded execution engine (k²-means, Lloyd,
    /// Elkan per-point passes and the update step). `0` = auto: honor
    /// `K2M_THREADS`, else available parallelism, scaled down for small
    /// workloads (see [`crate::coordinator::pool::resolve_threads`]).
    /// Any value produces bit-identical labels: per-point work is
    /// independent and reductions run in a thread-count-invariant order.
    pub threads: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            k: 10,
            kn: 10,
            m: 32,
            batch: 100,
            max_iters: 100,
            seed: 0,
            record_trace: true,
            target_energy: None,
            use_bounds: true,
            threads: 0,
        }
    }
}

/// Outcome of one clustering run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub centers: Matrix,
    pub labels: Vec<u32>,
    /// Final energy (uncounted evaluation over all points).
    pub energy: f64,
    /// Iterations executed.
    pub iters: usize,
    /// Converged (assignments stable) before the cap / early stop.
    pub converged: bool,
    /// `(ops, energy)` per iteration when `record_trace`.
    pub trace: Trace,
}

/// The k-means update step: per-cluster means. Empty clusters keep their
/// previous center (the classical convention; the coordinator's
/// experiments never hinge on re-seeding policy). Counts one vector
/// addition per point (the accumulation), matching O(nd) in paper §2.
///
/// Serial entry point — see [`update_means_threaded`] for the sharded
/// variant the execution engine uses (bit-identical output).
pub fn update_means(
    x: &Matrix,
    labels: &[u32],
    old: &Matrix,
    counter: &mut OpCounter,
) -> (Matrix, Vec<u32>) {
    update_means_threaded(x, labels, old, counter, 1)
}

/// Sharded update step. Parallelism is over **clusters**, not points:
/// each worker owns a contiguous block of clusters and scans the whole
/// label array, accumulating only the points of its block. Every
/// cluster's f64 accumulation therefore visits its members in global
/// point order — exactly the serial order — so the resulting centers
/// are **bit-identical for any thread count** (point-sharded partial
/// sums would reassociate the f64 additions and drift between thread
/// counts). The extra cost is one label comparison per (worker, point),
/// negligible next to the `O(nd)` row additions.
pub fn update_means_threaded(
    x: &Matrix,
    labels: &[u32],
    old: &Matrix,
    counter: &mut OpCounter,
    threads: usize,
) -> (Matrix, Vec<u32>) {
    let k = old.rows();
    let d = x.cols();
    let threads = pool::resolve_threads(threads, labels.len()).min(k.max(1));
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u32; k];

    if threads <= 1 {
        for (i, &l) in labels.iter().enumerate() {
            let l = l as usize;
            debug_assert!(l < k);
            let row = x.row(i);
            let acc = &mut sums[l * d..(l + 1) * d];
            for (a, &v) in acc.iter_mut().zip(row) {
                *a += v as f64;
            }
            counts[l] += 1;
            counter.additions += 1;
        }
    } else {
        let kc = pool::chunk_len(k, threads);
        let shard_counters: Vec<OpCounter> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for (si, (sum_chunk, count_chunk)) in
                sums.chunks_mut(kc * d).zip(counts.chunks_mut(kc)).enumerate()
            {
                handles.push(scope.spawn(move || {
                    let j0 = si * kc;
                    let owned = count_chunk.len();
                    let mut ctr = OpCounter::default();
                    for (i, &l) in labels.iter().enumerate() {
                        let l = l as usize;
                        debug_assert!(l < k);
                        if l < j0 || l >= j0 + owned {
                            continue;
                        }
                        let acc = &mut sum_chunk[(l - j0) * d..(l - j0 + 1) * d];
                        for (a, &v) in acc.iter_mut().zip(x.row(i)) {
                            *a += v as f64;
                        }
                        count_chunk[l - j0] += 1;
                        ctr.additions += 1;
                    }
                    ctr
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        counter.merge_shards(shard_counters);
    }

    let mut centers = Matrix::zeros(k, d);
    for j in 0..k {
        let row = centers.row_mut(j);
        if counts[j] > 0 {
            let inv = 1.0 / counts[j] as f64;
            for (r, &s) in row.iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                *r = (s * inv) as f32;
            }
        } else {
            row.copy_from_slice(old.row(j));
        }
    }
    (centers, counts)
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::random_matrix;

    #[test]
    fn update_means_computes_means_and_counts() {
        let x = Matrix::from_vec(vec![0., 0., 2., 0., 10., 10., 12., 14.], 4, 2);
        let old = Matrix::zeros(2, 2);
        let labels = vec![0, 0, 1, 1];
        let mut c = OpCounter::default();
        let (centers, counts) = update_means(&x, &labels, &old, &mut c);
        assert_eq!(centers.row(0), &[1.0, 0.0]);
        assert_eq!(centers.row(1), &[11.0, 12.0]);
        assert_eq!(counts, vec![2, 2]);
        assert_eq!(c.additions, 4); // one per point
    }

    #[test]
    fn empty_cluster_keeps_old_center() {
        let x = random_matrix(5, 3, 1);
        let mut old = Matrix::zeros(3, 3);
        old.row_mut(2).copy_from_slice(&[7.0, 8.0, 9.0]);
        let labels = vec![0, 0, 1, 1, 0];
        let mut c = OpCounter::default();
        let (centers, counts) = update_means(&x, &labels, &old, &mut c);
        assert_eq!(counts[2], 0);
        assert_eq!(centers.row(2), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn config_default_matches_paper_protocol() {
        let cfg = Config::default();
        assert_eq!(cfg.batch, 100);
        assert_eq!(cfg.max_iters, 100);
        assert_eq!(cfg.threads, 0); // auto
    }

    #[test]
    fn threaded_update_bit_identical_to_serial() {
        let k = 13;
        let x = random_matrix(500, 7, 42);
        let old = random_matrix(k, 7, 43);
        // Deterministic, imbalanced labels with one empty cluster (12).
        let labels: Vec<u32> = (0..500usize).map(|i| ((i * 7 + 3) % (k - 1)) as u32).collect();
        let mut c0 = OpCounter::default();
        let (want_centers, want_counts) = update_means(&x, &labels, &old, &mut c0);
        for threads in [2usize, 3, 5, 13, 64] {
            let mut c = OpCounter::default();
            let (centers, counts) =
                update_means_threaded(&x, &labels, &old, &mut c, threads);
            assert_eq!(centers, want_centers, "threads={threads}");
            assert_eq!(counts, want_counts, "threads={threads}");
            assert_eq!(c.additions, c0.additions, "threads={threads}");
        }
    }
}
