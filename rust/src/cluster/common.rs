//! Shared configuration, result type, and the update step used by every
//! Lloyd-family algorithm — including the sharded-parallel update step
//! of the execution engine.

use std::cell::RefCell;

use crate::coordinator::pool;
use crate::core::kernels::quant::{self, QuantPair, QuantizedCodes};
use crate::core::{Matrix, NumericsMode, OpCounter, RefreshMode, ScanMode};
use crate::knn::NeighborGraph;
use crate::metrics::Trace;

use super::model::ClusterModel;

/// Common knobs for all algorithms (a method reads only what it needs:
/// `kn` is k²-means', `m` is AKM's, `batch` is MiniBatch's).
///
/// # `threads`: the sharded execution engine's knob
///
/// Every algorithm resolves `threads` through
/// [`crate::coordinator::pool::resolve_threads`]: `0` (the default) is
/// **auto** — honor `K2M_THREADS`, else available parallelism, scaled
/// down so every shard keeps at least
/// [`crate::coordinator::pool::MIN_AUTO_CHUNK`] points — and any
/// explicit value is honored exactly (clamped to the pass length).
/// Whatever the engine picks, results are bit-identical:
///
/// ```
/// use k2m::cluster::Config;
/// use k2m::coordinator::pool::{resolve_threads, MIN_AUTO_CHUNK};
///
/// let cfg = Config::default();
/// assert_eq!(cfg.threads, 0); // auto
/// // Auto keeps workloads below one shard's worth of points serial —
/// // spawn overhead would dominate a tiny pass…
/// assert_eq!(resolve_threads(cfg.threads, MIN_AUTO_CHUNK - 1), 1);
/// // …and explicit requests shard exactly as asked (clamped to n).
/// assert_eq!(resolve_threads(6, 60_000), 6);
/// assert_eq!(resolve_threads(6, 4), 4);
/// ```
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of clusters.
    pub k: usize,
    /// k²-means neighbourhood size (candidate centers per point).
    pub kn: usize,
    /// AKM distance checks per query.
    pub m: usize,
    /// MiniBatch batch size (paper §3.2: b = 100).
    pub batch: usize,
    /// Iteration cap (paper §3.2: 100 for all but MiniBatch).
    pub max_iters: usize,
    /// Seed for the algorithm's internal randomness (kd-tree axes,
    /// minibatch sampling).
    pub seed: u64,
    /// Record per-iteration `(ops, energy)` trace points.
    pub record_trace: bool,
    /// Early-stop as soon as the trace energy reaches this value — used
    /// by the speedup experiments so oracle runs don't waste work.
    pub target_energy: Option<f64>,
    /// k²-means ablation: `false` disables the triangle-inequality
    /// bounds, leaving only the kn-candidate restriction (quantifies how
    /// much each of the paper's two ideas contributes — `k2m ablation`).
    pub use_bounds: bool,
    /// Worker threads for the sharded execution engine (k²-means, Lloyd,
    /// Elkan per-point passes and the update step). `0` = auto: honor
    /// `K2M_THREADS`, else available parallelism, scaled down for small
    /// workloads (see [`crate::coordinator::pool::resolve_threads`]).
    /// Any value produces bit-identical labels: per-point work is
    /// independent and reductions run in a thread-count-invariant order.
    pub threads: usize,
    /// Distance-kernel numerics tier (CLI `--numerics`, manifest
    /// `numerics=`). The default resolves `K2M_NUMERICS` once per
    /// process and falls back to [`NumericsMode::Strict`] — bit-identical
    /// to the historical scalar loops. `Fast` switches every candidate
    /// scan to the lane-striped tier (`core::kernels::fast`):
    /// deterministic at any thread count, identical op-count bill, final
    /// energies within f32 accumulation accuracy of Strict. `Quantized`
    /// adds 1-bit-code pruning in front of the strict kernels
    /// (`core::kernels::quant`): labels/centers/energies bit-identical
    /// to Strict, exact-distance bills ≤ Strict's (see `core::kernels`,
    /// "The three numerics tiers").
    pub numerics: NumericsMode,
    /// Center-state refresh strategy (CLI `--refresh`, manifest
    /// `refresh=`). The default resolves `K2M_REFRESH` once per process
    /// and falls back to [`RefreshMode::Incremental`]: after each update
    /// step, only state touching *moved* centers (rows changed bitwise;
    /// the drift vector is already in hand) is recomputed — the center
    /// kNN graph, Elkan's `cc`/`s` table, Hamerly's `s`-table, and the
    /// Quantized tier's center codes — with every unmoved pair reused
    /// bitwise. Labels/centers/energies/iters are bit-identical to
    /// [`RefreshMode::Full`] at any thread count; the counted distance
    /// bill is ≤ Full's (strictly < once any center freezes), with the
    /// avoided evaluations logged to [`OpCounter::refresh_saved`]. This
    /// is an execution strategy, not result provenance, so it is
    /// deliberately **not** persisted in `.k2mm` model files (see
    /// `data::io::save_model`).
    pub refresh: RefreshMode,
    /// Candidate-scan execution strategy (CLI `--scan`, manifest
    /// `scan=`). The default resolves `K2M_SCAN` once per process and
    /// falls back to [`ScanMode::Batched`]: the bound-pruned inner loops
    /// filter candidates on cached bounds first (zero evaluations), then
    /// evaluate the survivors in `TILE`-wide blocks through
    /// [`crate::core::kernels::tile_scan_gated`] — with in-loop
    /// estimator pruning under the Quantized tier. Labels, centers,
    /// energies, iteration counts and center graphs are **bitwise
    /// equal** to [`ScanMode::Gated`] at any thread count and numerics
    /// mode; only the bill moves — at most `TILE − 1` extra evaluations
    /// per scan, billed on [`OpCounter::batch_extra`], keep
    /// `distances − batch_extra ≤` the gated bill. Like `refresh`, an
    /// execution strategy rather than result provenance, so it is not
    /// persisted in `.k2mm` model files.
    pub scan: ScanMode,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            k: 10,
            kn: 10,
            m: 32,
            batch: 100,
            max_iters: 100,
            seed: 0,
            record_trace: true,
            target_energy: None,
            use_bounds: true,
            threads: 0,
            numerics: NumericsMode::from_env(),
            refresh: RefreshMode::from_env(),
            scan: ScanMode::from_env(),
        }
    }
}

/// Per-worker scratch for the batched (gather-then-tile) candidate
/// scans: the phase-1 survivor handles/rows handed to
/// [`crate::core::kernels::tile_scan_gated`], plus a distance buffer
/// for the blocked rescans. Thread-local via [`with_tile_scratch`] —
/// the pool's workers are persistent, so each worker allocates once and
/// reuses across points, iterations and jobs.
#[derive(Default)]
pub(crate) struct TileScratch {
    /// Caller-side candidate handles (neighbour slot, center index, …),
    /// parallel to `ids`.
    pub tags: Vec<u32>,
    /// Matrix rows for the block kernel, parallel to `tags`.
    pub ids: Vec<u32>,
    /// Survivor distances for unguided blocked rescans (Hamerly).
    pub dists: Vec<f32>,
}

thread_local! {
    static TILE_SCRATCH: RefCell<TileScratch> = RefCell::new(TileScratch::default());
}

/// Run `f` with the calling worker's [`TileScratch`]. Acquire once per
/// shard pass and keep it across the shard's points — not once per
/// point — so the `RefCell` bookkeeping stays off the inner loop.
pub(crate) fn with_tile_scratch<R>(f: impl FnOnce(&mut TileScratch) -> R) -> R {
    TILE_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Derive the moved set after an update step: `moved[j]` is true iff
/// center `j`'s row changed **bitwise** (`f32::to_bits` compare, so a
/// `+0.0 → -0.0` flip counts as moved — conservative and therefore
/// always sound). This is the `M` of the incremental refresh layer;
/// it is a deterministic function of the two center matrices, hence
/// thread- and run-to-run invariant whenever the trainer is.
pub(crate) fn moved_rows(old: &Matrix, new: &Matrix) -> Vec<bool> {
    debug_assert_eq!(old.rows(), new.rows());
    (0..old.rows())
        .map(|j| {
            old.row(j)
                .iter()
                .zip(new.row(j))
                .any(|(a, b)| a.to_bits() != b.to_bits())
        })
        .collect()
}

/// Outcome of one clustering run.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub centers: Matrix,
    pub labels: Vec<u32>,
    /// Final energy (uncounted evaluation over all points).
    pub energy: f64,
    /// Iterations executed.
    pub iters: usize,
    /// Converged (assignments stable) before the cap / early stop.
    pub converged: bool,
    /// `(ops, energy)` per iteration when `record_trace`.
    pub trace: Trace,
    /// The serializable train/serve artifact assembled from the final
    /// centers (same rows as `centers`, bit for bit) — see
    /// [`ClusterModel`] and [`finish_run`].
    pub model: ClusterModel,
}

/// The one tail every trainer finishes through: assemble the
/// [`ClusterModel`] from the final centers and package the result.
/// `graph` is a trainer's donated in-loop kn-NN graph — pass it **only**
/// when it was built from exactly the returned centers. k²-means now
/// donates on **every** exit arm (its [`crate::knn::KnnGraphCache`] is
/// kept current through the max_iters fallthrough too), so the `None` →
/// post-hoc-rebuild arm exists solely for the six other trainers, which
/// never maintain a center graph in-loop. Either way the model assembly
/// is *uncounted* (packaging, not part of the method's op bill), so the
/// paper's tables are unchanged.
pub(crate) fn finish_run(
    centers: Matrix,
    labels: Vec<u32>,
    energy: f64,
    iters: usize,
    converged: bool,
    trace: Trace,
    graph: Option<NeighborGraph>,
    cfg: &Config,
) -> KmeansResult {
    let model = ClusterModel::from_training(centers.clone(), graph, cfg);
    KmeansResult { centers, labels, energy, iters, converged, trace, model }
}

/// The Quantized tier's in-loop side-structure: packed codes for every
/// point and for the current centers, sharing one centering vector `μ`
/// (the **initial** centers' column means — fixed for the whole run;
/// any fixed `μ` is sound, it only moves prune power, and freezing it
/// means point codes pack exactly once). Built only when
/// `cfg.numerics == Quantized` (`None` otherwise, and the `*_q`
/// dispatch methods degrade to the plain scans), and refreshed after
/// every center update. Packing bills [`OpCounter::packs`] — off the
/// paper's op total.
pub(crate) struct QuantState {
    points: QuantizedCodes,
    centers: QuantizedCodes,
    mu: Vec<f32>,
    refresh: RefreshMode,
}

impl QuantState {
    /// Pack points and initial centers — `Some` iff the config selects
    /// the Quantized tier.
    pub(crate) fn new(
        x: &Matrix,
        centers: &Matrix,
        cfg: &Config,
        c: &mut OpCounter,
    ) -> Option<QuantState> {
        if cfg.numerics != NumericsMode::Quantized {
            return None;
        }
        let mu = quant::column_means(centers);
        c.packs += (x.rows() + centers.rows()) as u64;
        Some(QuantState {
            points: QuantizedCodes::pack(x, &mu),
            centers: QuantizedCodes::pack(centers, &mu),
            mu,
            refresh: cfg.refresh,
        })
    }

    /// Re-pack the center codes after an update step. `μ` stays frozen
    /// for the whole run (the chosen policy: any fixed `μ` is sound —
    /// it only moves prune power — and freezing it is exactly what makes
    /// an unmoved center's code bitwise reusable). `moved` is the
    /// bitwise moved set ([`moved_rows`]); under
    /// [`RefreshMode::Incremental`] only those rows repack
    /// ([`QuantizedCodes::repack_row`]), billing `|M|` instead of `k`
    /// [`OpCounter::packs`] — a `+0.0 → -0.0`-only change is safely
    /// "unmoved" even under the drift-derived set, because the sign bit
    /// of a packed code is `v >= 0.0`, which both zeros satisfy. `None`
    /// (or Full mode) repacks every center.
    pub(crate) fn refresh(
        &mut self,
        centers: &Matrix,
        moved: Option<&[bool]>,
        c: &mut OpCounter,
    ) {
        match (self.refresh, moved) {
            (RefreshMode::Incremental, Some(moved)) => {
                debug_assert_eq!(moved.len(), centers.rows());
                for (j, _) in moved.iter().enumerate().filter(|(_, &b)| b) {
                    self.centers.repack_row(j, centers.row(j));
                    c.packs += 1;
                }
            }
            _ => {
                c.packs += centers.rows() as u64;
                self.centers = QuantizedCodes::pack(centers, &self.mu);
            }
        }
    }

    /// The (query = point `i`, candidates = current centers) pairing a
    /// pruned scan consumes.
    pub(crate) fn pair(&self, i: usize) -> QuantPair<'_> {
        QuantPair { query: self.points.row_q(i), cands: &self.centers }
    }
}

/// One shard's slices of the bound-based per-point state shared by the
/// Elkan-family accelerators: labels, the upper bound `u`, and a
/// lower-bound row of `width` entries per point (Elkan: `k`, Yinyang:
/// `ngroups`, Hamerly: `1`). k²-means carries an extra `lb_next` array
/// for its graph remap, so it keeps its own shard type.
pub(crate) struct BoundShard<'a> {
    pub labels: &'a mut [u32],
    pub u: &'a mut [f32],
    pub lb: &'a mut [f32],
}

/// Run `pass(shard_start, shard, shard_counter)` over contiguous point
/// shards on [`crate::coordinator::pool::sharded_reduce`], summing the
/// per-shard returns (the `changed` tallies); the engine merges the
/// per-shard counters in shard order and runs a single shard inline
/// (the serial path — identical instructions, no spawn). Shared by
/// Elkan, Hamerly and Yinyang so their shard layouts cannot drift.
pub(crate) fn sharded_bound_pass<F>(
    threads: usize,
    width: usize,
    labels: &mut [u32],
    u: &mut [f32],
    lb: &mut [f32],
    counter: &mut OpCounter,
    pass: F,
) -> usize
where
    F: Fn(usize, BoundShard<'_>, &mut OpCounter) -> usize + Sync,
{
    let chunk = pool::chunk_len(labels.len(), threads);
    let shards = labels
        .chunks_mut(chunk)
        .zip(u.chunks_mut(chunk))
        .zip(lb.chunks_mut(chunk * width))
        .map(|((labels, u), lb)| BoundShard { labels, u, lb });
    pool::sharded_reduce(shards, counter, |si, st, ctr| pass(si * chunk, st, ctr))
        .into_iter()
        .sum()
}

/// The k-means update step: per-cluster means. Empty clusters keep their
/// previous center (the classical convention; the coordinator's
/// experiments never hinge on re-seeding policy). Counts one vector
/// addition per point (the accumulation), matching O(nd) in paper §2.
///
/// Serial entry point — see [`update_means_threaded`] for the sharded
/// variant the execution engine uses (bit-identical output).
pub fn update_means(
    x: &Matrix,
    labels: &[u32],
    old: &Matrix,
    counter: &mut OpCounter,
) -> (Matrix, Vec<u32>) {
    update_means_threaded(x, labels, old, counter, 1)
}

/// Sharded update step. Parallelism is over **clusters**, not points:
/// each worker owns a contiguous block of clusters and scans the whole
/// label array, accumulating only the points of its block. Every
/// cluster's f64 accumulation therefore visits its members in global
/// point order — exactly the serial order — so the resulting centers
/// are **bit-identical for any thread count** (point-sharded partial
/// sums would reassociate the f64 additions and drift between thread
/// counts). The extra cost is one label comparison per (worker, point),
/// negligible next to the `O(nd)` row additions.
pub fn update_means_threaded(
    x: &Matrix,
    labels: &[u32],
    old: &Matrix,
    counter: &mut OpCounter,
    threads: usize,
) -> (Matrix, Vec<u32>) {
    let k = old.rows();
    let d = x.cols();
    let threads = pool::resolve_threads(threads, labels.len()).min(k.max(1));
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u32; k];

    // Each shard owns a contiguous block of clusters (`kc` rows of
    // `sums` / slots of `counts`) and scans the whole label array,
    // accumulating only its own block's points — in global point order,
    // which is what makes the f64 sums bit-identical to serial. A single
    // shard (serial) runs inline; the block test is then always true.
    let kc = pool::chunk_len(k, threads);
    // `.max(1)`: chunk sizes must be nonzero even for a zero-width
    // matrix (d == 0), where `sums` is empty and no shard runs.
    pool::sharded_reduce(
        sums.chunks_mut((kc * d).max(1)).zip(counts.chunks_mut(kc)),
        counter,
        |si, (sum_chunk, count_chunk): (&mut [f64], &mut [u32]), ctr| {
            let j0 = si * kc;
            let owned = count_chunk.len();
            for (i, &l) in labels.iter().enumerate() {
                let l = l as usize;
                debug_assert!(l < k);
                if l < j0 || l >= j0 + owned {
                    continue;
                }
                let acc = &mut sum_chunk[(l - j0) * d..(l - j0 + 1) * d];
                for (a, &v) in acc.iter_mut().zip(x.row(i)) {
                    *a += v as f64;
                }
                count_chunk[l - j0] += 1;
                ctr.additions += 1;
            }
        },
    );

    let mut centers = Matrix::zeros(k, d);
    for j in 0..k {
        let row = centers.row_mut(j);
        if counts[j] > 0 {
            let inv = 1.0 / counts[j] as f64;
            for (r, &s) in row.iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                *r = (s * inv) as f32;
            }
        } else {
            row.copy_from_slice(old.row(j));
        }
    }
    (centers, counts)
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::random_matrix;

    #[test]
    fn update_means_computes_means_and_counts() {
        let x = Matrix::from_vec(vec![0., 0., 2., 0., 10., 10., 12., 14.], 4, 2);
        let old = Matrix::zeros(2, 2);
        let labels = vec![0, 0, 1, 1];
        let mut c = OpCounter::default();
        let (centers, counts) = update_means(&x, &labels, &old, &mut c);
        assert_eq!(centers.row(0), &[1.0, 0.0]);
        assert_eq!(centers.row(1), &[11.0, 12.0]);
        assert_eq!(counts, vec![2, 2]);
        assert_eq!(c.additions, 4); // one per point
    }

    #[test]
    fn empty_cluster_keeps_old_center() {
        let x = random_matrix(5, 3, 1);
        let mut old = Matrix::zeros(3, 3);
        old.row_mut(2).copy_from_slice(&[7.0, 8.0, 9.0]);
        let labels = vec![0, 0, 1, 1, 0];
        let mut c = OpCounter::default();
        let (centers, counts) = update_means(&x, &labels, &old, &mut c);
        assert_eq!(counts[2], 0);
        assert_eq!(centers.row(2), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn config_default_matches_paper_protocol() {
        let cfg = Config::default();
        assert_eq!(cfg.batch, 100);
        assert_eq!(cfg.max_iters, 100);
        assert_eq!(cfg.threads, 0); // auto
    }

    #[test]
    fn threaded_update_bit_identical_to_serial() {
        let k = 13;
        let x = random_matrix(500, 7, 42);
        let old = random_matrix(k, 7, 43);
        // Deterministic, imbalanced labels with one empty cluster (12).
        let labels: Vec<u32> = (0..500usize).map(|i| ((i * 7 + 3) % (k - 1)) as u32).collect();
        let mut c0 = OpCounter::default();
        let (want_centers, want_counts) = update_means(&x, &labels, &old, &mut c0);
        for threads in [2usize, 3, 5, 13, 64] {
            let mut c = OpCounter::default();
            let (centers, counts) =
                update_means_threaded(&x, &labels, &old, &mut c, threads);
            assert_eq!(centers, want_centers, "threads={threads}");
            assert_eq!(counts, want_counts, "threads={threads}");
            assert_eq!(c.additions, c0.additions, "threads={threads}");
        }
    }
}
