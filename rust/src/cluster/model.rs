//! The serializable train/serve artifact: everything the query-time
//! bounded scan needs, packaged once at the end of every training run.
//!
//! Training (the seven algorithms in [`crate::cluster`]) is a *writer*
//! of [`ClusterModel`]s; the resident query service
//! ([`crate::runtime::serve`]) is a *reader*. The artifact carries the
//! final centers, the kn-NN center graph the paper's bounded scan walks
//! (k²-means donates the graph it already built when it is current;
//! every other algorithm builds it once post-hoc), the per-center
//! squared norms the engine's norm-trick assignment reuses, and the
//! [`Config`] provenance that produced it — enough to answer
//! assignment queries, audit a saved model, or resume serving after a
//! round-trip through [`ClusterModel::save`] / [`ClusterModel::load`].

use std::path::Path;
use std::sync::OnceLock;

use anyhow::{bail, Result};

use crate::core::kernels::quant::{self, QuantizedCodes};
use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::knn::{knn_graph_mode, NeighborGraph};

use super::common::Config;

/// A trained clustering model: the immutable artifact every algorithm's
/// [`super::KmeansResult`] now carries, and the unit of exchange between
/// training, serving, and the on-disk format in [`crate::data::io`].
///
/// # Contract
///
/// * `centers` is the `k × d` matrix of **final** centers — the same
///   rows as `KmeansResult::centers`, bit for bit.
/// * `graph` is the exact kn-NN graph **of those centers** (self at
///   slot 0, squared distances, rows sorted ascending after slot 0 —
///   the [`NeighborGraph`] invariants). Never stale: a trainer's
///   in-loop graph is donated only when it was built from the returned
///   centers, otherwise the graph is rebuilt post-hoc.
/// * `norms[j]` is the squared norm `‖c_j‖²` computed on
///   `config.numerics` — the cached half of the engine's norm-trick
///   assignment (`runtime::engine::RustEngine::assign_with_model`).
/// * `config` is the *provenance* — the exact [`Config`] the trainer
///   ran under. Serving defaults (threads, numerics tier) resolve from
///   it, and a loaded model reports how it was trained.
///
/// The post-hoc graph build is **uncounted** (a throwaway
/// [`OpCounter`]): model assembly is packaging, not part of a method's
/// measured op bill, so the paper's tables are unchanged by this
/// artifact existing.
#[derive(Clone, Debug)]
pub struct ClusterModel {
    centers: Matrix,
    graph: NeighborGraph,
    norms: Vec<f32>,
    config: Config,
    /// Quantized-tier 1-bit center codes (`μ` = the centers' own column
    /// means — fully determined by `centers`, so a lazy rebuild is
    /// bit-identical to a saved section). Populated eagerly when the
    /// config trains on the Quantized tier, seeded by the `.k2mm` loader
    /// when a codes section is present (after validating it against a
    /// rebuild), and rebuilt on first use otherwise — v1 files without
    /// the section keep serving identically.
    codes: OnceLock<QuantizedCodes>,
}

impl ClusterModel {
    /// Assemble the artifact at the end of a training run. `donated` is
    /// a trainer's in-loop graph (k²-means' break paths); it is used
    /// only when its shape matches what the final centers require —
    /// anything else triggers a fresh (uncounted) [`knn_graph_mode`]
    /// build on the config's threads and numerics tier.
    pub(crate) fn from_training(
        centers: Matrix,
        donated: Option<NeighborGraph>,
        cfg: &Config,
    ) -> ClusterModel {
        let k = centers.rows();
        let kn = cfg.kn.clamp(1, k.max(1));
        let graph = match donated {
            Some(g) if g.k() == k && g.kn() == kn => g,
            _ => knn_graph_mode(
                &centers,
                kn,
                &mut OpCounter::default(),
                cfg.threads,
                cfg.numerics,
            ),
        };
        let norms = (0..k).map(|j| cfg.numerics.norm2_raw(centers.row(j))).collect();
        let model =
            ClusterModel { centers, graph, norms, config: cfg.clone(), codes: OnceLock::new() };
        if cfg.numerics == NumericsMode::Quantized {
            // Serving on this tier will want the codes immediately; pack
            // them now (uncounted, like the graph and norms) rather than
            // on the first query.
            let _ = model.quant_codes();
        }
        model
    }

    /// Build a model directly from a center table (no training run) —
    /// the entry point for tests, benches, and external center sets.
    pub fn build(centers: Matrix, cfg: &Config) -> ClusterModel {
        ClusterModel::from_training(centers, None, cfg)
    }

    /// Reassemble a model from its serialized parts (the
    /// [`crate::data::io::load_model`] path), validating cross-part
    /// consistency: the graph must be over exactly these `k` centers
    /// and `norms` must have one entry per center. The graph's own
    /// structural invariants are validated by
    /// [`NeighborGraph::from_parts`] before this is called.
    /// `codes`, when present (a `.k2mm` v2 codes section), must be over
    /// exactly these centers — the loader has already verified it is
    /// bit-identical to a rebuild; the shape check here is the last
    /// line of defense for other callers.
    pub fn from_parts(
        centers: Matrix,
        graph: NeighborGraph,
        norms: Vec<f32>,
        config: Config,
        codes: Option<QuantizedCodes>,
    ) -> Result<ClusterModel> {
        if graph.k() != centers.rows() {
            bail!(
                "model: graph is over {} centers but the center table has {} rows",
                graph.k(),
                centers.rows()
            );
        }
        if norms.len() != centers.rows() {
            bail!(
                "model: {} norms for {} centers",
                norms.len(),
                centers.rows()
            );
        }
        let slot = OnceLock::new();
        if let Some(codes) = codes {
            if codes.rows() != centers.rows() || codes.dim() != centers.cols() {
                bail!(
                    "model: codes are {}x{} but the center table is {}x{}",
                    codes.rows(),
                    codes.dim(),
                    centers.rows(),
                    centers.cols()
                );
            }
            let _ = slot.set(codes);
        }
        Ok(ClusterModel { centers, graph, norms, config, codes: slot })
    }

    /// The `k × d` table of final centers.
    pub fn centers(&self) -> &Matrix {
        &self.centers
    }

    /// The exact kn-NN graph over [`ClusterModel::centers`].
    pub fn graph(&self) -> &NeighborGraph {
        &self.graph
    }

    /// Per-center squared norms `‖c_j‖²` on the config's numerics tier.
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Quantized 1-bit codes over [`ClusterModel::centers`] (`μ` = the
    /// centers' column means). Built on first use when the model was
    /// trained on another tier or loaded from a v1 file without a codes
    /// section — the rebuild is deterministic, so a lazily-built model
    /// serves bit-identically to one whose codes travelled in the file.
    pub fn quant_codes(&self) -> &QuantizedCodes {
        self.codes.get_or_init(|| {
            let mu = quant::column_means(&self.centers);
            QuantizedCodes::pack(&self.centers, &mu)
        })
    }

    /// Whether codes are already materialized (saved section or prior
    /// use) — the `.k2mm` writer serializes only materialized codes, so
    /// non-Quantized models keep their v1-shaped (section-free) layout.
    pub fn has_codes(&self) -> bool {
        self.codes.get().is_some()
    }

    /// The training provenance: the exact [`Config`] the trainer ran
    /// under (serving resolves its default threads/numerics from here).
    pub fn config(&self) -> &Config {
        &self.config
    }

    /// Number of centers.
    pub fn k(&self) -> usize {
        self.centers.rows()
    }

    /// Dimensionality.
    pub fn d(&self) -> usize {
        self.centers.cols()
    }

    /// Neighbourhood width of the center graph (post-clamp: `<= k`).
    pub fn kn(&self) -> usize {
        self.graph.kn()
    }

    /// Write the versioned binary format — see
    /// [`crate::data::io::save_model`] for the layout.
    pub fn save(&self, path: &Path) -> Result<()> {
        crate::data::io::save_model(self, path)
    }

    /// Load a model written by [`ClusterModel::save`], re-validating
    /// every structural invariant (a hand-edited file cannot produce a
    /// model whose "exact" serving answers would silently be wrong).
    pub fn load(path: &Path) -> Result<ClusterModel> {
        crate::data::io::load_model(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::NumericsMode;
    use crate::knn::knn_graph;
    use crate::testing::random_matrix;

    fn cfg(k: usize, kn: usize) -> Config {
        Config { k, kn, numerics: NumericsMode::Strict, ..Default::default() }
    }

    #[test]
    fn build_assembles_graph_and_norms() {
        let c = random_matrix(12, 5, 1);
        let m = ClusterModel::build(c.clone(), &cfg(12, 4));
        assert_eq!((m.k(), m.d(), m.kn()), (12, 5, 4));
        // Graph matches a direct strict build over the same centers.
        let want = knn_graph(&c, 4, &mut OpCounter::default());
        assert_eq!(m.graph().nbrs_flat(), want.nbrs_flat());
        assert_eq!(m.graph().dists_flat(), want.dists_flat());
        // Norms are the strict-tier squared norms.
        for j in 0..12 {
            assert_eq!(
                m.norms()[j].to_bits(),
                NumericsMode::Strict.norm2_raw(c.row(j)).to_bits()
            );
        }
    }

    #[test]
    fn kn_is_clamped_to_k() {
        let c = random_matrix(3, 4, 2);
        let m = ClusterModel::build(c, &cfg(3, 50));
        assert_eq!(m.kn(), 3);
    }

    #[test]
    fn stale_donation_is_rejected_and_rebuilt() {
        // A donated graph whose shape disagrees with the centers must be
        // discarded in favour of a fresh build.
        let old = random_matrix(8, 3, 3);
        let donated = knn_graph(&old, 2, &mut OpCounter::default());
        let c = random_matrix(10, 3, 4);
        let m = ClusterModel::from_training(c.clone(), Some(donated), &cfg(10, 4));
        let want = knn_graph(&c, 4, &mut OpCounter::default());
        assert_eq!(m.graph().nbrs_flat(), want.nbrs_flat());
    }

    #[test]
    fn from_parts_rejects_inconsistent_shapes() {
        let c = random_matrix(6, 3, 5);
        let g = knn_graph(&c, 3, &mut OpCounter::default());
        let norms = vec![0.0f32; 6];
        // Wrong norm count.
        assert!(ClusterModel::from_parts(
            c.clone(),
            g.clone(),
            vec![0.0; 5],
            cfg(6, 3),
            None
        )
        .is_err());
        // Graph over a different number of centers.
        let small = random_matrix(4, 3, 6);
        let gs = knn_graph(&small, 2, &mut OpCounter::default());
        assert!(ClusterModel::from_parts(c.clone(), gs, norms.clone(), cfg(6, 3), None).is_err());
        // Codes over the wrong shape.
        let other = random_matrix(5, 3, 7);
        let bad = QuantizedCodes::pack(&other, &quant::column_means(&other));
        assert!(ClusterModel::from_parts(c, g, norms, cfg(6, 3), Some(bad)).is_err());
    }

    #[test]
    fn quant_codes_lazy_rebuild_matches_eager_training_codes() {
        let c = random_matrix(9, 17, 8);
        let quantized = Config {
            k: 9,
            kn: 3,
            numerics: NumericsMode::Quantized,
            ..Default::default()
        };
        let eager = ClusterModel::build(c.clone(), &quantized);
        assert!(eager.has_codes());
        // Strict-trained model: codes absent until first use, then
        // bit-identical to the eager build (same centers, same μ rule).
        let lazy = ClusterModel::build(c, &cfg(9, 3));
        assert!(!lazy.has_codes());
        assert_eq!(lazy.quant_codes(), eager.quant_codes());
        assert!(lazy.has_codes());
    }
}
