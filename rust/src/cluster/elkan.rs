//! Elkan's exact accelerated k-means (ICML'03): triangle-inequality upper
//! and lower bounds skip distance computations while producing *exactly*
//! Lloyd's trajectory. Memory O(nk) lower bounds + O(k²) center distances
//! (paper Table 2); the first iteration is a full Lloyd pass and later
//! iterations get progressively cheaper — the behaviour the paper
//! contrasts k²-means against.
//!
//! Runs on the sharded execution engine: the bootstrap, bounded
//! assignment and drift-shift passes shard over contiguous point ranges
//! (`cfg.threads`; each point touches only its own `labels`/`u`/`lb`
//! slots plus shared immutable state, so labels are bit-identical for
//! any thread count); the update step is the cluster-sharded
//! [`update_means_threaded`].

use super::common::{
    finish_run, moved_rows, sharded_bound_pass, update_means_threaded, with_tile_scratch,
    BoundShard, Config, KmeansResult, QuantState,
};
use crate::coordinator::pool;
use crate::core::kernels::{quant, tile_scan_gated};
use crate::core::{kernels, Matrix, OpCounter, RefreshMode, ScanMode};
use crate::init::InitResult;
use crate::metrics::{energy, Trace};

/// Per-point fold state the batched step-3 scan threads through
/// [`tile_scan_gated`]: the running best plus everything the replayed
/// gate reads — the point's lb row and the center-center table (the cc
/// prune indexes the *current* best's row, Elkan's moving `c(x)`).
struct ElkanFold<'a> {
    best: (u32, f32),
    lb_row: &'a mut [f32],
    cc: &'a [f32],
    k: usize,
}

/// Run Elkan's algorithm. Produces identical assignments to [`fn@super::lloyd`]
/// from the same initialization (verified by property tests).
pub fn elkan(
    x: &Matrix,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    let n = x.rows();
    let k = init.k();
    let threads = pool::resolve_threads(cfg.threads, n);
    let nm = cfg.numerics;
    let mut centers = init.centers.clone();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;

    // Initial full assignment, establishing bounds.
    // u[i]  — upper bound on d(x_i, c_{a(i)})    (plain distance)
    // lb[i*k + j] — lower bound on d(x_i, c_j)
    let mut labels = vec![0u32; n];
    let mut u = vec![0.0f32; n];
    let mut lb = vec![0.0f32; n * k];
    {
        let centers_ref = &centers;
        sharded_bound_pass(
            threads,
            k,
            &mut labels,
            &mut u,
            &mut lb,
            counter,
            |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                for off in 0..st.labels.len() {
                    let xi = x.row(start + off);
                    // Blocked full scan straight into the point's lb
                    // row, then the earliest-min argmin — identical
                    // values and winner to the scalar loop.
                    let lb_row = &mut st.lb[off * k..(off + 1) * k];
                    nm.dist_rows(xi, centers_ref, 0, lb_row, ctr);
                    let (j, dist) = kernels::argmin(lb_row);
                    st.labels[off] = j as u32;
                    st.u[off] = dist;
                }
                0
            },
        );
    }

    // Center-center **plain**-distance table, persistent across
    // iterations so the moved-set refresh can reuse unmoved pairs
    // bitwise; `moved` is the bitwise moved set of the previous update
    // step (None on the first iteration — always a full build).
    let mut cc = vec![0.0f32; k * k];
    let mut s = vec![0.0f32; k]; // half distance to nearest other center
    let mut moved: Option<Vec<bool>> = None;

    // Center codes for the batched scan's in-loop estimator prune
    // (`QuantState::new` is `None` off the Quantized tier). The gated
    // scan interleaves each evaluation with the bound it tightens, so
    // it never holds a gathered survivor list to estimate — no codes.
    let mut qs = if cfg.scan == ScanMode::Batched {
        QuantState::new(x, &centers, cfg, counter)
    } else {
        None
    };

    for it in 0..cfg.max_iters {
        iters = it + 1;

        // Step 1: center-center distances and s(c). Full build: k(k-1)/2
        // counted, upper-triangle tiles. Incremental (`cfg.refresh`,
        // default): only rows+columns of centers in the moved set M are
        // recomputed — each such entry is the same per-pair squared
        // kernel plus the same per-entry `.sqrt()` the blocked build
        // applies, so the refreshed table is bitwise identical to a full
        // rebuild — billing `C(k,2) - C(k-|M|,2)` with the reused pairs
        // logged to `refresh_saved`.
        match (cfg.refresh, moved.as_deref()) {
            (RefreshMode::Incremental, Some(mv)) => {
                let m = mv.iter().filter(|&&b| b).count();
                counter.refresh_saved +=
                    ((k - m) * (k - m).saturating_sub(1) / 2) as u64;
                let mut row = vec![0.0f32; k];
                let mut prior_moved = 0u64;
                for j in 0..k {
                    if !mv[j] {
                        continue;
                    }
                    nm.sqdist_rows_raw(centers.row(j), &centers, 0, &mut row);
                    // Pairs with >= 1 moved endpoint billed once each
                    // (pairs among already-recomputed moved rows were
                    // charged by the earlier row): Σ = C(k,2)-C(k-m,2).
                    counter.distances += (k as u64 - 1) - prior_moved;
                    prior_moved += 1;
                    row[j] = 0.0;
                    for (i, &sq) in row.iter().enumerate() {
                        let plain = sq.sqrt();
                        cc[j * k + i] = plain;
                        if i != j {
                            cc[i * k + j] = plain;
                        }
                    }
                }
            }
            _ => nm.pairwise_dist_block(&centers, &mut cc, counter),
        }
        for j in 0..k {
            let mut m = f32::INFINITY;
            for j2 in 0..k {
                if j2 != j {
                    m = m.min(cc[j * k + j2]);
                }
            }
            s[j] = 0.5 * m;
        }

        // Steps 2–3: the bounded assignment pass, sharded over points
        // (all reads are shared immutable `centers`/`cc`/`s` or the
        // point's own slots — labels bit-identical for any threads).
        let changed = {
            let centers_ref = &centers;
            let cc_ref = &cc;
            let s_ref = &s;
            if cfg.scan == ScanMode::Gated {
                sharded_bound_pass(
                    threads,
                    k,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    counter,
                    |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                        let mut changed = 0usize;
                        for off in 0..st.labels.len() {
                            let a = st.labels[off] as usize;
                            // Step 2: u(x) <= s(c_a) => nearest center
                            // unchanged.
                            if st.u[off] <= s_ref[a] {
                                continue;
                            }
                            let xi = x.row(start + off);
                            let mut u_tight = false;
                            let mut best = (a as u32, st.u[off]);
                            for j in 0..k {
                                if j == best.0 as usize {
                                    continue;
                                }
                                // Step 3 conditions: candidate j can only win
                                // if both the lower bound and the
                                // center-center bound allow it. The cc prune
                                // uses the *current* assignment best.0
                                // (Elkan's c(x), which moves during the pass).
                                if best.1 <= st.lb[off * k + j]
                                    || best.1 <= 0.5 * cc_ref[best.0 as usize * k + j]
                                {
                                    continue;
                                }
                                // 3a: make u tight once.
                                if !u_tight {
                                    let dist = nm.dist_one(xi, centers_ref.row(a), ctr);
                                    st.lb[off * k + a] = dist;
                                    best.1 = dist;
                                    u_tight = true;
                                    if best.1 <= st.lb[off * k + j]
                                        || best.1 <= 0.5 * cc_ref[best.0 as usize * k + j]
                                    {
                                        continue;
                                    }
                                }
                                // 3b: compute the candidate distance, gated
                                // on the bounds above (the batched twin
                                // gathers these survivors into tiles
                                // instead).
                                let dist = nm.dist_one(xi, centers_ref.row(j), ctr);
                                st.lb[off * k + j] = dist;
                                if dist < best.1 {
                                    best = (j as u32, dist);
                                }
                            }
                            st.u[off] = best.1;
                            if best.0 != st.labels[off] {
                                st.labels[off] = best.0;
                                changed += 1;
                            }
                        }
                        changed
                    },
                )
            } else {
                // `ScanMode::Batched`: same gates, two phases plus a
                // bounds-only trigger walk. The walk replays the
                // untightened gate in slot order to find the first
                // candidate the gated loop would have admitted — that
                // is exactly where it spends its lazy 3a tighten, so
                // a point with no trigger spends nothing here either.
                // After tightening, phase 1 keeps every candidate from
                // the trigger onward that the static bound `d_a`
                // cannot prune — a superset of the gated loop's
                // evaluations, whose running best only shrinks from
                // `d_a`. Under the Quantized tier the estimator then
                // drops survivors certified farther than `d_a`
                // (certified non-improvers cannot change the strict-<
                // argmin), and phase 2 hands the rest to
                // [`tile_scan_gated`], which re-gathers under the live
                // gate and replays it per candidate.
                let qs_ref = qs.as_ref();
                sharded_bound_pass(
                    threads,
                    k,
                    &mut labels,
                    &mut u,
                    &mut lb,
                    counter,
                    |start, st: BoundShard<'_>, ctr: &mut OpCounter| {
                        with_tile_scratch(|scratch| {
                            let mut changed = 0usize;
                            for off in 0..st.labels.len() {
                                let a = st.labels[off] as usize;
                                // Step 2: u(x) <= s(c_a) => nearest center
                                // unchanged.
                                if st.u[off] <= s_ref[a] {
                                    continue;
                                }
                                let u0 = st.u[off];
                                let lb_row = &mut st.lb[off * k..(off + 1) * k];
                                let Some(j0) = (0..k).find(|&j| {
                                    j != a
                                        && u0 > lb_row[j]
                                        && u0 > 0.5 * cc_ref[a * k + j]
                                }) else {
                                    // No trigger: the gated loop would
                                    // evaluate nothing for this point.
                                    continue;
                                };
                                let xi = x.row(start + off);
                                // 3a: tighten once (same bill as gated).
                                let d_a = nm.dist_one(xi, centers_ref.row(a), ctr);
                                lb_row[a] = d_a;
                                // Phase 1: survivors of the static bound.
                                scratch.tags.clear();
                                scratch.ids.clear();
                                for j in j0..k {
                                    if j != a && d_a > lb_row[j] {
                                        scratch.tags.push(j as u32);
                                        scratch.ids.push(j as u32);
                                    }
                                }
                                if let Some(q) = qs_ref {
                                    let qp = q.pair(start + off);
                                    quant::prune_survivors(
                                        qp.query,
                                        qp.cands,
                                        &mut scratch.ids,
                                        Some(&mut scratch.tags),
                                        quant::plain_threshold_sq(d_a),
                                        ctr,
                                    );
                                }
                                // Phase 2: gather-and-tile, replaying the
                                // full dynamic gate (lb + cc row of the
                                // *current* best) between folds.
                                let mut fold = ElkanFold {
                                    best: (a as u32, d_a),
                                    lb_row,
                                    cc: cc_ref,
                                    k,
                                };
                                tile_scan_gated(
                                    nm,
                                    xi,
                                    centers_ref,
                                    &scratch.tags,
                                    &scratch.ids,
                                    &mut fold,
                                    ctr,
                                    |f, j| {
                                        let j = j as usize;
                                        j != f.best.0 as usize
                                            && f.best.1 > f.lb_row[j]
                                            && f.best.1
                                                > 0.5 * f.cc[f.best.0 as usize * f.k + j]
                                    },
                                    |f, j, dist| {
                                        let j = j as usize;
                                        f.lb_row[j] = dist;
                                        if dist < f.best.1 {
                                            f.best = (j as u32, dist);
                                        }
                                    },
                                );
                                let best = fold.best;
                                st.u[off] = best.1;
                                if best.0 != st.labels[off] {
                                    st.labels[off] = best.0;
                                    changed += 1;
                                }
                            }
                            changed
                        })
                    },
                )
            }
        };

        // Trace + termination (uncounted measurement).
        let e = energy(x, &centers, &labels);
        if cfg.record_trace {
            trace.push(counter.total(), e, it);
        }
        if changed == 0 && it > 0 {
            converged = true;
            break;
        }
        if cfg.target_energy.is_some_and(|t| e <= t) {
            break;
        }

        // Steps 4–7: move centers (cluster-sharded update), then shift
        // bounds by the drift (sharded over points).
        let (new_centers, _) =
            update_means_threaded(x, &labels, &centers, counter, cfg.threads);
        let mut drift = vec![0.0f32; k];
        nm.dist_rowwise(&centers, &new_centers, &mut drift, counter);
        {
            let drift_ref = &drift;
            sharded_bound_pass(
                threads,
                k,
                &mut labels,
                &mut u,
                &mut lb,
                counter,
                |_start, st: BoundShard<'_>, _ctr: &mut OpCounter| {
                    for off in 0..st.labels.len() {
                        st.u[off] += drift_ref[st.labels[off] as usize];
                        let row = &mut st.lb[off * k..(off + 1) * k];
                        for (l, &dj) in row.iter_mut().zip(drift_ref) {
                            *l = (*l - dj).max(0.0);
                        }
                    }
                    0
                },
            );
        }
        // Bitwise moved set for the next iteration's cc refresh (exact
        // row compare — f32 drift can underflow to 0.0 for a center
        // that moved, so only the bitwise test is unconditionally
        // sound for a bitwise reuse contract).
        moved = Some(moved_rows(&centers, &new_centers));
        centers = new_centers;
        if let Some(q) = qs.as_mut() {
            q.refresh(&centers, moved.as_deref(), counter);
        }
    }

    let final_e = energy(x, &centers, &labels);
    finish_run(centers, labels, final_e, iters, converged, trace, None, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::lloyd;
    use crate::init::{kmeans_pp, random_init};
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn matches_lloyd_trajectory_exactly() {
        // Same init => same final labels and (near-)identical energy.
        let x = random_matrix(250, 12, 1);
        let init = random_init(&x, 15, 2);
        let cfg = Config { k: 15, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let rl = lloyd(&x, &init, &cfg, &mut c1);
        let re = elkan(&x, &init, &cfg, &mut c2);
        assert_eq!(rl.labels, re.labels, "assignments diverged");
        assert!((rl.energy - re.energy).abs() <= 1e-4 * (1.0 + rl.energy));
    }

    #[test]
    fn uses_fewer_distances_than_lloyd() {
        let (x, _) = blobs(400, 8, 16, 12.0, 3);
        let init = kmeans_pp(&x, 8, &mut OpCounter::default(), 4);
        let cfg = Config { k: 8, ..Default::default() };
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let _ = lloyd(&x, &init, &cfg, &mut c1);
        let _ = elkan(&x, &init, &cfg, &mut c2);
        assert!(
            c2.distances < c1.distances,
            "Elkan {} >= Lloyd {}",
            c2.distances,
            c1.distances
        );
    }

    #[test]
    fn energy_monotone_along_trace() {
        let x = random_matrix(200, 6, 5);
        let init = random_init(&x, 12, 6);
        let mut c = OpCounter::default();
        let r = elkan(&x, &init, &Config { k: 12, ..Default::default() }, &mut c);
        for w in r.trace.points.windows(2) {
            assert!(w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()));
        }
    }

    #[test]
    fn sharded_matches_serial_bit_for_bit() {
        let (x, _) = blobs(500, 10, 14, 10.0, 11);
        let init = random_init(&x, 14, 12);
        let mut c1 = OpCounter::default();
        let want =
            elkan(&x, &init, &Config { k: 14, threads: 1, ..Default::default() }, &mut c1);
        for threads in [2usize, 5, 19] {
            let mut c2 = OpCounter::default();
            let got =
                elkan(&x, &init, &Config { k: 14, threads, ..Default::default() }, &mut c2);
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.iters, want.iters, "threads={threads}");
            assert_eq!(c1.distances, c2.distances, "threads={threads}");
        }
    }

    #[test]
    fn converges_and_reports() {
        let (x, _) = blobs(150, 5, 8, 30.0, 7);
        let init = kmeans_pp(&x, 5, &mut OpCounter::default(), 8);
        let mut c = OpCounter::default();
        let r = elkan(&x, &init, &Config { k: 5, ..Default::default() }, &mut c);
        assert!(r.converged);
        assert!(r.iters < 100);
    }
}
