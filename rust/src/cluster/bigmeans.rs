//! Big-means: the decomposition heuristic for minimum-sum-of-squares
//! clustering over data too large (or too slow) to iterate in full —
//! solve many fixed-size **sample subproblems**, each warm-started from
//! the best solution found so far, and keep the lowest-energy centers
//! as the incumbent (Mussabayev et al., "How to Use K-means for Big
//! Data Clustering", and Capó et al.'s massive-data k-means are the
//! nearest relatives in PAPERS.md). Here the inner solver is **any
//! roster algorithm** — k²-means by default, so every sample subproblem
//! enjoys the paper's kn-candidate restriction and bound pruning — and
//! the dataset is a [`DatasetSource`]: either a resident matrix or an
//! out-of-core [`crate::data::ChunkedMatrix`] streamed block by block.
//!
//! # Schedule and determinism
//!
//! The driver runs `samples` subproblems in **rounds** of `round` jobs.
//! Sample `s` draws its `sample_rows` row indices from
//! `Pcg32::new(seed, DRAW_STREAM + s)` ([`sample_indices`]) — a fixed
//! schedule independent of thread count, chunk size, and cache size.
//! Jobs within a round run concurrently on the worker pool; the
//! incumbent lives under a shared lock that the driver **writes only at
//! round barriers**, so every job in round `r` warm-starts from the
//! incumbent frozen at the end of round `r − 1` no matter how the pool
//! interleaves them. At each barrier, proposals are applied in
//! ascending sample order with strict `<` improvement. Net contract
//! (pinned by `rust/tests/bigmeans.rs`): fixed seed + fixed schedule ⇒
//! **bitwise-identical incumbent trajectory** at any thread count, any
//! concurrency budget, and any chunk-cache size.
//!
//! Energies of different subproblems are comparable because every
//! sample has the **same size** — the fixed-size convention of the
//! big-means literature. Round 0 jobs cold-start from the configured
//! [`JobInit`]; the incumbent is therefore well-defined from the first
//! barrier on.
//!
//! # Billing
//!
//! Each sample job bills its own [`OpCounter`] (init + iterations,
//! exactly what the same spec would bill standalone — the job runs
//! [`run_init`]/[`run_algo`], not a private re-implementation). The
//! driver merges per-job counters into the caller's counter in
//! ascending sample order, then merges the final assignment pass. That
//! pass streams the source chunk-by-chunk and bills like one Lloyd
//! iteration: `k` distances per row via
//! [`crate::core::NumericsMode::nearest_sq_rows`]. The per-job bills
//! and the assignment bill are all carried on [`BigMeansOutcome`], so
//! `Σ jobs + assign == caller's counter` reconstructs exactly.

use std::sync::{Arc, Mutex};

use super::common::finish_run;
use super::{Config, KmeansResult};
use crate::coordinator::jobs::{run_algo, run_init, JobAlgo, JobInit};
use crate::coordinator::pool;
use crate::core::{Matrix, OpCounter};
use crate::data::DatasetSource;
use crate::init::InitResult;
use crate::metrics::Trace;
use crate::rng::Pcg32;

/// Pcg32 stream base for sample-index draws (sample `s` uses
/// `DRAW_STREAM + s`). Disjoint from every other stream in the crate.
const DRAW_STREAM: u64 = 0xB16_0000;
/// Stream base for deriving per-job algorithm seeds (kd-tree axes,
/// minibatch sampling inside a sample job).
const SEED_STREAM: u64 = 0xB16_1000;

/// Knobs of the big-means driver (CLI `k2m bigmeans`, manifest
/// `method=bigmeans`).
#[derive(Clone, Copy, Debug)]
pub struct BigMeansOpts {
    /// Total sample subproblems to solve.
    pub samples: usize,
    /// Rows per sample (fixed size ⇒ comparable sample energies).
    pub sample_rows: usize,
    /// Jobs per round (the warm-start barrier width). `0` = one round
    /// of all `samples` jobs (fully independent cold/warm mix).
    pub round: usize,
    /// Inner solver for each sample subproblem.
    pub algo: JobAlgo,
    /// Cold-start seeding for round-0 jobs (warm jobs reuse the
    /// incumbent centers and skip seeding entirely).
    pub init: JobInit,
    /// Run the final full-data assignment pass (streamed, counted).
    /// `false` leaves labels empty and reports the sample energy.
    pub assign: bool,
    /// Max sample jobs in flight per round; `0` = one per pool worker.
    /// Concurrency never changes bits — only the round width does the
    /// scheduling, and it is part of the deterministic schedule.
    pub budget: usize,
}

impl Default for BigMeansOpts {
    fn default() -> BigMeansOpts {
        BigMeansOpts {
            samples: 8,
            sample_rows: 2048,
            round: 4,
            algo: JobAlgo::K2Means,
            init: JobInit::Gdi,
            assign: true,
            budget: 0,
        }
    }
}

/// What one sample subproblem did — enough to audit the incumbent
/// trajectory and reconstruct the driver's op bill exactly.
#[derive(Clone, Debug)]
pub struct SampleOutcome {
    /// Sample index `s` (also the draw-stream offset).
    pub sample: usize,
    /// Round this job ran in.
    pub round: usize,
    /// Warm-started from the incumbent (vs cold [`JobInit`] seeding).
    pub warm: bool,
    /// Final energy on the job's own sample.
    pub energy: f64,
    /// Inner-solver iterations executed.
    pub iters: usize,
    /// Op total at the end of this job's init phase (0 for warm jobs —
    /// reusing incumbent centers costs no counted ops).
    pub init_ops: f64,
    /// The job's full op bill (init + iterations).
    pub counter: OpCounter,
    /// Became the incumbent at its round barrier.
    pub improved: bool,
}

/// Result of a big-means run: the incumbent packaged as a standard
/// [`KmeansResult`] plus the per-sample audit trail.
#[derive(Clone, Debug)]
pub struct BigMeansOutcome {
    /// The incumbent centers as a roster-shaped result. `labels` /
    /// `energy` are the full-data assignment when `assign`, else empty
    /// labels and the incumbent's sample energy. `iters` = samples
    /// solved; `trace` holds the incumbent trajectory: one point per
    /// sample `(cumulative ops, incumbent sample energy, s)` in barrier
    /// order, plus a final full-data point when `assign`.
    pub result: KmeansResult,
    /// Σ cold-init bills — the driver's seeding cost, in the same
    /// "snapshot after init" convention as job outcomes.
    pub init_ops: f64,
    /// Per-sample outcomes in sample order.
    pub jobs: Vec<SampleOutcome>,
    /// The final assignment pass's bill (default when `!assign`).
    pub assign_counter: OpCounter,
    /// Incumbent energy on its own sample (comparable across samples).
    pub sample_energy: f64,
    /// Which sample produced the incumbent.
    pub best_sample: usize,
}

/// The row indices sample `s` draws — the fixed schedule, exposed so
/// tests and benches can reconstruct any job bit-for-bit. Sorted
/// ascending (chunk locality for out-of-core gathers; the sort is part
/// of the schedule, not an optimization detail).
pub fn sample_indices(seed: u64, sample: usize, n: usize, sample_rows: usize) -> Vec<usize> {
    let mut rng = Pcg32::new(seed, DRAW_STREAM + sample as u64);
    let mut idx = rng.sample_distinct(n, sample_rows);
    idx.sort_unstable();
    idx
}

/// The inner-solver seed for sample `s` (kd-tree axes, minibatch
/// draws). Derived, not shared: two jobs must never correlate.
pub fn job_seed(seed: u64, sample: usize) -> u64 {
    Pcg32::new(seed, SEED_STREAM + sample as u64).next_u64()
}

/// The incumbent: best centers so far, judged by sample energy.
struct Incumbent {
    centers: Matrix,
    energy: f64,
    sample: usize,
}

/// One sample job: gather, seed (cold or warm), solve. Runs exactly the
/// code a standalone job would ([`run_init`] / [`run_algo`]).
fn run_sample(
    src: &DatasetSource,
    cfg: &Config,
    opts: &BigMeansOpts,
    s: usize,
    round: usize,
    warm_centers: Option<Matrix>,
) -> (SampleOutcome, Matrix) {
    let idx = sample_indices(cfg.seed, s, src.rows(), opts.sample_rows);
    let xs = src.gather_rows(&idx);
    let mut jcfg = cfg.clone();
    jcfg.seed = job_seed(cfg.seed, s);
    jcfg.record_trace = false;
    jcfg.target_energy = None;
    let mut counter = OpCounter::default();
    let warm = warm_centers.is_some();
    let init = match warm_centers {
        Some(centers) => InitResult { centers, labels: None },
        None => run_init(&xs, opts.init, &jcfg, &mut counter),
    };
    let init_ops = counter.total();
    let res = run_algo(&xs, opts.algo, &init, &jcfg, &mut counter);
    let out = SampleOutcome {
        sample: s,
        round,
        warm,
        energy: res.energy,
        iters: res.iters,
        init_ops,
        counter,
        improved: false,
    };
    (out, res.centers)
}

/// Run the big-means global search over `src`. `cfg` is the shared
/// subproblem config (`k`, `kn`, numerics/refresh/scan tiers, threads,
/// iteration cap — all honored by the inner solver); `opts` is the
/// driver schedule. Bills into `counter` as documented in the module
/// header. Panics on an unsatisfiable schedule (`samples == 0`,
/// `sample_rows < k`, `sample_rows > n`) — the CLI validates first.
pub fn bigmeans(
    src: &DatasetSource,
    cfg: &Config,
    opts: &BigMeansOpts,
    counter: &mut OpCounter,
) -> BigMeansOutcome {
    let n = src.rows();
    assert!(opts.samples >= 1, "bigmeans: samples must be >= 1");
    assert!(opts.sample_rows >= cfg.k, "bigmeans: sample_rows < k");
    assert!(opts.sample_rows <= n, "bigmeans: sample_rows > n rows");

    let pool = pool::default_pool();
    let width = if opts.round == 0 { opts.samples } else { opts.round };
    let conc = if opts.budget == 0 { pool.threads() } else { opts.budget };
    let best: Arc<Mutex<Option<Incumbent>>> = Arc::new(Mutex::new(None));

    let mut jobs: Vec<SampleOutcome> = Vec::with_capacity(opts.samples);
    let mut trace = Trace::default();
    let mut done = 0usize;
    let mut round = 0usize;
    while done < opts.samples {
        let len = width.min(opts.samples - done);
        let base = done;
        // All jobs in this round read the same frozen incumbent: the
        // driver only writes the lock at the barrier below.
        let solved = pool.parallel_map_bounded(len, conc, |j| {
            let warm = lock_best(&best).as_ref().map(|b| b.centers.clone());
            run_sample(src, cfg, opts, base + j, round, warm)
        });
        // Barrier: merge bills and apply proposals in ascending sample
        // order, strict improvement only — scheduling can't reorder
        // this, so the trajectory is schedule-independent.
        let mut guard = lock_best(&best);
        for (mut out, centers) in solved {
            counter.merge(&out.counter);
            let improved = guard.as_ref().map_or(true, |b| out.energy < b.energy);
            if improved {
                *guard = Some(Incumbent { centers, energy: out.energy, sample: out.sample });
            }
            out.improved = improved;
            let energy_now = guard.as_ref().map(|b| b.energy).unwrap_or(f64::INFINITY);
            trace.push(counter.total(), energy_now, out.sample);
            jobs.push(out);
        }
        drop(guard);
        done += len;
        round += 1;
    }

    let incumbent = lock_best(&best).take().expect("bigmeans: samples >= 1 yields an incumbent");
    let Incumbent { centers, energy: sample_energy, sample: best_sample } = incumbent;

    // Final full-data assignment: streamed chunk-by-chunk, billed like
    // one Lloyd pass (k distances per row), energy summed f64 in row
    // order — the same bits for in-RAM and chunked sources.
    let mut assign_counter = OpCounter::default();
    let (labels, energy) = if opts.assign {
        let mut labels = vec![0u32; n];
        let mut energy = 0.0f64;
        src.for_each_chunk(|start, block| {
            for r in 0..block.rows() {
                let (l, d2) =
                    cfg.numerics.nearest_sq_rows(block.row(r), &centers, &mut assign_counter);
                labels[start + r] = l;
                energy += d2 as f64;
            }
        });
        counter.merge(&assign_counter);
        trace.push(counter.total(), energy, opts.samples);
        (labels, energy)
    } else {
        (Vec::new(), sample_energy)
    };

    let init_ops = jobs.iter().map(|j| j.init_ops).sum();
    let result = finish_run(centers, labels, energy, opts.samples, true, trace, None, cfg);
    BigMeansOutcome { result, init_ops, jobs, assign_counter, sample_energy, best_sample }
}

/// Lock helper tolerant of poisoning (a panicked job must not wedge
/// sibling jobs that only read the incumbent).
fn lock_best(m: &Mutex<Option<Incumbent>>) -> std::sync::MutexGuard<'_, Option<Incumbent>> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::blobs;

    fn small_cfg(k: usize, seed: u64) -> Config {
        Config { k, kn: k, max_iters: 12, seed, threads: 1, ..Config::default() }
    }

    #[test]
    fn schedule_is_a_pure_function_of_seed_and_sample() {
        let a = sample_indices(7, 3, 500, 64);
        let b = sample_indices(7, 3, 500, 64);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] < w[1]), "sorted ascending, distinct");
        assert_ne!(a, sample_indices(7, 4, 500, 64), "streams differ per sample");
        assert_ne!(job_seed(7, 0), job_seed(7, 1));
    }

    #[test]
    fn incumbent_is_min_over_samples_and_bills_reconstruct() {
        let (x, _) = blobs(600, 5, 6, 18.0, 11);
        let src = DatasetSource::from(x);
        let cfg = small_cfg(5, 11);
        let opts = BigMeansOpts { samples: 6, sample_rows: 120, round: 2, ..Default::default() };
        let mut counter = OpCounter::default();
        let out = bigmeans(&src, &cfg, &opts, &mut counter);

        assert_eq!(out.jobs.len(), 6);
        let min = out.jobs.iter().map(|j| j.energy).fold(f64::INFINITY, f64::min);
        assert_eq!(out.sample_energy, min, "incumbent = strict min over sample energies");
        assert!(out.jobs.iter().any(|j| j.improved));
        assert_eq!(out.jobs[out.best_sample].energy, out.sample_energy);

        // Σ per-job bills + assignment bill == the driver's bill.
        let mut rebuilt = OpCounter::default();
        for j in &out.jobs {
            rebuilt.merge(&j.counter);
        }
        rebuilt.merge(&out.assign_counter);
        assert_eq!(rebuilt, counter);
        // Assignment pass billed like one Lloyd pass: k per row.
        assert_eq!(out.assign_counter.distances, (src.rows() * cfg.k) as u64);
        assert_eq!(out.result.labels.len(), src.rows());
        assert_eq!(out.result.iters, 6);
        // Trajectory: one point per sample + the final full-data point.
        assert_eq!(out.result.trace.points.len(), 7);
    }

    #[test]
    fn round_zero_jobs_are_cold_later_rounds_warm() {
        let (x, _) = blobs(400, 4, 5, 15.0, 3);
        let src = DatasetSource::from(x);
        let cfg = small_cfg(4, 3);
        let opts = BigMeansOpts {
            samples: 4,
            sample_rows: 90,
            round: 2,
            assign: false,
            ..Default::default()
        };
        let out = bigmeans(&src, &cfg, &opts, &mut OpCounter::default());
        for j in &out.jobs {
            assert_eq!(j.warm, j.round > 0, "sample {} round {}", j.sample, j.round);
            if j.warm {
                assert_eq!(j.init_ops, 0.0, "warm start costs no counted init ops");
            } else {
                assert!(j.init_ops > 0.0, "cold start bills its seeding");
            }
        }
        assert!(out.result.labels.is_empty());
        assert_eq!(out.result.energy, out.sample_energy);
        assert_eq!(out.assign_counter, OpCounter::default());
    }
}
