//! Hand-rolled CLI argument parsing (no clap in the offline vendor set).
//!
//! Grammar: `k2m <command> [--flag value]... [--switch]...`. Flags take
//! exactly one value; switches are bare. Unknown flags are an error so
//! typos fail loudly.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
    switches: HashSet<String>,
}

impl Args {
    /// Parse `argv[1..]`. `known_flags` / `known_switches` define the
    /// accepted surface for the chosen command.
    pub fn parse(
        argv: &[String],
        known_flags: &[&str],
        known_switches: &[&str],
    ) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            args.command = cmd.clone();
        }
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument {tok:?}");
            };
            if known_switches.contains(&name) {
                args.switches.insert(name.to_string());
            } else if known_flags.contains(&name) {
                let value = it
                    .next()
                    .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                args.flags.insert(name.to_string(), value.clone());
            } else {
                bail!("unknown flag --{name} for command {:?}", args.command);
            }
        }
        Ok(args)
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.contains(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Typed flag with default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("flag --{name}: cannot parse {s:?}")),
        }
    }

    /// Required flag.
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required flag --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(
            &v(&["cluster", "--k", "20", "--full", "--dataset", "usps"]),
            &["k", "dataset"],
            &["full"],
        )
        .unwrap();
        assert_eq!(a.command, "cluster");
        assert_eq!(a.get_parse::<usize>("k", 0).unwrap(), 20);
        assert!(a.switch("full"));
        assert_eq!(a.require("dataset").unwrap(), "usps");
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&v(&["x"]), &["k"], &[]).unwrap();
        assert_eq!(a.get_parse::<usize>("k", 7).unwrap(), 7);
        assert!(a.require("k").is_err());
        assert!(Args::parse(&v(&["x", "--bogus", "1"]), &["k"], &[]).is_err());
        assert!(Args::parse(&v(&["x", "--k"]), &["k"], &[]).is_err());
        assert!(Args::parse(&v(&["x", "stray"]), &["k"], &[]).is_err());
    }

    #[test]
    fn bad_value_reports() {
        let a = Args::parse(&v(&["x", "--k", "abc"]), &["k"], &[]).unwrap();
        assert!(a.get_parse::<usize>("k", 0).is_err());
    }
}
