//! The concurrent job scheduler: many clustering jobs, one worker pool.
//!
//! A [`JobQueue`] accepts clustering jobs — (algorithm, initialization,
//! dataset, [`Config`]) — and runs them **concurrently on the persistent
//! worker pool** ([`crate::coordinator::pool`]), returning per-job
//! results and op counts in submission order. This is the serving-side
//! counterpart of the experiment grids: a codebook service answering
//! many independent clustering requests wants them overlapped, not
//! queued one behind another. [`JobStream`] is the open-ended variant:
//! jobs submitted while earlier ones are still training, for callers
//! that discover work incrementally. Either way a job can persist its
//! trained [`crate::cluster::ClusterModel`] (`save_model=` in the
//! manifest / [`JobSpec::saving_model`]), which is how the train side
//! of the train/serve split hands artifacts to `k2m serve`.
//!
//! Datasets ride as [`DatasetSource`]s — an `Arc`-shared in-RAM matrix
//! or an out-of-core [`crate::data::ChunkedMatrix`]. Roster jobs
//! materialize a chunked source once; a spec carrying [`JobSpec::big`]
//! runs the big-means global search ([`fn@crate::cluster::bigmeans`])
//! and streams it chunk-by-chunk instead.
//!
//! # Thread budget
//!
//! The queue's `budget` caps how many jobs are in flight at once; each
//! job occupies one pool worker, and any sharded pass *inside* a running
//! job executes inline on that worker (the pool's nested-dispatch rule),
//! so total thread usage is `min(budget, pool workers)` — outer jobs ×
//! inner shards can never oversubscribe the pool. One big job
//! submitted alone still shards across the full pool: with `budget = 1`
//! the queue degenerates to serial one-at-a-time execution on the
//! caller's thread.
//!
//! # Determinism
//!
//! Job results are **bit-identical to running the same spec serially**:
//! every algorithm's output depends only on its shard layout — not on
//! scheduling — and nested-inline execution preserves the layout (see
//! the engine contract in [`crate::coordinator::pool`]). Pinned by
//! `rust/tests/jobs.rs`.
//!
//! The CLI front-end is `k2m jobs --manifest <file>`; the library
//! submission API is [`crate::runtime::run_cluster_jobs`].

use std::time::Duration;

use super::pool::{self, WorkerPool};
use crate::cluster::{
    akm, bigmeans, elkan, hamerly, k2means, lloyd, minibatch, yinyang, BigMeansOpts, Config,
    KmeansResult, MiniBatchOpts,
};
use crate::core::{Matrix, OpCounter};
use crate::data::DatasetSource;
use crate::init::{
    gdi, kmeans_par, kmeans_pp_numerics, random_init, GdiOpts, InitResult, KmeansParOpts,
};

/// The algorithm a job runs — the full roster of [`crate::cluster`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobAlgo {
    K2Means,
    Lloyd,
    Elkan,
    Hamerly,
    Yinyang,
    MiniBatch,
    Akm,
}

impl JobAlgo {
    /// Manifest-spelling parser (`method=` values of `k2m jobs`).
    pub fn parse(s: &str) -> Option<JobAlgo> {
        Some(match s {
            "k2means" | "k2-means" => JobAlgo::K2Means,
            "lloyd" => JobAlgo::Lloyd,
            "elkan" => JobAlgo::Elkan,
            "hamerly" => JobAlgo::Hamerly,
            "yinyang" => JobAlgo::Yinyang,
            "minibatch" => JobAlgo::MiniBatch,
            "akm" => JobAlgo::Akm,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            JobAlgo::K2Means => "k2means",
            JobAlgo::Lloyd => "lloyd",
            JobAlgo::Elkan => "elkan",
            JobAlgo::Hamerly => "hamerly",
            JobAlgo::Yinyang => "yinyang",
            JobAlgo::MiniBatch => "minibatch",
            JobAlgo::Akm => "akm",
        }
    }
}

/// The initialization a job seeds from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobInit {
    Random,
    KmeansPp,
    KmeansPar,
    Gdi,
}

impl JobInit {
    /// Manifest-spelling parser (`init=` values of `k2m jobs`).
    pub fn parse(s: &str) -> Option<JobInit> {
        Some(match s {
            "random" => JobInit::Random,
            "kmeans++" | "kmeanspp" | "pp" => JobInit::KmeansPp,
            "kmeans||" | "kmeanspar" | "par" => JobInit::KmeansPar,
            "gdi" => JobInit::Gdi,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            JobInit::Random => "random",
            JobInit::KmeansPp => "kmeans++",
            JobInit::KmeansPar => "kmeans||",
            JobInit::Gdi => "gdi",
        }
    }

    /// The paper's pairing: k²-means seeds from GDI, everything else
    /// from random sampling (the speedup tables' convention).
    pub fn default_for(algo: JobAlgo) -> JobInit {
        match algo {
            JobAlgo::K2Means => JobInit::Gdi,
            _ => JobInit::Random,
        }
    }
}

/// One clustering job: what to run, seeded how, with which knobs. The
/// dataset rides separately (a [`DatasetSource`] — an `Arc`-shared
/// in-RAM matrix or a chunked on-disk store — shared across jobs).
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Caller-chosen label, echoed in the outcome (manifest `name=`).
    pub name: String,
    pub algo: JobAlgo,
    pub init: JobInit,
    pub cfg: Config,
    /// When set, the job saves its trained [`crate::cluster::ClusterModel`]
    /// to this path on completion (manifest `save_model=`); success or
    /// failure lands in [`JobOutcome::saved`] without failing the job.
    pub save_model: Option<String>,
    /// When set, the job is a **big-means global search**
    /// ([`fn@crate::cluster::bigmeans`]) instead of one roster run: the
    /// opts name the per-sample solver and its cold seeding
    /// ([`BigMeansOpts::algo`] / [`BigMeansOpts::init`] — authoritative
    /// over this spec's `algo`/`init`, which the manifest parser keeps
    /// in sync), and the outcome's result is the incumbent (manifest
    /// `method=bigmeans` plus `samples=`/`sample_rows=`/`round=`/
    /// `assign=`). Big-means jobs read their [`DatasetSource`]
    /// chunk-by-chunk instead of materializing it.
    pub big: Option<BigMeansOpts>,
}

impl JobSpec {
    /// A spec with the paper's default init pairing for `algo`.
    pub fn new(name: impl Into<String>, algo: JobAlgo, cfg: Config) -> JobSpec {
        JobSpec {
            name: name.into(),
            algo,
            init: JobInit::default_for(algo),
            cfg,
            save_model: None,
            big: None,
        }
    }

    /// Builder form of [`JobSpec::save_model`].
    pub fn saving_model(mut self, path: impl Into<String>) -> JobSpec {
        self.save_model = Some(path.into());
        self
    }

    /// Builder form of [`JobSpec::big`]: turn this spec into a big-means
    /// global search whose per-sample solver is `self.algo`.
    pub fn as_bigmeans(mut self, opts: BigMeansOpts) -> JobSpec {
        self.big = Some(opts);
        self
    }
}

/// One finished job: the clustering result plus the counted-op bill.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub algo: JobAlgo,
    pub init: JobInit,
    pub result: KmeansResult,
    /// Full counter (init + iterations, the tables' convention).
    pub counter: OpCounter,
    /// `counter.total()` snapshot taken right after initialization.
    pub init_ops: f64,
    pub wall: Duration,
    /// Model-save outcome when the spec asked for one: `Ok(path)` or
    /// `Err(message)` (plain strings so the outcome stays `Clone`).
    pub saved: Option<std::result::Result<String, String>>,
}

/// Run one seeding by its [`JobInit`] spelling. The init phase rides the
/// job's threads AND numerics knobs, so a fast-mode job is fast (and
/// deterministic) end to end. Shared by [`run_job`] and the big-means
/// driver's cold-start jobs ([`fn@crate::cluster::bigmeans`]).
pub fn run_init(x: &Matrix, init: JobInit, cfg: &Config, counter: &mut OpCounter) -> InitResult {
    match init {
        JobInit::Random => random_init(x, cfg.k, cfg.seed),
        JobInit::KmeansPp => {
            kmeans_pp_numerics(x, cfg.k, counter, cfg.seed, cfg.threads, cfg.numerics)
        }
        JobInit::KmeansPar => kmeans_par(
            x,
            cfg.k,
            &KmeansParOpts { threads: cfg.threads, numerics: cfg.numerics, ..Default::default() },
            counter,
            cfg.seed,
        ),
        JobInit::Gdi => gdi(
            x,
            cfg.k,
            counter,
            cfg.seed,
            &GdiOpts { threads: cfg.threads, numerics: cfg.numerics, ..Default::default() },
        ),
    }
}

/// Run one roster algorithm by its [`JobAlgo`] spelling from a prepared
/// init. Shared by [`run_job`] and the big-means driver's per-sample
/// solves, so a sample subproblem runs *exactly* the code a standalone
/// job would.
pub fn run_algo(
    x: &Matrix,
    algo: JobAlgo,
    init: &InitResult,
    cfg: &Config,
    counter: &mut OpCounter,
) -> KmeansResult {
    match algo {
        JobAlgo::K2Means => k2means(x, init, cfg, counter),
        JobAlgo::Lloyd => lloyd(x, init, cfg, counter),
        JobAlgo::Elkan => elkan(x, init, cfg, counter),
        JobAlgo::Hamerly => hamerly(x, init, cfg, counter),
        JobAlgo::Yinyang => yinyang(x, init, cfg, counter),
        // Scheduled runs are bounded like every other method: exactly
        // `cfg.max_iters` gradient steps. (The paper's open-ended
        // `t = n/2` convention is the `cluster`-command default, not
        // the scheduler's — a serving queue wants predictable jobs.)
        JobAlgo::MiniBatch => minibatch(
            x,
            init,
            cfg,
            &MiniBatchOpts { iterations: Some(cfg.max_iters), ..Default::default() },
            counter,
        ),
        JobAlgo::Akm => akm(x, init, cfg, counter),
    }
}

/// Persist a job's trained model if the spec asked for one. An IO
/// failure is recorded, not raised: the clustering result is still valid
/// and other jobs in the same queue must keep running.
fn save_outcome(
    spec: &JobSpec,
    model: &crate::cluster::ClusterModel,
) -> Option<std::result::Result<String, String>> {
    spec.save_model.as_ref().map(|p| match model.save(std::path::Path::new(p)) {
        Ok(()) => Ok(p.clone()),
        Err(e) => Err(format!("{e:#}")),
    })
}

/// Run one job to completion on the current thread. Called by the
/// scheduler from a pool worker (where the job's inner passes execute
/// inline) and usable directly for a serial reference run — both give
/// bit-identical results. A spec carrying [`JobSpec::big`] runs the
/// big-means driver over the matrix as an in-RAM source.
pub fn run_job(x: &Matrix, spec: &JobSpec) -> JobOutcome {
    if spec.big.is_some() {
        // The serial-reference entry for a big-means spec: wrap the
        // borrowed matrix as an owned in-RAM source (one copy — this is
        // the reference path, not the scheduler's).
        return run_job_source(&DatasetSource::from(x.clone()), spec);
    }
    let cfg = &spec.cfg;
    let mut counter = OpCounter::default();
    let t0 = std::time::Instant::now();
    let init = run_init(x, spec.init, cfg, &mut counter);
    let init_ops = counter.total();
    let result = run_algo(x, spec.algo, &init, cfg, &mut counter);
    let saved = save_outcome(spec, &result.model);
    JobOutcome {
        name: spec.name.clone(),
        algo: spec.algo,
        init: spec.init,
        result,
        counter,
        init_ops,
        wall: t0.elapsed(),
        saved,
    }
}

/// Run one job against a [`DatasetSource`] — the scheduler's actual
/// unit of work. Roster jobs materialize the source (free for in-RAM
/// sources; a one-time cached assembly for chunked files, since every
/// roster algorithm wants all rows resident); big-means jobs
/// ([`JobSpec::big`]) stream it chunk-by-chunk instead.
pub fn run_job_source(src: &DatasetSource, spec: &JobSpec) -> JobOutcome {
    let Some(opts) = &spec.big else {
        return run_job(&src.materialize(), spec);
    };
    let mut counter = OpCounter::default();
    let t0 = std::time::Instant::now();
    let out = bigmeans(src, &spec.cfg, opts, &mut counter);
    let saved = save_outcome(spec, &out.result.model);
    JobOutcome {
        name: spec.name.clone(),
        algo: opts.algo,
        init: opts.init,
        init_ops: out.init_ops,
        result: out.result,
        counter,
        wall: t0.elapsed(),
        saved,
    }
}

/// A queue of clustering jobs executed concurrently on the worker pool.
///
/// ```
/// use std::sync::Arc;
/// use k2m::cluster::Config;
/// use k2m::coordinator::jobs::{JobAlgo, JobQueue, JobSpec};
/// use k2m::testing::blobs;
///
/// let (x, _) = blobs(300, 8, 4, 20.0, 1);
/// let x = Arc::new(x);
/// let mut queue = JobQueue::with_budget(2);
/// for (i, algo) in [JobAlgo::Lloyd, JobAlgo::Elkan].into_iter().enumerate() {
///     let cfg = Config { k: 6, max_iters: 10, ..Default::default() };
///     queue.submit(Arc::clone(&x), JobSpec::new(format!("job{i}"), algo, cfg));
/// }
/// let outcomes = queue.run();
/// assert_eq!(outcomes.len(), 2);
/// assert_eq!(outcomes[0].name, "job0"); // submission order, always
/// // Exact accelerators agree with Lloyd on the same seed/init.
/// assert_eq!(outcomes[0].result.labels, outcomes[1].result.labels);
/// ```
#[derive(Default)]
pub struct JobQueue {
    jobs: Vec<(DatasetSource, JobSpec)>,
    /// Max jobs in flight; `0` = one per pool worker.
    budget: usize,
}

impl JobQueue {
    /// An empty queue with the default budget (one job per pool worker).
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// An empty queue capped at `budget` concurrent jobs (`0` = one per
    /// pool worker; `1` = serial one-at-a-time on the caller's thread).
    pub fn with_budget(budget: usize) -> JobQueue {
        JobQueue { jobs: Vec::new(), budget }
    }

    /// Enqueue a job; returns its id (= its index in `run`'s output).
    /// Accepts anything that converts into a [`DatasetSource`] — an
    /// `Arc<Matrix>` (shared across jobs at no extra cost, the
    /// historical shape) or an `Arc<ChunkedMatrix>` out-of-core store.
    pub fn submit(&mut self, data: impl Into<DatasetSource>, spec: JobSpec) -> usize {
        self.jobs.push((data.into(), spec));
        self.jobs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Execute every submitted job on the process-wide default pool;
    /// outcomes come back in submission order.
    pub fn run(self) -> Vec<JobOutcome> {
        self.run_on(pool::default_pool())
    }

    /// Execute on an explicit pool (tests; isolated budgets).
    pub fn run_on(self, pool: &WorkerPool) -> Vec<JobOutcome> {
        let JobQueue { jobs, budget } = self;
        let width = if budget == 0 { pool.threads() } else { budget };
        pool.parallel_map_bounded(jobs.len(), width, |ji| {
            let (src, spec) = &jobs[ji];
            run_job_source(src, spec)
        })
    }
}

/// A streaming job scheduler: submit jobs *while earlier ones run*.
///
/// Where [`JobQueue`] collects everything up front and then executes,
/// a `JobStream` opens resident runners on the pool immediately and
/// hands each submission to the first free one — training overlaps with
/// submission, which is the shape of a long-lived model service
/// ingesting requests as they arrive. [`JobStream::finish`] returns the
/// outcomes in submission order, and each job is bit-identical to a
/// serial [`run_job`] of the same spec (the queue's determinism
/// contract; pinned by `rust/tests/jobs.rs`).
///
/// The submitting thread must not dispatch its own pool passes while a
/// stream is open (see [`WorkerPool::stream`]); jobs *inside* the stream
/// shard freely — their nested passes run inline on the runner.
pub struct JobStream {
    inner: pool::PoolStream<(DatasetSource, JobSpec), JobOutcome>,
}

impl JobStream {
    /// Open a stream on the process-wide default pool. `budget` caps
    /// concurrent jobs (`0` = one per pool worker), exactly like
    /// [`JobQueue::with_budget`].
    pub fn start(budget: usize) -> JobStream {
        JobStream::start_on(pool::default_pool(), budget)
    }

    /// Open on an explicit pool (tests; isolated budgets).
    pub fn start_on(pool: &WorkerPool, budget: usize) -> JobStream {
        let width = if budget == 0 { pool.threads() } else { budget };
        let inner = pool.stream(width, |_id, (src, spec): (DatasetSource, JobSpec)| {
            run_job_source(&src, &spec)
        });
        JobStream { inner }
    }

    /// Submit a job; returns its id (= its index in [`JobStream::finish`]'s
    /// output). Never blocks: submissions park until a runner frees up.
    pub fn submit(&self, data: impl Into<DatasetSource>, spec: JobSpec) -> usize {
        self.inner.submit((data.into(), spec))
    }

    /// Close the stream and wait for every submitted job; outcomes come
    /// back in submission order.
    pub fn finish(self) -> Vec<JobOutcome> {
        self.inner.finish()
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::testing::blobs;

    #[test]
    fn parse_roundtrips() {
        for algo in [
            JobAlgo::K2Means,
            JobAlgo::Lloyd,
            JobAlgo::Elkan,
            JobAlgo::Hamerly,
            JobAlgo::Yinyang,
            JobAlgo::MiniBatch,
            JobAlgo::Akm,
        ] {
            assert_eq!(JobAlgo::parse(algo.name()), Some(algo));
        }
        for init in [JobInit::Random, JobInit::KmeansPp, JobInit::KmeansPar, JobInit::Gdi] {
            assert_eq!(JobInit::parse(init.name()), Some(init));
        }
        assert_eq!(JobAlgo::parse("bogus"), None);
        assert_eq!(JobInit::parse("bogus"), None);
    }

    #[test]
    fn default_init_pairing_matches_paper() {
        assert_eq!(JobInit::default_for(JobAlgo::K2Means), JobInit::Gdi);
        assert_eq!(JobInit::default_for(JobAlgo::Lloyd), JobInit::Random);
    }

    #[test]
    fn empty_queue_runs_to_nothing() {
        let queue = JobQueue::new();
        assert!(queue.is_empty());
        assert!(queue.run().is_empty());
    }

    #[test]
    fn budget_one_equals_default_budget() {
        // Scheduling must not change results: serial one-at-a-time vs
        // pool-wide concurrency, same outcomes bit for bit.
        let (x, _) = blobs(400, 8, 4, 15.0, 21);
        let x = Arc::new(x);
        let build = |budget: usize| {
            let mut q = JobQueue::with_budget(budget);
            for (i, algo) in [JobAlgo::Lloyd, JobAlgo::K2Means, JobAlgo::Hamerly]
                .into_iter()
                .enumerate()
            {
                let cfg = Config { k: 8, kn: 4, max_iters: 12, seed: 3, ..Default::default() };
                q.submit(Arc::clone(&x), JobSpec::new(format!("j{i}"), algo, cfg));
            }
            q
        };
        let serial = build(1).run();
        let wide = build(0).run();
        assert_eq!(serial.len(), wide.len());
        for (s, w) in serial.iter().zip(&wide) {
            assert_eq!(s.name, w.name);
            assert_eq!(s.result.labels, w.result.labels, "{}", s.name);
            assert_eq!(s.result.centers, w.result.centers, "{}", s.name);
            assert_eq!(s.result.energy.to_bits(), w.result.energy.to_bits(), "{}", s.name);
            assert_eq!(s.counter, w.counter, "{}", s.name);
        }
    }

    #[test]
    fn streaming_matches_serial_run_job() {
        // The overlapped path must not change results: every outcome
        // bit-identical to calling run_job directly on the same spec.
        let (x, _) = blobs(350, 8, 4, 15.0, 9);
        let x = Arc::new(x);
        let specs: Vec<JobSpec> = [JobAlgo::Lloyd, JobAlgo::K2Means, JobAlgo::Elkan, JobAlgo::Akm]
            .into_iter()
            .enumerate()
            .map(|(i, algo)| {
                let cfg = Config { k: 8, kn: 4, max_iters: 10, seed: 5, ..Default::default() };
                JobSpec::new(format!("s{i}"), algo, cfg)
            })
            .collect();
        let stream = JobStream::start(2);
        for spec in &specs {
            stream.submit(Arc::clone(&x), spec.clone());
        }
        let streamed = stream.finish();
        assert_eq!(streamed.len(), specs.len());
        for (out, spec) in streamed.iter().zip(&specs) {
            let reference = run_job(&x, spec);
            assert_eq!(out.name, spec.name);
            assert_eq!(out.result.labels, reference.result.labels, "{}", spec.name);
            assert_eq!(out.result.centers, reference.result.centers, "{}", spec.name);
            assert_eq!(
                out.result.energy.to_bits(),
                reference.result.energy.to_bits(),
                "{}",
                spec.name
            );
            assert_eq!(out.counter, reference.counter, "{}", spec.name);
            assert!(out.saved.is_none());
        }
    }

    #[test]
    fn save_model_records_outcome_and_survives_failure() {
        let (x, _) = blobs(200, 6, 3, 12.0, 4);
        let x = Arc::new(x);
        let cfg = Config { k: 6, kn: 3, max_iters: 8, seed: 2, ..Default::default() };
        let mut good = std::env::temp_dir();
        good.push(format!("k2m_test_{}_job_model.k2mm", std::process::id()));
        let good_s = good.to_string_lossy().into_owned();

        let spec = JobSpec::new("save", JobAlgo::K2Means, cfg.clone()).saving_model(&good_s);
        let out = run_job(&x, &spec);
        assert_eq!(out.saved, Some(Ok(good_s.clone())));
        let model = crate::cluster::ClusterModel::load(&good).unwrap();
        assert_eq!(model.centers().as_slice(), out.result.model.centers().as_slice());
        std::fs::remove_file(&good).ok();

        // An unwritable path is reported in `saved`, not a panic/abort:
        // the clustering result itself is still returned intact.
        let bad = "/nonexistent_k2m_dir/model.k2mm";
        let spec = JobSpec::new("savefail", JobAlgo::Lloyd, cfg).saving_model(bad);
        let out = run_job(&x, &spec);
        assert!(matches!(&out.saved, Some(Err(msg)) if !msg.is_empty()));
        assert_eq!(out.result.labels.len(), 200);
    }
}
