//! The concurrent job scheduler: many clustering jobs, one worker pool.
//!
//! A [`JobQueue`] accepts clustering jobs — (algorithm, initialization,
//! dataset, [`Config`]) — and runs them **concurrently on the persistent
//! worker pool** ([`crate::coordinator::pool`]), returning per-job
//! results and op counts in submission order. This is the serving-side
//! counterpart of the experiment grids: a codebook service answering
//! many independent clustering requests wants them overlapped, not
//! queued one behind another.
//!
//! # Thread budget
//!
//! The queue's `budget` caps how many jobs are in flight at once; each
//! job occupies one pool worker, and any sharded pass *inside* a running
//! job executes inline on that worker (the pool's nested-dispatch rule),
//! so total thread usage is `min(budget, pool workers)` — outer jobs ×
//! inner shards can never oversubscribe the pool. One big job
//! submitted alone still shards across the full pool: with `budget = 1`
//! the queue degenerates to serial one-at-a-time execution on the
//! caller's thread.
//!
//! # Determinism
//!
//! Job results are **bit-identical to running the same spec serially**:
//! every algorithm's output depends only on its shard layout — not on
//! scheduling — and nested-inline execution preserves the layout (see
//! the engine contract in [`crate::coordinator::pool`]). Pinned by
//! `rust/tests/jobs.rs`.
//!
//! The CLI front-end is `k2m jobs --manifest <file>`; the library
//! submission API is [`crate::runtime::run_cluster_jobs`].

use std::sync::Arc;
use std::time::Duration;

use super::pool::{self, WorkerPool};
use crate::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, Config, KmeansResult, MiniBatchOpts,
};
use crate::core::{Matrix, OpCounter};
use crate::init::{
    gdi, kmeans_par, kmeans_pp_numerics, random_init, GdiOpts, InitResult, KmeansParOpts,
};

/// The algorithm a job runs — the full roster of [`crate::cluster`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobAlgo {
    K2Means,
    Lloyd,
    Elkan,
    Hamerly,
    Yinyang,
    MiniBatch,
    Akm,
}

impl JobAlgo {
    /// Manifest-spelling parser (`method=` values of `k2m jobs`).
    pub fn parse(s: &str) -> Option<JobAlgo> {
        Some(match s {
            "k2means" | "k2-means" => JobAlgo::K2Means,
            "lloyd" => JobAlgo::Lloyd,
            "elkan" => JobAlgo::Elkan,
            "hamerly" => JobAlgo::Hamerly,
            "yinyang" => JobAlgo::Yinyang,
            "minibatch" => JobAlgo::MiniBatch,
            "akm" => JobAlgo::Akm,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            JobAlgo::K2Means => "k2means",
            JobAlgo::Lloyd => "lloyd",
            JobAlgo::Elkan => "elkan",
            JobAlgo::Hamerly => "hamerly",
            JobAlgo::Yinyang => "yinyang",
            JobAlgo::MiniBatch => "minibatch",
            JobAlgo::Akm => "akm",
        }
    }
}

/// The initialization a job seeds from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobInit {
    Random,
    KmeansPp,
    KmeansPar,
    Gdi,
}

impl JobInit {
    /// Manifest-spelling parser (`init=` values of `k2m jobs`).
    pub fn parse(s: &str) -> Option<JobInit> {
        Some(match s {
            "random" => JobInit::Random,
            "kmeans++" | "kmeanspp" | "pp" => JobInit::KmeansPp,
            "kmeans||" | "kmeanspar" | "par" => JobInit::KmeansPar,
            "gdi" => JobInit::Gdi,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            JobInit::Random => "random",
            JobInit::KmeansPp => "kmeans++",
            JobInit::KmeansPar => "kmeans||",
            JobInit::Gdi => "gdi",
        }
    }

    /// The paper's pairing: k²-means seeds from GDI, everything else
    /// from random sampling (the speedup tables' convention).
    pub fn default_for(algo: JobAlgo) -> JobInit {
        match algo {
            JobAlgo::K2Means => JobInit::Gdi,
            _ => JobInit::Random,
        }
    }
}

/// One clustering job: what to run, seeded how, with which knobs. The
/// dataset rides separately (an `Arc<Matrix>` shared across jobs).
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Caller-chosen label, echoed in the outcome (manifest `name=`).
    pub name: String,
    pub algo: JobAlgo,
    pub init: JobInit,
    pub cfg: Config,
}

impl JobSpec {
    /// A spec with the paper's default init pairing for `algo`.
    pub fn new(name: impl Into<String>, algo: JobAlgo, cfg: Config) -> JobSpec {
        JobSpec { name: name.into(), algo, init: JobInit::default_for(algo), cfg }
    }
}

/// One finished job: the clustering result plus the counted-op bill.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub name: String,
    pub algo: JobAlgo,
    pub init: JobInit,
    pub result: KmeansResult,
    /// Full counter (init + iterations, the tables' convention).
    pub counter: OpCounter,
    /// `counter.total()` snapshot taken right after initialization.
    pub init_ops: f64,
    pub wall: Duration,
}

/// Run one job to completion on the current thread. Called by the
/// scheduler from a pool worker (where the job's inner passes execute
/// inline) and usable directly for a serial reference run — both give
/// bit-identical results.
pub fn run_job(x: &Matrix, spec: &JobSpec) -> JobOutcome {
    let cfg = &spec.cfg;
    let mut counter = OpCounter::default();
    let t0 = std::time::Instant::now();
    // The init phase rides the job's threads AND numerics knobs, so a
    // fast-mode job is fast (and deterministic) end to end.
    let init: InitResult = match spec.init {
        JobInit::Random => random_init(x, cfg.k, cfg.seed),
        JobInit::KmeansPp => {
            kmeans_pp_numerics(x, cfg.k, &mut counter, cfg.seed, cfg.threads, cfg.numerics)
        }
        JobInit::KmeansPar => kmeans_par(
            x,
            cfg.k,
            &KmeansParOpts { threads: cfg.threads, numerics: cfg.numerics, ..Default::default() },
            &mut counter,
            cfg.seed,
        ),
        JobInit::Gdi => gdi(
            x,
            cfg.k,
            &mut counter,
            cfg.seed,
            &GdiOpts { threads: cfg.threads, numerics: cfg.numerics, ..Default::default() },
        ),
    };
    let init_ops = counter.total();
    let result = match spec.algo {
        JobAlgo::K2Means => k2means(x, &init, cfg, &mut counter),
        JobAlgo::Lloyd => lloyd(x, &init, cfg, &mut counter),
        JobAlgo::Elkan => elkan(x, &init, cfg, &mut counter),
        JobAlgo::Hamerly => hamerly(x, &init, cfg, &mut counter),
        JobAlgo::Yinyang => yinyang(x, &init, cfg, &mut counter),
        // Scheduled runs are bounded like every other method: exactly
        // `cfg.max_iters` gradient steps. (The paper's open-ended
        // `t = n/2` convention is the `cluster`-command default, not
        // the scheduler's — a serving queue wants predictable jobs.)
        JobAlgo::MiniBatch => minibatch(
            x,
            &init,
            cfg,
            &MiniBatchOpts { iterations: Some(cfg.max_iters), ..Default::default() },
            &mut counter,
        ),
        JobAlgo::Akm => akm(x, &init, cfg, &mut counter),
    };
    JobOutcome {
        name: spec.name.clone(),
        algo: spec.algo,
        init: spec.init,
        result,
        counter,
        init_ops,
        wall: t0.elapsed(),
    }
}

/// A queue of clustering jobs executed concurrently on the worker pool.
///
/// ```
/// use std::sync::Arc;
/// use k2m::cluster::Config;
/// use k2m::coordinator::jobs::{JobAlgo, JobQueue, JobSpec};
/// use k2m::testing::blobs;
///
/// let (x, _) = blobs(300, 8, 4, 20.0, 1);
/// let x = Arc::new(x);
/// let mut queue = JobQueue::with_budget(2);
/// for (i, algo) in [JobAlgo::Lloyd, JobAlgo::Elkan].into_iter().enumerate() {
///     let cfg = Config { k: 6, max_iters: 10, ..Default::default() };
///     queue.submit(Arc::clone(&x), JobSpec::new(format!("job{i}"), algo, cfg));
/// }
/// let outcomes = queue.run();
/// assert_eq!(outcomes.len(), 2);
/// assert_eq!(outcomes[0].name, "job0"); // submission order, always
/// // Exact accelerators agree with Lloyd on the same seed/init.
/// assert_eq!(outcomes[0].result.labels, outcomes[1].result.labels);
/// ```
#[derive(Default)]
pub struct JobQueue {
    jobs: Vec<(Arc<Matrix>, JobSpec)>,
    /// Max jobs in flight; `0` = one per pool worker.
    budget: usize,
}

impl JobQueue {
    /// An empty queue with the default budget (one job per pool worker).
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// An empty queue capped at `budget` concurrent jobs (`0` = one per
    /// pool worker; `1` = serial one-at-a-time on the caller's thread).
    pub fn with_budget(budget: usize) -> JobQueue {
        JobQueue { jobs: Vec::new(), budget }
    }

    /// Enqueue a job; returns its id (= its index in `run`'s output).
    /// Datasets are `Arc`-shared so submitting many jobs over one matrix
    /// costs nothing extra.
    pub fn submit(&mut self, data: Arc<Matrix>, spec: JobSpec) -> usize {
        self.jobs.push((data, spec));
        self.jobs.len() - 1
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Execute every submitted job on the process-wide default pool;
    /// outcomes come back in submission order.
    pub fn run(self) -> Vec<JobOutcome> {
        self.run_on(pool::default_pool())
    }

    /// Execute on an explicit pool (tests; isolated budgets).
    pub fn run_on(self, pool: &WorkerPool) -> Vec<JobOutcome> {
        let JobQueue { jobs, budget } = self;
        let width = if budget == 0 { pool.threads() } else { budget };
        pool.parallel_map_bounded(jobs.len(), width, |ji| {
            let (x, spec) = &jobs[ji];
            run_job(x, spec)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::blobs;

    #[test]
    fn parse_roundtrips() {
        for algo in [
            JobAlgo::K2Means,
            JobAlgo::Lloyd,
            JobAlgo::Elkan,
            JobAlgo::Hamerly,
            JobAlgo::Yinyang,
            JobAlgo::MiniBatch,
            JobAlgo::Akm,
        ] {
            assert_eq!(JobAlgo::parse(algo.name()), Some(algo));
        }
        for init in [JobInit::Random, JobInit::KmeansPp, JobInit::KmeansPar, JobInit::Gdi] {
            assert_eq!(JobInit::parse(init.name()), Some(init));
        }
        assert_eq!(JobAlgo::parse("bogus"), None);
        assert_eq!(JobInit::parse("bogus"), None);
    }

    #[test]
    fn default_init_pairing_matches_paper() {
        assert_eq!(JobInit::default_for(JobAlgo::K2Means), JobInit::Gdi);
        assert_eq!(JobInit::default_for(JobAlgo::Lloyd), JobInit::Random);
    }

    #[test]
    fn empty_queue_runs_to_nothing() {
        let queue = JobQueue::new();
        assert!(queue.is_empty());
        assert!(queue.run().is_empty());
    }

    #[test]
    fn budget_one_equals_default_budget() {
        // Scheduling must not change results: serial one-at-a-time vs
        // pool-wide concurrency, same outcomes bit for bit.
        let (x, _) = blobs(400, 8, 4, 15.0, 21);
        let x = Arc::new(x);
        let build = |budget: usize| {
            let mut q = JobQueue::with_budget(budget);
            for (i, algo) in [JobAlgo::Lloyd, JobAlgo::K2Means, JobAlgo::Hamerly]
                .into_iter()
                .enumerate()
            {
                let cfg = Config { k: 8, kn: 4, max_iters: 12, seed: 3, ..Default::default() };
                q.submit(Arc::clone(&x), JobSpec::new(format!("j{i}"), algo, cfg));
            }
            q
        };
        let serial = build(1).run();
        let wide = build(0).run();
        assert_eq!(serial.len(), wide.len());
        for (s, w) in serial.iter().zip(&wide) {
            assert_eq!(s.name, w.name);
            assert_eq!(s.result.labels, w.result.labels, "{}", s.name);
            assert_eq!(s.result.centers, w.result.centers, "{}", s.name);
            assert_eq!(s.result.energy.to_bits(), w.result.energy.to_bits(), "{}", s.name);
            assert_eq!(s.counter, w.counter, "{}", s.name);
        }
    }
}
