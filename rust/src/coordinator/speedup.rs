//! The paper's speedup protocol (§3.4, Tables 5/6 and supp. 8–11):
//!
//! 1. Per (dataset, k, seed): run Lloyd++ to convergence (100-iter cap) —
//!    its final energy is the *reference*; the target band is
//!    `E_ref * (1 + band)` for band ∈ {0, 0.5%, 1%, 2%}.
//! 2. Every method runs with early stop at the target; its cost is the
//!    cumulative counted ops (init included) at the first trace point
//!    inside the band.
//! 3. Speedup = Lloyd++'s ops-to-band / the method's ops-to-band,
//!    averaged over seeds that reached the band; `-` when none did.
//! 4. AKM's `m` and k²-means' `kn` are chosen by an oracle: the grid
//!    value {3,5,10,20,30,50,100,200} with the highest average speedup.

use super::datasets::WorkloadSet;
use super::methods::{run_method, Method, MethodRun, PARAM_GRID};
use super::pool::parallel_map;

/// Fixed generator seed for the datasets themselves (the paper's datasets
/// are fixed; per-run seeds only vary the initializations).
pub const DATA_SEED: u64 = 0xD5;

/// Speedup experiment configuration.
#[derive(Clone, Debug)]
pub struct SpeedupConfig {
    /// Relative band over the reference energy (0.01 = Table 5).
    pub band: f64,
    /// Iteration cap (paper: 100).
    pub max_iters: usize,
    pub set: WorkloadSet,
    /// Print per-cell progress.
    pub verbose: bool,
}

/// One (dataset, k) row of the table.
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    pub dataset: String,
    pub n: usize,
    pub d: usize,
    pub k: usize,
    /// Per method: (mean speedup over reaching seeds, oracle param).
    pub cells: Vec<(Method, Option<f64>, usize)>,
}

/// The rendered table's data.
#[derive(Clone, Debug)]
pub struct SpeedupTable {
    pub band: f64,
    pub rows: Vec<SpeedupRow>,
    /// Per method: average speedup over all cells where it succeeded.
    pub avg: Vec<(Method, Option<f64>)>,
}

/// Cost to reach the band: cumulative ops at the first trace point with
/// `energy <= target` (init ops are part of the trace's op axis).
fn ops_to_band(run: &MethodRun, target: f64) -> Option<f64> {
    run.trace.ops_to_reach(target)
}

/// Run the full protocol for every (workload, k) cell.
pub fn speedup_table(cfg: &SpeedupConfig) -> SpeedupTable {
    let set = &cfg.set;
    // Materialize datasets once (shared, read-only).
    let datasets: Vec<_> = set.workloads.iter().map(|w| w.load(DATA_SEED)).collect();

    // Cells: (workload idx, k).
    let cells: Vec<(usize, usize)> = (0..set.workloads.len())
        .flat_map(|wi| set.ks.iter().map(move |&k| (wi, k)))
        .collect();

    // Phase A: references, parallel over (cell, seed).
    let ref_tasks: Vec<(usize, u64)> = cells
        .iter()
        .enumerate()
        .flat_map(|(ci, _)| set.seeds.iter().map(move |&s| (ci, s)))
        .collect();
    let refs: Vec<MethodRun> = parallel_map(ref_tasks.len(), |ti| {
        let (ci, seed) = ref_tasks[ti];
        let (wi, k) = cells[ci];
        run_method(&datasets[wi].x, k, Method::LloydPp, 0, seed, cfg.max_iters, None)
    });
    // targets[cell][seed_idx]
    let nseeds = set.seeds.len();
    let targets: Vec<Vec<f64>> = (0..cells.len())
        .map(|ci| {
            (0..nseeds)
                .map(|si| refs[ci * nseeds + si].energy * (1.0 + cfg.band))
                .collect()
        })
        .collect();
    if cfg.verbose {
        eprintln!("[speedup] {} reference runs done", refs.len());
    }

    // Phase B: all (cell, seed, method, param) runs.
    struct Task {
        ci: usize,
        si: usize,
        method: Method,
        param: usize,
    }
    let mut tasks: Vec<Task> = Vec::new();
    for (ci, &(_, k)) in cells.iter().enumerate() {
        for si in 0..nseeds {
            for method in Method::ALL {
                if method == Method::LloydPp {
                    continue; // reference itself
                }
                if method.has_param() {
                    for &p in PARAM_GRID.iter().filter(|&&p| p <= k) {
                        tasks.push(Task { ci, si, method, param: p });
                    }
                } else {
                    tasks.push(Task { ci, si, method, param: 0 });
                }
            }
        }
    }
    let runs: Vec<MethodRun> = parallel_map(tasks.len(), |ti| {
        let t = &tasks[ti];
        let (wi, k) = cells[t.ci];
        run_method(
            &datasets[wi].x,
            k,
            t.method,
            t.param,
            set.seeds[t.si],
            cfg.max_iters,
            Some(targets[t.ci][t.si]),
        )
    });
    if cfg.verbose {
        eprintln!("[speedup] {} method runs done", runs.len());
    }

    // Aggregate. speed[cell][method][param] -> per-seed Option<speedup>.
    use std::collections::HashMap;
    let mut per: HashMap<(usize, Method, usize), Vec<Option<f64>>> = HashMap::new();
    for (ti, run) in tasks.iter().zip(&runs) {
        let target = targets[ti.ci][ti.si];
        let ref_run = &refs[ti.ci * nseeds + ti.si];
        let ref_ops = ops_to_band(ref_run, target)
            .unwrap_or(ref_run.total_ops); // converged run always reaches
        let entry = per
            .entry((ti.ci, ti.method, ti.param))
            .or_insert_with(|| vec![None; nseeds]);
        entry[ti.si] = ops_to_band(run, target).map(|ops| ref_ops / ops);
    }

    let mean_reaching = |v: &[Option<f64>]| -> Option<f64> {
        let hits: Vec<f64> = v.iter().flatten().copied().collect();
        if hits.is_empty() {
            None
        } else {
            Some(hits.iter().sum::<f64>() / hits.len() as f64)
        }
    };

    let mut rows = Vec::new();
    for (ci, &(wi, k)) in cells.iter().enumerate() {
        let mut cell_results = Vec::new();
        for method in Method::ALL {
            if method == Method::LloydPp {
                cell_results.push((method, Some(1.0), 0));
                continue;
            }
            if method.has_param() {
                // Oracle: best param by mean speedup.
                let mut best: (Option<f64>, usize) = (None, 0);
                for &p in PARAM_GRID.iter().filter(|&&p| p <= k) {
                    if let Some(v) = per.get(&(ci, method, p)) {
                        if let Some(mean) = mean_reaching(v) {
                            if best.0.map_or(true, |b| mean > b) {
                                best = (Some(mean), p);
                            }
                        }
                    }
                }
                cell_results.push((method, best.0, best.1));
            } else {
                let mean = per.get(&(ci, method, 0)).and_then(|v| mean_reaching(v));
                cell_results.push((method, mean, 0));
            }
        }
        rows.push(SpeedupRow {
            dataset: datasets[wi].name.clone(),
            n: datasets[wi].n(),
            d: datasets[wi].d(),
            k,
            cells: cell_results,
        });
        if cfg.verbose {
            eprintln!("[speedup] aggregated {}/k={}", datasets[wi].name, k);
        }
    }

    // Per-method average over successful cells (the tables' last row).
    let avg = Method::ALL
        .iter()
        .map(|&m| {
            let vals: Vec<f64> = rows
                .iter()
                .filter_map(|r| {
                    r.cells.iter().find(|(mm, _, _)| *mm == m).and_then(|(_, v, _)| *v)
                })
                .collect();
            if vals.is_empty() {
                (m, None)
            } else {
                (m, Some(vals.iter().sum::<f64>() / vals.len() as f64))
            }
        })
        .collect();

    SpeedupTable { band: cfg.band, rows, avg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::datasets::Workload;

    /// A tiny end-to-end protocol run (2 datasets, 1 k, 2 seeds) — this
    /// is the integration test of the whole oracle machinery.
    #[test]
    fn tiny_protocol_runs_and_k2means_wins_big() {
        let set = WorkloadSet {
            workloads: vec![
                Workload { name: "usps", scale: 0.07, d_cap: 32 },
                Workload { name: "mnist50", scale: 0.01, d_cap: 50 },
            ],
            ks: vec![32],
            seeds: vec![0, 1],
        };
        let cfg = SpeedupConfig { band: 0.01, max_iters: 40, set, verbose: false };
        let table = speedup_table(&cfg);
        assert_eq!(table.rows.len(), 2);
        for row in &table.rows {
            // Lloyd++ is 1.0 by definition.
            let lpp = row.cells.iter().find(|(m, _, _)| *m == Method::LloydPp).unwrap();
            assert_eq!(lpp.1, Some(1.0));
            // k2-means reached the band with some speedup.
            let k2 = row.cells.iter().find(|(m, _, _)| *m == Method::K2Means).unwrap();
            if let Some(s) = k2.1 {
                assert!(s > 0.2, "k2-means speedup suspiciously low: {s}");
            }
        }
        // The averages row exists for every method.
        assert_eq!(table.avg.len(), Method::ALL.len());
    }
}
