//! Method roster plumbing: each paper method = (initialization,
//! algorithm) pair with its own counted run.

use crate::cluster::{akm, elkan, k2means, lloyd, minibatch, Config, KmeansResult, MiniBatchOpts};
use crate::core::{Matrix, OpCounter};
use crate::init::{gdi, kmeans_pp_threaded, random_init, GdiOpts, InitResult};
use crate::metrics::Trace;

/// The methods of the paper's speedup tables (Table 5 column order).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// AKM (random init; `param` = m distance checks).
    Akm,
    /// Elkan + k-means++ init.
    ElkanPp,
    /// Elkan + random init.
    Elkan,
    /// Lloyd + k-means++ init (the reference).
    LloydPp,
    /// Lloyd + random init.
    Lloyd,
    /// MiniBatch + random init (b=100, t=n/2).
    MiniBatch,
    /// k²-means + GDI init (`param` = kn).
    K2Means,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Akm,
        Method::ElkanPp,
        Method::Elkan,
        Method::LloydPp,
        Method::Lloyd,
        Method::MiniBatch,
        Method::K2Means,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Akm => "AKM",
            Method::ElkanPp => "Elkan++",
            Method::Elkan => "Elkan",
            Method::LloydPp => "Lloyd++",
            Method::Lloyd => "Lloyd",
            Method::MiniBatch => "MiniBatch",
            Method::K2Means => "k2-means",
        }
    }

    /// Does this method have an accuracy/speed parameter to sweep?
    pub fn has_param(&self) -> bool {
        matches!(self, Method::Akm | Method::K2Means)
    }
}

/// The paper's oracle parameter grid for AKM's m and k²-means' kn (§3.4).
pub const PARAM_GRID: [usize; 8] = [3, 5, 10, 20, 30, 50, 100, 200];

/// One counted method run: init + algorithm on a shared counter, so the
/// trace's op axis includes initialization cost (the tables' convention).
#[derive(Clone, Debug)]
pub struct MethodRun {
    pub method: Method,
    pub param: usize,
    pub seed: u64,
    pub energy: f64,
    pub iters: usize,
    pub init_ops: f64,
    pub total_ops: f64,
    pub trace: Trace,
}

/// Execute `method` on `x` with `k` clusters. `param` is m for AKM and kn
/// for k²-means (ignored otherwise). `target_energy` early-stops the run
/// once the trace reaches it (oracle protocol).
///
/// Threading: runs pin `Config::threads = 1`. The grids parallelize
/// across runs via `pool::parallel_map` (one run per worker), so
/// letting each nested run auto-shard would oversubscribe every core
/// W² at `--full` scale. Sharded single runs go through the CLI
/// (`k2m cluster --threads N`) or the library API instead.
pub fn run_method(
    x: &Matrix,
    k: usize,
    method: Method,
    param: usize,
    seed: u64,
    max_iters: usize,
    target_energy: Option<f64>,
) -> MethodRun {
    let mut counter = OpCounter::default();
    let cfg = Config {
        k,
        kn: param.clamp(1, k),
        m: param.max(1),
        max_iters,
        seed,
        record_trace: true,
        target_energy,
        threads: 1, // grid-level parallelism only; see the doc comment
        ..Default::default()
    };

    type AlgoFn = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;
    let (init, algo): (_, AlgoFn) = match method {
        Method::Akm => (random_init(x, k, seed), akm as _),
        // kmeans_pp_threaded(.., 1) — same grid policy as cfg above.
        Method::ElkanPp => (kmeans_pp_threaded(x, k, &mut counter, seed, 1), elkan as _),
        Method::Elkan => (random_init(x, k, seed), elkan as _),
        Method::LloydPp => (kmeans_pp_threaded(x, k, &mut counter, seed, 1), lloyd as _),
        Method::Lloyd => (random_init(x, k, seed), lloyd as _),
        Method::MiniBatch => (random_init(x, k, seed), lloyd as _), // replaced below
        // threads: 1 — same grid policy as cfg above (GDI's scans
        // would otherwise auto-shard inside every grid worker).
        Method::K2Means => (
            gdi(x, k, &mut counter, seed, &GdiOpts { threads: 1, ..Default::default() }),
            k2means as _,
        ),
    };
    let init_ops = counter.total();

    let result = if method == Method::MiniBatch {
        minibatch(x, &init, &cfg, &MiniBatchOpts::default(), &mut counter)
    } else {
        algo(x, &init, &cfg, &mut counter)
    };

    MethodRun {
        method,
        param: if method.has_param() { param } else { 0 },
        seed,
        energy: result.energy,
        iters: result.iters,
        init_ops,
        total_ops: counter.total(),
        trace: result.trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::blobs;

    #[test]
    fn every_method_runs_and_counts() {
        let (x, _) = blobs(200, 5, 8, 15.0, 1);
        for method in Method::ALL {
            let run = run_method(&x, 5, method, 3, 0, 8, None);
            assert!(run.total_ops > 0.0, "{}", method.name());
            assert!(run.energy.is_finite(), "{}", method.name());
            assert!(!run.trace.points.is_empty(), "{}", method.name());
            // Init ops included in the trace's op axis.
            assert!(run.trace.points[0].ops >= run.init_ops);
        }
    }

    #[test]
    fn param_threads_through() {
        let (x, _) = blobs(150, 8, 6, 10.0, 2);
        let a = run_method(&x, 8, Method::K2Means, 2, 0, 5, None);
        let b = run_method(&x, 8, Method::K2Means, 8, 0, 5, None);
        assert_eq!(a.param, 2);
        assert_eq!(b.param, 8);
        assert!(a.total_ops < b.total_ops);
        let l = run_method(&x, 8, Method::Lloyd, 99, 0, 5, None);
        assert_eq!(l.param, 0); // non-parametric methods report 0
    }

    #[test]
    fn target_energy_early_stops() {
        let (x, _) = blobs(300, 6, 8, 20.0, 3);
        let free = run_method(&x, 6, Method::LloydPp, 0, 1, 100, None);
        let capped = run_method(&x, 6, Method::LloydPp, 0, 1, 100, Some(free.energy * 1.5));
        assert!(capped.total_ops <= free.total_ops);
    }
}
