//! The initialization comparison (paper Tables 4 / 7): random vs
//! k-means++ vs GDI, each followed by Lloyd to convergence; reports
//! average/minimum converged energy and initialization op cost, all
//! relative to k-means++.

use super::datasets::WorkloadSet;
use super::pool::parallel_map;
use super::speedup::DATA_SEED;
use crate::cluster::{lloyd, Config};
use crate::core::{Matrix, OpCounter};
use crate::init::{gdi, kmeans_pp, random_init, GdiOpts, InitResult};

/// The three initializations of Tables 4/7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitMethod {
    Random,
    KmeansPp,
    Gdi,
}

impl InitMethod {
    pub const ALL: [InitMethod; 3] = [InitMethod::Random, InitMethod::KmeansPp, InitMethod::Gdi];

    pub fn name(&self) -> &'static str {
        match self {
            InitMethod::Random => "random",
            InitMethod::KmeansPp => "k-means++",
            InitMethod::Gdi => "GDI",
        }
    }

    /// Run the initialization (counted).
    pub fn run(&self, x: &Matrix, k: usize, seed: u64, counter: &mut OpCounter) -> InitResult {
        match self {
            InitMethod::Random => random_init(x, k, seed),
            InitMethod::KmeansPp => kmeans_pp(x, k, counter, seed),
            // threads: 1 — the init grids parallelize across runs via
            // parallel_map; auto-sharding inside each worker would
            // oversubscribe (same policy as methods::run_method).
            InitMethod::Gdi => {
                gdi(x, k, counter, seed, &GdiOpts { threads: 1, ..Default::default() })
            }
        }
    }
}

/// One (dataset, k) row: per init, (avg energy, min energy, avg init ops),
/// absolute values (relativization happens at render time).
#[derive(Clone, Debug)]
pub struct InitRow {
    pub dataset: String,
    pub k: usize,
    /// Aligned with [`InitMethod::ALL`].
    pub avg_energy: [f64; 3],
    pub min_energy: [f64; 3],
    pub avg_init_ops: [f64; 3],
}

/// Run the comparison over the workload set.
pub fn init_table(set: &WorkloadSet, max_iters: usize, verbose: bool) -> Vec<InitRow> {
    let datasets: Vec<_> = set.workloads.iter().map(|w| w.load(DATA_SEED)).collect();
    let cells: Vec<(usize, usize)> = (0..set.workloads.len())
        .flat_map(|wi| set.ks.iter().map(move |&k| (wi, k)))
        .collect();

    // All (cell, seed, init) runs in parallel.
    let tasks: Vec<(usize, u64, usize)> = cells
        .iter()
        .enumerate()
        .flat_map(|(ci, _)| {
            set.seeds.iter().flat_map(move |&s| (0..3usize).map(move |im| (ci, s, im)))
        })
        .collect();
    let results: Vec<(f64, f64)> = parallel_map(tasks.len(), |ti| {
        let (ci, seed, im) = tasks[ti];
        let (wi, k) = cells[ci];
        let x = &datasets[wi].x;
        let mut counter = OpCounter::default();
        let init = InitMethod::ALL[im].run(x, k, seed, &mut counter);
        let init_ops = counter.total();
        let cfg = Config { k, max_iters, record_trace: false, ..Default::default() };
        let run = lloyd(x, &init, &cfg, &mut counter);
        (run.energy, init_ops)
    });
    if verbose {
        eprintln!("[init] {} runs done", results.len());
    }

    let nseeds = set.seeds.len();
    cells
        .iter()
        .enumerate()
        .map(|(ci, &(wi, k))| {
            let mut avg_energy = [0.0f64; 3];
            let mut min_energy = [f64::INFINITY; 3];
            let mut avg_init_ops = [0.0f64; 3];
            for (ti, &(tci, _, im)) in tasks.iter().enumerate() {
                if tci != ci {
                    continue;
                }
                let (e, ops) = results[ti];
                avg_energy[im] += e / nseeds as f64;
                min_energy[im] = min_energy[im].min(e);
                avg_init_ops[im] += ops / nseeds as f64;
            }
            InitRow {
                dataset: datasets[wi].name.clone(),
                k,
                avg_energy,
                min_energy,
                avg_init_ops,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::datasets::Workload;

    #[test]
    fn tiny_init_comparison() {
        // k large enough that GDI's O(n log k) beats ++'s O(nk) (the
        // crossover the paper's Table 7 shows growing with k).
        let set = WorkloadSet {
            workloads: vec![Workload { name: "usps", scale: 0.25, d_cap: 32 }],
            ks: vec![128],
            seeds: vec![0, 1, 2],
        };
        let rows = init_table(&set, 30, false);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        // random init costs zero ops; ++ costs ~n*k; GDI in between.
        assert_eq!(r.avg_init_ops[0], 0.0);
        assert!(r.avg_init_ops[1] > r.avg_init_ops[2]);
        assert!(r.avg_init_ops[2] > 0.0);
        // Energies are in the same ballpark (within 2x of each other).
        let epp = r.avg_energy[1];
        for im in 0..3 {
            assert!(r.avg_energy[im] < 2.0 * epp, "{:?}", r.avg_energy);
            assert!(r.min_energy[im] <= r.avg_energy[im] + 1e-9);
        }
    }
}
