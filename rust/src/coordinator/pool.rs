//! Scoped-thread parallel map. The experiment grids are embarrassingly
//! parallel with coarse tasks, so a work-stealing-free atomic-index queue
//! over `std::thread::scope` is all that's needed (no rayon offline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads: `K2M_THREADS` or available parallelism.
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("K2M_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every index in `0..n` across worker threads, preserving
/// order in the returned vector.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                results.lock().unwrap()[i] = Some(out);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("worker completed every task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn actually_concurrent_under_load() {
        // Not a strict concurrency proof; just exercises the multi-thread
        // path with enough tasks per worker.
        let out = parallel_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..1000u64 {
                acc = acc.wrapping_add(i as u64 * j);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }
}
