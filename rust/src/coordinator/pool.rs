//! Scoped-thread parallelism substrate — the **one** place in the crate
//! that spawns threads.
//!
//! Two primitives cover every parallel workload:
//!
//! * [`parallel_map`] — a dynamic atomic-index queue for the coarse
//!   experiment grids (tasks of wildly different cost, order-preserving
//!   results).
//! * [`sharded_reduce`] — the fine-grained **sharded execution engine**
//!   used inside the algorithms: one pass over contiguous index shards,
//!   one worker per shard, per-shard accumulators merged back **in fixed
//!   shard order**. It powers the per-point/per-row/per-cluster hot
//!   paths in [`crate::cluster`], [`crate::init`] and [`crate::knn`]:
//!   k²-means, Lloyd, Elkan, Hamerly, Yinyang, MiniBatch's batch
//!   assignment, GDI's projective-split scans, the center kNN graph,
//!   and the update step. (AKM's kd-tree queries and the k-means++ /
//!   k-means|| seeding are still serial — see ROADMAP.)
//!
//! # The `sharded_reduce` contract
//!
//! **Shard layout.** The caller splits its mutable per-item state into
//! contiguous shards (`chunks_mut(chunk_len(n, threads))` over every
//! parallel array) and passes the shard iterator in. Shard `si` owns
//! items `si * chunk .. (si + 1) * chunk`; the engine never re-splits or
//! re-orders shards. With one shard (serial, or tiny `n`) the pass runs
//! inline on the caller's thread — the serial and sharded paths execute
//! the identical closure, so they cannot drift.
//!
//! **Merge order.** Per-shard results come back as a `Vec` indexed by
//! shard, and per-shard [`OpCounter`]s are folded into the caller's
//! counter left-to-right in shard order ([`OpCounter::merge_shards`]).
//! Nothing about the merge depends on thread scheduling.
//!
//! **Determinism.** If each shard's computation reads only shared
//! immutable state plus its own shard (true for every pass in this
//! crate), the outputs are **bit-identical for any thread count**, and
//! the integer [`OpCounter`] categories (distances, inner products,
//! additions) are exactly thread-count-invariant. The one caveat is the
//! f64 `sort_scaled` category: it is a sum, so its final bits follow the
//! shard layout (identical run-to-run at a fixed thread count). The
//! contract is pinned by `rust/tests/sharding.rs` across k²-means,
//! Lloyd, Elkan, Hamerly, Yinyang, MiniBatch and GDI.
//!
//! No rayon in the offline vendor set: `std::thread::scope` plus
//! lock-free per-slot result writes is all that's needed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::core::OpCounter;

/// Number of worker threads: `K2M_THREADS` or available parallelism.
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("K2M_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Minimum points a shard must own before auto mode spends a thread on
/// it. Keeps tiny workloads (unit tests, the scaled experiment grids,
/// inner runs nested under `parallel_map`) on the serial path where
/// spawn overhead would dominate, without limiting explicit requests.
pub const MIN_AUTO_CHUNK: usize = 1024;

/// Resolve a `Config::threads`-style request into an effective thread
/// count for a pass over `n` items.
///
/// * `requested == 0` (auto): `K2M_THREADS`/available parallelism,
///   scaled down so every shard keeps at least [`MIN_AUTO_CHUNK`] items.
/// * `requested >= 1`: honored exactly (clamped to `n` so no shard is
///   empty) — this is what the 1-vs-N determinism tests rely on.
///
/// ```
/// use k2m::coordinator::pool::{resolve_threads, MIN_AUTO_CHUNK};
///
/// // Auto (0) keeps sub-shard-size workloads serial…
/// assert_eq!(resolve_threads(0, MIN_AUTO_CHUNK - 1), 1);
/// // …while explicit requests are honored exactly (clamped to n so no
/// // shard is empty). Any value yields bit-identical results.
/// assert_eq!(resolve_threads(7, 1_000_000), 7);
/// assert_eq!(resolve_threads(7, 3), 3);
/// ```
pub fn resolve_threads(requested: usize, n: usize) -> usize {
    let t = if requested == 0 {
        worker_count().min(n / MIN_AUTO_CHUNK).max(1)
    } else {
        requested
    };
    t.clamp(1, n.max(1))
}

/// Contiguous chunk length that splits `0..n` into at most `threads`
/// shards (the last may be shorter; `chunks_mut(chunk_len(..))` yields
/// exactly the shard layout the engine uses everywhere).
pub fn chunk_len(n: usize, threads: usize) -> usize {
    let t = threads.max(1);
    ((n + t - 1) / t).max(1)
}

/// The sharded execution engine's single scoped-thread scaffold: run
/// `pass(shard_index, shard, &mut shard_counter)` once per shard, each
/// shard on its own scoped worker thread, and merge the per-shard
/// accumulators back **in fixed shard order**.
///
/// * `shards` — any iterator of per-shard state. A shard is typically a
///   struct (or tuple) of `chunks_mut` slices over the caller's parallel
///   arrays, all covering the same contiguous index range; it must be
///   [`Send`] so it can move onto a worker.
/// * `counter` — the caller's [`OpCounter`]. Each shard counts into a
///   fresh shard-local counter (no `&mut` serialization through the hot
///   loops); the locals are folded into `counter` left-to-right in shard
///   order ([`OpCounter::merge_shards`]), so the integer op categories
///   are exact and thread-count-invariant.
/// * `pass` — the per-shard closure. Its first argument is the shard
///   index (multiply by the caller's chunk length for the global start
///   index). Its return values come back as a `Vec` in shard order —
///   sum them for `changed`-style tallies, or ignore them for pure
///   in-place passes.
///
/// With zero or one shard, `pass` runs inline on the caller's thread
/// against the caller's counter — no spawn, identical instructions —
/// which is exactly the serial path of the 1-vs-N determinism contract
/// (see the module docs).
///
/// ```
/// use k2m::coordinator::pool::{chunk_len, sharded_reduce};
/// use k2m::core::OpCounter;
///
/// // A pass over 10 items on 4 workers: shard the state, run the pass,
/// // reduce the per-shard partial sums in shard order.
/// let mut data = vec![1u64; 10];
/// let chunk = chunk_len(data.len(), 4); // 3 items per shard, last gets 1
/// let mut counter = OpCounter::default();
/// let partials = sharded_reduce(
///     data.chunks_mut(chunk),
///     &mut counter,
///     |si, shard: &mut [u64], ctr| {
///         for v in shard.iter_mut() {
///             *v += si as u64; // writes stay inside the shard
///             ctr.additions += 1;
///         }
///         shard.iter().sum::<u64>() // per-shard partial, merged below
///     },
/// );
/// assert_eq!(partials, vec![3, 6, 9, 4]); // shard order, not finish order
/// assert_eq!(partials.iter().sum::<u64>(), 22);
/// assert_eq!(counter.additions, 10); // shard counters fold back exactly
/// ```
pub fn sharded_reduce<S, R, F, I>(shards: I, counter: &mut OpCounter, pass: F) -> Vec<R>
where
    I: IntoIterator<Item = S>,
    S: Send,
    R: Send,
    F: Fn(usize, S, &mut OpCounter) -> R + Sync,
{
    let shards: Vec<S> = shards.into_iter().collect();
    if shards.len() <= 1 {
        // Serial fast path: same closure, caller's counter, no spawn.
        return shards.into_iter().map(|shard| pass(0, shard, counter)).collect();
    }
    let results: Vec<(R, OpCounter)> = std::thread::scope(|scope| {
        let pass = &pass;
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(si, shard)| {
                scope.spawn(move || {
                    let mut ctr = OpCounter::default();
                    let out = pass(si, shard, &mut ctr);
                    (out, ctr)
                })
            })
            .collect();
        // Joining in spawn order (not finish order) fixes the merge
        // order below regardless of scheduling.
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut out = Vec::with_capacity(results.len());
    let mut ctrs = Vec::with_capacity(results.len());
    for (r, ctr) in results {
        out.push(r);
        ctrs.push(ctr);
    }
    counter.merge_shards(ctrs);
    out
}

/// Apply `f` to every index in `0..n` across worker threads, preserving
/// order in the returned vector.
///
/// Work distribution is a dynamic atomic-index queue (tasks may have
/// very different costs in the experiment grids); each result lands in
/// its own pre-allocated [`OnceLock`] slot, so there is no shared lock
/// on the results — the fix for the per-task mutex contention that made
/// the old pool unusable for fine-grained work. (`T: Sync` because the
/// slot vector is shared across workers; every result type in the
/// grids is plain data.)
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                // Each index is handed out exactly once, so the slot is
                // always empty; set() cannot fail.
                let _ = results[i].set(out);
            });
        }
    });
    results
        .into_iter()
        .map(|slot| slot.into_inner().expect("worker completed every task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn actually_concurrent_under_load() {
        // Not a strict concurrency proof; just exercises the multi-thread
        // path with enough tasks per worker.
        let out = parallel_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..1000u64 {
                acc = acc.wrapping_add(i as u64 * j);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn uneven_task_costs_land_in_order() {
        // Heavier early tasks finish last under the dynamic queue; the
        // per-slot writes must still reassemble in index order.
        let out = parallel_map(32, |i| {
            let spin = if i < 4 { 200_000u64 } else { 100 };
            let mut acc = 0u64;
            for j in 0..spin {
                acc = acc.wrapping_add(j ^ i as u64);
            }
            (i, acc)
        });
        for (i, (gi, _)) in out.iter().enumerate() {
            assert_eq!(i, *gi);
        }
    }

    #[test]
    fn resolve_threads_policy() {
        // Explicit requests are honored, clamped to n.
        assert_eq!(resolve_threads(8, 100_000), 8);
        assert_eq!(resolve_threads(8, 3), 3);
        assert_eq!(resolve_threads(1, 50), 1);
        // Auto keeps small passes serial.
        assert_eq!(resolve_threads(0, 100), 1);
        assert_eq!(resolve_threads(0, MIN_AUTO_CHUNK - 1), 1);
        // Auto never exceeds the worker count and never returns 0.
        let auto = resolve_threads(0, 1 << 20);
        assert!(auto >= 1 && auto <= worker_count());
        assert_eq!(resolve_threads(0, 0), 1);
    }

    #[test]
    fn chunk_len_covers_exactly() {
        for (n, t) in [(10, 3), (9, 3), (1, 8), (0, 4), (100, 1), (7, 7)] {
            let c = chunk_len(n, t);
            assert!(c >= 1);
            let chunks = if n == 0 { 0 } else { (n + c - 1) / c };
            assert!(chunks <= t.max(1), "n={n} t={t} -> {chunks} chunks");
            assert!(chunks * c >= n);
        }
    }

    #[test]
    fn sharded_reduce_results_in_shard_order() {
        let mut data: Vec<u32> = (0..37).collect();
        let chunk = chunk_len(data.len(), 5);
        let mut counter = OpCounter::default();
        let firsts = sharded_reduce(
            data.chunks_mut(chunk),
            &mut counter,
            |si, shard: &mut [u32], _ctr| (si, shard[0]),
        );
        // Results are indexed by shard regardless of which thread
        // finished first.
        for (i, &(si, first)) in firsts.iter().enumerate() {
            assert_eq!(si, i);
            assert_eq!(first as usize, i * chunk);
        }
    }

    #[test]
    fn sharded_reduce_merges_counters_exactly() {
        let mut data = vec![0u8; 1000];
        for threads in [1usize, 3, 7, 16] {
            let chunk = chunk_len(data.len(), threads);
            let mut counter = OpCounter::default();
            sharded_reduce(data.chunks_mut(chunk), &mut counter, |_si, shard: &mut [u8], ctr| {
                ctr.distances += shard.len() as u64;
                ctr.additions += 1;
            });
            assert_eq!(counter.distances, 1000, "threads={threads}");
            let shards = (1000 + chunk - 1) / chunk;
            assert_eq!(counter.additions, shards as u64, "threads={threads}");
        }
    }

    #[test]
    fn sharded_reduce_single_shard_runs_inline() {
        // One shard: the pass must see the caller's counter directly
        // (pre-seeded value survives and is added to, not replaced).
        let mut data = vec![1u64; 8];
        let mut counter = OpCounter { distances: 5, ..Default::default() };
        let sums = sharded_reduce(
            data.chunks_mut(8),
            &mut counter,
            |si, shard: &mut [u64], ctr| {
                assert_eq!(si, 0);
                ctr.distances += shard.len() as u64;
                shard.iter().sum::<u64>()
            },
        );
        assert_eq!(sums, vec![8]);
        assert_eq!(counter.distances, 13);
    }

    #[test]
    fn sharded_reduce_empty_is_empty() {
        let mut data: Vec<u64> = Vec::new();
        let mut counter = OpCounter::default();
        let out: Vec<u64> =
            sharded_reduce(data.chunks_mut(4), &mut counter, |_si, shard: &mut [u64], _c| {
                shard.iter().sum()
            });
        assert!(out.is_empty());
        assert_eq!(counter, OpCounter::default());
    }

    #[test]
    fn sharded_reduce_disjoint_writes_compose() {
        // The canonical engine usage: two parallel arrays sharded with
        // the same chunk length, written in place, verified globally.
        let n = 103usize;
        let mut a: Vec<u32> = vec![0; n];
        let mut b: Vec<u32> = vec![0; n];
        let chunk = chunk_len(n, 4);
        let mut counter = OpCounter::default();
        sharded_reduce(
            a.chunks_mut(chunk).zip(b.chunks_mut(chunk)),
            &mut counter,
            |si, (ac, bc): (&mut [u32], &mut [u32]), _ctr| {
                let start = si * chunk;
                for (off, (av, bv)) in ac.iter_mut().zip(bc.iter_mut()).enumerate() {
                    *av = (start + off) as u32;
                    *bv = 2 * (start + off) as u32;
                }
            },
        );
        for i in 0..n {
            assert_eq!(a[i], i as u32);
            assert_eq!(b[i], 2 * i as u32);
        }
    }
}
