//! The persistent worker pool — the **one** place in the crate that owns
//! threads.
//!
//! Three primitives cover every parallel workload, all dispatching onto
//! the same resident workers:
//!
//! * [`fn@parallel_map`] — a dynamic shared-index queue for the coarse
//!   experiment grids and the [`crate::coordinator::jobs`] scheduler
//!   (tasks of wildly different cost, order-preserving results).
//! * [`WorkerPool::stream`] — the submit-while-running variant of
//!   `parallel_map`: resident runner tasks pull items as they are
//!   submitted, so a caller can enqueue work against an open channel
//!   and collect submission-ordered results at [`PoolStream::finish`]
//!   (the [`crate::coordinator::jobs::JobStream`] path).
//! * [`fn@sharded_reduce`] — the fine-grained **sharded execution
//!   engine** used inside the algorithms: one pass over contiguous index
//!   shards, one task per shard, per-shard accumulators merged back **in
//!   fixed shard order**. It powers the per-point/per-row/per-cluster
//!   hot paths in [`crate::cluster`], [`crate::init`] and [`crate::knn`]:
//!   k²-means, Lloyd, Elkan, Hamerly, Yinyang, MiniBatch's batch
//!   assignment, AKM's kd-tree queries, the k-means++ / k-means||
//!   seeding scans, GDI's projective-split scans, the center kNN graph,
//!   and the update step.
//!
//! # Pool lifecycle
//!
//! **Startup.** [`WorkerPool::new`] spawns exactly `threads` OS threads
//! (`k2m-pool-N`) that live for the pool's lifetime. The process-wide
//! [`default_pool`] is built lazily on the first multi-shard dispatch,
//! sized by [`worker_count`] — the `K2M_THREADS` env var (else available
//! parallelism), **read once per process** and cached, so no hot path
//! ever touches `std::env`. Explicit `WorkerPool::new(threads)` exists
//! for tests that need an isolated pool.
//!
//! **Parking.** Idle workers block on a condvar guarding the shared task
//! queue — zero CPU between passes. A dispatch pushes one task per shard
//! and wakes workers; the caller blocks on a per-pass completion latch
//! until every shard task has finished. This replaces the per-pass
//! `thread::scope` spawn/join of the previous engine: the short passes
//! the paper optimizes for (small n per shard, hundreds of clusters) no
//! longer pay thread creation on every iteration.
//!
//! **Nested dispatch.** A task that itself calls [`fn@sharded_reduce`] /
//! [`fn@parallel_map`] (a grid run, a [`crate::coordinator::jobs`] job)
//! executes its shards *inline on the worker, in shard order* — never
//! re-entering the queue. That makes nested use deadlock-free and keeps
//! outer × inner thread usage bounded by the pool width, and because
//! results depend only on the shard layout (see the contract below) the
//! inline execution is bit-identical to a dispatched one.
//!
//! **Panic propagation.** A panicking shard task is caught on the
//! worker, recorded in the pass's latch, and **re-raised on the calling
//! thread** after every sibling shard of that pass has completed (the
//! tasks borrow the caller's stack frame, so the caller must not unwind
//! before they all finish). Workers survive task panics and go back to
//! parking; the pool stays usable.
//!
//! **Shutdown.** Dropping a `WorkerPool` flags shutdown, wakes all
//! workers, and joins them; workers drain any queued tasks before
//! exiting. The default pool is `'static` and lives until process exit.
//!
//! # The `sharded_reduce` contract
//!
//! **Shard layout.** The caller splits its mutable per-item state into
//! contiguous shards (`chunks_mut(chunk_len(n, threads))` over every
//! parallel array) and passes the shard iterator in. Shard `si` owns
//! items `si * chunk .. (si + 1) * chunk`; the engine never re-splits or
//! re-orders shards. With one shard (serial, or tiny `n`) the pass runs
//! inline on the caller's thread — the serial and sharded paths execute
//! the identical closure, so they cannot drift.
//!
//! **Merge order.** Per-shard results come back as a `Vec` indexed by
//! shard, and per-shard [`OpCounter`]s are folded into the caller's
//! counter left-to-right in shard order ([`OpCounter::merge_shards`]).
//! Nothing about the merge depends on thread scheduling — or on whether
//! shards ran dispatched, queued behind other passes, or inline.
//!
//! **Determinism.** If each shard's computation reads only shared
//! immutable state plus its own shard (true for every pass in this
//! crate), the outputs are **bit-identical for any thread count**, and
//! the integer [`OpCounter`] categories (distances, inner products,
//! additions) are exactly thread-count-invariant. The one caveat is the
//! f64 `sort_scaled` category: it is a sum, so its final bits follow the
//! shard layout (identical run-to-run at a fixed thread count). The
//! contract is pinned by `rust/tests/sharding.rs` across the full
//! roster, including AKM and the k-means++ / k-means|| seedings.
//!
//! No rayon in the offline vendor set: resident `std::thread` workers, a
//! condvar-parked queue, and lock-free per-slot result writes are all
//! that's needed.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};

use crate::core::OpCounter;

/// Number of worker threads the default pool is built with:
/// `K2M_THREADS` (else available parallelism), resolved through
/// [`crate::core::env::knob`] — **once per process** on first use and
/// cached, consistent with the pool's own lifetime, and keeping
/// `std::env` reads out of the per-pass hot paths ([`resolve_threads`]
/// calls this on every auto-mode pass).
pub fn worker_count() -> usize {
    static ENV_THREADS: OnceLock<usize> = OnceLock::new();
    crate::core::env::knob(
        &ENV_THREADS,
        "K2M_THREADS",
        |s| s.parse::<usize>().ok().map(|n| n.max(1)),
        || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
    )
}

/// The process-wide pool: built lazily on first use, `worker_count()`
/// resident workers, lives until process exit. Every free-function
/// dispatch ([`fn@sharded_reduce`], [`fn@parallel_map`]) lands here, so
/// repeated passes — the paper's regime of many cheap iterations — reuse
/// the same parked threads instead of spawning fresh ones.
pub fn default_pool() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(worker_count()))
}

/// Default minimum points a shard must own before auto mode spends a
/// thread on it. Keeps tiny workloads (unit tests, the scaled experiment
/// grids, inner runs nested under `parallel_map`) on the serial path
/// where dispatch overhead would dominate, without limiting explicit
/// requests. Tunable without a rebuild via `K2M_SHARD_MIN` — see
/// [`min_auto_chunk`].
pub const MIN_AUTO_CHUNK: usize = 1024;

/// The effective auto-mode shard-size floor: `K2M_SHARD_MIN` (clamped to
/// `>= 1`), read **once per process** and cached like `K2M_THREADS`;
/// unset or unparsable values fall back to [`MIN_AUTO_CHUNK`].
///
/// The 1024-point default was tuned for the strict distance tier; the
/// fast tier (`K2M_NUMERICS=fast`) makes each shard's scan cheaper, so
/// deployments can lower the floor (more parallelism on mid-size passes)
/// or raise it (less dispatch on oversubscribed boxes) per machine:
///
/// ```text
/// K2M_SHARD_MIN=512 K2M_NUMERICS=fast k2m cluster --dataset mnist50 --k 200
/// ```
pub fn min_auto_chunk() -> usize {
    static SHARD_MIN: OnceLock<usize> = OnceLock::new();
    crate::core::env::knob(&SHARD_MIN, "K2M_SHARD_MIN", parse_shard_min, || MIN_AUTO_CHUNK)
}

/// Parse rule behind [`min_auto_chunk`], on top of the shared
/// [`crate::core::env::parse_knob`] policy (trim, garbage → default):
/// `0` is clamped to 1, because a zero floor would divide by zero in
/// auto mode.
fn parse_shard_min(s: &str) -> Option<usize> {
    s.parse::<usize>().ok().map(|n| n.max(1))
}

/// Resolve a `Config::threads`-style request into an effective thread
/// count for a pass over `n` items.
///
/// * `requested == 0` (auto): `K2M_THREADS`/available parallelism,
///   scaled down so every shard keeps at least [`min_auto_chunk`] items
///   ([`MIN_AUTO_CHUNK`] unless overridden via `K2M_SHARD_MIN`).
/// * `requested >= 1`: honored exactly (clamped to `n` so no shard is
///   empty) — this is what the 1-vs-N determinism tests rely on.
///
/// ```
/// use k2m::coordinator::pool::{resolve_threads, MIN_AUTO_CHUNK};
///
/// // Auto (0) keeps sub-shard-size workloads serial…
/// assert_eq!(resolve_threads(0, MIN_AUTO_CHUNK - 1), 1);
/// // …while explicit requests are honored exactly (clamped to n so no
/// // shard is empty). Any value yields bit-identical results.
/// assert_eq!(resolve_threads(7, 1_000_000), 7);
/// assert_eq!(resolve_threads(7, 3), 3);
/// ```
pub fn resolve_threads(requested: usize, n: usize) -> usize {
    let t = if requested == 0 {
        worker_count().min(n / min_auto_chunk()).max(1)
    } else {
        requested
    };
    t.clamp(1, n.max(1))
}

/// Contiguous chunk length that splits `0..n` into at most `threads`
/// shards (the last may be shorter; `chunks_mut(chunk_len(..))` yields
/// exactly the shard layout the engine uses everywhere).
pub fn chunk_len(n: usize, threads: usize) -> usize {
    n.div_ceil(threads.max(1)).max(1)
}

// ---------------------------------------------------------------------
// Pool internals
// ---------------------------------------------------------------------

/// A lifetime-erased unit of work. Dispatch erases the borrow of the
/// caller's stack frame (`unsafe`, see [`WorkerPool::dispatch_shards`]);
/// the per-pass latch guarantees the frame outlives every task.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Task {
    job: Job,
    latch: Arc<PassLatch>,
}

struct QueueState {
    tasks: VecDeque<Task>,
    shutdown: bool,
}

struct PoolInner {
    queue: Mutex<QueueState>,
    /// Signalled when a task is pushed (workers park here when idle).
    available: Condvar,
}

impl PoolInner {
    fn submit(&self, task: Task) {
        plock(&self.queue).tasks.push_back(task);
        self.available.notify_one();
    }
}

/// Completion latch for one dispatched pass: the caller blocks until
/// every task of the pass has run, and the first task panic is carried
/// back to be re-raised on the calling thread.
///
/// The count starts at zero and is [`register`]ed up immediately before
/// each task is queued, so a wait only ever covers tasks that really
/// entered the queue — if the submit loop unwinds partway, the guard
/// drains exactly the already-queued tasks instead of hanging on ones
/// that will never exist.
///
/// [`register`]: PassLatch::register
struct PassLatch {
    state: Mutex<LatchState>,
    done: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn Any + Send>>,
}

impl PassLatch {
    fn new() -> PassLatch {
        PassLatch {
            state: Mutex::new(LatchState { remaining: 0, panic: None }),
            done: Condvar::new(),
        }
    }

    /// Count one task in, just before it is queued. (A worker cannot
    /// complete a task before it is queued, so the count never goes
    /// transiently negative; it can touch zero mid-submission, but
    /// nobody waits until submission is done.)
    fn register(&self) {
        plock(&self.state).remaining += 1;
    }

    /// Called by a worker after running one task of the pass (with the
    /// panic payload if the task unwound).
    fn complete(&self, panic: Option<Box<dyn Any + Send>>) {
        let mut st = plock(&self.state);
        if st.panic.is_none() {
            st.panic = panic;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every task has completed, then re-raise the first
    /// task panic (after — never before — all siblings finished, since
    /// the tasks borrow the caller's frame).
    fn wait(&self) {
        let mut st = plock(&self.state);
        while st.remaining > 0 {
            st = pwait(&self.done, st);
        }
        let panic = st.panic.take();
        drop(st);
        if let Some(p) = panic {
            resume_unwind(p);
        }
    }

    /// Latch-only wait (no panic propagation) — the unwind-safety net.
    fn wait_quiet(&self) {
        let mut st = plock(&self.state);
        while st.remaining > 0 {
            st = pwait(&self.done, st);
        }
    }
}

/// Blocks in `drop` until every task registered so far completes —
/// makes dispatch safe even if the submitting loop itself unwinds: the
/// borrowed, already-queued tasks always finish before the caller's
/// frame is torn down (and never-queued ones were never registered).
struct CompletionGuard<'a>(&'a PassLatch);

impl Drop for CompletionGuard<'_> {
    fn drop(&mut self) {
        self.0.wait_quiet();
    }
}

fn plock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // Task panics are caught on the workers and never poison the pool
    // locks while held; tolerate poisoning anyway so one odd unwind
    // can't wedge the whole process.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn pwait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

thread_local! {
    /// Set (forever) on pool worker threads; [`in_pool_worker`] is how
    /// nested dispatches detect they must run inline.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// True on a pool worker thread (any pool). Nested [`fn@sharded_reduce`]
/// / [`fn@parallel_map`] calls check this and run inline — deadlock-free
/// by construction, bit-identical by the engine contract.
pub fn in_pool_worker() -> bool {
    IS_POOL_WORKER.with(|f| f.get())
}

/// The nested-dispatch execution path: run the shards inline, in shard
/// order, with per-shard counters merged exactly like a dispatch —
/// bit-identical output (same layout, same merge order), no queue
/// re-entry, no deadlock.
fn run_shards_inline<S, R, F>(shards: Vec<S>, counter: &mut OpCounter, pass: F) -> Vec<R>
where
    F: Fn(usize, S, &mut OpCounter) -> R,
{
    let mut ctrs = Vec::with_capacity(shards.len());
    let out: Vec<R> = shards
        .into_iter()
        .enumerate()
        .map(|(si, shard)| {
            let mut ctr = OpCounter::default();
            let r = pass(si, shard, &mut ctr);
            ctrs.push(ctr);
            r
        })
        .collect();
    counter.merge_shards(ctrs);
    out
}

fn worker_loop(inner: Arc<PoolInner>) {
    IS_POOL_WORKER.with(|f| f.set(true));
    loop {
        let task = {
            let mut q = plock(&inner.queue);
            loop {
                if let Some(t) = q.tasks.pop_front() {
                    break t;
                }
                if q.shutdown {
                    // Queue drained and shutdown flagged: exit.
                    return;
                }
                q = pwait(&inner.available, q);
            }
        };
        let Task { job, latch } = task;
        let outcome = catch_unwind(AssertUnwindSafe(move || job()));
        latch.complete(outcome.err());
    }
}

// ---------------------------------------------------------------------
// WorkerPool
// ---------------------------------------------------------------------

/// A persistent pool of parked worker threads. See the module docs for
/// the lifecycle (startup, parking, nested dispatch, panic propagation,
/// shutdown-on-drop) and the `sharded_reduce` contract it preserves.
///
/// Production code uses the process-wide [`default_pool`] through the
/// free functions; construct an explicit pool only when a test needs
/// isolation (e.g. pinning behavior at a worker count independent of
/// `K2M_THREADS`).
///
/// ```
/// use k2m::coordinator::pool::WorkerPool;
/// use k2m::core::OpCounter;
///
/// let pool = WorkerPool::new(3);
/// let mut data = vec![0u32; 9];
/// let mut ctr = OpCounter::default();
/// let firsts = pool.sharded_reduce(
///     data.chunks_mut(3),
///     &mut ctr,
///     |si, shard: &mut [u32], _c| {
///         for v in shard.iter_mut() {
///             *v = si as u32;
///         }
///         shard[0]
///     },
/// );
/// assert_eq!(data, [0, 0, 0, 1, 1, 1, 2, 2, 2]);
/// assert_eq!(firsts, [0, 1, 2]); // shard order, not finish order
/// // The pool is reusable: the workers are parked again, not joined.
/// let sums = pool.sharded_reduce(data.chunks_mut(3), &mut ctr, |_si, shard: &mut [u32], _c| {
///     shard.iter().sum::<u32>()
/// });
/// assert_eq!(sums, [0, 3, 6]);
/// ```
pub struct WorkerPool {
    inner: Arc<PoolInner>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn a pool of `threads.max(1)` resident workers.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let inner = Arc::new(PoolInner {
            queue: Mutex::new(QueueState { tasks: VecDeque::new(), shutdown: false }),
            available: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|wi| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("k2m-pool-{wi}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn pool worker")
            })
            .collect();
        WorkerPool { inner, workers, threads }
    }

    /// Number of resident workers. Passes may submit more shards than
    /// this (explicit `threads` requests are honored exactly); the extra
    /// shards queue and run as workers free up — same results, by the
    /// layout-only determinism contract.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The pool-method form of [`fn@sharded_reduce`] — identical
    /// contract, explicit pool.
    pub fn sharded_reduce<S, R, F, I>(&self, shards: I, counter: &mut OpCounter, pass: F) -> Vec<R>
    where
        I: IntoIterator<Item = S>,
        S: Send,
        R: Send,
        F: Fn(usize, S, &mut OpCounter) -> R + Sync,
    {
        let shards: Vec<S> = shards.into_iter().collect();
        self.sharded_reduce_vec(shards, counter, pass)
    }

    fn sharded_reduce_vec<S, R, F>(
        &self,
        shards: Vec<S>,
        counter: &mut OpCounter,
        pass: F,
    ) -> Vec<R>
    where
        S: Send,
        R: Send,
        F: Fn(usize, S, &mut OpCounter) -> R + Sync,
    {
        if shards.len() <= 1 {
            // Serial fast path: same closure, caller's counter, no
            // dispatch.
            return shards.into_iter().map(|shard| pass(0, shard, counter)).collect();
        }
        if in_pool_worker() {
            return run_shards_inline(shards, counter, pass);
        }
        self.dispatch_shards(shards, counter, pass)
    }

    /// Queue one task per shard on the resident workers and block on the
    /// pass latch until all complete; merge per-shard counters in shard
    /// order.
    fn dispatch_shards<S, R, F>(&self, shards: Vec<S>, counter: &mut OpCounter, pass: F) -> Vec<R>
    where
        S: Send,
        R: Send,
        F: Fn(usize, S, &mut OpCounter) -> R + Sync,
    {
        let m = shards.len();
        // One uncontended slot per shard (written once by one worker,
        // read after the latch opens). Mutex rather than OnceLock keeps
        // the bound at `R: Send`, matching the scoped-spawn engine.
        let slots: Vec<Mutex<Option<(R, OpCounter)>>> = (0..m).map(|_| Mutex::new(None)).collect();
        let latch = Arc::new(PassLatch::new());
        {
            // Even if submission itself unwinds, the guard blocks until
            // every already-queued task (which borrows this frame) has
            // finished — and only those, thanks to per-submit register.
            let _guard = CompletionGuard(&latch);
            let pass_ref = &pass;
            let slots_ref = &slots;
            for (si, shard) in shards.into_iter().enumerate() {
                let task_latch = Arc::clone(&latch);
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let mut ctr = OpCounter::default();
                    let out = pass_ref(si, shard, &mut ctr);
                    *plock(&slots_ref[si]) = Some((out, ctr));
                });
                // SAFETY: the job borrows `pass`, `slots` and the moved
                // shard state from this stack frame. `latch.wait()`
                // below (and the guard on the unwind path) does not
                // return until every job has completed, so the erased
                // borrows never outlive their referents.
                let job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                };
                latch.register();
                self.inner.submit(Task { job, latch: task_latch });
            }
            // Re-raises the first worker panic once all shards finished.
            latch.wait();
        }
        let mut out = Vec::with_capacity(m);
        let mut ctrs = Vec::with_capacity(m);
        for slot in slots {
            let (r, ctr) = slot
                .into_inner()
                .unwrap_or_else(|e| e.into_inner())
                .expect("pool worker completed every shard");
            out.push(r);
            ctrs.push(ctr);
        }
        counter.merge_shards(ctrs);
        out
    }

    /// The pool-method form of [`fn@parallel_map`]: width defaults to
    /// the pool's worker count.
    pub fn parallel_map<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + Sync,
        F: Fn(usize) -> T + Sync,
    {
        self.parallel_map_bounded(n, self.threads, f)
    }

    /// Apply `f` to every index in `0..n` with at most `width` tasks in
    /// flight, preserving order in the returned vector.
    ///
    /// Work distribution is a dynamic shared-index queue (tasks may have
    /// very different costs in the experiment grids); each result lands
    /// in its own pre-allocated [`OnceLock`] slot, so there is no shared
    /// lock on the results. `width` is the **concurrency budget**: the
    /// pool runs `min(width, n)` runner tasks, each pulling the next
    /// index — this is how [`crate::coordinator::jobs::JobQueue`] caps
    /// concurrent jobs below the worker count.
    pub fn parallel_map_bounded<T, F>(&self, n: usize, width: usize, f: F) -> Vec<T>
    where
        T: Send + Sync,
        F: Fn(usize) -> T + Sync,
    {
        let runners = width.max(1).min(n.max(1));
        if runners <= 1 || n <= 1 || in_pool_worker() {
            // Serial / nested path: same closure, index order.
            return (0..n).map(f).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
        let latch = Arc::new(PassLatch::new());
        {
            let _guard = CompletionGuard(&latch);
            let f_ref = &f;
            let slots_ref = &slots;
            let next_ref = &next;
            for _ in 0..runners {
                let task_latch = Arc::clone(&latch);
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || loop {
                    let i = next_ref.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // Each index is handed out exactly once, so the slot
                    // is always empty; set() cannot fail.
                    let _ = slots_ref[i].set(f_ref(i));
                });
                // SAFETY: as in `dispatch_shards` — the latch (and the
                // guard on the unwind path) keeps this frame alive until
                // every runner has exited.
                let job = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Job>(job)
                };
                latch.register();
                self.inner.submit(Task { job, latch: task_latch });
            }
            latch.wait();
        }
        slots
            .into_iter()
            .map(|slot| slot.into_inner().expect("pool worker completed every task"))
            .collect()
    }

    /// Open a streaming submission channel: up to `width` resident
    /// runner tasks pull items as they are submitted, so submission and
    /// execution **overlap** — unlike [`WorkerPool::parallel_map`],
    /// which needs the whole work list up front. The serve/jobs layers
    /// use this for submit-while-running request handling
    /// ([`crate::coordinator::jobs::JobStream`]).
    ///
    /// Items are processed by `f(index, item)` (index = submission
    /// order); [`PoolStream::finish`] closes the channel, waits for the
    /// runners, and returns the results **in submission order**. All
    /// state is `'static` (`Arc`-owned) — no borrow of the submitting
    /// frame — so no unsafe lifetime erasure is involved; a panicking
    /// `f` is re-raised by `finish` via the pass latch, like any
    /// dispatched shard.
    ///
    /// **Caveat**: the runners are resident pool tasks for the stream's
    /// whole lifetime. Between `submit` calls the *submitting* thread
    /// must not dispatch pool passes of its own (with `width` runners
    /// parked on the stream, a full-width stream leaves no worker free
    /// and the dispatch would wait until `finish`). Work *inside* `f`
    /// may freely use nested `sharded_reduce`/`parallel_map` — nested
    /// dispatch runs inline on the runner, per the pool contract.
    pub fn stream<I, T, F>(&self, width: usize, f: F) -> PoolStream<I, T>
    where
        I: Send + 'static,
        T: Send + 'static,
        F: Fn(usize, I) -> T + Send + Sync + 'static,
    {
        let runners = width.clamp(1, self.threads);
        let state = Arc::new(StreamState {
            queue: Mutex::new(StreamQueue {
                pending: VecDeque::new(),
                results: Vec::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        });
        let latch = Arc::new(PassLatch::new());
        let f = Arc::new(f);
        for _ in 0..runners {
            let st = Arc::clone(&state);
            let fr = Arc::clone(&f);
            let job: Job = Box::new(move || stream_runner(&st, &*fr));
            latch.register();
            self.inner.submit(Task { job, latch: Arc::clone(&latch) });
        }
        PoolStream { state, latch, runners }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut q = plock(&self.inner.queue);
            q.shutdown = true;
        }
        self.inner.available.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------
// Streaming submission (the submit-while-running primitive)
// ---------------------------------------------------------------------

/// Shared state of one [`PoolStream`]: a closable work queue plus
/// grow-only result slots. Fully owned (`'static`) by the runners and
/// the handle together, so — unlike the pass primitives — no task
/// borrows the submitting frame.
struct StreamState<I, T> {
    queue: Mutex<StreamQueue<I, T>>,
    /// Signalled on every push and on close (runners park here).
    ready: Condvar,
}

struct StreamQueue<I, T> {
    pending: VecDeque<(usize, I)>,
    /// One slot per submitted item, indexed by submission order; a
    /// runner fills slot `i` when item `i` completes.
    results: Vec<Option<T>>,
    closed: bool,
}

/// Handle to an open streaming channel — see [`WorkerPool::stream`].
/// Dropping the handle without calling [`PoolStream::finish`] closes
/// the channel and waits for the runners (without re-raising panics or
/// returning results), so a leaked stream cannot wedge the pool.
pub struct PoolStream<I, T> {
    state: Arc<StreamState<I, T>>,
    latch: Arc<PassLatch>,
    runners: usize,
}

impl<I, T> PoolStream<I, T> {
    /// Queue one item; returns its submission index (= its slot in
    /// [`PoolStream::finish`]'s result vector). Never blocks on the
    /// runners.
    pub fn submit(&self, item: I) -> usize {
        let mut q = plock(&self.state.queue);
        debug_assert!(!q.closed);
        let id = q.results.len();
        q.results.push(None);
        q.pending.push_back((id, item));
        drop(q);
        self.state.ready.notify_one();
        id
    }

    /// Number of runner tasks serving this stream.
    pub fn width(&self) -> usize {
        self.runners
    }

    /// Close the channel, wait for every runner to drain and exit, and
    /// return the results in submission order. The first panic raised
    /// inside the stream's closure is re-raised here (after all runners
    /// have exited), like any dispatched pass.
    pub fn finish(self) -> Vec<T> {
        {
            let mut q = plock(&self.state.queue);
            q.closed = true;
        }
        self.state.ready.notify_all();
        self.latch.wait();
        let mut q = plock(&self.state.queue);
        let results = std::mem::take(&mut q.results);
        results
            .into_iter()
            .map(|slot| slot.expect("stream runner completed every submitted item"))
            .collect()
    }
}

impl<I, T> Drop for PoolStream<I, T> {
    fn drop(&mut self) {
        // Idempotent after `finish` (channel already closed, latch at
        // zero). On the non-finish path this releases the runners so
        // they cannot occupy pool workers forever; `wait_quiet` because
        // propagating panics out of drop would abort.
        {
            let mut q = plock(&self.state.queue);
            q.closed = true;
        }
        self.state.ready.notify_all();
        self.latch.wait_quiet();
    }
}

fn stream_runner<I, T, F: Fn(usize, I) -> T>(state: &StreamState<I, T>, f: &F) {
    loop {
        let (id, item) = {
            let mut q = plock(&state.queue);
            loop {
                if let Some(next) = q.pending.pop_front() {
                    break next;
                }
                if q.closed {
                    return;
                }
                q = pwait(&state.ready, q);
            }
        };
        let out = f(id, item);
        plock(&state.queue).results[id] = Some(out);
    }
}

// ---------------------------------------------------------------------
// Free-function entry points (the default pool)
// ---------------------------------------------------------------------

/// The sharded execution engine's single dispatch point: run
/// `pass(shard_index, shard, &mut shard_counter)` once per shard on the
/// process-wide [`default_pool`]'s resident workers, and merge the
/// per-shard accumulators back **in fixed shard order**.
///
/// * `shards` — any iterator of per-shard state. A shard is typically a
///   struct (or tuple) of `chunks_mut` slices over the caller's parallel
///   arrays, all covering the same contiguous index range; it must be
///   [`Send`] so it can move onto a worker.
/// * `counter` — the caller's [`OpCounter`]. Each shard counts into a
///   fresh shard-local counter (no `&mut` serialization through the hot
///   loops); the locals are folded into `counter` left-to-right in shard
///   order ([`OpCounter::merge_shards`]), so the integer op categories
///   are exact and thread-count-invariant.
/// * `pass` — the per-shard closure. Its first argument is the shard
///   index (multiply by the caller's chunk length for the global start
///   index). Its return values come back as a `Vec` in shard order —
///   sum them for `changed`-style tallies, or ignore them for pure
///   in-place passes.
///
/// With zero or one shard, `pass` runs inline on the caller's thread
/// against the caller's counter — no dispatch, identical instructions —
/// which is exactly the serial path of the 1-vs-N determinism contract
/// (see the module docs). On a pool worker (nested use) the shards run
/// inline in shard order, also bit-identical.
///
/// ```
/// use k2m::coordinator::pool::{chunk_len, sharded_reduce};
/// use k2m::core::OpCounter;
///
/// // A pass over 10 items on 4 workers: shard the state, run the pass,
/// // reduce the per-shard partial sums in shard order.
/// let mut data = vec![1u64; 10];
/// let chunk = chunk_len(data.len(), 4); // 3 items per shard, last gets 1
/// let mut counter = OpCounter::default();
/// let partials = sharded_reduce(
///     data.chunks_mut(chunk),
///     &mut counter,
///     |si, shard: &mut [u64], ctr| {
///         for v in shard.iter_mut() {
///             *v += si as u64; // writes stay inside the shard
///             ctr.additions += 1;
///         }
///         shard.iter().sum::<u64>() // per-shard partial, merged below
///     },
/// );
/// assert_eq!(partials, vec![3, 6, 9, 4]); // shard order, not finish order
/// assert_eq!(partials.iter().sum::<u64>(), 22);
/// assert_eq!(counter.additions, 10); // shard counters fold back exactly
/// ```
pub fn sharded_reduce<S, R, F, I>(shards: I, counter: &mut OpCounter, pass: F) -> Vec<R>
where
    I: IntoIterator<Item = S>,
    S: Send,
    R: Send,
    F: Fn(usize, S, &mut OpCounter) -> R + Sync,
{
    let shards: Vec<S> = shards.into_iter().collect();
    if shards.len() <= 1 {
        // Serial fast path: never touches (or lazily builds) the pool.
        return shards.into_iter().map(|shard| pass(0, shard, counter)).collect();
    }
    if in_pool_worker() {
        // Nested (the caller already occupies a worker of some pool):
        // run inline without lazily building the default pool either.
        return run_shards_inline(shards, counter, pass);
    }
    default_pool().sharded_reduce_vec(shards, counter, pass)
}

/// Apply `f` to every index in `0..n` across the [`default_pool`]'s
/// workers, preserving order in the returned vector. See
/// [`WorkerPool::parallel_map_bounded`] for the queue semantics. Serial
/// workloads (`n <= 1`, one-worker pools) and nested calls never touch
/// the pool.
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    if n <= 1 || worker_count() <= 1 || in_pool_worker() {
        return (0..n).map(f).collect();
    }
    default_pool().parallel_map(n, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn actually_concurrent_under_load() {
        // Not a strict concurrency proof; just exercises the multi-task
        // path with enough tasks per worker.
        let out = parallel_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..1000u64 {
                acc = acc.wrapping_add(i as u64 * j);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn uneven_task_costs_land_in_order() {
        // Heavier early tasks finish last under the dynamic queue; the
        // per-slot writes must still reassemble in index order.
        let out = parallel_map(32, |i| {
            let spin = if i < 4 { 200_000u64 } else { 100 };
            let mut acc = 0u64;
            for j in 0..spin {
                acc = acc.wrapping_add(j ^ i as u64);
            }
            (i, acc)
        });
        for (i, (gi, _)) in out.iter().enumerate() {
            assert_eq!(i, *gi);
        }
    }

    #[test]
    fn resolve_threads_policy() {
        // Explicit requests are honored, clamped to n.
        assert_eq!(resolve_threads(8, 100_000), 8);
        assert_eq!(resolve_threads(8, 3), 3);
        assert_eq!(resolve_threads(1, 50), 1);
        // Auto keeps small passes serial.
        assert_eq!(resolve_threads(0, 100), 1);
        assert_eq!(resolve_threads(0, MIN_AUTO_CHUNK - 1), 1);
        // Auto never exceeds the worker count and never returns 0.
        let auto = resolve_threads(0, 1 << 20);
        assert!(auto >= 1 && auto <= worker_count());
        assert_eq!(resolve_threads(0, 0), 1);
    }

    #[test]
    fn shard_min_parse_policy() {
        // The K2M_SHARD_MIN rule, tested through the shared env-knob
        // policy so it needs no process-env mutation: garbage/unset fall
        // back to the default, zero clamps to 1, whitespace is trimmed
        // by `parse_knob`, real values pass through.
        use crate::core::env::parse_knob;
        let resolve = |raw: Option<&str>| parse_knob(raw, parse_shard_min, || MIN_AUTO_CHUNK);
        assert_eq!(resolve(None), MIN_AUTO_CHUNK);
        assert_eq!(resolve(Some("")), MIN_AUTO_CHUNK);
        assert_eq!(resolve(Some("abc")), MIN_AUTO_CHUNK);
        assert_eq!(resolve(Some("-3")), MIN_AUTO_CHUNK);
        assert_eq!(resolve(Some("0")), 1);
        assert_eq!(resolve(Some("1")), 1);
        assert_eq!(resolve(Some(" 512 ")), 512);
        assert_eq!(resolve(Some("4096")), 4096);
    }

    #[test]
    fn shard_min_is_cached_and_drives_auto_mode() {
        // One env resolution per process; auto mode keeps passes below
        // one floor's worth of points serial whatever the floor is.
        let floor = min_auto_chunk();
        assert_eq!(floor, min_auto_chunk());
        assert!(floor >= 1);
        assert_eq!(resolve_threads(0, floor.saturating_sub(1)), 1);
        // Explicit requests ignore the floor entirely.
        assert_eq!(resolve_threads(3, floor.max(4)), 3);
    }

    #[test]
    fn worker_count_is_cached_and_stable() {
        // One env resolution per process: repeated calls agree (the
        // OnceLock result), and stay >= 1.
        let a = worker_count();
        let b = worker_count();
        assert_eq!(a, b);
        assert!(a >= 1);
    }

    #[test]
    fn chunk_len_covers_exactly() {
        for (n, t) in [(10, 3), (9, 3), (1, 8), (0, 4), (100, 1), (7, 7)] {
            let c = chunk_len(n, t);
            assert!(c >= 1);
            let chunks = if n == 0 { 0 } else { n.div_ceil(c) };
            assert!(chunks <= t.max(1), "n={n} t={t} -> {chunks} chunks");
            assert!(chunks * c >= n);
        }
    }

    #[test]
    fn sharded_reduce_results_in_shard_order() {
        let mut data: Vec<u32> = (0..37).collect();
        let chunk = chunk_len(data.len(), 5);
        let mut counter = OpCounter::default();
        let firsts = sharded_reduce(
            data.chunks_mut(chunk),
            &mut counter,
            |si, shard: &mut [u32], _ctr| (si, shard[0]),
        );
        // Results are indexed by shard regardless of which worker
        // finished first.
        for (i, &(si, first)) in firsts.iter().enumerate() {
            assert_eq!(si, i);
            assert_eq!(first as usize, i * chunk);
        }
    }

    #[test]
    fn sharded_reduce_merges_counters_exactly() {
        let mut data = vec![0u8; 1000];
        for threads in [1usize, 3, 7, 16] {
            let chunk = chunk_len(data.len(), threads);
            let mut counter = OpCounter::default();
            sharded_reduce(data.chunks_mut(chunk), &mut counter, |_si, shard: &mut [u8], ctr| {
                ctr.distances += shard.len() as u64;
                ctr.additions += 1;
            });
            assert_eq!(counter.distances, 1000, "threads={threads}");
            let shards = 1000usize.div_ceil(chunk);
            assert_eq!(counter.additions, shards as u64, "threads={threads}");
        }
    }

    #[test]
    fn sharded_reduce_single_shard_runs_inline() {
        // One shard: the pass must see the caller's counter directly
        // (pre-seeded value survives and is added to, not replaced).
        let mut data = vec![1u64; 8];
        let mut counter = OpCounter { distances: 5, ..Default::default() };
        let sums = sharded_reduce(
            data.chunks_mut(8),
            &mut counter,
            |si, shard: &mut [u64], ctr| {
                assert_eq!(si, 0);
                ctr.distances += shard.len() as u64;
                shard.iter().sum::<u64>()
            },
        );
        assert_eq!(sums, vec![8]);
        assert_eq!(counter.distances, 13);
    }

    #[test]
    fn sharded_reduce_empty_is_empty() {
        let mut data: Vec<u64> = Vec::new();
        let mut counter = OpCounter::default();
        let out: Vec<u64> =
            sharded_reduce(data.chunks_mut(4), &mut counter, |_si, shard: &mut [u64], _c| {
                shard.iter().sum()
            });
        assert!(out.is_empty());
        assert_eq!(counter, OpCounter::default());
    }

    #[test]
    fn sharded_reduce_disjoint_writes_compose() {
        // The canonical engine usage: two parallel arrays sharded with
        // the same chunk length, written in place, verified globally.
        let n = 103usize;
        let mut a: Vec<u32> = vec![0; n];
        let mut b: Vec<u32> = vec![0; n];
        let chunk = chunk_len(n, 4);
        let mut counter = OpCounter::default();
        sharded_reduce(
            a.chunks_mut(chunk).zip(b.chunks_mut(chunk)),
            &mut counter,
            |si, (ac, bc): (&mut [u32], &mut [u32]), _ctr| {
                let start = si * chunk;
                for (off, (av, bv)) in ac.iter_mut().zip(bc.iter_mut()).enumerate() {
                    *av = (start + off) as u32;
                    *bv = 2 * (start + off) as u32;
                }
            },
        );
        for i in 0..n {
            assert_eq!(a[i], i as u32);
            assert_eq!(b[i], 2 * i as u32);
        }
    }

    #[test]
    fn pool_is_reused_across_passes() {
        // Many short passes on one explicit pool: results identical each
        // time (the pool holds no pass state between dispatches).
        let pool = WorkerPool::new(4);
        let mut want: Option<Vec<u64>> = None;
        for _ in 0..50 {
            let mut data: Vec<u64> = (0..1000).collect();
            let chunk = chunk_len(data.len(), 4);
            let mut counter = OpCounter::default();
            let sums = pool.sharded_reduce(
                data.chunks_mut(chunk),
                &mut counter,
                |_si, shard: &mut [u64], ctr| {
                    ctr.additions += shard.len() as u64;
                    shard.iter().sum::<u64>()
                },
            );
            assert_eq!(counter.additions, 1000);
            match &want {
                None => want = Some(sums),
                Some(w) => assert_eq!(&sums, w),
            }
        }
    }

    #[test]
    fn more_shards_than_workers_queue_up() {
        // 2 workers, 16 shards: extra shards wait in the queue; results
        // and counters still come back in shard order.
        let pool = WorkerPool::new(2);
        let mut data: Vec<u64> = (0..64).collect();
        let mut counter = OpCounter::default();
        let firsts = pool.sharded_reduce(
            data.chunks_mut(4),
            &mut counter,
            |si, shard: &mut [u64], ctr| {
                ctr.distances += 1;
                (si, shard[0])
            },
        );
        assert_eq!(firsts.len(), 16);
        for (i, &(si, first)) in firsts.iter().enumerate() {
            assert_eq!(si, i);
            assert_eq!(first, (i * 4) as u64);
        }
        assert_eq!(counter.distances, 16);
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u32; 9];
        let mut counter = OpCounter::default();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.sharded_reduce(data.chunks_mut(3), &mut counter, |si, _shard: &mut [u32], _c| {
                if si == 1 {
                    panic!("shard 1 exploded");
                }
                si
            });
        }));
        assert!(caught.is_err(), "the shard panic must re-raise on the caller");
        // The workers caught the panic and went back to parking: the
        // pool still dispatches fine.
        let mut counter = OpCounter::default();
        let out =
            pool.sharded_reduce(data.chunks_mut(3), &mut counter, |si, _s: &mut [u32], _c| si);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn nested_dispatch_runs_inline_and_matches() {
        // An outer parallel_map task calling sharded_reduce must not
        // deadlock (workers never wait on queued subtasks) and must give
        // the same answer as a top-level dispatch.
        let pool = WorkerPool::new(2);
        let expect: Vec<u64> = (0..4)
            .map(|t| {
                let mut data: Vec<u64> = (0..200).map(|v| v + t).collect();
                let chunk = chunk_len(data.len(), 4);
                let mut counter = OpCounter::default();
                let sums = pool.sharded_reduce(
                    data.chunks_mut(chunk),
                    &mut counter,
                    |_si, shard: &mut [u64], _c| shard.iter().sum::<u64>(),
                );
                sums.into_iter().sum::<u64>()
            })
            .collect();
        let got: Vec<u64> = pool.parallel_map(4, |t| {
            let mut data: Vec<u64> = (0..200).map(|v| v + t as u64).collect();
            let chunk = chunk_len(data.len(), 4);
            let mut counter = OpCounter::default();
            // Nested: runs inline on the worker, same shard layout.
            let sums = pool.sharded_reduce(
                data.chunks_mut(chunk),
                &mut counter,
                |_si, shard: &mut [u64], _c| shard.iter().sum::<u64>(),
            );
            sums.into_iter().sum::<u64>()
        });
        assert_eq!(got, expect);
    }

    #[test]
    fn parallel_map_bounded_caps_width() {
        // width=1 degenerates to the serial path; width > n clamps.
        let pool = WorkerPool::new(4);
        assert_eq!(pool.parallel_map_bounded(6, 1, |i| i * 2), vec![0, 2, 4, 6, 8, 10]);
        assert_eq!(pool.parallel_map_bounded(3, 64, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn drop_joins_workers_cleanly() {
        // Dispatch, drop, and rebuild a few pools: no hangs, no leaks of
        // queued work (drop drains the queue before joining).
        for round in 0..3 {
            let pool = WorkerPool::new(3);
            let out = pool.parallel_map(8, |i| i + round);
            assert_eq!(out.len(), 8);
            drop(pool);
        }
    }

    #[test]
    fn stream_returns_results_in_submission_order() {
        let pool = WorkerPool::new(3);
        let stream = pool.stream(2, |id: usize, item: u64| (id as u64) * 1000 + item * item);
        assert_eq!(stream.width(), 2);
        for v in 0..20u64 {
            assert_eq!(stream.submit(v), v as usize);
        }
        let out = stream.finish();
        let want: Vec<u64> = (0..20u64).map(|v| v * 1000 + v * v).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn stream_overlaps_submission_with_execution() {
        // Items submitted *after* earlier ones have already been pulled
        // still land in their slots; interleave submits with real work
        // inside the closure (including a nested pool pass).
        let pool = WorkerPool::new(4);
        let stream = pool.stream(4, |_id, n: usize| {
            let mut data: Vec<u64> = (0..n as u64).collect();
            let chunk = chunk_len(data.len().max(1), 2);
            let mut counter = OpCounter::default();
            // Free-function form: the closure must be 'static, and a
            // nested dispatch from a pool worker runs inline anyway.
            let sums = sharded_reduce(
                data.chunks_mut(chunk),
                &mut counter,
                |_si, shard: &mut [u64], _c| shard.iter().sum::<u64>(),
            );
            sums.into_iter().sum::<u64>()
        });
        for n in [100usize, 3, 57, 0, 9, 300, 1] {
            stream.submit(n);
            // Give runners a chance to start pulling before the next
            // submit — the overlap this primitive exists for.
            std::thread::yield_now();
        }
        let out = stream.finish();
        let want: Vec<u64> =
            [100usize, 3, 57, 0, 9, 300, 1].iter().map(|&n| (0..n as u64).sum()).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn stream_panic_reraises_on_finish_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let stream = pool.stream(2, |_id, v: u32| {
            if v == 7 {
                panic!("item 7 exploded");
            }
            v * 2
        });
        for v in [1u32, 7, 3] {
            stream.submit(v);
        }
        let caught = catch_unwind(AssertUnwindSafe(|| stream.finish()));
        assert!(caught.is_err(), "the item panic must re-raise on finish");
        // Runners exited; the pool still dispatches fine.
        let out = pool.parallel_map(4, |i| i);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn dropped_stream_releases_its_runners() {
        let pool = WorkerPool::new(2);
        let stream = pool.stream(2, |_id, v: u32| v);
        stream.submit(5);
        drop(stream); // close + drain without collecting results
        // All workers are free again for normal passes.
        let out = pool.parallel_map(4, |i| i * 3);
        assert_eq!(out, vec![0, 3, 6, 9]);
    }
}
