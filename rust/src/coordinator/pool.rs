//! Scoped-thread parallelism substrate — shared by the coarse experiment
//! grids (`parallel_map`) and the fine-grained sharded execution engine
//! inside the algorithms (`resolve_threads` + per-pass `thread::scope`
//! loops in `cluster::*` / `knn::brute`).
//!
//! No rayon in the offline vendor set: an atomic-index queue over
//! `std::thread::scope` with lock-free per-slot result writes is all
//! that's needed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads: `K2M_THREADS` or available parallelism.
pub fn worker_count() -> usize {
    if let Ok(v) = std::env::var("K2M_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Minimum points a shard must own before auto mode spends a thread on
/// it. Keeps tiny workloads (unit tests, the scaled experiment grids,
/// inner runs nested under `parallel_map`) on the serial path where
/// spawn overhead would dominate, without limiting explicit requests.
pub const MIN_AUTO_CHUNK: usize = 1024;

/// Resolve a `Config::threads`-style request into an effective thread
/// count for a pass over `n` items.
///
/// * `requested == 0` (auto): `K2M_THREADS`/available parallelism,
///   scaled down so every shard keeps at least [`MIN_AUTO_CHUNK`] items.
/// * `requested >= 1`: honored exactly (clamped to `n` so no shard is
///   empty) — this is what the 1-vs-N determinism tests rely on.
pub fn resolve_threads(requested: usize, n: usize) -> usize {
    let t = if requested == 0 {
        worker_count().min(n / MIN_AUTO_CHUNK).max(1)
    } else {
        requested
    };
    t.clamp(1, n.max(1))
}

/// Contiguous chunk length that splits `0..n` into at most `threads`
/// shards (the last may be shorter; `chunks_mut(chunk_len(..))` yields
/// exactly the shard layout the engine uses everywhere).
pub fn chunk_len(n: usize, threads: usize) -> usize {
    let t = threads.max(1);
    ((n + t - 1) / t).max(1)
}

/// Apply `f` to every index in `0..n` across worker threads, preserving
/// order in the returned vector.
///
/// Work distribution is a dynamic atomic-index queue (tasks may have
/// very different costs in the experiment grids); each result lands in
/// its own pre-allocated [`OnceLock`] slot, so there is no shared lock
/// on the results — the fix for the per-task mutex contention that made
/// the old pool unusable for fine-grained work. (`T: Sync` because the
/// slot vector is shared across workers; every result type in the
/// grids is plain data.)
pub fn parallel_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + Sync,
    F: Fn(usize) -> T + Sync,
{
    let workers = worker_count().min(n.max(1));
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Vec<OnceLock<T>> = (0..n).map(|_| OnceLock::new()).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                // Each index is handed out exactly once, so the slot is
                // always empty; set() cannot fail.
                let _ = results[i].set(out);
            });
        }
    });
    results
        .into_iter()
        .map(|slot| slot.into_inner().expect("worker completed every task"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        assert!(parallel_map(0, |i| i).is_empty());
        assert_eq!(parallel_map(1, |i| i + 7), vec![7]);
    }

    #[test]
    fn actually_concurrent_under_load() {
        // Not a strict concurrency proof; just exercises the multi-thread
        // path with enough tasks per worker.
        let out = parallel_map(64, |i| {
            let mut acc = 0u64;
            for j in 0..1000u64 {
                acc = acc.wrapping_add(i as u64 * j);
            }
            acc
        });
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn uneven_task_costs_land_in_order() {
        // Heavier early tasks finish last under the dynamic queue; the
        // per-slot writes must still reassemble in index order.
        let out = parallel_map(32, |i| {
            let spin = if i < 4 { 200_000u64 } else { 100 };
            let mut acc = 0u64;
            for j in 0..spin {
                acc = acc.wrapping_add(j ^ i as u64);
            }
            (i, acc)
        });
        for (i, (gi, _)) in out.iter().enumerate() {
            assert_eq!(i, *gi);
        }
    }

    #[test]
    fn resolve_threads_policy() {
        // Explicit requests are honored, clamped to n.
        assert_eq!(resolve_threads(8, 100_000), 8);
        assert_eq!(resolve_threads(8, 3), 3);
        assert_eq!(resolve_threads(1, 50), 1);
        // Auto keeps small passes serial.
        assert_eq!(resolve_threads(0, 100), 1);
        assert_eq!(resolve_threads(0, MIN_AUTO_CHUNK - 1), 1);
        // Auto never exceeds the worker count and never returns 0.
        let auto = resolve_threads(0, 1 << 20);
        assert!(auto >= 1 && auto <= worker_count());
        assert_eq!(resolve_threads(0, 0), 1);
    }

    #[test]
    fn chunk_len_covers_exactly() {
        for (n, t) in [(10, 3), (9, 3), (1, 8), (0, 4), (100, 1), (7, 7)] {
            let c = chunk_len(n, t);
            assert!(c >= 1);
            let chunks = if n == 0 { 0 } else { (n + c - 1) / c };
            assert!(chunks <= t.max(1), "n={n} t={t} -> {chunks} chunks");
            assert!(chunks * c >= n);
        }
    }
}
