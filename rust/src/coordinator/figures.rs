//! Convergence-curve emission for the paper's Figures 2/3 (energy vs
//! counted ops per method) and Figure 4 (AKM/k²-means parameter sweeps).
//! Output is CSV — one file per (dataset, k) — with energies relative to
//! the best Lloyd++ converged energy, exactly the quantity the paper
//! plots.

use std::path::Path;

use anyhow::{Context, Result};

use super::datasets::Workload;
use super::methods::{run_method, Method, MethodRun, PARAM_GRID};
use super::pool::parallel_map;
use super::speedup::DATA_SEED;

/// Figure-2/3 roster: the datasets and ks the paper plots.
pub fn fig2_cells(full: bool) -> Vec<(Workload, usize)> {
    let names = ["cifar", "cnnvoc", "mnist", "mnist50"];
    let ks: Vec<usize> = if full { vec![50, 200, 1000] } else { vec![50, 200] };
    names
        .iter()
        .flat_map(|&name| {
            let w = if full {
                Workload { name, scale: 1.0, d_cap: usize::MAX }
            } else {
                super::datasets::scaled_default(name)
            };
            ks.iter().map(move |&k| (w.clone(), k))
        })
        .collect()
}

/// Emit one CSV per (dataset, k): `method,param,iter,ops,energy_rel`.
/// For AKM/k²-means, uses the paper's rule — the parameter with the
/// highest speedup at the 1% band.
pub fn emit_fig2(out_dir: &Path, full: bool, max_iters: usize) -> Result<Vec<String>> {
    std::fs::create_dir_all(out_dir)?;
    let cells = fig2_cells(full);
    let seed = 0u64;
    let mut written = Vec::new();

    for (w, k) in cells {
        let ds = w.load(DATA_SEED);
        // Reference + band for oracle param selection.
        let reference = run_method(&ds.x, k, Method::LloydPp, 0, seed, max_iters, None);
        let e_ref = reference.energy;
        let target = e_ref * 1.01;

        // All runs (params unbounded by target so curves are complete).
        struct Curve {
            method: Method,
            param: usize,
            run: MethodRun,
        }
        let mut jobs: Vec<(Method, usize)> = Vec::new();
        for m in Method::ALL {
            if m == Method::LloydPp {
                continue;
            }
            if m.has_param() {
                for &p in PARAM_GRID.iter().filter(|&&p| p <= k) {
                    jobs.push((m, p));
                }
            } else {
                jobs.push((m, 0));
            }
        }
        let runs: Vec<Curve> = parallel_map(jobs.len(), |ji| {
            let (m, p) = jobs[ji];
            Curve { method: m, param: p, run: run_method(&ds.x, k, m, p, seed, max_iters, None) }
        });

        // Oracle pick per parametric method (highest speedup at 1%).
        let ref_ops = reference.trace.ops_to_reach(target).unwrap_or(reference.total_ops);
        let mut best_param: std::collections::HashMap<Method, usize> = Default::default();
        for m in [Method::Akm, Method::K2Means] {
            let mut best: (f64, usize) = (-1.0, 0);
            for c in runs.iter().filter(|c| c.method == m) {
                if let Some(ops) = c.run.trace.ops_to_reach(target) {
                    let speedup = ref_ops / ops;
                    if speedup > best.0 {
                        best = (speedup, c.param);
                    }
                }
            }
            best_param.insert(m, best.1);
        }

        let mut csv = String::from("method,param,iter,ops,energy_rel\n");
        let mut push_curve = |name: &str, param: usize, run: &MethodRun| {
            for p in &run.trace.points {
                csv.push_str(&format!(
                    "{},{},{},{:.1},{:.6}\n",
                    name,
                    param,
                    p.iter,
                    p.ops,
                    p.energy / e_ref
                ));
            }
        };
        push_curve("Lloyd++", 0, &reference);
        for c in &runs {
            let keep = if c.method.has_param() {
                best_param.get(&c.method) == Some(&c.param)
            } else {
                true
            };
            if keep {
                push_curve(c.method.name(), c.param, &c.run);
            }
        }
        let fname = format!("fig2_{}_k{}.csv", ds.name, k);
        std::fs::write(out_dir.join(&fname), &csv)
            .with_context(|| format!("write {fname}"))?;
        eprintln!("[fig2] wrote {fname}");
        written.push(fname);
    }
    Ok(written)
}

/// Figure 4: full parameter sweeps for AKM (m) and k²-means (kn) on the
/// same cells — every parameter's curve, not just the oracle's.
pub fn emit_fig4(out_dir: &Path, full: bool, max_iters: usize) -> Result<Vec<String>> {
    std::fs::create_dir_all(out_dir)?;
    let cells = fig2_cells(full);
    let seed = 0u64;
    let mut written = Vec::new();

    for (w, k) in cells {
        let ds = w.load(DATA_SEED);
        let reference = run_method(&ds.x, k, Method::LloydPp, 0, seed, max_iters, None);
        let e_ref = reference.energy;

        let mut jobs: Vec<(Method, usize)> = Vec::new();
        for m in [Method::Akm, Method::K2Means] {
            for &p in PARAM_GRID.iter().filter(|&&p| p <= k) {
                jobs.push((m, p));
            }
        }
        let runs: Vec<MethodRun> = parallel_map(jobs.len(), |ji| {
            let (m, p) = jobs[ji];
            run_method(&ds.x, k, m, p, seed, max_iters, None)
        });

        let mut csv = String::from("method,param,iter,ops,energy_rel\n");
        for ((m, p), run) in jobs.iter().zip(&runs) {
            for pt in &run.trace.points {
                csv.push_str(&format!(
                    "{},{},{},{:.1},{:.6}\n",
                    m.name(),
                    p,
                    pt.iter,
                    pt.ops,
                    pt.energy / e_ref
                ));
            }
        }
        let fname = format!("fig4_{}_k{}.csv", ds.name, k);
        std::fs::write(out_dir.join(&fname), &csv)
            .with_context(|| format!("write {fname}"))?;
        eprintln!("[fig4] wrote {fname}");
        written.push(fname);
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_cells_roster() {
        let cells = fig2_cells(false);
        assert_eq!(cells.len(), 4 * 2);
        let cells_full = fig2_cells(true);
        assert_eq!(cells_full.len(), 4 * 3);
        assert!(cells_full.iter().any(|(w, k)| w.name == "cifar" && *k == 1000));
    }
}
