//! Plain-text rendering of the paper's tables + CSV escape hatch.

use super::inits::{InitMethod, InitRow};
use super::methods::Method;
use super::speedup::SpeedupTable;

/// Render a speedup table in the paper's layout (Tables 5/6/8–11):
/// one row per (dataset, k), one column per method, `-` for failures,
/// the oracle's param in brackets for AKM / k²-means.
pub fn render_speedup(table: &SpeedupTable) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Algorithmic speedup vs Lloyd++ at {:.1}% band (oracle params in brackets)\n",
        table.band * 100.0
    ));
    out.push_str(&format!(
        "{:<14}{:>7}{:>7}{:>6}",
        "dataset", "n", "d", "k"
    ));
    for m in Method::ALL {
        out.push_str(&format!("{:>14}", m.name()));
    }
    out.push('\n');
    for row in &table.rows {
        out.push_str(&format!(
            "{:<14}{:>7}{:>7}{:>6}",
            row.dataset, row.n, row.d, row.k
        ));
        for (m, v, p) in &row.cells {
            let cell = match v {
                Some(s) if m.has_param() => format!("{s:.1} [{p}]"),
                Some(s) => format!("{s:.1}"),
                None => "-".to_string(),
            };
            out.push_str(&format!("{cell:>14}"));
        }
        out.push('\n');
    }
    out.push_str(&format!("{:<34}", "avg. speedup"));
    for (_, v) in &table.avg {
        let cell = v.map_or("-".to_string(), |s| format!("{s:.1}"));
        out.push_str(&format!("{cell:>14}"));
    }
    out.push('\n');
    out
}

/// CSV form of a speedup table (for downstream plotting).
pub fn speedup_csv(table: &SpeedupTable) -> String {
    let mut out = String::from("dataset,n,d,k,method,speedup,param\n");
    for row in &table.rows {
        for (m, v, p) in &row.cells {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                row.dataset,
                row.n,
                row.d,
                row.k,
                m.name(),
                v.map_or(String::from(""), |s| format!("{s:.4}")),
                p
            ));
        }
    }
    out
}

/// Render the init comparison (Tables 4/7), values relative to k-means++
/// exactly as the paper prints them.
pub fn render_init(rows: &[InitRow], per_k: bool) -> String {
    let mut out = String::new();
    out.push_str(
        "Initialization comparison (relative to k-means++)\n\
         columns: avg energy | min energy | init ops, per method\n",
    );
    out.push_str(&format!("{:<14}{:>6}", "dataset", "k"));
    for m in InitMethod::ALL {
        out.push_str(&format!("{:>11}.E", m.name()));
    }
    for m in InitMethod::ALL {
        out.push_str(&format!("{:>10}.mE", m.name()));
    }
    for m in InitMethod::ALL {
        out.push_str(&format!("{:>9}.ops", m.name()));
    }
    out.push('\n');

    // Optionally aggregate across k per dataset (paper Table 4 averages
    // over its k grid; Table 7 is per-k).
    let mut agg: Vec<InitRow> = Vec::new();
    if per_k {
        agg = rows.to_vec();
    } else {
        let mut names: Vec<String> = rows.iter().map(|r| r.dataset.clone()).collect();
        names.dedup();
        for name in names {
            let group: Vec<&InitRow> = rows.iter().filter(|r| r.dataset == name).collect();
            let nk = group.len() as f64;
            let mut row = InitRow {
                dataset: name,
                k: 0,
                avg_energy: [0.0; 3],
                min_energy: [0.0; 3],
                avg_init_ops: [0.0; 3],
            };
            // Paper averages the *relative* values across k settings.
            for g in &group {
                for i in 0..3 {
                    row.avg_energy[i] += g.avg_energy[i] / g.avg_energy[1] / nk;
                    row.min_energy[i] += g.min_energy[i] / g.min_energy[1] / nk;
                    let rel_ops = if g.avg_init_ops[1] > 0.0 {
                        g.avg_init_ops[i] / g.avg_init_ops[1]
                    } else {
                        0.0
                    };
                    row.avg_init_ops[i] += rel_ops / nk;
                }
            }
            // Mark as already relative.
            row.k = usize::MAX;
            agg.push(row);
        }
    }

    for row in &agg {
        let (rel_e, rel_me, rel_ops): ([f64; 3], [f64; 3], [f64; 3]) = if row.k == usize::MAX {
            (row.avg_energy, row.min_energy, row.avg_init_ops)
        } else {
            let mut e = [0.0; 3];
            let mut me = [0.0; 3];
            let mut ops = [0.0; 3];
            for i in 0..3 {
                e[i] = row.avg_energy[i] / row.avg_energy[1];
                me[i] = row.min_energy[i] / row.min_energy[1];
                ops[i] = if row.avg_init_ops[1] > 0.0 {
                    row.avg_init_ops[i] / row.avg_init_ops[1]
                } else {
                    0.0
                };
            }
            (e, me, ops)
        };
        let kcol = if row.k == usize::MAX { "all".to_string() } else { row.k.to_string() };
        out.push_str(&format!("{:<14}{:>6}", row.dataset, kcol));
        for v in rel_e {
            out.push_str(&format!("{v:>13.3}"));
        }
        for v in rel_me {
            out.push_str(&format!("{v:>12.3}"));
        }
        for v in rel_ops {
            out.push_str(&format!("{v:>13.3}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::speedup::SpeedupRow;

    fn fake_table() -> SpeedupTable {
        let cells = vec![
            (Method::Akm, Some(8.7), 20),
            (Method::ElkanPp, Some(3.6), 0),
            (Method::Elkan, None, 0),
            (Method::LloydPp, Some(1.0), 0),
            (Method::Lloyd, Some(1.1), 0),
            (Method::MiniBatch, None, 0),
            (Method::K2Means, Some(33.0), 30),
        ];
        SpeedupTable {
            band: 0.01,
            rows: vec![SpeedupRow {
                dataset: "mnist50".into(),
                n: 60000,
                d: 50,
                k: 200,
                cells,
            }],
            avg: Method::ALL.iter().map(|&m| (m, Some(2.0))).collect(),
        }
    }

    #[test]
    fn speedup_render_contains_key_cells() {
        let s = render_speedup(&fake_table());
        assert!(s.contains("mnist50"));
        assert!(s.contains("33.0 [30]"));
        assert!(s.contains('-'));
        assert!(s.contains("avg. speedup"));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let s = speedup_csv(&fake_table());
        assert!(s.starts_with("dataset,n,d,k,method,speedup,param"));
        assert_eq!(s.lines().count(), 1 + 7);
        assert!(s.contains("k2-means,33.0000,30"));
    }

    #[test]
    fn init_render_relativizes() {
        let rows = vec![InitRow {
            dataset: "usps".into(),
            k: 100,
            avg_energy: [102.0, 100.0, 99.0],
            min_energy: [101.0, 100.0, 99.5],
            avg_init_ops: [0.0, 1000.0, 100.0],
        }];
        let s = render_init(&rows, true);
        assert!(s.contains("1.020"), "{s}");
        assert!(s.contains("0.990"), "{s}");
        assert!(s.contains("0.100"), "{s}");
        let agg = render_init(&rows, false);
        assert!(agg.contains("all"));
    }
}
