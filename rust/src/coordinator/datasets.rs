//! Workload construction for the experiment grids.
//!
//! The paper's full grid (8 datasets up to n=150000, d=32256, k=1000,
//! 100 Lloyd iterations, 8-param oracle sweeps) is hours of single-node
//! compute. The default grids therefore run *scaled* workloads — same
//! generators, reduced `n` (generator scale) and `d` (seeded gaussian
//! random projection, which preserves relative distances by
//! Johnson–Lindenstrauss) — while `--full` reproduces the paper's sizes.
//! Scaling preserves what the tables measure: *relative* op counts
//! between methods as functions of (n, k, kn, m). See EXPERIMENTS.md.

use crate::data::{self, random_projection, Dataset};

/// One dataset's workload parameters.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: &'static str,
    /// Multiplies the paper's n.
    pub scale: f64,
    /// Cap on d; larger dimensions are randomly projected down.
    pub d_cap: usize,
}

impl Workload {
    /// Materialize the dataset (generation + optional projection).
    pub fn load(&self, seed: u64) -> Dataset {
        let ds = data::by_name(self.name, self.scale, seed)
            .unwrap_or_else(|| panic!("unknown dataset {}", self.name));
        if ds.d() > self.d_cap {
            let x = random_projection(&ds.x, self.d_cap, seed ^ 0xd0_00c4);
            Dataset { name: ds.name, x, seed }
        } else {
            ds
        }
    }
}

/// A set of workloads + the k grid and seed count for an experiment.
#[derive(Clone, Debug)]
pub struct WorkloadSet {
    pub workloads: Vec<Workload>,
    pub ks: Vec<usize>,
    pub seeds: Vec<u64>,
}

/// The speedup-table roster (paper Tables 5/6/8–11).
pub fn speedup_set(full: bool, seeds: usize) -> WorkloadSet {
    let seeds = (0..seeds as u64).collect();
    if full {
        WorkloadSet {
            workloads: data::SPEEDUP_ROSTER
                .iter()
                .map(|&name| Workload { name, scale: 1.0, d_cap: usize::MAX })
                .collect(),
            ks: vec![50, 200, 1000],
            seeds,
        }
    } else {
        WorkloadSet {
            workloads: data::SPEEDUP_ROSTER
                .iter()
                .map(|&name| scaled_default(name))
                .collect(),
            ks: vec![50, 200],
            seeds,
        }
    }
}

/// The init-comparison roster (paper Tables 4/7 exclude cifar/tiny10k).
pub fn init_set(full: bool, seeds: usize) -> WorkloadSet {
    let seeds = (0..seeds as u64).collect();
    if full {
        WorkloadSet {
            workloads: data::INIT_ROSTER
                .iter()
                .map(|&name| Workload { name, scale: 1.0, d_cap: usize::MAX })
                .collect(),
            ks: vec![100, 200, 500],
            seeds,
        }
    } else {
        WorkloadSet {
            workloads: data::INIT_ROSTER.iter().map(|&name| scaled_default(name)).collect(),
            ks: vec![100, 200],
            seeds,
        }
    }
}

/// Default scaled workload per dataset: n capped near 2000, d near 128.
/// Paper n values: cifar 50000, cnnvoc 15662, covtype 150000,
/// mnist/mnist50 60000, tinygist10k/tiny10k 10000, usps 7291, yale 2414.
pub fn scaled_default(name: &str) -> Workload {
    let (scale, d_cap) = match name {
        "cifar" => (0.04, 128),       // n=2000, d 3072->128
        "cnnvoc" => (0.128, 128),     // n=2005, d 4096->128
        "covtype" => (0.0134, 54),    // n=2010, d=54
        "mnist" => (0.0334, 128),     // n=2004, d 784->128
        "mnist50" => (0.0334, 50),    // n=2004, d=50
        "tinygist10k" => (0.2, 128),  // n=2000, d 384->128
        "tiny10k" => (0.2, 128),      // n=2000, d 3072->128
        "usps" => (0.274, 128),       // n=1998, d=256->128
        "yale" => (0.829, 128),       // n=2001, d 32256->128
        _ => (0.05, 128),
    };
    let name: &'static str = data::SPEEDUP_ROSTER
        .iter()
        .chain(&["tiny10k"])
        .find(|&&n| n == name)
        .copied()
        .unwrap_or("mnist50");
    Workload { name, scale, d_cap }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_workloads_have_expected_shape() {
        let w = scaled_default("cifar");
        let ds = w.load(1);
        assert_eq!(ds.d(), 128);
        assert!((1900..2100).contains(&ds.n()), "n={}", ds.n());
    }

    #[test]
    fn covtype_keeps_native_dimension() {
        let ds = scaled_default("covtype").load(2);
        assert_eq!(ds.d(), 54);
    }

    #[test]
    fn rosters_build() {
        let s = speedup_set(false, 2);
        assert_eq!(s.workloads.len(), 8);
        assert_eq!(s.seeds.len(), 2);
        let i = init_set(false, 3);
        assert_eq!(i.workloads.len(), 7);
    }

    #[test]
    fn load_is_deterministic() {
        let w = scaled_default("usps");
        assert_eq!(w.load(5).x, w.load(5).x);
    }
}
