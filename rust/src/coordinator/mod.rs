//! The experiment coordinator: everything needed to regenerate every
//! table and figure of the paper (DESIGN.md §5).
//!
//! * [`pool`]     — scoped-thread parallel map (no rayon in the vendor set)
//! * [`datasets`] — scaled workload construction + caching
//! * [`methods`]  — the method roster: init × algorithm plumbing
//! * [`speedup`]  — the paper's oracle speedup protocol (Tables 5/6/8–11)
//! * [`inits`]    — the initialization comparison (Tables 4/7)
//! * [`figures`]  — convergence-curve CSV emission (Figures 2–4)
//! * [`tablefmt`] — plain-text table rendering

pub mod datasets;
pub mod figures;
pub mod inits;
pub mod methods;
pub mod pool;
pub mod speedup;
pub mod tablefmt;

pub use datasets::{Workload, WorkloadSet};
pub use methods::{run_method, Method, MethodRun};
pub use speedup::{speedup_table, SpeedupConfig};
