//! The experiment coordinator: everything needed to regenerate every
//! table and figure of the paper (DESIGN.md §5).
//!
//! * [`pool`]     — the persistent worker pool (no rayon in the vendor set)
//! * [`jobs`]     — the concurrent clustering-job scheduler on that pool
//! * [`datasets`] — scaled workload construction + caching
//! * [`methods`]  — the method roster: init × algorithm plumbing
//! * [`speedup`]  — the paper's oracle speedup protocol (Tables 5/6/8–11)
//! * [`inits`]    — the initialization comparison (Tables 4/7)
//! * [`figures`]  — convergence-curve CSV emission (Figures 2–4)
//! * [`tablefmt`] — plain-text table rendering

pub mod datasets;
pub mod figures;
pub mod inits;
pub mod jobs;
pub mod methods;
pub mod pool;
pub mod speedup;
pub mod tablefmt;

pub use datasets::{Workload, WorkloadSet};
pub use jobs::{JobOutcome, JobQueue, JobSpec};
pub use methods::{run_method, Method, MethodRun};
pub use speedup::{speedup_table, SpeedupConfig};
