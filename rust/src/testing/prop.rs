//! Minimal seeded property-testing harness.
//!
//! The offline vendor set has no `proptest`, so this carries the part we
//! need: run a property over many seeded random cases and, on failure,
//! print the exact case seed so the failure replays deterministically
//! (`PROP_SEED=<seed> cargo test <name>`). No shrinking — the generators
//! used in this crate already produce small cases.

use crate::rng::Pcg32;

/// Run `property` over `cases` seeded PRNGs. Panics with the failing case
/// seed on the first violation.
pub fn check<F: FnMut(&mut Pcg32)>(name: &str, cases: usize, mut property: F) {
    // Optional replay of a single case.
    if let Ok(s) = std::env::var("PROP_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Pcg32::new(seed, 0x70726f70);
            property(&mut rng);
            return;
        }
    }
    let base: u64 = 0xC0FFEE ^ fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Pcg32::new(seed, 0x70726f70);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            property(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}; replay with PROP_SEED={seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Tiny deterministic string hash (FxHash-style) for per-property seeds.
fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

/// Draw a "sized" usize biased toward small values (like proptest's sizes).
pub fn small_usize(rng: &mut Pcg32, lo: usize, hi: usize) -> usize {
    assert!(lo < hi);
    let span = hi - lo;
    // Square the unit draw to bias small.
    let u = rng.f64();
    lo + ((u * u * span as f64) as usize).min(span - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0;
        check("counts", 17, |_| count += 1);
        assert_eq!(count, 17);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failure() {
        check("fails", 5, |rng| {
            let v = rng.gen_below(3);
            assert!(v < 2, "triggered");
        });
    }

    #[test]
    fn small_usize_in_range_and_biased() {
        let mut rng = Pcg32::seeded(1);
        let mut below_mid = 0;
        for _ in 0..1000 {
            let v = small_usize(&mut rng, 10, 110);
            assert!((10..110).contains(&v));
            if v < 60 {
                below_mid += 1;
            }
        }
        assert!(below_mid > 600, "not biased small: {below_mid}");
    }
}
