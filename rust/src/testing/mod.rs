//! Test support: the in-repo property-testing harness (the offline vendor
//! set has no proptest — see DESIGN.md §3) and shared fixture generators.

pub mod prop;

use crate::core::Matrix;
use crate::rng::Pcg32;

/// Random gaussian matrix fixture.
pub fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32();
        }
    }
    m
}

/// Gaussian-blob fixture with known generating labels:
/// `k` well-separated modes in `d` dims.
pub fn blobs(n: usize, k: usize, d: usize, spread: f32, seed: u64) -> (Matrix, Vec<u32>) {
    let mut rng = Pcg32::seeded(seed);
    let centers = {
        let mut c = Matrix::zeros(k, d);
        for i in 0..k {
            for v in c.row_mut(i) {
                *v = rng.gaussian_f32() * spread;
            }
        }
        c
    };
    let mut x = Matrix::zeros(n, d);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let m = rng.gen_below(k);
        labels.push(m as u32);
        let (xr, cr) = (x.row_mut(i), centers.row(m));
        for (v, &c) in xr.iter_mut().zip(cr) {
            *v = c + rng.gaussian_f32();
        }
    }
    (x, labels)
}
