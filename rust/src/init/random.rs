//! Random initialization: `k` distinct data points, uniformly.
//! Costs zero vector operations (paper Table 3: Time O(k)).

use super::InitResult;
use crate::core::Matrix;
use crate::rng::Pcg32;

/// Sample `k` distinct rows of `x` as seed centers.
pub fn random_init(x: &Matrix, k: usize, seed: u64) -> InitResult {
    assert!(k >= 1 && k <= x.rows(), "need 1 <= k <= n (k={k}, n={})", x.rows());
    let mut rng = Pcg32::new(seed, 0x72616e64);
    let idx = rng.sample_distinct(x.rows(), k);
    InitResult { centers: Matrix::gather(x, &idx), labels: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::random_matrix;

    #[test]
    fn picks_k_distinct_data_rows() {
        let x = random_matrix(50, 4, 1);
        let init = random_init(&x, 10, 7);
        assert_eq!(init.k(), 10);
        assert!(init.labels.is_none());
        // Every center is an actual data row.
        for i in 0..10 {
            let c = init.centers.row(i);
            assert!(
                (0..50).any(|r| x.row(r) == c),
                "center {i} is not a data point"
            );
        }
        // Distinct rows.
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert_ne!(init.centers.row(i), init.centers.row(j));
            }
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let x = random_matrix(30, 3, 2);
        assert_eq!(random_init(&x, 5, 9).centers, random_init(&x, 5, 9).centers);
        assert_ne!(random_init(&x, 5, 9).centers, random_init(&x, 5, 10).centers);
    }

    #[test]
    fn k_equals_n_takes_everything() {
        let x = random_matrix(8, 2, 3);
        let init = random_init(&x, 8, 1);
        assert_eq!(init.k(), 8);
    }
}
