//! Initialization methods: `random`, `k-means++`, and the paper's
//! contribution — **Greedy Divisive Initialization (GDI)** with
//! **Projective Split** (paper Algorithms 2 and 3).

mod gdi;
mod kmeanspar;
mod kmeanspp;
mod random;
pub mod split;

pub use gdi::{gdi, GdiOpts};
pub use kmeanspar::{kmeans_par, KmeansParOpts};
pub use kmeanspp::{kmeans_pp, kmeans_pp_numerics, kmeans_pp_threaded};
pub use random::random_init;

use crate::core::Matrix;

/// The product of an initialization: `k` seed centers, plus the cluster
/// assignments when the method produces them as a by-product (GDI and
/// k-means++ do; random sampling does not). k²-means consumes the labels
/// to skip its first full assignment, exactly as in the paper where GDI
/// hands its partition to Algorithm 1 line 3.
#[derive(Clone, Debug)]
pub struct InitResult {
    pub centers: Matrix,
    pub labels: Option<Vec<u32>>,
}

impl InitResult {
    pub fn k(&self) -> usize {
        self.centers.rows()
    }
}
