//! Greedy Divisive Initialization (paper Algorithm 2): start from one
//! cluster and repeatedly Projective-Split the highest-energy cluster
//! until there are `k`. Time complexity between `O(n log k (d + log n))`
//! and `O(n k (d + log n))` depending on split balance (paper §2.2) — in
//! practice an order of magnitude cheaper than k-means++ (paper Table 4).
//!
//! # The optimal 2-clustering along a direction
//!
//! Each split projects the picked cluster onto the direction between
//! two tentative centers, sorts the projections, and takes the
//! **minimum-energy** split point along that ordering — the optimal
//! 2-clustering *along that direction* (paper Figure 1; see
//! [`projective_split`] for the O(|Xj|) sweep that makes every split
//! position's two-sided energy available from running sufficient
//! statistics). The greedy loop always splits the cluster with the
//! highest energy `phi`, so the partition it hands to k²-means is the
//! one the paper's Algorithm 1 line 3 consumes.
//!
//! # Sharded execution
//!
//! The projection/`<S, x_i>` scans inside every split run over
//! contiguous member shards ([`GdiOpts::threads`]; `0` = auto). Outputs
//! are bit-identical for any thread count — pinned, together with the
//! op-counter categories, by `rust/tests/sharding.rs`.

use super::split::{projective_split, sqnorms};
use super::InitResult;
use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::rng::Pcg32;

/// GDI tuning knobs.
#[derive(Clone, Debug)]
pub struct GdiOpts {
    /// Projective Split iterations (paper §3.2 uses 2).
    pub split_iters: usize,
    /// Worker threads for the sharded projection/scan passes inside
    /// each [`projective_split`] call. `0` = auto (see
    /// [`crate::coordinator::pool::resolve_threads`]; small late-stage
    /// clusters stay serial). Any value produces bit-identical centers,
    /// labels and op counts. Explicit counts are honored exactly — per
    /// the engine contract — even for the tiny late splits where spawn
    /// overhead exceeds the scan work, so prefer auto outside the
    /// determinism tests and benches that need forced sharding.
    pub threads: usize,
    /// Numerics tier for the blocked projection scans (default: the
    /// process-wide `K2M_NUMERICS` resolution, else Strict) — same
    /// contract as `cluster::Config::numerics`. The split sweep's f64
    /// sufficient statistics are tier-independent.
    pub numerics: NumericsMode,
}

impl Default for GdiOpts {
    fn default() -> Self {
        GdiOpts { split_iters: 2, threads: 0, numerics: NumericsMode::from_env() }
    }
}

struct Cluster {
    members: Vec<u32>,
    center: Vec<f32>,
    phi: f64,
}

/// Greedy Divisive Initialization: `k` centers + the partition they came
/// from (consumed by k²-means as its initial assignment).
pub fn gdi(
    x: &Matrix,
    k: usize,
    counter: &mut OpCounter,
    seed: u64,
    opts: &GdiOpts,
) -> InitResult {
    let n = x.rows();
    assert!(k >= 1 && k <= n, "need 1 <= k <= n (k={k}, n={n})");
    let mut rng = Pcg32::new(seed, 0x676469);

    // Per-point squared norms, shared by every Projective-Split scan
    // (counted once: n inner products).
    let sq = sqnorms(x, counter);

    // Line 3: one cluster holding everything. Its center/phi are only
    // needed if k == 1; the split loop always splits it first otherwise.
    let all: Vec<u32> = (0..n as u32).collect();
    let mut clusters: Vec<Cluster> = vec![Cluster {
        members: all,
        center: Vec::new(),
        phi: f64::INFINITY, // forces first pick; real phi never needed
    }];

    // Lines 4–13: split the highest-energy splittable cluster.
    while clusters.len() < k {
        let pick = clusters
            .iter()
            .enumerate()
            .filter(|(_, c)| c.members.len() >= 2)
            .max_by(|(_, a), (_, b)| {
                a.phi
                    .partial_cmp(&b.phi)
                    .unwrap()
                    .then(a.members.len().cmp(&b.members.len()))
            })
            .map(|(i, _)| i)
            .expect("k <= n guarantees a splittable cluster exists");

        let split = projective_split(
            x,
            &clusters[pick].members,
            opts.split_iters,
            &sq,
            counter,
            &mut rng,
            opts.threads,
            opts.numerics,
        )
        .expect("picked cluster has >= 2 members");

        clusters[pick] = Cluster {
            members: split.left,
            center: split.c_left,
            phi: split.phi_left,
        };
        clusters.push(Cluster {
            members: split.right,
            center: split.c_right,
            phi: split.phi_right,
        });
    }

    // k == 1 never entered the loop: finish the lone cluster's center.
    if clusters.len() == 1 && clusters[0].center.is_empty() {
        let d = x.cols();
        let mut acc = vec![0.0f64; d];
        for i in 0..n {
            for (a, &v) in acc.iter_mut().zip(x.row(i)) {
                *a += v as f64;
            }
            counter.additions += 1;
        }
        clusters[0].center = acc.iter().map(|&a| (a / n as f64) as f32).collect();
    }

    let mut labels = vec![0u32; n];
    let mut centers = Matrix::zeros(k, x.cols());
    for (j, c) in clusters.iter().enumerate() {
        centers.row_mut(j).copy_from_slice(&c.center);
        for &i in &c.members {
            labels[i as usize] = j as u32;
        }
    }
    InitResult { centers, labels: Some(labels) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{energy, phi};
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn produces_k_nonempty_clusters() {
        let x = random_matrix(200, 8, 1);
        let mut c = OpCounter::default();
        let init = gdi(&x, 12, &mut c, 2, &GdiOpts::default());
        assert_eq!(init.k(), 12);
        let labels = init.labels.unwrap();
        let mut counts = vec![0usize; 12];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&ct| ct > 0), "{counts:?}");
        assert_eq!(counts.iter().sum::<usize>(), 200);
    }

    #[test]
    fn centers_are_member_means() {
        let x = random_matrix(100, 5, 3);
        let mut c = OpCounter::default();
        let init = gdi(&x, 7, &mut c, 4, &GdiOpts::default());
        let labels = init.labels.unwrap();
        for j in 0..7 {
            let members: Vec<u32> = (0..100u32).filter(|&i| labels[i as usize] == j).collect();
            let mut mean = vec![0.0f64; 5];
            for &i in &members {
                for (m, &v) in mean.iter_mut().zip(x.row(i as usize)) {
                    *m += v as f64;
                }
            }
            for (dim, m) in mean.iter().enumerate() {
                let want = (m / members.len() as f64) as f32;
                let got = init.centers.row(j as usize)[dim];
                assert!((got - want).abs() < 1e-4, "cluster {j} dim {dim}");
            }
        }
    }

    #[test]
    fn recovers_separated_blobs() {
        let (x, true_labels) = blobs(400, 6, 10, 60.0, 5);
        let mut c = OpCounter::default();
        let init = gdi(&x, 6, &mut c, 6, &GdiOpts::default());
        let labels = init.labels.unwrap();
        // Each found cluster should be pure (one true blob).
        for j in 0..6u32 {
            let mut seen = std::collections::HashSet::new();
            for i in 0..400 {
                if labels[i] == j {
                    seen.insert(true_labels[i]);
                }
            }
            assert_eq!(seen.len(), 1, "cluster {j} mixes blobs {seen:?}");
        }
    }

    #[test]
    fn much_cheaper_than_kmeans_pp_at_large_k() {
        // Paper Tables 4/7: the GDI/++ cost gap widens with k; at k=256
        // GDI must be well under half the ++ cost (it is ~0.1x at the
        // paper's k=500).
        let x = random_matrix(2000, 64, 7);
        let mut c_gdi = OpCounter::default();
        let _ = gdi(&x, 256, &mut c_gdi, 8, &GdiOpts::default());
        let mut c_pp = OpCounter::default();
        let _ = crate::init::kmeans_pp(&x, 256, &mut c_pp, 8);
        assert!(
            c_gdi.total() < 0.5 * c_pp.total(),
            "GDI {} vs ++ {}",
            c_gdi.total(),
            c_pp.total()
        );
    }

    #[test]
    fn total_energy_decomposes_into_cluster_phis() {
        let x = random_matrix(150, 6, 9);
        let mut c = OpCounter::default();
        let init = gdi(&x, 10, &mut c, 10, &GdiOpts::default());
        let labels = init.labels.clone().unwrap();
        let e = energy(&x, &init.centers, &labels);
        let mut phisum = 0.0;
        for j in 0..10u32 {
            let members: Vec<u32> = (0..150u32).filter(|&i| labels[i as usize] == j).collect();
            phisum += phi(&x, &members);
        }
        assert!((e - phisum).abs() <= 1e-4 * (1.0 + e), "{e} vs {phisum}");
    }

    #[test]
    fn k_equals_one_returns_global_mean() {
        let x = random_matrix(50, 4, 11);
        let mut c = OpCounter::default();
        let init = gdi(&x, 1, &mut c, 12, &GdiOpts::default());
        assert_eq!(init.k(), 1);
        let mut mean = vec![0.0f64; 4];
        for i in 0..50 {
            for (m, &v) in mean.iter_mut().zip(x.row(i)) {
                *m += v as f64;
            }
        }
        for (dim, m) in mean.iter().enumerate() {
            assert!((init.centers.row(0)[dim] - (m / 50.0) as f32).abs() < 1e-5);
        }
    }

    #[test]
    fn k_equals_n_all_singletons() {
        let x = random_matrix(12, 3, 13);
        let mut c = OpCounter::default();
        let init = gdi(&x, 12, &mut c, 14, &GdiOpts::default());
        let labels = init.labels.unwrap();
        let set: std::collections::HashSet<u32> = labels.iter().copied().collect();
        assert_eq!(set.len(), 12);
    }

    #[test]
    fn deterministic_in_seed() {
        let x = random_matrix(80, 5, 15);
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let a = gdi(&x, 9, &mut c1, 16, &GdiOpts::default());
        let b = gdi(&x, 9, &mut c2, 16, &GdiOpts::default());
        assert_eq!(a.centers, b.centers);
        assert_eq!(c1, c2);
    }
}
