//! Projective Split (paper Algorithm 3): a variant of 2-means that, given
//! two tentative centers `c_a, c_b`, projects the cluster onto the
//! direction `c_a − c_b`, sorts, and takes the *minimum-energy* split
//! along that direction — instead of the midpoint hyperplane a standard
//! 2-means assignment step would use (paper Figure 1).
//!
//! The scan exploits the energy identity behind the paper's Lemma 1:
//!
//! ```text
//! phi(S) = Σ_{x∈S} ||x||² − ||Σ_{x∈S} x||² / |S|
//! ```
//!
//! so with per-point squared norms precomputed once per GDI call, one
//! forward sweep maintains the left/right sufficient statistics
//! (running sums + scalar norm accumulators) and yields *every* split's
//! two-sided energy in O(|Xj|) counted vector operations — the paper's
//! "O(|Xj|) distance computations and mean updates" — plus one counted
//! sort (paper §2.2). The winning split's means fall out of the same
//! sufficient statistics for free.
//!
//! # Sharded execution
//!
//! The two per-member map passes — the `<S, x_i>` precomputation and
//! each iteration's projection onto `c_a − c_b` — run over contiguous
//! member shards on [`pool::sharded_reduce`] (`threads`; `0` = auto,
//! which keeps the small late-stage clusters serial). Both are pure
//! per-element maps into the member's own slot, so the output is
//! **bit-identical for any thread count**. The min-energy sweep itself
//! stays serial: it is a running prefix over the *sorted* order whose
//! f64 sufficient statistics must accumulate in exactly that order.

use crate::coordinator::pool;
use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::rng::Pcg32;

/// Result of splitting one cluster into two.
#[derive(Clone, Debug)]
pub struct SplitResult {
    pub left: Vec<u32>,
    pub right: Vec<u32>,
    pub c_left: Vec<f32>,
    pub c_right: Vec<f32>,
    pub phi_left: f64,
    pub phi_right: f64,
}

/// Per-point squared norms in f64 (counted: one inner product per point).
/// GDI computes this once and shares it across every split call.
pub fn sqnorms(x: &Matrix, counter: &mut OpCounter) -> Vec<f64> {
    counter.inner_products += x.rows() as u64;
    (0..x.rows())
        .map(|i| x.row(i).iter().map(|&v| v as f64 * v as f64).sum())
        .collect()
}

fn norm2_f64(v: &[f64]) -> f64 {
    v.iter().map(|&a| a * a).sum()
}

/// Projective Split of the sub-cluster `members` of `x`.
///
/// `sq` are the precomputed per-point squared norms from [`sqnorms`]
/// (indexed by global row id). Returns `None` when `members.len() < 2`.
/// Runs at most `max_iters` scan iterations (the paper uses 2), breaking
/// early when the partition stops changing. `threads` shards the
/// projection passes (`0` = auto; any value is bit-identical — see the
/// module docs); `nm` picks the numerics tier of the blocked projection
/// scans (the f64 sufficient-statistic sweep is tier-independent).
#[allow(clippy::too_many_arguments)] // the paper's full parameter surface
pub fn projective_split(
    x: &Matrix,
    members: &[u32],
    max_iters: usize,
    sq: &[f64],
    counter: &mut OpCounter,
    rng: &mut Pcg32,
    threads: usize,
    nm: NumericsMode,
) -> Option<SplitResult> {
    let nj = members.len();
    if nj < 2 {
        return None;
    }
    let d = x.cols();
    let threads = pool::resolve_threads(threads, nj);
    let chunk = pool::chunk_len(nj, threads);

    // Line 2: two random member samples as tentative centers.
    let ia = rng.gen_below(nj);
    let mut ib = rng.gen_below(nj - 1);
    if ib >= ia {
        ib += 1;
    }
    let mut c_a: Vec<f32> = x.row(members[ia] as usize).to_vec();
    let mut c_b: Vec<f32> = x.row(members[ib] as usize).to_vec();

    // Whole-cluster sufficient statistics (counted: one addition per
    // point; they are reused by every scan iteration).
    let mut s_tot = vec![0.0f64; d];
    let mut q_tot = 0.0f64;
    for &i in members {
        for (a, &v) in s_tot.iter_mut().zip(x.row(i as usize)) {
            *a += v as f64;
        }
        counter.additions += 1;
        q_tot += sq[i as usize];
    }
    let s_tot_norm2 = norm2_f64(&s_tot);
    // sx[i] = <S_tot, x_i> — direction-independent, so computed once per
    // split call and reused by both scan iterations (counted inner
    // products). With it, ||S_R||² = ||S||² − 2·<S,S_L> + ||S_L||² falls
    // out of scalar bookkeeping and the scan needs only the left-side
    // running statistics. A pure per-member map: sharded.
    let mut sx = vec![0.0f64; nj];
    {
        let s_tot_ref = &s_tot;
        pool::sharded_reduce(
            sx.chunks_mut(chunk).zip(members.chunks(chunk)),
            counter,
            |_si, (sx_c, m_c): (&mut [f64], &[u32]), ctr: &mut OpCounter| {
                for (out, &i) in sx_c.iter_mut().zip(m_c) {
                    *out = x
                        .row(i as usize)
                        .iter()
                        .zip(s_tot_ref)
                        .map(|(&v, &s)| v as f64 * s)
                        .sum();
                }
                ctr.inner_products += m_c.len() as u64;
            },
        );
    }
    use std::collections::HashMap;
    let sx_idx: HashMap<u32, f64> =
        members.iter().copied().zip(sx.iter().copied()).collect();

    let mut order: Vec<u32> = members.to_vec();
    let mut proj = vec![0.0f32; nj];
    let mut sl = vec![0.0f64; d];
    let mut best_sl = vec![0.0f64; d];
    let mut prev_lmin = usize::MAX;
    let mut lmin = 1usize;
    let mut best_phi = (0.0f64, 0.0f64);

    for _ in 0..max_iters.max(1) {
        // Direction v = c_a − c_b (one vector op).
        let v: Vec<f32> = c_a.iter().zip(&c_b).map(|(&a, &b)| a - b).collect();
        counter.additions += 1;

        // Lines 4–6: project (counted inner products; a pure per-member
        // map into the member's own slot — sharded) and sort. The
        // direction is the query row of one blocked dot-product scan
        // per shard ([`kernels::dot_block`]; `f32` multiplication
        // commutes bitwise, so either argument order matches the old
        // per-member `dot_raw` calls).
        {
            let v_ref = &v;
            let order_ref = &order;
            pool::sharded_reduce(
                proj.chunks_mut(chunk).zip(order_ref.chunks(chunk)),
                counter,
                |_si, (p_c, o_c): (&mut [f32], &[u32]), ctr: &mut OpCounter| {
                    nm.dot_block(v_ref, x, o_c, p_c, ctr);
                },
            );
        }
        let mut pairs: Vec<(f32, u32)> =
            proj.iter().copied().zip(order.iter().copied()).collect();
        pairs.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        counter.count_sort(nj, d);
        for (slot, &(p, i)) in pairs.iter().enumerate() {
            order[slot] = i;
            proj[slot] = p;
        }

        // Lines 7–8: single sweep over every split position. Per point:
        // one sufficient-statistic update (counted addition), one running
        // norm (counted inner product); the right side is pure scalar
        // bookkeeping thanks to the precomputed <S, x_i>.
        sl.iter_mut().for_each(|a| *a = 0.0);
        let mut ql = 0.0f64;
        let mut s_dot_sl = 0.0f64;
        let mut best = (f64::INFINITY, 1usize, 0.0f64, 0.0f64);
        for (pos, &i) in order[..nj - 1].iter().enumerate() {
            let l = pos + 1;
            let row = x.row(i as usize);
            for (a, &vv) in sl.iter_mut().zip(row) {
                *a += vv as f64;
            }
            counter.additions += 1;
            ql += sq[i as usize];
            s_dot_sl += sx_idx[&i];
            let sl_norm2 = norm2_f64(&sl);
            counter.inner_products += 1;
            let sr_norm2 = (s_tot_norm2 - 2.0 * s_dot_sl + sl_norm2).max(0.0);
            let phi_l = (ql - sl_norm2 / l as f64).max(0.0);
            let phi_r = ((q_tot - ql) - sr_norm2 / (nj - l) as f64).max(0.0);
            let total = phi_l + phi_r;
            if total < best.0 {
                best = (total, l, phi_l, phi_r);
                best_sl.copy_from_slice(&sl);
            }
        }
        lmin = best.1;
        best_phi = (best.2, best.3);

        // Line 10: the sides' means straight from the winning statistics.
        let invl = 1.0 / lmin as f64;
        let invr = 1.0 / (nj - lmin) as f64;
        c_a = best_sl.iter().map(|&a| (a * invl) as f32).collect();
        c_b = best_sl
            .iter()
            .zip(&s_tot)
            .map(|(&a, &t)| ((t - a) * invr) as f32)
            .collect();
        counter.additions += 2; // the two mean extractions

        if lmin == prev_lmin {
            break; // partition stabilized
        }
        prev_lmin = lmin;
    }

    Some(SplitResult {
        left: order[..lmin].to_vec(),
        right: order[lmin..].to_vec(),
        c_left: c_a,
        c_right: c_b,
        phi_left: best_phi.0,
        phi_right: best_phi.1,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Matrix;
    use crate::metrics::phi;
    use crate::rng::Pcg32;
    use crate::testing::random_matrix;

    fn split_helper(
        x: &Matrix,
        members: &[u32],
        c: &mut OpCounter,
        rng: &mut Pcg32,
    ) -> Option<SplitResult> {
        let sq = sqnorms(x, c);
        projective_split(x, members, 2, &sq, c, rng, 1, NumericsMode::Strict)
    }

    #[test]
    fn sqnorms_match_direct() {
        let x = random_matrix(30, 7, 0);
        let mut c = OpCounter::default();
        let sq = sqnorms(&x, &mut c);
        assert_eq!(c.inner_products, 30);
        for i in 0..30 {
            let want: f64 = x.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum();
            assert!((sq[i] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn separated_blobs_split_at_the_gap() {
        // 30 points near -10, 50 near +10 in dim 0.
        let mut x = Matrix::zeros(80, 4);
        let mut rng = Pcg32::seeded(3);
        for i in 0..80 {
            let base = if i < 30 { -10.0 } else { 10.0 };
            let r = x.row_mut(i);
            r[0] = base + rng.gaussian_f32();
            for v in r.iter_mut().skip(1) {
                *v = rng.gaussian_f32();
            }
        }
        let members: Vec<u32> = (0..80).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(4);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        let left_ids: std::collections::HashSet<u32> = s.left.iter().copied().collect();
        let blob_a: std::collections::HashSet<u32> = (0..30).collect();
        let blob_b: std::collections::HashSet<u32> = (30..80).collect();
        assert!(
            left_ids == blob_a || left_ids == blob_b,
            "split did not separate blobs: |left|={}",
            s.left.len()
        );
    }

    #[test]
    fn split_sides_partition_members() {
        let x = random_matrix(33, 5, 5);
        let members: Vec<u32> = (0..33).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(6);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        assert!(!s.left.is_empty() && !s.right.is_empty());
        let mut all: Vec<u32> = s.left.iter().chain(&s.right).copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..33).collect::<Vec<_>>());
    }

    #[test]
    fn returned_phis_match_direct() {
        let x = random_matrix(25, 3, 7);
        let members: Vec<u32> = (0..25).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(8);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        let wl = phi(&x, &s.left);
        let wr = phi(&x, &s.right);
        assert!((s.phi_left - wl).abs() <= 1e-5 * (1.0 + wl), "{} vs {wl}", s.phi_left);
        assert!((s.phi_right - wr).abs() <= 1e-5 * (1.0 + wr), "{} vs {wr}", s.phi_right);
    }

    #[test]
    fn chosen_split_is_energy_minimal_along_direction() {
        // Verify against a brute-force scan of every split position
        // (recomputing energies directly) using the same final direction.
        let x = random_matrix(40, 4, 21);
        let members: Vec<u32> = (0..40).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(22);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        let got = s.phi_left + s.phi_right;
        // Any other partition induced by the same returned ordering
        // cannot be better than what the scan chose — reconstruct the
        // ordering from the split result (left then right order).
        let order: Vec<u32> = s.left.iter().chain(&s.right).copied().collect();
        for l in 1..40 {
            let e = phi(&x, &order[..l]) + phi(&x, &order[l..]);
            assert!(got <= e + 1e-6 * (1.0 + e), "l={l}: {got} > {e}");
        }
    }

    #[test]
    fn centers_are_side_means() {
        let x = random_matrix(20, 4, 9);
        let members: Vec<u32> = (0..20).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(10);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        let mut mean = vec![0.0f64; 4];
        for &i in &s.left {
            for (m, &v) in mean.iter_mut().zip(x.row(i as usize)) {
                *m += v as f64;
            }
        }
        for (g, m) in s.c_left.iter().zip(&mean) {
            assert!((g - (m / s.left.len() as f64) as f32).abs() < 1e-5);
        }
    }

    #[test]
    fn split_beats_or_equals_unsplit_energy() {
        let x = random_matrix(50, 6, 11);
        let members: Vec<u32> = (0..50).collect();
        let whole = phi(&x, &members);
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(12);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        assert!(s.phi_left + s.phi_right <= whole + 1e-6);
    }

    #[test]
    fn op_cost_is_linear_in_cluster_size() {
        let x = random_matrix(512, 8, 13);
        let members: Vec<u32> = (0..512).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(14);
        let sq = sqnorms(&x, &mut c);
        let base = c.total();
        let _ = projective_split(&x, &members, 2, &sq, &mut c, &mut srng, 1, NumericsMode::Strict);
        let per_point = (c.total() - base) / 512.0;
        // ~5 vector ops + sort share per point per scan iteration, 2 iters.
        assert!(per_point < 14.0, "per-point split cost too high: {per_point}");
    }

    #[test]
    fn too_small_returns_none_and_two_points_split() {
        let x = random_matrix(5, 3, 13);
        let mut c = OpCounter::default();
        let sq = sqnorms(&x, &mut c);
        let mut srng = Pcg32::seeded(14);
        let nm = NumericsMode::Strict;
        assert!(projective_split(&x, &[2], 2, &sq, &mut c, &mut srng, 1, nm).is_none());
        let s = projective_split(&x, &[1, 3], 2, &sq, &mut c, &mut srng, 1, nm).unwrap();
        assert_eq!(s.left.len() + s.right.len(), 2);
        assert_eq!(s.left.len(), 1);
        assert!(s.phi_left.abs() < 1e-9 && s.phi_right.abs() < 1e-9);
    }

    #[test]
    fn sharded_split_bit_identical_to_serial() {
        let x = random_matrix(2000, 16, 31);
        let members: Vec<u32> = (0..2000).collect();
        let mut c1 = OpCounter::default();
        let sq = sqnorms(&x, &mut c1);
        let mut r1 = Pcg32::seeded(32);
        let nm = NumericsMode::Strict;
        let want = projective_split(&x, &members, 2, &sq, &mut c1, &mut r1, 1, nm).unwrap();
        for threads in [4usize, 7] {
            let mut c2 = OpCounter::default();
            let sq2 = sqnorms(&x, &mut c2);
            let mut r2 = Pcg32::seeded(32);
            let got = projective_split(&x, &members, 2, &sq2, &mut c2, &mut r2, threads, nm)
                .unwrap();
            assert_eq!(got.left, want.left, "threads={threads}");
            assert_eq!(got.right, want.right, "threads={threads}");
            assert_eq!(got.c_left, want.c_left, "threads={threads}");
            assert_eq!(got.c_right, want.c_right, "threads={threads}");
            assert_eq!(got.phi_left.to_bits(), want.phi_left.to_bits(), "threads={threads}");
            assert_eq!(got.phi_right.to_bits(), want.phi_right.to_bits(), "threads={threads}");
            assert_eq!(c1.inner_products, c2.inner_products, "threads={threads}");
            assert_eq!(c1.additions, c2.additions, "threads={threads}");
        }
    }

    #[test]
    fn identical_points_do_not_crash() {
        let mut x = Matrix::zeros(10, 3);
        for i in 0..10 {
            x.row_mut(i).copy_from_slice(&[1.0, 2.0, 3.0]);
        }
        let members: Vec<u32> = (0..10).collect();
        let mut c = OpCounter::default();
        let mut srng = Pcg32::seeded(15);
        let s = split_helper(&x, &members, &mut c, &mut srng).unwrap();
        assert_eq!(s.left.len() + s.right.len(), 10);
        assert!(s.phi_left + s.phi_right < 1e-5);
    }
}
