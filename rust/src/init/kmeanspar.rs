//! k-means|| — scalable k-means++ (Bahmani et al., VLDB'12), cited by
//! the paper as the parallel variant of ++ that "did not reduce the time
//! complexity". Included as an extension init baseline: oversample
//! ~l=2k candidates over r rounds, weight them by attraction counts,
//! then reduce to k with weighted k-means++.
//!
//! # Sharded execution
//!
//! The three `O(n·…)` distance scans — the round-0 seeding scan, the
//! per-round tightening against the new candidates, and the attraction
//! (weight) scan — run over contiguous point shards on the execution
//! engine ([`pool::sharded_reduce`]; [`KmeansParOpts::threads`], 0 =
//! auto). Each point's work reads only shared immutable state and
//! writes its own slots, and the per-round tightening takes a min over
//! the same candidate set in any order, so centers and the integer op
//! counts are **bit-identical for any thread count** (pinned by
//! `rust/tests/sharding.rs`). The `O(m²)`-ish candidate reduction
//! (weighted ++ over the m ≪ n candidates) is sequential sampling and
//! stays on the caller's thread.

use super::InitResult;
use crate::coordinator::pool;
use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::rng::Pcg32;

/// k-means|| options.
#[derive(Clone, Debug)]
pub struct KmeansParOpts {
    /// Sampling rounds (paper suggests ~5 suffice).
    pub rounds: usize,
    /// Oversampling factor: expected samples per round = factor * k.
    pub factor: f64,
    /// Worker threads for the sharded distance scans. `0` = auto (see
    /// [`crate::coordinator::pool::resolve_threads`]); any value yields
    /// bit-identical centers and op counts.
    pub threads: usize,
    /// Numerics tier for the distance scans (default: the process-wide
    /// `K2M_NUMERICS` resolution, else Strict) — same contract as
    /// `cluster::Config::numerics`.
    pub numerics: NumericsMode,
}

impl Default for KmeansParOpts {
    fn default() -> Self {
        KmeansParOpts { rounds: 5, factor: 2.0, threads: 0, numerics: NumericsMode::from_env() }
    }
}

/// Run k-means|| initialization.
pub fn kmeans_par(
    x: &Matrix,
    k: usize,
    opts: &KmeansParOpts,
    counter: &mut OpCounter,
    seed: u64,
) -> InitResult {
    let n = x.rows();
    assert!(k >= 1 && k <= n);
    let mut rng = Pcg32::new(seed, 0x6b7c7c);
    let threads = pool::resolve_threads(opts.threads, n);
    let chunk = pool::chunk_len(n, threads);
    let nm = opts.numerics;

    // Round 0: one uniform center; track d²(x, C) (sharded scan).
    let mut cand: Vec<usize> = vec![rng.gen_below(n)];
    let mut d2 = vec![0.0f64; n];
    {
        let first_row = x.row(cand[0]);
        pool::sharded_reduce(
            d2.chunks_mut(chunk),
            counter,
            |si, shard: &mut [f64], ctr: &mut OpCounter| {
                // Blocked scan: the seed is the query row, the shard's
                // points are the contiguous candidate block.
                let mut buf = vec![0.0f32; shard.len()];
                nm.sqdist_rows(first_row, x, si * chunk, &mut buf, ctr);
                for (v, &nd) in shard.iter_mut().zip(&buf) {
                    *v = nd as f64;
                }
            },
        );
    }

    for _ in 0..opts.rounds {
        let phi: f64 = d2.iter().sum();
        if phi <= 0.0 {
            break;
        }
        let l = opts.factor * k as f64;
        // Independent sampling with p = min(1, l*d²/phi). Sequential
        // RNG stream — serial by design.
        let mut new: Vec<usize> = Vec::new();
        for i in 0..n {
            let p = (l * d2[i] / phi).min(1.0);
            if rng.f64() < p {
                new.push(i);
            }
        }
        // Tighten d² against the new candidates (counted; sharded over
        // points — the min over the round's candidate set is the same
        // in any evaluation order). Each point runs one blocked
        // candidate-list scan, then folds the min in candidate order.
        if !new.is_empty() {
            let new_u32: Vec<u32> = new.iter().map(|&c| c as u32).collect();
            let new_ref = &new_u32;
            pool::sharded_reduce(
                d2.chunks_mut(chunk),
                counter,
                |si, shard: &mut [f64], ctr: &mut OpCounter| {
                    let start = si * chunk;
                    let mut buf = vec![0.0f32; new_ref.len()];
                    for (off, v) in shard.iter_mut().enumerate() {
                        let xi = x.row(start + off);
                        nm.sqdist_block(xi, x, new_ref, &mut buf, ctr);
                        for &ndf in buf.iter() {
                            let nd = ndf as f64;
                            if nd < *v {
                                *v = nd;
                            }
                        }
                    }
                },
            );
        }
        cand.extend(new);
    }
    cand.sort_unstable();
    cand.dedup();

    // Weight candidates by attraction counts: find each point's nearest
    // candidate (counted, sharded), then tally in global point order —
    // exact +1.0 sums, so the serial tally is bit-identical regardless
    // of the scan's shard layout.
    let m = cand.len();
    let cand_u32: Vec<u32> = cand.iter().map(|&c| c as u32).collect();
    let mut weights = vec![0.0f64; m];
    let mut best_cand = vec![0u32; n];
    {
        let cand_ref = &cand_u32;
        pool::sharded_reduce(
            best_cand.chunks_mut(chunk),
            counter,
            |si, shard: &mut [u32], ctr: &mut OpCounter| {
                let start = si * chunk;
                for (off, b) in shard.iter_mut().enumerate() {
                    let xi = x.row(start + off);
                    // Blocked argmin over the candidate list (lowest
                    // slot wins ties — the serial loop's tie-break).
                    let (slot, _) = nm.nearest_sq_in_block(xi, x, cand_ref, ctr);
                    *b = slot as u32;
                }
            },
        );
    }
    for &b in &best_cand {
        weights[b as usize] += 1.0;
    }

    // Reduce to k with weighted k-means++ over the m candidates.
    if m <= k {
        // Rare degenerate case: pad with uniform extras.
        let mut chosen = cand.clone();
        while chosen.len() < k {
            let i = rng.gen_below(n);
            if !chosen.contains(&i) {
                chosen.push(i);
            }
        }
        return InitResult { centers: Matrix::gather(x, &chosen), labels: None };
    }
    let first = rng.choose_weighted(&weights);
    let mut chosen = vec![cand[first]];
    let mut buf = vec![0.0f32; m];
    nm.sqdist_block(x.row(chosen[0]), x, &cand_u32, &mut buf, counter);
    let mut cd2: Vec<f64> = (0..m).map(|ci| weights[ci] * buf[ci] as f64).collect();
    while chosen.len() < k {
        let pick = rng.choose_weighted(&cd2);
        chosen.push(cand[pick]);
        nm.sqdist_block(x.row(cand[pick]), x, &cand_u32, &mut buf, counter);
        for ci in 0..m {
            let nd = weights[ci] * buf[ci] as f64;
            if nd < cd2[ci] {
                cd2[ci] = nd;
            }
        }
    }
    InitResult { centers: Matrix::gather(x, &chosen), labels: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn produces_k_distinct_centers() {
        let x = random_matrix(400, 6, 1);
        let mut c = OpCounter::default();
        let init = kmeans_par(&x, 20, &KmeansParOpts::default(), &mut c, 2);
        assert_eq!(init.k(), 20);
        for i in 0..20 {
            for j in (i + 1)..20 {
                assert_ne!(init.centers.row(i), init.centers.row(j));
            }
        }
        assert!(c.total() > 0.0);
    }

    #[test]
    fn covers_separated_blobs() {
        let (x, true_labels) = blobs(600, 6, 8, 60.0, 3);
        let mut c = OpCounter::default();
        let init = kmeans_par(&x, 6, &KmeansParOpts::default(), &mut c, 4);
        let mut hit = [false; 6];
        for ci in 0..6 {
            let row = init.centers.row(ci);
            if let Some(src) = (0..600).find(|&i| x.row(i) == row) {
                hit[true_labels[src] as usize] = true;
            }
        }
        assert!(hit.iter().filter(|&&h| h).count() >= 5, "{hit:?}");
    }

    #[test]
    fn comparable_quality_to_kmeanspp_after_lloyd() {
        let (x, _) = blobs(500, 10, 8, 12.0, 5);
        let cfg = crate::cluster::Config { k: 10, ..Default::default() };
        let mut c1 = OpCounter::default();
        let r1 = crate::cluster::lloyd(
            &x,
            &crate::init::kmeans_pp(&x, 10, &mut c1, 6),
            &cfg,
            &mut c1,
        );
        let mut c2 = OpCounter::default();
        let r2 = crate::cluster::lloyd(
            &x,
            &kmeans_par(&x, 10, &KmeansParOpts::default(), &mut c2, 6),
            &cfg,
            &mut c2,
        );
        assert!(r2.energy <= 1.3 * r1.energy, "{} vs {}", r2.energy, r1.energy);
    }

    #[test]
    fn threaded_scans_bit_identical_to_serial() {
        // Unit-scale version of the tests/sharding.rs contract.
        let x = random_matrix(500, 8, 9);
        let run = |threads: usize| {
            let opts = KmeansParOpts { threads, ..Default::default() };
            let mut c = OpCounter::default();
            let init = kmeans_par(&x, 15, &opts, &mut c, 10);
            (init, c)
        };
        let (want, c1) = run(1);
        for threads in [3usize, 8] {
            let (got, c) = run(threads);
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(c.distances, c1.distances, "threads={threads}");
        }
    }

    #[test]
    fn degenerate_small_n() {
        let x = random_matrix(10, 3, 7);
        let mut c = OpCounter::default();
        let init = kmeans_par(&x, 8, &KmeansParOpts::default(), &mut c, 8);
        assert_eq!(init.k(), 8);
    }
}
