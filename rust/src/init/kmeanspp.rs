//! k-means++ initialization (Arthur & Vassilvitskii, SODA'07): D²-weighted
//! sequential sampling. Time O(nkd) — one counted distance per (point,
//! new center) pair, i.e. exactly `n*k` distances (paper Table 3), which
//! is what makes it too expensive at large k and motivates GDI.
//!
//! # Sharded execution
//!
//! The distance scans (the initial pass against the first center and the
//! per-new-center tightening pass) run over contiguous point shards on
//! the execution engine ([`pool::sharded_reduce`];
//! [`kmeans_pp_threaded`], 0 = auto). Every scan writes only its own
//! point's `d2`/`owner` slots given shared immutable state, so centers,
//! labels and the integer op counts are **bit-identical for any thread
//! count** (pinned by `rust/tests/sharding.rs`). The D² *sampling* that
//! separates the scans is inherently sequential (each draw conditions on
//! the previous) and stays on the caller's thread.

use super::InitResult;
use crate::coordinator::pool;
use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::rng::Pcg32;

/// D²-sampling initialization. Labels come free from the closest-center
/// bookkeeping the sampler maintains anyway. Auto-sharded — see
/// [`kmeans_pp_threaded`] for an explicit thread count.
pub fn kmeans_pp(x: &Matrix, k: usize, counter: &mut OpCounter, seed: u64) -> InitResult {
    kmeans_pp_threaded(x, k, counter, seed, 0)
}

/// [`kmeans_pp`] with an explicit worker-thread request for the distance
/// scans (`0` = auto; any value is bit-identical — the engine contract).
/// Numerics ride the process default (`K2M_NUMERICS`, else Strict); see
/// [`kmeans_pp_numerics`] for an explicit tier.
pub fn kmeans_pp_threaded(
    x: &Matrix,
    k: usize,
    counter: &mut OpCounter,
    seed: u64,
    threads: usize,
) -> InitResult {
    kmeans_pp_numerics(x, k, counter, seed, threads, NumericsMode::from_env())
}

/// The full-surface k-means++ entry: explicit thread count and numerics
/// tier (the jobs scheduler threads `Config::{threads, numerics}` in
/// here). The D² draws are mode-independent only insofar as the sampled
/// weights agree; both tiers are deterministic, so a (seed, mode) pair
/// always reproduces the same centers.
pub fn kmeans_pp_numerics(
    x: &Matrix,
    k: usize,
    counter: &mut OpCounter,
    seed: u64,
    threads: usize,
    nm: NumericsMode,
) -> InitResult {
    let n = x.rows();
    assert!(k >= 1 && k <= n, "need 1 <= k <= n (k={k}, n={n})");
    let mut rng = Pcg32::new(seed, 0x6b2b2b);
    let threads = pool::resolve_threads(threads, n);
    let chunk = pool::chunk_len(n, threads);

    let mut chosen: Vec<usize> = Vec::with_capacity(k);
    let first = rng.gen_below(n);
    chosen.push(first);

    // Closest squared distance + owning center per point, seeded by the
    // scan against the first center (sharded over points).
    let mut d2 = vec![0.0f64; n];
    let mut owner = vec![0u32; n];
    {
        let first_row = x.row(first);
        pool::sharded_reduce(
            d2.chunks_mut(chunk),
            counter,
            |si, shard: &mut [f64], ctr: &mut OpCounter| {
                // Blocked scan: the new center is the query row, the
                // shard's points are the contiguous candidate block.
                let mut buf = vec![0.0f32; shard.len()];
                nm.sqdist_rows(first_row, x, si * chunk, &mut buf, ctr);
                for (v, &nd) in shard.iter_mut().zip(&buf) {
                    *v = nd as f64;
                }
            },
        );
    }

    for c in 1..k {
        // Sequential D² draw (reads all of d2; stays serial by design).
        let next = rng.choose_weighted(&d2);
        chosen.push(next);
        // One counted distance per point per new center, sharded.
        let next_row = x.row(next);
        let cidx = c as u32;
        pool::sharded_reduce(
            d2.chunks_mut(chunk).zip(owner.chunks_mut(chunk)),
            counter,
            |si, (d2s, owners): (&mut [f64], &mut [u32]), ctr: &mut OpCounter| {
                let mut buf = vec![0.0f32; d2s.len()];
                nm.sqdist_rows(next_row, x, si * chunk, &mut buf, ctr);
                for ((v, o), &ndf) in d2s.iter_mut().zip(owners.iter_mut()).zip(&buf) {
                    let nd = ndf as f64;
                    if nd < *v {
                        *v = nd;
                        *o = cidx;
                    }
                }
            },
        );
    }

    InitResult { centers: Matrix::gather(x, &chosen), labels: Some(owner) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ops;
    use crate::testing::{blobs, random_matrix};

    #[test]
    fn counts_exactly_nk_distances() {
        let x = random_matrix(100, 5, 1);
        let mut c = OpCounter::default();
        let _ = kmeans_pp(&x, 7, &mut c, 3);
        assert_eq!(c.distances, 100 * 7);
    }

    #[test]
    fn labels_point_to_nearest_chosen_center() {
        let x = random_matrix(80, 6, 2);
        let mut c = OpCounter::default();
        let init = kmeans_pp(&x, 5, &mut c, 4);
        let labels = init.labels.unwrap();
        for i in 0..80 {
            let mine = ops::sqdist_raw(x.row(i), init.centers.row(labels[i] as usize));
            for j in 0..5 {
                let other = ops::sqdist_raw(x.row(i), init.centers.row(j));
                assert!(mine <= other + 1e-4, "point {i}: {mine} > {other}");
            }
        }
    }

    #[test]
    fn spreads_across_separated_blobs() {
        // With 5 well-separated blobs and k=5, ++ should hit every blob
        // (this is its raison d'être vs random init).
        let (x, true_labels) = blobs(500, 5, 8, 60.0, 5);
        let mut c = OpCounter::default();
        let init = kmeans_pp(&x, 5, &mut c, 6);
        // Map each chosen center to the blob of its source point.
        let mut hit = [false; 5];
        for ci in 0..5 {
            let row = init.centers.row(ci);
            let src = (0..500).find(|&i| x.row(i) == row).expect("center is a data point");
            hit[true_labels[src] as usize] = true;
        }
        assert!(hit.iter().all(|&h| h), "missed a blob: {hit:?}");
    }

    #[test]
    fn deterministic_in_seed() {
        let x = random_matrix(60, 4, 7);
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        assert_eq!(
            kmeans_pp(&x, 6, &mut c1, 11).centers,
            kmeans_pp(&x, 6, &mut c2, 11).centers
        );
    }

    #[test]
    fn threaded_scans_bit_identical_to_serial() {
        // Unit-scale version of the tests/sharding.rs contract: any
        // thread count gives the same centers, labels and op counts.
        let x = random_matrix(400, 6, 9);
        let mut c1 = OpCounter::default();
        let want = kmeans_pp_threaded(&x, 12, &mut c1, 13, 1);
        for threads in [2usize, 5, 16] {
            let mut c = OpCounter::default();
            let got = kmeans_pp_threaded(&x, 12, &mut c, 13, threads);
            assert_eq!(got.centers, want.centers, "threads={threads}");
            assert_eq!(got.labels, want.labels, "threads={threads}");
            assert_eq!(c.distances, c1.distances, "threads={threads}");
        }
    }

    #[test]
    fn k_equals_one() {
        let x = random_matrix(10, 3, 8);
        let mut c = OpCounter::default();
        let init = kmeans_pp(&x, 1, &mut c, 1);
        assert_eq!(init.k(), 1);
        assert_eq!(init.labels.unwrap(), vec![0u32; 10]);
    }
}
