//! # k2m — k²-means for fast and accurate large scale clustering
//!
//! A production-grade reproduction of Agustsson, Timofte & Van Gool,
//! *"k²-means for fast and accurate large scale clustering"* (2016), built
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the clustering engine and benchmark laboratory:
//!   every algorithm the paper evaluates ([`fn@cluster::lloyd`],
//!   [`fn@cluster::elkan`], [`fn@cluster::minibatch`], [`fn@cluster::akm`],
//!   [`fn@cluster::k2means`]), every initialization ([`init::random_init`],
//!   [`init::kmeans_pp`], [`fn@init::gdi`]), the op-counting instrumentation
//!   ([`core::OpCounter`]) that reproduces the paper's
//!   "distance computations" methodology, dataset simulacra ([`data`]),
//!   and the experiment coordinator ([`coordinator`]) that regenerates
//!   every table and figure of the paper.
//! * **L2/L1 (python/, build-time only)** — JAX graphs calling tiled
//!   Pallas kernels for the distance hot paths, AOT-lowered to HLO text
//!   artifacts that [`runtime::XlaEngine`] loads and executes through the
//!   PJRT C API. Python never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use k2m::{cluster, data, init, core::OpCounter};
//!
//! let ds = data::mnist50_like(1.0, 42);            // n=60000, d=50 simulacrum
//! let mut counter = OpCounter::default();
//! let cfg = cluster::Config { k: 200, kn: 30, max_iters: 100, ..Default::default() };
//! let seeds = init::gdi(&ds.x, cfg.k, &mut counter, 42, &Default::default());
//! let result = cluster::k2means(&ds.x, &seeds, &cfg, &mut counter);
//! println!("energy = {:.4e} after {} iters, {:.3e} vector ops",
//!          result.energy, result.iters, counter.total());
//! ```

// Style lints at odds with this crate's deliberate idiom: index-juggling
// hot loops that mirror the paper's pseudocode, explicit state-slice
// threading through the sharded passes, fn-pointer method rosters, and
// Default impls that document the paper's protocol constants.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::derivable_impls,
    clippy::manual_range_contains
)]

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod core;
pub mod data;
pub mod init;
pub mod knn;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod testing;
