//! The PJRT backend: executes the AOT HLO-text artifacts on the CPU
//! client. This is the three-layer architecture's request path — the
//! artifacts were authored in JAX + Pallas at build time; here they are
//! loaded, compiled once, cached, and fed with padded literals.
//!
//! # Build gating
//!
//! The real implementation needs the external `xla` crate (PJRT C API
//! bindings plus the `xla_extension` native library), which is not part
//! of the offline vendor set. It is therefore compiled only with the
//! `xla-pjrt` cargo feature; the default build gets an API-compatible
//! stub whose constructor fails with a clear message, so every caller
//! (CLI `--engine xla`, `k2m engines`, benches, integration tests)
//! degrades gracefully instead of breaking the build.
//!
//! Padding contract (mirrors the kernels' docstrings):
//! * extra **d** columns are zero (contribute nothing to distances/sums);
//! * ghost **centers** get a single huge coordinate (1e18 → squared
//!   distance ~1e36, never the argmin);
//! * ghost **points** in an update slab carry label `k_menu` (outside
//!   every one-hot column);
//! * ghost **candidate slots** repeat the point's slot-0 center
//!   (duplicates are harmless in an argmin).

#[cfg(feature = "xla-pjrt")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::{anyhow, Context, Result};

    use crate::core::Matrix;
    use crate::runtime::engine::Engine;
    use crate::runtime::manifest::{Manifest, ManifestEntry};

    /// Sentinel coordinate for ghost centers (squared: ~1e36, finite in f32).
    const GHOST_COORD: f32 = 1.0e18;

    /// PJRT-backed engine. Compiled executables are cached per artifact.
    pub struct XlaEngine {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl XlaEngine {
        /// Create from an artifact directory (see `make artifacts`).
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(artifact_dir)?;
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(XlaEngine { client, manifest, cache: HashMap::new() })
        }

        /// Platform string of the underlying PJRT client.
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn executable(&mut self, entry: &ManifestEntry) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(&entry.name) {
                let path = self.manifest.path_of(entry);
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {}: {e:?}", entry.name))?;
                self.cache.insert(entry.name.clone(), exe);
            }
            Ok(&self.cache[&entry.name])
        }

        fn select(
            &self,
            op: &str,
            k: Option<usize>,
            kn: Option<usize>,
            d: Option<usize>,
        ) -> Result<ManifestEntry> {
            self.manifest.select(op, k, kn, d).cloned().ok_or_else(|| {
                anyhow!(
                    "no artifact fits op={op} k={k:?} kn={kn:?} d={d:?} \
                     (menu: rebuild with `python -m compile.aot --menu big`)"
                )
            })
        }

        /// Pad a slab of `x` rows [start, start+rows) into an (nb, d_menu)
        /// f32 literal; ghost rows are zero.
        fn pad_points(x: &Matrix, start: usize, nb: usize, d_menu: usize) -> Result<xla::Literal> {
            let d = x.cols();
            let mut buf = vec![0.0f32; nb * d_menu];
            let rows = nb.min(x.rows() - start);
            for r in 0..rows {
                buf[r * d_menu..r * d_menu + d].copy_from_slice(x.row(start + r));
            }
            literal2(&buf, nb, d_menu)
        }

        /// Pad the center table into (k_menu, d_menu); ghost centers get the
        /// sentinel coordinate.
        fn pad_centers(c: &Matrix, k_menu: usize, d_menu: usize) -> Result<xla::Literal> {
            let (k, d) = (c.rows(), c.cols());
            let mut buf = vec![0.0f32; k_menu * d_menu];
            for r in 0..k {
                buf[r * d_menu..r * d_menu + d].copy_from_slice(c.row(r));
            }
            for r in k..k_menu {
                buf[r * d_menu] = GHOST_COORD;
            }
            literal2(&buf, k_menu, d_menu)
        }
    }

    fn literal2(buf: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        xla::Literal::vec1(buf)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    fn literal2_i32(buf: &[i32], rows: usize, cols: usize) -> Result<xla::Literal> {
        xla::Literal::vec1(buf)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| anyhow!("reshape literal: {e:?}"))
    }

    fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
    }

    impl Engine for XlaEngine {
        fn assign_full(&mut self, x: &Matrix, c: &Matrix) -> Result<(Vec<u32>, Vec<f32>)> {
            let (n, d) = (x.rows(), x.cols());
            let k = c.rows();
            let entry = self.select("assign_full", Some(k), None, Some(d))?;
            let (nb, k_menu, d_menu) =
                (entry.nb.context("nb")?, entry.k.context("k")?, entry.d.context("d")?);
            let centers = Self::pad_centers(c, k_menu, d_menu)?;
            self.executable(&entry)?;

            let mut labels = Vec::with_capacity(n);
            let mut dists = Vec::with_capacity(n);
            let mut start = 0usize;
            while start < n {
                let points = Self::pad_points(x, start, nb, d_menu)?;
                let exe = &self.cache[&entry.name];
                let outs = run(exe, &[points, centers.clone()])?;
                let lab: Vec<i32> = outs[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                let dst: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                let take = nb.min(n - start);
                labels.extend(lab[..take].iter().map(|&v| v as u32));
                dists.extend_from_slice(&dst[..take]);
                start += nb;
            }
            Ok((labels, dists))
        }

        fn assign_candidates(
            &mut self,
            x: &Matrix,
            c: &Matrix,
            cand: &[u32],
            kn: usize,
        ) -> Result<(Vec<u32>, Vec<f32>)> {
            let (n, d) = (x.rows(), x.cols());
            let k = c.rows();
            assert_eq!(cand.len(), n * kn);
            let entry = self.select("assign_candidates", Some(k), Some(kn), Some(d))?;
            let (nb, k_menu, kn_menu, d_menu) = (
                entry.nb.context("nb")?,
                entry.k.context("k")?,
                entry.kn.context("kn")?,
                entry.d.context("d")?,
            );
            let centers = Self::pad_centers(c, k_menu, d_menu)?;
            self.executable(&entry)?;

            let mut labels = Vec::with_capacity(n);
            let mut dists = Vec::with_capacity(n);
            let mut start = 0usize;
            while start < n {
                let rows = nb.min(n - start);
                let points = Self::pad_points(x, start, nb, d_menu)?;
                // Candidate table: ghost slots repeat slot 0; ghost rows all 0.
                let mut cbuf = vec![0i32; nb * kn_menu];
                for r in 0..rows {
                    let src = &cand[(start + r) * kn..(start + r + 1) * kn];
                    for (t, &v) in src.iter().enumerate() {
                        cbuf[r * kn_menu + t] = v as i32;
                    }
                    for t in kn..kn_menu {
                        cbuf[r * kn_menu + t] = src[0] as i32;
                    }
                }
                let cand_lit = literal2_i32(&cbuf, nb, kn_menu)?;
                let exe = &self.cache[&entry.name];
                let outs = run(exe, &[points, centers.clone(), cand_lit])?;
                let lab: Vec<i32> = outs[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                let dst: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                labels.extend(lab[..rows].iter().map(|&v| v as u32));
                dists.extend_from_slice(&dst[..rows]);
                start += nb;
            }
            Ok((labels, dists))
        }

        fn center_knn(&mut self, c: &Matrix, kn: usize) -> Result<(Vec<u32>, Vec<f32>)> {
            let (k, d) = (c.rows(), c.cols());
            let kn = kn.min(k);
            let entry = self.select("center_knn", Some(k), Some(kn), Some(d))?;
            let (k_menu, kn_menu, d_menu) =
                (entry.k.context("k")?, entry.kn.context("kn")?, entry.d.context("d")?);
            let centers = Self::pad_centers(c, k_menu, d_menu)?;
            self.executable(&entry)?;
            let exe = &self.cache[&entry.name];
            let outs = run(exe, &[centers])?;
            let idx: Vec<i32> = outs[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            let dst: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
            // Slice each real center's first kn slots. Ghost centers sort
            // after every real one, so slots [0, kn) are always real when
            // kn <= k (see module docs).
            let mut nbrs = vec![0u32; k * kn];
            let mut nds = vec![0.0f32; k * kn];
            for i in 0..k {
                for t in 0..kn {
                    nbrs[i * kn + t] = idx[i * kn_menu + t] as u32;
                    nds[i * kn + t] = dst[i * kn_menu + t];
                }
            }
            Ok((nbrs, nds))
        }

        fn update_stats(
            &mut self,
            x: &Matrix,
            labels: &[u32],
            k: usize,
        ) -> Result<(Matrix, Vec<f32>)> {
            let (n, d) = (x.rows(), x.cols());
            let entry = self.select("update_stats", Some(k), None, Some(d))?;
            let (nb, k_menu, d_menu) =
                (entry.nb.context("nb")?, entry.k.context("k")?, entry.d.context("d")?);
            self.executable(&entry)?;

            let mut sums = Matrix::zeros(k, d);
            let mut counts = vec![0.0f32; k];
            let mut start = 0usize;
            while start < n {
                let rows = nb.min(n - start);
                let points = Self::pad_points(x, start, nb, d_menu)?;
                let mut lbuf = vec![k_menu as i32; nb]; // ghosts -> no column
                for r in 0..rows {
                    lbuf[r] = labels[start + r] as i32;
                }
                let lab_lit = xla::Literal::vec1(&lbuf);
                let exe = &self.cache[&entry.name];
                let outs = run(exe, &[points, lab_lit])?;
                let s: Vec<f32> = outs[0].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                let c: Vec<f32> = outs[1].to_vec().map_err(|e| anyhow!("{e:?}"))?;
                for j in 0..k {
                    let acc = sums.row_mut(j);
                    for (a, &v) in acc.iter_mut().zip(&s[j * d_menu..j * d_menu + d]) {
                        *a += v;
                    }
                    counts[j] += c[j];
                }
                start += nb;
            }
            Ok((sums, counts))
        }

        fn name(&self) -> &'static str {
            "xla-pjrt"
        }
    }
}

#[cfg(not(feature = "xla-pjrt"))]
mod stub {
    use std::path::Path;

    use anyhow::{bail, Result};

    use crate::core::Matrix;
    use crate::runtime::engine::Engine;

    const UNAVAILABLE: &str = "XLA/PJRT backend not compiled in: rebuild with \
         `--features xla-pjrt` (requires the external `xla` crate, absent from \
         the offline vendor set); the native `rust` engine covers every op";

    /// Stub standing in for the PJRT engine when the `xla-pjrt` feature is
    /// off. [`XlaEngine::new`] always fails with an explanatory error, so
    /// the `Engine` methods below are unreachable in practice but keep the
    /// trait surface identical across builds.
    pub struct XlaEngine {
        _private: (),
    }

    impl XlaEngine {
        /// Always fails in this build; see the module docs.
        pub fn new(_artifact_dir: &Path) -> Result<Self> {
            bail!("{UNAVAILABLE}");
        }

        /// Platform string of the underlying PJRT client.
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    impl Engine for XlaEngine {
        fn assign_full(&mut self, _x: &Matrix, _c: &Matrix) -> Result<(Vec<u32>, Vec<f32>)> {
            bail!("{UNAVAILABLE}");
        }

        fn assign_candidates(
            &mut self,
            _x: &Matrix,
            _c: &Matrix,
            _cand: &[u32],
            _kn: usize,
        ) -> Result<(Vec<u32>, Vec<f32>)> {
            bail!("{UNAVAILABLE}");
        }

        fn center_knn(&mut self, _c: &Matrix, _kn: usize) -> Result<(Vec<u32>, Vec<f32>)> {
            bail!("{UNAVAILABLE}");
        }

        fn update_stats(
            &mut self,
            _x: &Matrix,
            _labels: &[u32],
            _k: usize,
        ) -> Result<(Matrix, Vec<f32>)> {
            bail!("{UNAVAILABLE}");
        }

        fn name(&self) -> &'static str {
            "xla-pjrt (stub)"
        }
    }
}

#[cfg(feature = "xla-pjrt")]
pub use pjrt::XlaEngine;
#[cfg(not(feature = "xla-pjrt"))]
pub use stub::XlaEngine;
