//! Batched clustering loops over an [`Engine`] — the paper's algorithms
//! expressed purely in the artifact vocabulary, so the same code runs on
//! the native backend and on the PJRT/AOT path.
//!
//! The scalar triangle-inequality bookkeeping stays in
//! [`fn@crate::cluster::k2means`] (DESIGN.md §Hardware-Adaptation: bounds
//! are scalar control flow, hostile to the MXU; the batched path instead
//! shrinks the contraction to the kn candidates, which is where the TPU
//! win lives).

use anyhow::Result;

use super::engine::{finish_update, Engine};
use crate::coordinator::jobs::{JobOutcome, JobQueue, JobSpec};
use crate::core::Matrix;
use crate::data::DatasetSource;
use crate::metrics::Trace;

/// The runtime's job-submission API: execute a batch of clustering
/// jobs **concurrently** on the persistent worker pool and return their
/// outcomes in submission order.
///
/// This is the serving entry point the CLI's `k2m jobs` subcommand (a
/// manifest of runs) sits on. Submissions pair a spec with anything
/// convertible into a [`DatasetSource`] — an `Arc<Matrix>` (the
/// historical shape) or an `Arc<crate::data::ChunkedMatrix>` out-of-core
/// store. `budget` caps jobs in flight (`0` = one per pool worker);
/// inside a running job every sharded pass executes inline on its
/// worker, so outer jobs × inner shards never oversubscribe the pool —
/// and every outcome is bit-identical to a serial one-at-a-time run of
/// the same spec (the engine contract; see
/// [`crate::coordinator::jobs`]).
pub fn run_cluster_jobs<S>(submissions: &[(S, JobSpec)], budget: usize) -> Vec<JobOutcome>
where
    S: Clone + Into<DatasetSource>,
{
    let mut queue = JobQueue::with_budget(budget);
    for (x, spec) in submissions {
        queue.submit(x.clone(), spec.clone());
    }
    queue.run()
}

/// Result of an engine-path run.
#[derive(Clone, Debug)]
pub struct EngineRunResult {
    pub centers: Matrix,
    pub labels: Vec<u32>,
    pub energy: f64,
    pub iters: usize,
    pub converged: bool,
    pub trace: Trace,
}

/// Batched Lloyd through the engine: assign_full + update_stats per
/// iteration until assignments stabilize.
pub fn lloyd_engine(
    x: &Matrix,
    seeds: &Matrix,
    max_iters: usize,
    engine: &mut dyn Engine,
) -> Result<EngineRunResult> {
    let k = seeds.rows();
    let mut centers = seeds.clone();
    let mut labels: Vec<u32> = Vec::new();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;
    let mut energy = f64::INFINITY;

    for it in 0..max_iters {
        iters = it + 1;
        let (new_labels, dists) = engine.assign_full(x, &centers)?;
        energy = dists.iter().map(|&v| v as f64).sum();
        trace.push(0.0, energy, it);
        let changed = new_labels != labels;
        labels = new_labels;
        if !changed && it > 0 {
            converged = true;
            break;
        }
        let (sums, counts) = engine.update_stats(x, &labels, k)?;
        centers = finish_update(&sums, &counts, &centers);
    }
    Ok(EngineRunResult { centers, labels, energy, iters, converged, trace })
}

/// Batched k²-means through the engine: center_knn + assign_candidates +
/// update_stats per iteration (paper Algorithm 1, dense-tile form).
pub fn k2means_engine(
    x: &Matrix,
    seeds: &Matrix,
    init_labels: Option<&[u32]>,
    kn: usize,
    max_iters: usize,
    engine: &mut dyn Engine,
) -> Result<EngineRunResult> {
    let n = x.rows();
    let k = seeds.rows();
    let kn = kn.clamp(1, k);
    let mut centers = seeds.clone();
    let mut trace = Trace::default();
    let mut converged = false;
    let mut iters = 0;
    let mut energy = f64::INFINITY;

    // Bootstrap assignment: init labels or one full pass.
    let mut labels: Vec<u32> = match init_labels {
        Some(l) => l.to_vec(),
        None => engine.assign_full(x, &centers)?.0,
    };

    let mut cand = vec![0u32; n * kn];
    for it in 0..max_iters {
        iters = it + 1;
        // Line 6: the kn-NN center graph.
        let (nbrs, _) = engine.center_knn(&centers, kn)?;
        // Lines 7–12: each point considers its center's neighbourhood.
        for i in 0..n {
            let l = labels[i] as usize;
            cand[i * kn..(i + 1) * kn].copy_from_slice(&nbrs[l * kn..(l + 1) * kn]);
        }
        let (new_labels, dists) = engine.assign_candidates(x, &centers, &cand, kn)?;
        energy = dists.iter().map(|&v| v as f64).sum();
        trace.push(0.0, energy, it);
        let changed = new_labels != labels;
        labels = new_labels;
        if !changed && it > 0 {
            converged = true;
            break;
        }
        // Lines 13–15: update step.
        let (sums, counts) = engine.update_stats(x, &labels, k)?;
        centers = finish_update(&sums, &counts, &centers);
    }
    Ok(EngineRunResult { centers, labels, energy, iters, converged, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RustEngine;
    use crate::testing::blobs;

    #[test]
    fn lloyd_engine_recovers_blobs() {
        // ++ seeding so every blob gets a center (random init can merge
        // two blobs and park Lloyd at a high-energy local minimum).
        let (x, _) = blobs(300, 5, 8, 40.0, 1);
        let seeds =
            crate::init::kmeans_pp(&x, 5, &mut crate::core::OpCounter::default(), 2).centers;
        let mut e = RustEngine::default();
        let r = lloyd_engine(&x, &seeds, 50, &mut e).unwrap();
        assert!(r.converged);
        // Energy per point ~ d (unit noise): 8 per point.
        assert!(r.energy / 300.0 < 12.0, "energy {}", r.energy);
    }

    #[test]
    fn k2means_engine_tracks_lloyd_engine_with_kn_k() {
        let (x, _) = blobs(250, 6, 10, 20.0, 3);
        let seeds = crate::init::random_init(&x, 6, 4).centers;
        let mut e1 = RustEngine::default();
        let mut e2 = RustEngine::default();
        let rl = lloyd_engine(&x, &seeds, 60, &mut e1).unwrap();
        let r2 = k2means_engine(&x, &seeds, None, 6, 60, &mut e2).unwrap();
        assert_eq!(rl.labels, r2.labels);
        assert!((rl.energy - r2.energy).abs() < 1e-3 * (1.0 + rl.energy));
    }

    #[test]
    fn k2means_engine_energy_decreases() {
        let (x, _) = blobs(400, 10, 12, 10.0, 5);
        let init = crate::init::gdi(
            &x,
            10,
            &mut crate::core::OpCounter::default(),
            6,
            &Default::default(),
        );
        let mut e = RustEngine::default();
        let r = k2means_engine(
            &x,
            &init.centers,
            init.labels.as_deref(),
            4,
            60,
            &mut e,
        )
        .unwrap();
        for w in r.trace.points.windows(2) {
            assert!(w[1].energy <= w[0].energy + 1e-3 * (1.0 + w[0].energy.abs()));
        }
    }
}
