//! The resident query service: the train/serve split's *read* side.
//!
//! A [`ServeService`] holds a trained [`ClusterModel`] and answers
//! batched `assign(points) -> labels` and `nearest_centers(points, m)`
//! requests with the paper's machinery turned query-side: instead of
//! scanning all `k` centers per query, it walks the model's kn-NN
//! center graph (greedy descent over neighbourhoods) and accepts the
//! fixed point only when the neighbourhood's coverage radius *proves*
//! no unvisited center can win — exactly the cluster-closure view of
//! the paper's restricted assignment. Batches shard over the persistent
//! [`crate::coordinator::pool`] workers.
//!
//! # The exactness contract
//!
//! Serving is **not approximate**. For every query, on every numerics
//! tier ([`NumericsMode`] dispatch):
//!
//! * [`ServeService::assign`] returns the label and plain distance that
//!   a full scan over all `k` centers on the same tier would return,
//!   **bit for bit** (same per-pair kernel arithmetic, same
//!   lowest-index tie-break as [`NumericsMode::nearest_rows`]).
//! * [`ServeService::nearest_centers`] returns the exact top-`m`
//!   centers in ascending `(distance, index)` order — slot 0 always
//!   equals `assign`'s answer.
//! * Results and op bills are **identical at any thread count** (shards
//!   are independent; per-shard counters merge in shard order).
//! * The per-query op bill is **never more than the full scan's** `k`
//!   distances: the scratch cache guarantees each center is evaluated
//!   at most once, whether during descent or in the completion
//!   fallback.
//!
//! How the guarantee works: the descent stops at a center `l` whose
//! whole neighbourhood `N_kn(c_l)` has been evaluated, with `u` the
//! best distance seen. Any *unvisited* center `c_j` is outside the
//! neighbourhood, so `d(c_l, c_j) >= r_l` (the graph row's last — i.e.
//! largest — distance) and by the triangle inequality `d(x, c_j) >=
//! r_l - d(x, c_l) >= r_l - u`-ish; the service accepts only when the
//! margin test proves every unvisited center strictly loses (with a
//! small conservative slack for f32 rounding). Otherwise it *completes*
//! the scan over exactly the not-yet-evaluated centers — never
//! restarting — which is why the bill can only go down relative to a
//! full scan, never up. `rust/tests/serve.rs` pins all of this across
//! every algorithm's model, 1/4/7 threads, and all numerics tiers.
//!
//! On the **Quantized** tier the completion itself prunes: the query is
//! packed against the model's 1-bit center codes
//! ([`ClusterModel::quant_codes`] — saved in the `.k2mm` v2 codes
//! section or rebuilt lazily) and a center whose certified squared
//! lower bound exceeds the incumbent's threshold is skipped without an
//! exact kernel call. Estimates and packs are billed on their own
//! [`OpCounter`] counters, off the distance bill, so the exact bill
//! only ever shrinks — and the answers stay bit-identical, because a
//! pruned center is *certified* to lose even through f32 rounding.

use crate::cluster::ClusterModel;
use crate::coordinator::pool;
use crate::core::kernels::quant::{self, QuantRow};
use crate::core::kernels::tile_scan_gated;
use crate::core::{Matrix, NumericsMode, OpCounter, ScanMode};

/// Multiplicative safety slack on the coverage tests. The accept
/// condition compares f32 quantities whose last-bit rounding could
/// otherwise flip a borderline accept; shrinking the radius by 0.1%
/// only ever *adds* completion scans (more evaluated centers), so the
/// slack is strictly on the conservative side of the exactness
/// guarantee.
const COVER_SLACK: f32 = 0.999;

/// Squared-domain prune threshold for an incumbent **plain** distance
/// `u`: `(u·(1+1e-4))²` in `f64`. A center whose certified squared
/// lower bound exceeds this provably loses to the incumbent even after
/// every f32 rounding in play (see [`ServeService::complete_pruned`]);
/// `u == 0` degenerates to "prune only what is provably nonzero away".
/// Widening the margin only ever *shrinks* the pruned set, so like
/// [`COVER_SLACK`] it sits on the conservative side. Shared with the
/// trainers' batched in-loop prune ([`quant::plain_threshold_sq`]) so
/// both sides certify against the identical margin.
fn prune_threshold_sq(best_plain: f32) -> f64 {
    quant::plain_threshold_sq(best_plain)
}

/// Per-shard query scratch: a stamped distance cache (one slot per
/// center, O(1) reset per query) plus the list of evaluated centers.
/// The cache is what enforces the "each center at most once" bill.
/// `qbits` is the reusable word buffer for packing the query on the
/// Quantized tier's pruned completion path.
struct Scratch {
    dist: Vec<f32>,
    stamp: Vec<u32>,
    tick: u32,
    evals: Vec<u32>,
    qbits: Vec<u64>,
    /// Gathered candidate ids for the batched scan mode (taken out of
    /// the scratch around each [`tile_scan_gated`] call so the driver
    /// can borrow it immutably while the fold mutates the cache).
    ids: Vec<u32>,
}

impl Scratch {
    fn new(k: usize) -> Scratch {
        Scratch {
            dist: vec![0.0; k],
            stamp: vec![0; k],
            tick: 0,
            evals: Vec::with_capacity(k),
            qbits: Vec::new(),
            ids: Vec::with_capacity(k),
        }
    }

    fn begin(&mut self) {
        self.evals.clear();
        if self.tick == u32::MAX {
            self.stamp.fill(0);
            self.tick = 0;
        }
        self.tick += 1;
    }

    #[inline(always)]
    fn cached(&self, j: usize) -> bool {
        self.stamp[j] == self.tick
    }

    #[inline(always)]
    fn insert(&mut self, j: usize, d: f32) {
        self.stamp[j] = self.tick;
        self.dist[j] = d;
        self.evals.push(j as u32);
    }
}

/// The resident bounded-scan query service over one [`ClusterModel`].
/// See the module docs for the exactness contract.
pub struct ServeService {
    model: ClusterModel,
    threads: usize,
    numerics: NumericsMode,
    scan: ScanMode,
}

impl ServeService {
    /// Serve `model` with the threads/numerics/scan defaults of its
    /// training provenance (`model.config()`).
    pub fn new(model: ClusterModel) -> ServeService {
        let threads = model.config().threads;
        let numerics = model.config().numerics;
        let scan = model.config().scan;
        ServeService { model, threads, numerics, scan }
    }

    /// Serve with explicit overrides (the CLI's `--threads`/`--numerics`
    /// path and the test matrix). Note the exactness contract is
    /// *within* a tier: serving a model on a different tier than it was
    /// trained under is still exact against a full scan **on the serving
    /// tier**. The scan mode starts from the model's provenance (itself
    /// defaulting to `K2M_SCAN`/Batched); see [`ServeService::set_scan`].
    pub fn with_options(
        model: ClusterModel,
        threads: usize,
        numerics: NumericsMode,
    ) -> ServeService {
        let scan = model.config().scan;
        ServeService { model, threads, numerics, scan }
    }

    /// Override the scan execution mode (the CLI's `--scan` path and
    /// the test matrix). Serving is bitwise identical either way —
    /// descent and completion have no bound gates that could go stale,
    /// so Batched only changes how survivors reach the kernels, never
    /// which centers are evaluated or what the bill reads.
    pub fn set_scan(&mut self, scan: ScanMode) {
        self.scan = scan;
    }

    /// The served model.
    pub fn model(&self) -> &ClusterModel {
        &self.model
    }

    /// The serving numerics tier.
    pub fn numerics(&self) -> NumericsMode {
        self.numerics
    }

    /// Batched assignment: for each query row, the nearest center's
    /// index and **plain** (non-squared) distance — bit-identical to a
    /// full [`NumericsMode::nearest_rows`] scan on the serving tier,
    /// for at most the full scan's `k` counted distances per query.
    pub fn assign(&self, queries: &Matrix, counter: &mut OpCounter) -> (Vec<u32>, Vec<f32>) {
        assert_eq!(
            queries.cols(),
            self.model.d(),
            "query dimensionality must match the model"
        );
        let n = queries.rows();
        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f32; n];
        if n == 0 {
            return (labels, dists);
        }
        let threads = pool::resolve_threads(self.threads, n);
        let chunk = pool::chunk_len(n, threads);
        pool::sharded_reduce(
            labels.chunks_mut(chunk).zip(dists.chunks_mut(chunk)),
            counter,
            |si, (lab, dst): (&mut [u32], &mut [f32]), ctr| {
                let mut scratch = Scratch::new(self.model.k());
                for (off, (l, dv)) in lab.iter_mut().zip(dst.iter_mut()).enumerate() {
                    let (j, dist) =
                        self.query_one(queries.row(si * chunk + off), &mut scratch, ctr);
                    *l = j;
                    *dv = dist;
                }
            },
        );
        (labels, dists)
    }

    /// Batched exact top-`m`: flat `n × m` center indices and **plain**
    /// distances, each query's row sorted ascending by
    /// `(distance, index)` — slot 0 is exactly [`ServeService::assign`]'s
    /// answer. `m` is clamped to `k`. The ranking sort is uncounted
    /// (selection bookkeeping, like the trainers' sort convention);
    /// counted distances stay ≤ `k` per query.
    pub fn nearest_centers(
        &self,
        queries: &Matrix,
        m: usize,
        counter: &mut OpCounter,
    ) -> (Vec<u32>, Vec<f32>) {
        assert_eq!(
            queries.cols(),
            self.model.d(),
            "query dimensionality must match the model"
        );
        assert!(m >= 1, "m must be >= 1");
        let m = m.min(self.model.k());
        let n = queries.rows();
        let mut idx = vec![0u32; n * m];
        let mut dists = vec![0.0f32; n * m];
        if n == 0 {
            return (idx, dists);
        }
        let threads = pool::resolve_threads(self.threads, n);
        let chunk = pool::chunk_len(n, threads);
        pool::sharded_reduce(
            idx.chunks_mut(chunk * m).zip(dists.chunks_mut(chunk * m)),
            counter,
            |si, (ic, dc): (&mut [u32], &mut [f32]), ctr| {
                let mut scratch = Scratch::new(self.model.k());
                for (off, (ir, dr)) in
                    ic.chunks_exact_mut(m).zip(dc.chunks_exact_mut(m)).enumerate()
                {
                    self.query_topm(queries.row(si * chunk + off), m, &mut scratch, ctr, ir, dr);
                }
            },
        );
        (idx, dists)
    }

    /// Greedy graph descent from center 0: evaluate the current
    /// center's whole neighbourhood, hop to the best center seen so far
    /// (lexicographic `(distance, index)` — the full scan's tie-break),
    /// stop when the best *is* the current center. Each hop strictly
    /// improves the best, and the cache evaluates each center at most
    /// once, so the descent terminates within `k` distance evaluations.
    /// Returns `(u, l)`: the best plain distance and its center — which
    /// is also the descent's fixed point.
    fn descend(&self, xi: &[f32], s: &mut Scratch, ctr: &mut OpCounter) -> (f32, u32) {
        let centers = self.model.centers();
        let graph = self.model.graph();
        let nm = self.numerics;
        s.begin();
        let d0 = nm.dist_one(xi, centers.row(0), ctr);
        s.insert(0, d0);
        let mut best = (d0, 0u32);
        let mut l = 0usize;
        if self.scan == ScanMode::Batched {
            // Gather each hop's uncached neighbours, then evaluate them
            // in tiles through the shared driver. A graph row holds
            // distinct centers and the cache only ever grows, so the
            // replayed gate can never fail late: same evaluations, same
            // fold order, same bill, `batch_extra` untouched.
            let mut ids = std::mem::take(&mut s.ids);
            loop {
                ids.clear();
                ids.extend(
                    graph.nbrs_row(l)[1..]
                        .iter()
                        .copied()
                        .filter(|&t| !s.cached(t as usize)),
                );
                tile_scan_gated(
                    nm,
                    xi,
                    centers,
                    &ids,
                    &ids,
                    s,
                    ctr,
                    |s, t| !s.cached(t as usize),
                    |s, t, dj| {
                        s.insert(t as usize, dj);
                        if dj < best.0 || (dj == best.0 && t < best.1) {
                            best = (dj, t);
                        }
                    },
                );
                if best.1 as usize == l {
                    s.ids = ids;
                    return best;
                }
                l = best.1 as usize;
            }
        }
        loop {
            for &t in &graph.nbrs_row(l)[1..] {
                let j = t as usize;
                if s.cached(j) {
                    // Already evaluated (and already compared into
                    // `best` when it was) — the bill stays ≤ k.
                    continue;
                }
                let dj = nm.dist_one(xi, centers.row(j), ctr);
                s.insert(j, dj);
                if dj < best.0 || (dj == best.0 && t < best.1) {
                    best = (dj, t);
                }
            }
            if best.1 as usize == l {
                return best;
            }
            l = best.1 as usize;
        }
    }

    /// Evaluate every not-yet-cached center (the completion fallback —
    /// never a restart, so the total per-query bill stays ≤ `k`).
    fn complete(&self, xi: &[f32], s: &mut Scratch, ctr: &mut OpCounter) {
        let centers = self.model.centers();
        let nm = self.numerics;
        if self.scan == ScanMode::Batched {
            // Gather-then-tile over exactly the not-yet-cached centers:
            // identical evaluation set and bill to the scalar walk.
            let mut ids = std::mem::take(&mut s.ids);
            ids.clear();
            ids.extend((0..self.model.k() as u32).filter(|&j| !s.cached(j as usize)));
            tile_scan_gated(
                nm,
                xi,
                centers,
                &ids,
                &ids,
                s,
                ctr,
                |s, j| !s.cached(j as usize),
                |s, j, dj| s.insert(j as usize, dj),
            );
            s.ids = ids;
            return;
        }
        for j in 0..self.model.k() {
            if !s.cached(j) {
                let dj = nm.dist_one(xi, centers.row(j), ctr);
                s.insert(j, dj);
            }
        }
    }

    /// The Quantized tier's completion fallback: pack the query against
    /// the model codes' `μ` (one billed pack per completing query),
    /// estimate every not-yet-cached center from the 1-bit codes (one
    /// billed estimate each, off the distance bill), and run the exact
    /// strict kernel only on centers whose certified squared lower bound
    /// does not exceed `thresh_sq`.
    ///
    /// Pruning is sound against the plain-distance answer: `thresh_sq`
    /// is `(u·(1+1e-4))²` for the incumbent plain distance `u` (see
    /// [`prune_threshold_sq`]), and the estimator's slack already covers
    /// the strict kernel's own f32 accumulation, so `lb > thresh_sq`
    /// certifies the kernel's squared value exceeds the threshold — a
    /// relative gap of `1e-4`, orders of magnitude above an f32 ulp, so
    /// the plain f32 distance after the square root still strictly
    /// exceeds `u` and the pruned center can neither win nor tie.
    /// Pruned centers never enter the cache, which only shrinks the
    /// exact bill — still ≤ `k` distances per query.
    fn complete_pruned(&self, xi: &[f32], s: &mut Scratch, thresh_sq: f64, ctr: &mut OpCounter) {
        let centers = self.model.centers();
        let nm = self.numerics;
        let codes = self.model.quant_codes();
        let dim = self.model.d();
        let mut bits = std::mem::take(&mut s.qbits);
        let head = quant::pack_row(xi, codes.mu(), &mut bits);
        ctr.packs += 1;
        let q = QuantRow { head, bits: &bits };
        if self.scan == ScanMode::Batched {
            // Gather the uncached centers, drop the certified losers in
            // one estimator sweep ([`quant::prune_survivors`] — same
            // per-center estimate bill as the scalar walk), then tile
            // the survivors through the shared driver: identical
            // evaluation set, bills and inserted values.
            let mut ids = std::mem::take(&mut s.ids);
            ids.clear();
            ids.extend((0..self.model.k() as u32).filter(|&j| !s.cached(j as usize)));
            quant::prune_survivors(q, codes, &mut ids, None, thresh_sq, ctr);
            tile_scan_gated(
                nm,
                xi,
                centers,
                &ids,
                &ids,
                s,
                ctr,
                |s, j| !s.cached(j as usize),
                |s, j, dj| s.insert(j as usize, dj),
            );
            s.ids = ids;
            s.qbits = bits;
            return;
        }
        for j in 0..self.model.k() {
            if s.cached(j) {
                continue;
            }
            ctr.estimates += 1;
            let (lb, _ub) = quant::estimate_bounds(q, codes.row_q(j), dim);
            if lb > thresh_sq {
                continue; // certified loser: skip the exact kernel
            }
            let dj = nm.dist_one(xi, centers.row(j), ctr);
            s.insert(j, dj);
        }
        s.qbits = bits;
    }

    /// Coverage radius of center `l`'s neighbourhood: the plain
    /// distance to its farthest graph neighbour. Every center *not* in
    /// `N_kn(c_l)` is at least this far from `c_l`.
    #[inline]
    fn radius(&self, l: u32) -> f32 {
        let graph = self.model.graph();
        graph.plain_dist(l as usize, graph.kn() - 1)
    }

    fn query_one(&self, xi: &[f32], s: &mut Scratch, ctr: &mut OpCounter) -> (u32, f32) {
        let k = self.model.k();
        let kn = self.model.kn();
        let (u, l) = self.descend(xi, s, ctr);
        // Accept iff every unvisited center j provably loses: d(x, c_j)
        // >= d(c_l, c_j) - d(x, c_l) >= r_l - u > u, i.e. 2u < r_l
        // (slack-shrunk). With kn == k the graph holds every center and
        // the descent's first neighbourhood already was a full scan.
        if kn == k || 2.0 * u < COVER_SLACK * self.radius(l) {
            return (l, u);
        }
        if self.numerics == NumericsMode::Quantized {
            self.complete_pruned(xi, s, prune_threshold_sq(u), ctr);
        } else {
            self.complete(xi, s, ctr);
        }
        let mut best = (u, l);
        for &j in &s.evals {
            let dj = s.dist[j as usize];
            if dj < best.0 || (dj == best.0 && j < best.1) {
                best = (dj, j);
            }
        }
        (best.1, best.0)
    }

    fn query_topm(
        &self,
        xi: &[f32],
        m: usize,
        s: &mut Scratch,
        ctr: &mut OpCounter,
        out_idx: &mut [u32],
        out_dist: &mut [f32],
    ) {
        let k = self.model.k();
        let kn = self.model.kn();
        let (u, l) = self.descend(xi, s, ctr);
        // Rank the evaluated set by (distance, index) — uncounted
        // selection bookkeeping.
        let mut ranked: Vec<(f32, u32)> =
            s.evals.iter().map(|&j| (s.dist[j as usize], j)).collect();
        ranked.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        // Top-m coverage: with u_m the m-th best *evaluated* distance,
        // every unvisited center j satisfies d(x, c_j) >= r_l - u, so
        // u + u_m < r_l (slack-shrunk) proves the m evaluated leaders
        // all strictly beat every unvisited center.
        let covered = kn == k
            || (ranked.len() >= m && u + ranked[m - 1].0 < COVER_SLACK * self.radius(l));
        if !covered {
            // On the Quantized tier, the descent's m-th best (when it
            // exists) caps what a top-m contender may cost: completion
            // can only improve the m-th best, so pruning against the
            // pre-completion value is conservative. With fewer than m
            // evaluated centers there is no incumbent to prune against.
            if self.numerics == NumericsMode::Quantized && ranked.len() >= m {
                self.complete_pruned(xi, s, prune_threshold_sq(ranked[m - 1].0), ctr);
            } else {
                self.complete(xi, s, ctr);
            }
            ranked = s.evals.iter().map(|&j| (s.dist[j as usize], j)).collect();
            ranked.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        }
        for (slot, &(dv, j)) in ranked[..m].iter().enumerate() {
            out_idx[slot] = j;
            out_dist[slot] = dv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Config;
    use crate::testing::random_matrix;

    fn service(k: usize, kn: usize, d: usize, seed: u64) -> ServeService {
        let centers = random_matrix(k, d, seed);
        let cfg = Config { k, kn, numerics: NumericsMode::Strict, ..Default::default() };
        ServeService::with_options(ClusterModel::build(centers, &cfg), 1, NumericsMode::Strict)
    }

    fn full_scan(
        q: &Matrix,
        centers: &Matrix,
        nm: NumericsMode,
    ) -> (Vec<u32>, Vec<f32>, OpCounter) {
        let mut ctr = OpCounter::default();
        let mut labels = Vec::with_capacity(q.rows());
        let mut dists = Vec::with_capacity(q.rows());
        for i in 0..q.rows() {
            let (j, dist) = nm.nearest_rows(q.row(i), centers, &mut ctr);
            labels.push(j);
            dists.push(dist);
        }
        (labels, dists, ctr)
    }

    #[test]
    fn assign_matches_full_scan_bitwise() {
        let svc = service(30, 6, 8, 1);
        let q = random_matrix(120, 8, 2);
        let (want_l, want_d, want_ctr) = full_scan(&q, svc.model().centers(), svc.numerics());
        let mut ctr = OpCounter::default();
        let (l, dist) = svc.assign(&q, &mut ctr);
        assert_eq!(l, want_l);
        for (a, b) in dist.iter().zip(&want_d) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(ctr.distances <= want_ctr.distances);
    }

    #[test]
    fn kn_one_graph_still_exact_via_completion() {
        // A kn=1 graph (self-only rows, radius 0) can never accept the
        // descent — every query must fall through to completion and
        // still be exact at exactly k distances.
        let svc = service(12, 1, 5, 3);
        let q = random_matrix(40, 5, 4);
        let (want_l, want_d, _) = full_scan(&q, svc.model().centers(), svc.numerics());
        let mut ctr = OpCounter::default();
        let (l, dist) = svc.assign(&q, &mut ctr);
        assert_eq!(l, want_l);
        for (a, b) in dist.iter().zip(&want_d) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ctr.distances, 40 * 12);
    }

    #[test]
    fn duplicate_centers_keep_the_full_scan_tie_break() {
        // Duplicated center rows force exact distance ties; the serve
        // answer must still be the full scan's lowest-index winner.
        let mut centers = random_matrix(10, 4, 5);
        let dup = centers.row(7).to_vec();
        centers.row_mut(2).copy_from_slice(&dup);
        let cfg = Config { k: 10, kn: 4, numerics: NumericsMode::Strict, ..Default::default() };
        let svc = ServeService::with_options(
            ClusterModel::build(centers, &cfg),
            1,
            NumericsMode::Strict,
        );
        let q = random_matrix(60, 4, 6);
        let (want_l, want_d, _) = full_scan(&q, svc.model().centers(), svc.numerics());
        let mut ctr = OpCounter::default();
        let (l, dist) = svc.assign(&q, &mut ctr);
        assert_eq!(l, want_l);
        for (a, b) in dist.iter().zip(&want_d) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn nearest_centers_slot0_equals_assign_and_rows_sorted() {
        let svc = service(25, 5, 6, 7);
        let q = random_matrix(80, 6, 8);
        let mut c1 = OpCounter::default();
        let (labels, udists) = svc.assign(&q, &mut c1);
        let mut c2 = OpCounter::default();
        let m = 4;
        let (idx, dists) = svc.nearest_centers(&q, m, &mut c2);
        for i in 0..80 {
            assert_eq!(idx[i * m], labels[i]);
            assert_eq!(dists[i * m].to_bits(), udists[i].to_bits());
            let row: Vec<(f32, u32)> =
                (0..m).map(|t| (dists[i * m + t], idx[i * m + t])).collect();
            for w in row.windows(2) {
                assert!(w[0] < w[1], "row {i} not sorted: {row:?}");
            }
        }
        assert!(c2.distances <= (80 * 25) as u64);
    }

    /// Near-binary ±1 sign patterns with a touch of jitter — the regime
    /// where the 1-bit estimator's certified radius is far smaller than
    /// the distances it brackets, so the pruned completion actually
    /// prunes.
    fn near_binary(rows: usize, d: usize, seed: u64) -> Matrix {
        let mut m = random_matrix(rows, d, seed);
        let jit = random_matrix(rows, d, seed + 1);
        for (v, j) in m.as_mut_slice().iter_mut().zip(jit.as_slice()) {
            *v = v.signum() + 1e-4 * j;
        }
        m
    }

    #[test]
    fn quantized_serving_matches_strict_bitwise() {
        let centers = random_matrix(30, 8, 1);
        let cfg = Config { k: 30, kn: 6, numerics: NumericsMode::Quantized, ..Default::default() };
        let model = ClusterModel::build(centers, &cfg);
        assert!(model.has_codes());
        let svc_q =
            ServeService::with_options(model.clone(), 1, NumericsMode::Quantized);
        let svc_s = ServeService::with_options(model, 1, NumericsMode::Strict);
        let q = random_matrix(120, 8, 2);
        let (mut cq, mut cs) = (OpCounter::default(), OpCounter::default());
        let (lq, dq) = svc_q.assign(&q, &mut cq);
        let (ls, ds) = svc_s.assign(&q, &mut cs);
        assert_eq!(lq, ls);
        for (a, b) in dq.iter().zip(&ds) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Exact bill never exceeds the strict service's; estimator work
        // is billed on its own counters, and only by the quantized tier.
        assert!(cq.distances <= cs.distances);
        assert_eq!((cs.estimates, cs.packs), (0, 0));
        // Top-m agrees too.
        let (mut cq2, mut cs2) = (OpCounter::default(), OpCounter::default());
        let (iq, dq2) = svc_q.nearest_centers(&q, 5, &mut cq2);
        let (is, ds2) = svc_s.nearest_centers(&q, 5, &mut cs2);
        assert_eq!(iq, is);
        for (a, b) in dq2.iter().zip(&ds2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(cq2.distances <= cs2.distances);
    }

    #[test]
    fn quantized_completion_prunes_on_sign_structured_queries() {
        // Queries at 3× a center: far from every center (completion
        // always runs — 2u ≫ the coverage radius) yet with one center
        // hugely closer than the rest, so the certified bounds separate
        // and the exact bill drops below the strict service's.
        let centers = near_binary(30, 64, 11);
        let cfg = Config { k: 30, kn: 6, numerics: NumericsMode::Quantized, ..Default::default() };
        let model = ClusterModel::build(centers.clone(), &cfg);
        let mut q = Matrix::zeros(30, 64);
        for i in 0..30 {
            for (qv, &cv) in q.row_mut(i).iter_mut().zip(centers.row(i)) {
                *qv = 3.0 * cv;
            }
        }
        let svc_q =
            ServeService::with_options(model.clone(), 1, NumericsMode::Quantized);
        let svc_s = ServeService::with_options(model, 1, NumericsMode::Strict);
        let (mut cq, mut cs) = (OpCounter::default(), OpCounter::default());
        let (lq, dq) = svc_q.assign(&q, &mut cq);
        let (ls, ds) = svc_s.assign(&q, &mut cs);
        assert_eq!(lq, ls);
        for (a, b) in dq.iter().zip(&ds) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(cq.estimates > 0, "completion never ran quantized estimates");
        assert!(cq.packs > 0);
        assert!(
            cq.distances < cs.distances,
            "pruning never fired: {} vs {}",
            cq.distances,
            cs.distances
        );
    }

    #[test]
    fn m_clamped_to_k_gives_full_ranking() {
        let svc = service(6, 3, 4, 9);
        let q = random_matrix(10, 4, 10);
        let (idx, _) = svc.nearest_centers(&q, 99, &mut OpCounter::default());
        assert_eq!(idx.len(), 10 * 6);
        for i in 0..10 {
            let mut row: Vec<u32> = idx[i * 6..(i + 1) * 6].to_vec();
            row.sort_unstable();
            assert_eq!(row, (0..6u32).collect::<Vec<_>>());
        }
    }
}
