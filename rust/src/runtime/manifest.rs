//! `artifacts/manifest.txt` parser — the build-time/run-time contract.
//!
//! aot.py writes one artifact per line as space-separated `key=value`
//! pairs, e.g.
//!
//! ```text
//! d=64 file=assign_full_nb2048_k256_d64.hlo.txt k=256 name=... nb=2048 op=assign_full
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT artifact's metadata.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub op: String,
    pub name: String,
    pub file: String,
    /// Point-block rows per executable call (absent for center_knn).
    pub nb: Option<usize>,
    pub k: Option<usize>,
    pub kn: Option<usize>,
    pub d: Option<usize>,
    pub n: Option<usize>,
}

/// Parsed manifest plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut kv: HashMap<&str, &str> = HashMap::new();
            for field in line.split_whitespace() {
                let Some((key, value)) = field.split_once('=') else {
                    bail!("manifest line {}: bad field {field:?}", lineno + 1);
                };
                kv.insert(key, value);
            }
            let get = |key: &str| -> Result<String> {
                kv.get(key)
                    .map(|s| s.to_string())
                    .with_context(|| format!("manifest line {}: missing {key}", lineno + 1))
            };
            let parse_opt = |key: &str| -> Result<Option<usize>> {
                kv.get(key)
                    .map(|s| s.parse::<usize>().with_context(|| format!("bad {key}={s}")))
                    .transpose()
            };
            entries.push(ManifestEntry {
                op: get("op")?,
                name: get("name")?,
                file: get("file")?,
                nb: parse_opt("nb")?,
                k: parse_opt("k")?,
                kn: parse_opt("kn")?,
                d: parse_opt("d")?,
                n: parse_opt("n")?,
            });
        }
        if entries.is_empty() {
            bail!("empty manifest at {}", path.display());
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Smallest artifact of `op` fitting the requested shape: every
    /// requested dimension must be <= the artifact's; ties by total
    /// padded volume. Returns `None` when nothing fits (caller falls
    /// back to the native engine).
    pub fn select(
        &self,
        op: &str,
        k: Option<usize>,
        kn: Option<usize>,
        d: Option<usize>,
    ) -> Option<&ManifestEntry> {
        let fits = |have: Option<usize>, want: Option<usize>| match (want, have) {
            (None, _) => true,
            (Some(w), Some(h)) => w <= h,
            (Some(_), None) => false,
        };
        self.entries
            .iter()
            .filter(|e| e.op == op && fits(e.k, k) && fits(e.kn, kn) && fits(e.d, d))
            .min_by_key(|e| {
                e.k.unwrap_or(1) as u64 * e.kn.unwrap_or(1) as u64 * e.d.unwrap_or(1) as u64
            })
    }

    /// Full path of an entry's HLO text file.
    pub fn path_of(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(lines: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "k2m_manifest_{}_{}",
            std::process::id(),
            lines.len()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), lines).unwrap();
        dir
    }

    #[test]
    fn parses_and_selects_smallest_fit() {
        let dir = write_manifest(
            "d=64 file=a.hlo.txt k=256 name=a nb=2048 op=assign_full\n\
             d=512 file=b.hlo.txt k=256 name=b nb=2048 op=assign_full\n\
             d=64 file=c.hlo.txt k=1024 name=c nb=2048 op=assign_full\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.select("assign_full", Some(200), None, Some(50)).unwrap();
        assert_eq!(e.name, "a");
        let e = m.select("assign_full", Some(300), None, Some(50)).unwrap();
        assert_eq!(e.name, "c");
        let e = m.select("assign_full", Some(200), None, Some(100)).unwrap();
        assert_eq!(e.name, "b");
        assert!(m.select("assign_full", Some(2000), None, Some(50)).is_none());
        assert!(m.select("nonexistent", None, None, None).is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_lines() {
        let dir = write_manifest("this is not key=value at all\n");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("k2m_no_manifest_here");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Soft check against the actual artifacts dir when present.
        let dir = crate::runtime::default_artifact_dir();
        if dir.join("manifest.txt").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.select("assign_full", Some(256), None, Some(64)).is_some());
            assert!(m.select("update_stats", Some(256), None, Some(64)).is_some());
        }
    }
}
