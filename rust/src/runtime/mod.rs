//! The request-path runtime: loads the HLO-text artifacts that
//! `python/compile/aot.py` produced at build time and executes them on
//! the PJRT CPU client through the `xla` crate — Python never runs here.
//!
//! * [`manifest`] — parses `artifacts/manifest.txt` (the line-based
//!   contract written by aot.py; no serde in the offline vendor set).
//! * [`engine`] — the [`engine::Engine`] trait with two backends:
//!   [`engine::RustEngine`] (native loops; the op-counted algorithms in
//!   [`crate::cluster`] are separate, finer-grained implementations) and
//!   [`XlaEngine`] (PJRT execution of the AOT artifacts with shape
//!   padding/dispatch; requires the `xla-pjrt` cargo feature — the
//!   default build ships an API-compatible stub whose constructor
//!   explains how to enable the real backend).
//! * [`cluster_engine`] — batched Lloyd and k²-means loops running
//!   entirely through an [`engine::Engine`], demonstrating the paper's
//!   algorithm end-to-end on the XLA path (triangle-inequality bounds
//!   stay in the scalar L3 variant, per DESIGN.md §Hardware-Adaptation),
//!   plus [`run_cluster_jobs`] — the submission API that executes many
//!   clustering jobs concurrently on the persistent worker pool
//!   ([`crate::coordinator::jobs`]).
//! * [`serve`] — the resident bounded-scan query service over a trained
//!   [`crate::cluster::ClusterModel`]: batched exact `assign` /
//!   `nearest_centers` via the model's center graph, sharded over the
//!   persistent pool, with a strict exactness contract (see the module
//!   docs) — the *read* side of the train/serve split.

pub mod cluster_engine;
pub mod engine;
pub mod manifest;
pub mod serve;
mod xla_engine;

pub use cluster_engine::{k2means_engine, lloyd_engine, run_cluster_jobs};
pub use engine::{Engine, RustEngine};
pub use manifest::{Manifest, ManifestEntry};
pub use serve::ServeService;
pub use xla_engine::XlaEngine;

use std::path::PathBuf;

/// Default artifact directory: `$K2M_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("K2M_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
