//! The batched execution interface the coordinator's engine-path loops
//! run on, with the native reference backend.
//!
//! The op-counted algorithms in [`crate::cluster`] are scalar-granular
//! (they need per-point bound bookkeeping); the engine interface instead
//! exposes the *batched* steps that the AOT artifacts implement, so the
//! same loop runs on either backend and the two can be cross-checked.

use anyhow::Result;

use crate::cluster::ClusterModel;
use crate::core::{kernels, Matrix, NumericsMode};

/// Batched clustering steps. Shapes: `x` is n×d, `c` is k×d.
pub trait Engine {
    /// Full assignment: nearest center per point → (labels, sq-dists).
    fn assign_full(&mut self, x: &Matrix, c: &Matrix) -> Result<(Vec<u32>, Vec<f32>)>;

    /// Candidate-restricted assignment (k²-means step). `cand` is a
    /// row-major n×kn table of center indices (must include the current
    /// center of each point).
    fn assign_candidates(
        &mut self,
        x: &Matrix,
        c: &Matrix,
        cand: &[u32],
        kn: usize,
    ) -> Result<(Vec<u32>, Vec<f32>)>;

    /// kn-NN graph over centers → (row-major k×kn indices, sq-dists).
    fn center_knn(&mut self, c: &Matrix, kn: usize) -> Result<(Vec<u32>, Vec<f32>)>;

    /// Update-step sufficient statistics → (sums k×d, counts k).
    fn update_stats(&mut self, x: &Matrix, labels: &[u32], k: usize)
        -> Result<(Matrix, Vec<f32>)>;

    /// Backend name for logs/benches.
    fn name(&self) -> &'static str;
}

/// Native Rust backend: the blocked raw kernels of
/// [`crate::core::kernels`] for the candidate scans and the center
/// table, plus the norm-trick full assignment over the raw one-pair
/// primitives (wallclock path — not op-counted; the counted algorithms
/// live in [`crate::cluster`]). All scans dispatch on the `numerics`
/// field, so the backend rides `K2M_NUMERICS` / CLI `--numerics` like
/// the counted algorithms do.
pub struct RustEngine {
    /// Numerics tier for every batched scan (default: the process-wide
    /// `K2M_NUMERICS` resolution, else Strict).
    pub numerics: NumericsMode,
}

impl Default for RustEngine {
    fn default() -> Self {
        RustEngine { numerics: NumericsMode::from_env() }
    }
}

impl RustEngine {
    /// A backend pinned to an explicit tier (the CLI's `--engine rust
    /// --numerics ...` path; tests that compare tiers).
    pub fn with_numerics(numerics: NumericsMode) -> RustEngine {
        RustEngine { numerics }
    }

    /// Full assignment against a trained [`ClusterModel`], reusing the
    /// model's cached `‖c_j‖²` instead of recomputing the center norms
    /// per call. Bit-identical to [`Engine::assign_full`] over
    /// `model.centers()` whenever `self.numerics` matches the tier the
    /// model's norms were computed on (`model.config().numerics` — the
    /// [`ClusterModel`] contract); on a mismatched tier it is still a
    /// correct norm-trick assignment, just with norms from the other
    /// tier's summation order.
    pub fn assign_with_model(
        &mut self,
        x: &Matrix,
        model: &ClusterModel,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        assert_eq!(x.cols(), model.d(), "query dims must match the model");
        let nm = self.numerics;
        let c = model.centers();
        let c2 = model.norms();
        let n = x.rows();
        let k = model.k();
        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f32; n];
        for i in 0..n {
            let xi = x.row(i);
            let x2 = nm.norm2_raw(xi);
            let mut best = (0u32, f32::INFINITY);
            for j in 0..k {
                let dist = x2 + c2[j] - 2.0 * nm.dot_one_raw(xi, c.row(j));
                if dist < best.1 {
                    best = (j as u32, dist);
                }
            }
            labels[i] = best.0;
            dists[i] = best.1.max(0.0);
        }
        Ok((labels, dists))
    }
}

impl Engine for RustEngine {
    fn assign_full(&mut self, x: &Matrix, c: &Matrix) -> Result<(Vec<u32>, Vec<f32>)> {
        // §Perf note: a 4-point/shared-center-row micro-tile was tried
        // here and measured *slower* (19.3 ms vs 14.9 ms at n=4096,
        // k=256, d=64) than the plain per-point loop over the 8-wide
        // `sqdist_raw` — the gathered-accumulator structure defeated
        // LLVM's packed-FMA codegen. Reverted; see EXPERIMENTS.md §Perf.
        // Norm-trick form: ||x−c||² = ||x||² + ||c||² − 2⟨x,c⟩. The dot
        // inner loop is 2 flops/element vs sqdist's 3 — measured 1.35×
        // on the assignment step (EXPERIMENTS.md §Perf row 4).
        let nm = self.numerics;
        let n = x.rows();
        let k = c.rows();
        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f32; n];
        let c2: Vec<f32> = (0..k).map(|j| nm.norm2_raw(c.row(j))).collect();
        for i in 0..n {
            let xi = x.row(i);
            let x2 = nm.norm2_raw(xi);
            let mut best = (0u32, f32::INFINITY);
            for j in 0..k {
                let dist = x2 + c2[j] - 2.0 * nm.dot_one_raw(xi, c.row(j));
                if dist < best.1 {
                    best = (j as u32, dist);
                }
            }
            // Guard against tiny negative values from cancellation.
            labels[i] = best.0;
            dists[i] = best.1.max(0.0);
        }
        Ok((labels, dists))
    }

    fn assign_candidates(
        &mut self,
        x: &Matrix,
        c: &Matrix,
        cand: &[u32],
        kn: usize,
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        let n = x.rows();
        assert_eq!(cand.len(), n * kn);
        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f32; n];
        // Blocked candidate scan per point (uncounted wallclock path) —
        // earliest-slot tie-break, like the counted k²-means scan.
        let mut dbuf = vec![0.0f32; kn];
        for i in 0..n {
            let row = &cand[i * kn..(i + 1) * kn];
            self.numerics.sqdist_block_raw(x.row(i), c, row, &mut dbuf);
            let (slot, dist) = kernels::argmin(&dbuf);
            labels[i] = row[slot];
            dists[i] = dist;
        }
        Ok((labels, dists))
    }

    fn center_knn(&mut self, c: &Matrix, kn: usize) -> Result<(Vec<u32>, Vec<f32>)> {
        let k = c.rows();
        let kn = kn.min(k);
        let mut nbrs = vec![0u32; k * kn];
        let mut nds = vec![0.0f32; k * kn];
        // One blocked O(k) row per center (same memory footprint and
        // pair count as the old per-pair loop, same selection sort —
        // identical output); the O(k²) table would defeat the cache
        // at large k.
        let mut dbuf = vec![0.0f32; k];
        let mut row: Vec<(f32, u32)> = Vec::with_capacity(k);
        for i in 0..k {
            self.numerics.sqdist_rows_raw(c.row(i), c, 0, &mut dbuf);
            row.clear();
            for (j, &dv) in dbuf.iter().enumerate() {
                row.push((dv, j as u32));
            }
            row.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
            for t in 0..kn {
                nbrs[i * kn + t] = row[t].1;
                nds[i * kn + t] = row[t].0;
            }
        }
        Ok((nbrs, nds))
    }

    fn update_stats(
        &mut self,
        x: &Matrix,
        labels: &[u32],
        k: usize,
    ) -> Result<(Matrix, Vec<f32>)> {
        let d = x.cols();
        let mut sums = Matrix::zeros(k, d);
        let mut counts = vec![0.0f32; k];
        for (i, &l) in labels.iter().enumerate() {
            let acc = sums.row_mut(l as usize);
            for (a, &v) in acc.iter_mut().zip(x.row(i)) {
                *a += v;
            }
            counts[l as usize] += 1.0;
        }
        Ok((sums, counts))
    }

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Shared helper: finish an update step — divide sums by counts, keep the
/// old center where a cluster went empty.
pub fn finish_update(sums: &Matrix, counts: &[f32], old: &Matrix) -> Matrix {
    let k = old.rows();
    let d = old.cols();
    let mut out = Matrix::zeros(k, d);
    for j in 0..k {
        let row = out.row_mut(j);
        if counts[j] > 0.0 {
            let inv = 1.0 / counts[j];
            for (r, &s) in row.iter_mut().zip(sums.row(j)) {
                *r = s * inv;
            }
        } else {
            row.copy_from_slice(old.row(j));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ops;
    use crate::testing::random_matrix;

    #[test]
    fn assign_full_matches_bruteforce() {
        // assign_full uses the norm-trick form — compare against direct
        // sqdist with a cancellation-sized tolerance.
        let x = random_matrix(50, 6, 1);
        let c = random_matrix(7, 6, 2);
        let mut e = RustEngine::default();
        let (labels, dists) = e.assign_full(&x, &c).unwrap();
        for i in 0..50 {
            for j in 0..7 {
                let dj = ops::sqdist_raw(x.row(i), c.row(j));
                assert!(dists[i] <= dj + 1e-3 * (1.0 + dj));
            }
            let dl = ops::sqdist_raw(x.row(i), c.row(labels[i] as usize));
            assert!((dl - dists[i]).abs() < 1e-3 * (1.0 + dl));
        }
    }

    #[test]
    fn candidates_with_full_set_equal_assign_full() {
        let x = random_matrix(40, 5, 3);
        let c = random_matrix(6, 5, 4);
        let mut e = RustEngine::default();
        let cand: Vec<u32> = (0..40).flat_map(|_| 0..6u32).collect();
        let (l1, d1) = e.assign_candidates(&x, &c, &cand, 6).unwrap();
        let (l2, d2) = e.assign_full(&x, &c).unwrap();
        assert_eq!(l1, l2);
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn center_knn_self_first() {
        let c = random_matrix(10, 4, 5);
        let mut e = RustEngine::default();
        let (nbrs, nds) = e.center_knn(&c, 3).unwrap();
        for i in 0..10 {
            assert_eq!(nbrs[i * 3], i as u32);
            assert_eq!(nds[i * 3], 0.0);
        }
    }

    #[test]
    fn assign_with_model_matches_assign_full_bitwise() {
        use crate::cluster::{ClusterModel, Config};
        use crate::core::NumericsMode;
        let x = random_matrix(60, 6, 7);
        let c = random_matrix(9, 6, 8);
        for nm in [NumericsMode::Strict, NumericsMode::Fast] {
            let cfg = Config { k: 9, kn: 3, numerics: nm, ..Default::default() };
            let model = ClusterModel::build(c.clone(), &cfg);
            let mut e = RustEngine::with_numerics(nm);
            let (l1, d1) = e.assign_with_model(&x, &model).unwrap();
            let (l2, d2) = e.assign_full(&x, &c).unwrap();
            assert_eq!(l1, l2);
            for (a, b) in d1.iter().zip(&d2) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn update_stats_and_finish() {
        let x = Matrix::from_vec(vec![0., 0., 2., 0., 5., 5.], 3, 2);
        let labels = vec![0, 0, 1];
        let mut e = RustEngine::default();
        let (sums, counts) = e.update_stats(&x, &labels, 3).unwrap();
        assert_eq!(sums.row(0), &[2.0, 0.0]);
        assert_eq!(counts, vec![2.0, 1.0, 0.0]);
        let old = Matrix::from_vec(vec![9., 9., 9., 9., 7., 7.], 3, 2);
        let new = finish_update(&sums, &counts, &old);
        assert_eq!(new.row(0), &[1.0, 0.0]);
        assert_eq!(new.row(1), &[5.0, 5.0]);
        assert_eq!(new.row(2), &[7.0, 7.0]); // empty keeps old
    }
}
