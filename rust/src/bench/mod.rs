//! In-repo wallclock bench harness (the offline vendor set has no
//! criterion — DESIGN.md §3). Reports median / p10 / p90 of N timed
//! iterations after warmup, plus derived throughput.
//!
//! Used by the `rust/benches/*.rs` targets (`cargo bench`, `harness =
//! false`) and by the §Perf iteration loop in EXPERIMENTS.md.
//!
//! Setting `K2M_BENCH_JSON=<path>` additionally appends one JSON object
//! per completed benchmark to `<path>` (JSON-lines, created on first
//! row): `{"bench", "shape", "mode", "median_ns", "p10_ns", "p90_ns",
//! "iters"}`. `shape`/`mode` are empty for [`Harness::run`]; bench
//! sections that sweep a knob (e.g. gated-vs-batched scans) tag rows
//! via [`Harness::run_tagged`] so downstream tooling can pivot without
//! parsing display names.

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl Stats {
    /// items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// The `K2M_BENCH_JSON` sink path, resolved once per process (same
/// policy as the mode env knobs: the first read wins).
fn json_sink() -> Option<&'static PathBuf> {
    static SINK: OnceLock<Option<PathBuf>> = OnceLock::new();
    SINK.get_or_init(|| std::env::var_os("K2M_BENCH_JSON").map(PathBuf::from)).as_ref()
}

/// Minimal string escape for the fields we emit (bench names never
/// carry control characters).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// One JSON-lines record for a completed benchmark.
fn json_row(stats: &Stats, shape: &str, mode: &str) -> String {
    format!(
        "{{\"bench\":\"{}\",\"shape\":\"{}\",\"mode\":\"{}\",\"median_ns\":{},\"p10_ns\":{},\"p90_ns\":{},\"iters\":{}}}\n",
        json_escape(&stats.name),
        json_escape(shape),
        json_escape(mode),
        stats.median.as_nanos(),
        stats.p10.as_nanos(),
        stats.p90.as_nanos(),
        stats.iters,
    )
}

/// Append a machine-readable row to the `K2M_BENCH_JSON` file (no-op
/// when the variable is unset). Failures warn instead of panicking — a
/// read-only filesystem should not kill a bench run.
pub fn emit_json(stats: &Stats, shape: &str, mode: &str) {
    let Some(path) = json_sink() else { return };
    let row = json_row(stats, shape, mode);
    let appended = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .and_then(|mut f| f.write_all(row.as_bytes()));
    if let Err(e) = appended {
        eprintln!("[bench] K2M_BENCH_JSON append to {} failed: {e}", path.display());
    }
}

/// Bench runner: fixed warmup, then timed iterations until both a minimum
/// count and a minimum total time are met (so fast ops get enough samples
/// and slow ops do not run forever).
pub struct Harness {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for Harness {
    fn default() -> Self {
        Harness {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            min_time: Duration::from_millis(300),
        }
    }
}

impl Harness {
    /// Time `f` and print + return the stats. `f` should do one unit of
    /// work and return something opaque to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, f: F) -> Stats {
        self.run_tagged(name, "", "", f)
    }

    /// [`Harness::run`] with explicit `shape`/`mode` tags on the
    /// `K2M_BENCH_JSON` record, for sections that sweep a knob and want
    /// the pivot columns machine-readable rather than embedded in the
    /// display name.
    pub fn run_tagged<T, F: FnMut() -> T>(
        &self,
        name: &str,
        shape: &str,
        mode: &str,
        mut f: F,
    ) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let stats = Stats {
            name: name.to_string(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            iters: samples.len(),
        };
        println!(
            "{:40} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} iters)",
            stats.name, stats.median, stats.p10, stats.p90, stats.iters
        );
        emit_json(&stats, shape, mode);
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_orders_quantiles() {
        let h = Harness {
            warmup: 1,
            min_iters: 5,
            max_iters: 10,
            min_time: Duration::from_millis(1),
        };
        let s = h.run("noop", || 1 + 1);
        assert!(s.iters >= 5);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn json_row_shape() {
        let s = Stats {
            name: "k2means 4096x32 k=64 \"q\"".to_string(),
            median: Duration::from_nanos(1500),
            p10: Duration::from_nanos(1000),
            p90: Duration::from_nanos(2000),
            iters: 7,
        };
        let row = json_row(&s, "4096x32 k=64", "batched");
        assert!(row.ends_with('\n'));
        assert!(row.contains("\"mode\":\"batched\""));
        assert!(row.contains("\"median_ns\":1500"));
        // Embedded quotes survive as valid JSON escapes.
        assert!(row.contains("\\\"q\\\""));
    }

    #[test]
    fn throughput_positive() {
        let h = Harness {
            warmup: 0,
            min_iters: 3,
            max_iters: 3,
            min_time: Duration::from_millis(0),
        };
        let s = h.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.throughput(10_000.0) > 0.0);
    }
}
