//! In-repo wallclock bench harness (the offline vendor set has no
//! criterion — DESIGN.md §3). Reports median / p10 / p90 of N timed
//! iterations after warmup, plus derived throughput.
//!
//! Used by the `rust/benches/*.rs` targets (`cargo bench`, `harness =
//! false`) and by the §Perf iteration loop in EXPERIMENTS.md.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub iters: usize,
}

impl Stats {
    /// items/sec given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median.as_secs_f64()
    }
}

/// Bench runner: fixed warmup, then timed iterations until both a minimum
/// count and a minimum total time are met (so fast ops get enough samples
/// and slow ops do not run forever).
pub struct Harness {
    pub warmup: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub min_time: Duration,
}

impl Default for Harness {
    fn default() -> Self {
        Harness {
            warmup: 2,
            min_iters: 5,
            max_iters: 200,
            min_time: Duration::from_millis(300),
        }
    }
}

impl Harness {
    /// Time `f` and print + return the stats. `f` should do one unit of
    /// work and return something opaque to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Stats {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        samples.sort_unstable();
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let stats = Stats {
            name: name.to_string(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            iters: samples.len(),
        };
        println!(
            "{:40} median {:>12?}  p10 {:>12?}  p90 {:>12?}  ({} iters)",
            stats.name, stats.median, stats.p10, stats.p90, stats.iters
        );
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_orders_quantiles() {
        let h = Harness {
            warmup: 1,
            min_iters: 5,
            max_iters: 10,
            min_time: Duration::from_millis(1),
        };
        let s = h.run("noop", || 1 + 1);
        assert!(s.iters >= 5);
        assert!(s.p10 <= s.median && s.median <= s.p90);
    }

    #[test]
    fn throughput_positive() {
        let h = Harness {
            warmup: 0,
            min_iters: 3,
            max_iters: 3,
            min_time: Duration::from_millis(0),
        };
        let s = h.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.throughput(10_000.0) > 0.0);
    }
}
