//! Dataset persistence: a minimal self-describing binary format
//! (one ASCII header line + f32le rows) and a CSV loader so users can
//! bring their own data to the CLI (`k2m cluster --data file.k2b`).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::core::Matrix;

/// Save as `.k2b`: header `k2b <name> <rows> <cols>\n` then rows*cols f32le.
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "k2b {} {} {}", ds.name.replace(' ', "_"), ds.x.rows(), ds.x.cols())?;
    let bytes: Vec<u8> = ds.x.as_slice().iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

/// Load a `.k2b` file written by [`save_bin`].
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    if parts.len() != 4 || parts[0] != "k2b" {
        bail!("bad k2b header: {header:?}");
    }
    let name = parts[1].to_string();
    let rows: usize = parts[2].parse().context("rows")?;
    let cols: usize = parts[3].parse().context("cols")?;
    let mut buf = vec![0u8; rows * cols * 4];
    r.read_exact(&mut buf).context("payload shorter than header promises")?;
    let data: Vec<f32> = buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(Dataset { name, x: Matrix::from_vec(data, rows, cols), seed: 0 })
}

/// Load numeric CSV (no header detection: lines starting with non-numeric
/// first field are skipped). Ragged rows are an error.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let mut data: Vec<f32> = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        let parsed: Option<Vec<f32>> = fields.iter().map(|s| s.parse().ok()).collect();
        let Some(vals) = parsed else {
            if rows == 0 {
                continue; // header line
            }
            bail!("non-numeric field at line {}", lineno + 1);
        };
        if rows == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            bail!("ragged row at line {} ({} vs {} cols)", lineno + 1, vals.len(), cols);
        }
        data.extend_from_slice(&vals);
        rows += 1;
    }
    if rows == 0 {
        bail!("no data rows in {}", path.display());
    }
    let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    Ok(Dataset { name, x: Matrix::from_vec(data, rows, cols), seed: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("k2m_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn bin_roundtrip() {
        let ds = crate::data::usps_like(0.01, 3);
        let p = tmpfile("roundtrip.k2b");
        save_bin(&ds, &p).unwrap();
        let back = load_bin(&p).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.x, ds.x);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_rejects_garbage() {
        let p = tmpfile("garbage.k2b");
        std::fs::write(&p, b"not a k2b file\n").unwrap();
        assert!(load_bin(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_parses_with_header() {
        let p = tmpfile("data.csv");
        std::fs::write(&p, "a,b,c\n1,2,3\n4.5,5,6\n").unwrap();
        let ds = load_csv(&p).unwrap();
        assert_eq!(ds.x.rows(), 2);
        assert_eq!(ds.x.cols(), 3);
        assert_eq!(ds.x.row(1)[0], 4.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
