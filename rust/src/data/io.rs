//! Dataset and model persistence: a minimal self-describing binary
//! format (one ASCII header line + f32le rows) for matrices, a CSV
//! loader so users can bring their own data to the CLI (`k2m cluster
//! --data file.k2b`), and the versioned [`save_model`]/[`load_model`]
//! pair behind [`crate::cluster::ClusterModel`]'s train → save → serve
//! round-trip.
//!
//! Every loader rejects malformed input with a descriptive error —
//! ragged rows, zero dims, truncated or oversized payloads, unknown
//! versions — rather than panicking or silently misparsing; the model
//! loader additionally re-validates the graph/model structural
//! invariants so a hand-edited file cannot produce a model whose
//! "exact" serving answers would silently be wrong.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::cluster::{ClusterModel, Config};
use crate::core::{Matrix, NumericsMode};
use crate::knn::NeighborGraph;

/// Save as `.k2b`: header `k2b <name> <rows> <cols>\n` then rows*cols f32le.
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "k2b {} {} {}", ds.name.replace(' ', "_"), ds.x.rows(), ds.x.cols())?;
    let bytes: Vec<u8> = ds.x.as_slice().iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

/// Load a `.k2b` file written by [`save_bin`].
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    if parts.len() != 4 || parts[0] != "k2b" {
        bail!("bad k2b header: {header:?}");
    }
    let name = parts[1].to_string();
    let rows: usize = parts[2].parse().context("rows")?;
    let cols: usize = parts[3].parse().context("cols")?;
    if rows == 0 || cols == 0 {
        bail!("{}: zero-dimension matrix ({rows}x{cols}) in k2b header", path.display());
    }
    let data = read_f32s(&mut r, rows, cols, "k2b payload")?;
    Ok(Dataset { name, x: Matrix::from_vec(data, rows, cols), seed: 0 })
}

/// Byte length of a `rows × cols` 4-byte-element payload, refusing
/// headers whose promised size overflows `usize` (a corrupt or hostile
/// header must not wrap into a tiny allocation).
fn payload_bytes(rows: usize, cols: usize, what: &str) -> Result<usize> {
    rows.checked_mul(cols)
        .and_then(|e| e.checked_mul(4))
        .with_context(|| format!("{what}: {rows}x{cols} payload size overflows"))
}

fn read_f32s(r: &mut impl Read, rows: usize, cols: usize, what: &str) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; payload_bytes(rows, cols, what)?];
    r.read_exact(&mut buf)
        .with_context(|| format!("{what}: file shorter than the header promises"))?;
    Ok(buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_u32s(r: &mut impl Read, rows: usize, cols: usize, what: &str) -> Result<Vec<u32>> {
    let mut buf = vec![0u8; payload_bytes(rows, cols, what)?];
    r.read_exact(&mut buf)
        .with_context(|| format!("{what}: file shorter than the header promises"))?;
    Ok(buf
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// Load numeric CSV (no header detection: lines starting with non-numeric
/// first field are skipped). Ragged rows are an error.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let mut data: Vec<f32> = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        let parsed: Option<Vec<f32>> = fields.iter().map(|s| s.parse().ok()).collect();
        let Some(vals) = parsed else {
            if rows == 0 {
                continue; // header line
            }
            bail!("non-numeric field at line {}", lineno + 1);
        };
        if rows == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            bail!("ragged row at line {} ({} vs {} cols)", lineno + 1, vals.len(), cols);
        }
        data.extend_from_slice(&vals);
        rows += 1;
    }
    if rows == 0 || cols == 0 {
        bail!("no data rows in {}", path.display());
    }
    let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    Ok(Dataset { name, x: Matrix::from_vec(data, rows, cols), seed: 0 })
}

// ---------------------------------------------------------------------
// ClusterModel persistence (version 1)
// ---------------------------------------------------------------------

/// Magic tag of the model format.
const MODEL_MAGIC: &str = "k2mm";
/// The one format version this build writes and reads. Bumped on any
/// layout change; [`load_model`] refuses other versions by name rather
/// than guessing.
const MODEL_VERSION: u32 = 1;

/// Write a [`ClusterModel`] as the versioned binary model format:
///
/// ```text
/// k2mm 1 <k> <d> <kn>\n                     — magic, version, geometry
/// cfg k=… kn=… m=… batch=… iters=… seed=… trace=0|1 target=-|<f64 hex bits>
///     bounds=0|1 threads=… numerics=strict|fast\n   — Config provenance (one line)
/// centers   k·d  f32le                       — final centers, row-major
/// norms     k    f32le                       — per-center squared norms
/// nbrs      k·kn u32le                       — graph neighbour indices
/// dists     k·kn f32le                       — graph squared distances
/// ```
///
/// `target` uses the hex bit pattern of the `f64` so the round-trip is
/// lossless; everything binary is little-endian `f32`/`u32`, making the
/// save → load round-trip bit-identical (pinned in this module's tests
/// and end-to-end in `rust/tests/serve.rs`).
pub fn save_model(model: &ClusterModel, path: &Path) -> Result<()> {
    let (k, d, kn) = (model.k(), model.d(), model.kn());
    if k == 0 || d == 0 {
        bail!("refusing to save a zero-dimension model ({k}x{d})");
    }
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{MODEL_MAGIC} {MODEL_VERSION} {k} {d} {kn}")?;
    let cfg = model.config();
    writeln!(
        w,
        "cfg k={} kn={} m={} batch={} iters={} seed={} trace={} target={} bounds={} \
         threads={} numerics={}",
        cfg.k,
        cfg.kn,
        cfg.m,
        cfg.batch,
        cfg.max_iters,
        cfg.seed,
        cfg.record_trace as u8,
        cfg.target_energy
            .map_or_else(|| "-".to_string(), |t| format!("{:016x}", t.to_bits())),
        cfg.use_bounds as u8,
        cfg.threads,
        cfg.numerics.name(),
    )?;
    write_f32s(&mut w, model.centers().as_slice())?;
    write_f32s(&mut w, model.norms())?;
    let nbytes: Vec<u8> =
        model.graph().nbrs_flat().iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&nbytes)?;
    write_f32s(&mut w, model.graph().dists_flat())?;
    Ok(())
}

fn write_f32s(w: &mut impl Write, vals: &[f32]) -> std::io::Result<()> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)
}

/// Load a model written by [`save_model`], re-validating everything: the
/// magic/version header (unknown versions are refused by name), the
/// geometry, the `Config` provenance line, exact payload length (both
/// truncated and oversized files are errors), and the structural
/// invariants of the graph and model
/// ([`NeighborGraph::from_parts`] / [`ClusterModel::from_parts`]).
pub fn load_model(path: &Path) -> Result<ClusterModel> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    if parts.len() != 5 || parts[0] != MODEL_MAGIC {
        bail!("{}: not a k2m model file (header {header:?})", path.display());
    }
    let version: u32 = parts[1]
        .parse()
        .with_context(|| format!("{}: bad model version field {:?}", path.display(), parts[1]))?;
    if version != MODEL_VERSION {
        bail!(
            "{}: unsupported model version {version} (this build reads version {MODEL_VERSION})",
            path.display()
        );
    }
    let k: usize = parts[2].parse().context("model k")?;
    let d: usize = parts[3].parse().context("model d")?;
    let kn: usize = parts[4].parse().context("model kn")?;
    if k == 0 || d == 0 || kn == 0 {
        bail!("{}: zero-dimension model (k={k} d={d} kn={kn})", path.display());
    }
    let mut cfg_line = String::new();
    r.read_line(&mut cfg_line)?;
    let config = parse_config_line(cfg_line.trim())
        .with_context(|| format!("{}: bad model config line", path.display()))?;
    let centers = read_f32s(&mut r, k, d, "model centers")?;
    let norms = read_f32s(&mut r, k, 1, "model norms")?;
    let nbrs = read_u32s(&mut r, k, kn, "model graph indices")?;
    let dists = read_f32s(&mut r, k, kn, "model graph distances")?;
    let mut trailing = [0u8; 1];
    if r.read(&mut trailing)? != 0 {
        bail!("{}: trailing bytes after the model payload", path.display());
    }
    let graph = NeighborGraph::from_parts(k, kn, nbrs, dists)
        .with_context(|| format!("{}: invalid center graph", path.display()))?;
    ClusterModel::from_parts(Matrix::from_vec(centers, k, d), graph, norms, config)
        .with_context(|| format!("{}: inconsistent model parts", path.display()))
}

fn parse_bool01(v: &str) -> Result<bool> {
    match v {
        "0" => Ok(false),
        "1" => Ok(true),
        _ => bail!("expected 0 or 1, got {v:?}"),
    }
}

/// Parse the `cfg k=… … numerics=…` provenance line. All 11 keys are
/// required (the format is versioned — a new key means a new version),
/// and unknown keys are an error rather than silently ignored.
fn parse_config_line(line: &str) -> Result<Config> {
    let mut toks = line.split_whitespace();
    if toks.next() != Some("cfg") {
        bail!("expected a 'cfg' line, got {line:?}");
    }
    let mut cfg = Config::default();
    let mut seen = 0u32;
    for tok in toks {
        let (key, val) = tok.split_once('=').with_context(|| format!("bad cfg token {tok:?}"))?;
        match key {
            "k" => cfg.k = val.parse().context("cfg k")?,
            "kn" => cfg.kn = val.parse().context("cfg kn")?,
            "m" => cfg.m = val.parse().context("cfg m")?,
            "batch" => cfg.batch = val.parse().context("cfg batch")?,
            "iters" => cfg.max_iters = val.parse().context("cfg iters")?,
            "seed" => cfg.seed = val.parse().context("cfg seed")?,
            "trace" => cfg.record_trace = parse_bool01(val).context("cfg trace")?,
            "target" => {
                cfg.target_energy = if val == "-" {
                    None
                } else {
                    Some(f64::from_bits(
                        u64::from_str_radix(val, 16).context("cfg target")?,
                    ))
                }
            }
            "bounds" => cfg.use_bounds = parse_bool01(val).context("cfg bounds")?,
            "threads" => cfg.threads = val.parse().context("cfg threads")?,
            "numerics" => {
                cfg.numerics = NumericsMode::parse(val)
                    .with_context(|| format!("unknown numerics tier {val:?}"))?
            }
            other => bail!("unknown cfg key {other:?}"),
        }
        seen += 1;
    }
    if seen != 11 {
        bail!("cfg line has {seen} keys, expected 11");
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("k2m_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn bin_roundtrip() {
        let ds = crate::data::usps_like(0.01, 3);
        let p = tmpfile("roundtrip.k2b");
        save_bin(&ds, &p).unwrap();
        let back = load_bin(&p).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.x, ds.x);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_rejects_garbage() {
        let p = tmpfile("garbage.k2b");
        std::fs::write(&p, b"not a k2b file\n").unwrap();
        assert!(load_bin(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_parses_with_header() {
        let p = tmpfile("data.csv");
        std::fs::write(&p, "a,b,c\n1,2,3\n4.5,5,6\n").unwrap();
        let ds = load_csv(&p).unwrap();
        assert_eq!(ds.x.rows(), 2);
        assert_eq!(ds.x.cols(), 3);
        assert_eq!(ds.x.row(1)[0], 4.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_rejects_zero_dims_and_truncation() {
        let p = tmpfile("zerodim.k2b");
        std::fs::write(&p, b"k2b x 0 4\n").unwrap();
        let err = load_bin(&p).unwrap_err().to_string();
        assert!(err.contains("zero-dimension"), "{err}");
        // Truncated payload: header promises 2x2 but only one f32 follows.
        std::fs::write(&p, b"k2b x 2 2\n\x00\x00\x80\x3f").unwrap();
        let err = load_bin(&p).unwrap_err().to_string();
        assert!(err.contains("shorter than the header promises"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    fn sample_model() -> ClusterModel {
        let centers = crate::testing::random_matrix(9, 5, 21);
        let cfg = Config {
            k: 9,
            kn: 4,
            seed: 33,
            threads: 2,
            target_energy: Some(1.25),
            record_trace: false,
            ..Default::default()
        };
        ClusterModel::build(centers, &cfg)
    }

    #[test]
    fn model_roundtrip_is_bit_identical() {
        let m = sample_model();
        let p = tmpfile("model.k2mm");
        save_model(&m, &p).unwrap();
        let back = load_model(&p).unwrap();
        // Lossless: centers, norms, and the graph bit for bit.
        assert_eq!(back.centers(), m.centers());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(back.norms()), bits(m.norms()));
        assert_eq!(back.graph().nbrs_flat(), m.graph().nbrs_flat());
        assert_eq!(
            bits(back.graph().dists_flat()),
            bits(m.graph().dists_flat())
        );
        // Config provenance survives, including the hex-bits f64 target.
        let (a, b) = (back.config(), m.config());
        assert_eq!((a.k, a.kn, a.m, a.batch), (b.k, b.kn, b.m, b.batch));
        assert_eq!((a.max_iters, a.seed, a.threads), (b.max_iters, b.seed, b.threads));
        assert_eq!((a.record_trace, a.use_bounds), (b.record_trace, b.use_bounds));
        assert_eq!(a.numerics, b.numerics);
        assert_eq!(
            a.target_energy.map(f64::to_bits),
            b.target_energy.map(f64::to_bits)
        );
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn model_rejects_mismatched_version() {
        let m = sample_model();
        let p = tmpfile("model_v9.k2mm");
        save_model(&m, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // Tamper the version field: "k2mm 1 ..." -> "k2mm 9 ...".
        assert_eq!(&bytes[..6], b"k2mm 1");
        bytes[5] = b'9';
        std::fs::write(&p, &bytes).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("unsupported model version 9"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn model_rejects_truncation_trailing_and_garbage() {
        let m = sample_model();
        let p = tmpfile("model_bad.k2mm");
        save_model(&m, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // Truncated: drop the last byte of the graph-distance section.
        std::fs::write(&p, &bytes[..bytes.len() - 1]).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("shorter than the header promises"), "{err}");
        // Trailing bytes after the promised payload.
        let mut longer = bytes.clone();
        longer.push(0);
        std::fs::write(&p, &longer).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
        // Not a model file at all.
        std::fs::write(&p, b"k2b x 2 2\n").unwrap();
        assert!(load_model(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn model_rejects_corrupt_graph_payload() {
        let m = sample_model();
        let p = tmpfile("model_graph.k2mm");
        save_model(&m, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // The first graph index (row 0, slot 0 — the self index, value 0)
        // lives right after centers (9*5 f32) and norms (9 f32). Point it
        // at a non-self center: from_parts must refuse the row.
        let header_len = bytes.len() - (9 * 5 + 9 + 9 * 4 + 9 * 4) * 4;
        let off = header_len + (9 * 5 + 9) * 4;
        assert_eq!(&bytes[off..off + 4], &[0, 0, 0, 0]);
        bytes[off] = 7;
        std::fs::write(&p, &bytes).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("invalid center graph"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
