//! Dataset and model persistence: a minimal self-describing binary
//! format (one ASCII header line + f32le rows) for matrices, a CSV
//! loader so users can bring their own data to the CLI (`k2m cluster
//! --data file.k2b`), and the versioned [`save_model`]/[`load_model`]
//! pair behind [`crate::cluster::ClusterModel`]'s train → save → serve
//! round-trip.
//!
//! Every loader rejects malformed input with a descriptive error —
//! ragged rows, zero dims, truncated or oversized payloads, unknown
//! versions — rather than panicking or silently misparsing; the model
//! loader additionally re-validates the graph/model structural
//! invariants so a hand-edited file cannot produce a model whose
//! "exact" serving answers would silently be wrong.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Dataset;
use crate::cluster::{ClusterModel, Config};
use crate::core::kernels::quant::{self, QuantizedCodes};
use crate::core::{Matrix, NumericsMode};
use crate::knn::NeighborGraph;

/// Save as `.k2b`: header `k2b <name> <rows> <cols>\n` then rows*cols f32le.
pub fn save_bin(ds: &Dataset, path: &Path) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "k2b {} {} {}", ds.name.replace(' ', "_"), ds.x.rows(), ds.x.cols())?;
    let bytes: Vec<u8> = ds.x.as_slice().iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

/// Load a `.k2b` file written by [`save_bin`].
pub fn load_bin(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    if parts.len() != 4 || parts[0] != "k2b" {
        bail!("bad k2b header: {header:?}");
    }
    let name = parts[1].to_string();
    let rows: usize = parts[2].parse().context("rows")?;
    let cols: usize = parts[3].parse().context("cols")?;
    if rows == 0 || cols == 0 {
        bail!("{}: zero-dimension matrix ({rows}x{cols}) in k2b header", path.display());
    }
    let data = read_f32s(&mut r, rows, cols, "k2b payload")?;
    Ok(Dataset { name, x: Matrix::from_vec(data, rows, cols), seed: 0 })
}

/// Byte length of a `rows × cols` payload of `elem`-byte elements,
/// refusing headers whose promised size overflows `usize` (a corrupt or
/// hostile header must not wrap into a tiny allocation). Shared with
/// the chunked store ([`crate::data::store`]), whose open-time length
/// check runs the same arithmetic.
pub(crate) fn payload_bytes(rows: usize, cols: usize, elem: usize, what: &str) -> Result<usize> {
    rows.checked_mul(cols)
        .and_then(|e| e.checked_mul(elem))
        .with_context(|| format!("{what}: {rows}x{cols} payload size overflows"))
}

pub(crate) fn read_f32s(
    r: &mut impl Read,
    rows: usize,
    cols: usize,
    what: &str,
) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; payload_bytes(rows, cols, 4, what)?];
    r.read_exact(&mut buf)
        .with_context(|| format!("{what}: file shorter than the header promises"))?;
    Ok(buf
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_u32s(r: &mut impl Read, rows: usize, cols: usize, what: &str) -> Result<Vec<u32>> {
    let mut buf = vec![0u8; payload_bytes(rows, cols, 4, what)?];
    r.read_exact(&mut buf)
        .with_context(|| format!("{what}: file shorter than the header promises"))?;
    Ok(buf
        .chunks_exact(4)
        .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

fn read_u64s(r: &mut impl Read, rows: usize, cols: usize, what: &str) -> Result<Vec<u64>> {
    let mut buf = vec![0u8; payload_bytes(rows, cols, 8, what)?];
    r.read_exact(&mut buf)
        .with_context(|| format!("{what}: file shorter than the header promises"))?;
    Ok(buf
        .chunks_exact(8)
        .map(|b| u64::from_le_bytes(b.try_into().expect("chunks_exact(8)")))
        .collect())
}

/// Load numeric CSV (no header detection: lines starting with non-numeric
/// first field are skipped). Ragged rows are an error.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let r = BufReader::new(f);
    let mut data: Vec<f32> = Vec::new();
    let mut cols = 0usize;
    let mut rows = 0usize;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        let parsed: Option<Vec<f32>> = fields.iter().map(|s| s.parse().ok()).collect();
        let Some(vals) = parsed else {
            if rows == 0 {
                continue; // header line
            }
            bail!("non-numeric field at line {}", lineno + 1);
        };
        if rows == 0 {
            cols = vals.len();
        } else if vals.len() != cols {
            bail!("ragged row at line {} ({} vs {} cols)", lineno + 1, vals.len(), cols);
        }
        data.extend_from_slice(&vals);
        rows += 1;
    }
    if rows == 0 || cols == 0 {
        bail!("no data rows in {}", path.display());
    }
    let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
    Ok(Dataset { name, x: Matrix::from_vec(data, rows, cols), seed: 0 })
}

// ---------------------------------------------------------------------
// ClusterModel persistence (version 2; version 1 still loads)
// ---------------------------------------------------------------------

/// Magic tag of the model format.
const MODEL_MAGIC: &str = "k2mm";
/// The format version this build writes. [`load_model`] additionally
/// accepts version 1 (identical layout minus the optional codes
/// section); anything else is refused by name rather than guessed at.
const MODEL_VERSION: u32 = 2;

/// Write a [`ClusterModel`] as the versioned binary model format:
///
/// ```text
/// k2mm 2 <k> <d> <kn>\n                     — magic, version, geometry
/// cfg k=… kn=… m=… batch=… iters=… seed=… trace=0|1 target=-|<f64 hex bits>
///     bounds=0|1 threads=… numerics=strict|fast|quantized\n — Config (one line)
/// centers   k·d  f32le                       — final centers, row-major
/// norms     k    f32le                       — per-center squared norms
/// nbrs      k·kn u32le                       — graph neighbour indices
/// dists     k·kn f32le                       — graph squared distances
/// codes <words>\n                            — OPTIONAL section tag
/// mu        d        f32le                   — centering vector μ
/// heads     k·4      f32le                   — norm2/sum_abs/scale/err per row
/// bits      k·words  u64le                   — 1-bit sign codes
/// ```
///
/// The codes section is written only when the model's quantized codes
/// are materialized ([`ClusterModel::has_codes`] — Quantized-trained or
/// already-served models); other models keep the section-free layout,
/// which is byte-for-byte the version-1 body. Since `μ` is the centers'
/// own column means, the section is fully determined by the centers —
/// a reader without it rebuilds bit-identical codes lazily.
///
/// `target` uses the hex bit pattern of the `f64` so the round-trip is
/// lossless; everything binary is little-endian `f32`/`u32`/`u64`,
/// making the save → load round-trip bit-identical (pinned in this
/// module's tests and end-to-end in `rust/tests/serve.rs`).
pub fn save_model(model: &ClusterModel, path: &Path) -> Result<()> {
    let (k, d, kn) = (model.k(), model.d(), model.kn());
    if k == 0 || d == 0 {
        bail!("refusing to save a zero-dimension model ({k}x{d})");
    }
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "{MODEL_MAGIC} {MODEL_VERSION} {k} {d} {kn}")?;
    let cfg = model.config();
    writeln!(
        w,
        "cfg k={} kn={} m={} batch={} iters={} seed={} trace={} target={} bounds={} \
         threads={} numerics={}",
        cfg.k,
        cfg.kn,
        cfg.m,
        cfg.batch,
        cfg.max_iters,
        cfg.seed,
        cfg.record_trace as u8,
        cfg.target_energy
            .map_or_else(|| "-".to_string(), |t| format!("{:016x}", t.to_bits())),
        cfg.use_bounds as u8,
        cfg.threads,
        cfg.numerics.name(),
    )?;
    write_f32s(&mut w, model.centers().as_slice())?;
    write_f32s(&mut w, model.norms())?;
    let nbytes: Vec<u8> =
        model.graph().nbrs_flat().iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&nbytes)?;
    write_f32s(&mut w, model.graph().dists_flat())?;
    if model.has_codes() {
        let codes = model.quant_codes();
        writeln!(w, "codes {}", codes.words())?;
        write_f32s(&mut w, codes.mu())?;
        write_f32s(&mut w, &codes.heads_flat())?;
        let cbytes: Vec<u8> = codes.bits().iter().flat_map(|v| v.to_le_bytes()).collect();
        w.write_all(&cbytes)?;
    }
    Ok(())
}

fn write_f32s(w: &mut impl Write, vals: &[f32]) -> std::io::Result<()> {
    let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)
}

/// Load a model written by [`save_model`], re-validating everything: the
/// magic/version header (unknown versions are refused by name; version 1
/// is accepted and never carries a codes section), the geometry, the
/// `Config` provenance line, exact payload length (both truncated and
/// oversized files are errors), the structural invariants of the graph
/// and model ([`NeighborGraph::from_parts`] /
/// [`ClusterModel::from_parts`]), and — when a codes section is present
/// — that the codes are bit-identical to a rebuild from the loaded
/// centers, so a hand-edited section cannot silently steer the
/// prune/re-rank path to wrong answers.
pub fn load_model(path: &Path) -> Result<ClusterModel> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut header = String::new();
    r.read_line(&mut header)?;
    let parts: Vec<&str> = header.split_whitespace().collect();
    if parts.len() != 5 || parts[0] != MODEL_MAGIC {
        bail!("{}: not a k2m model file (header {header:?})", path.display());
    }
    let version: u32 = parts[1]
        .parse()
        .with_context(|| format!("{}: bad model version field {:?}", path.display(), parts[1]))?;
    if version != 1 && version != MODEL_VERSION {
        bail!(
            "{}: unsupported model version {version} (this build reads versions 1 and \
             {MODEL_VERSION})",
            path.display()
        );
    }
    let k: usize = parts[2].parse().context("model k")?;
    let d: usize = parts[3].parse().context("model d")?;
    let kn: usize = parts[4].parse().context("model kn")?;
    if k == 0 || d == 0 || kn == 0 {
        bail!("{}: zero-dimension model (k={k} d={d} kn={kn})", path.display());
    }
    let mut cfg_line = String::new();
    r.read_line(&mut cfg_line)?;
    let config = parse_config_line(cfg_line.trim())
        .with_context(|| format!("{}: bad model config line", path.display()))?;
    let centers = Matrix::from_vec(read_f32s(&mut r, k, d, "model centers")?, k, d);
    let norms = read_f32s(&mut r, k, 1, "model norms")?;
    let nbrs = read_u32s(&mut r, k, kn, "model graph indices")?;
    let dists = read_f32s(&mut r, k, kn, "model graph distances")?;
    let codes = if version == 1 {
        // Version 1 predates the codes section: the payload must end
        // exactly here (codes rebuild lazily on first quantized use).
        expect_eof(&mut r, path)?;
        None
    } else {
        read_codes_section(&mut r, k, d, &centers, path)?
    };
    let graph = NeighborGraph::from_parts(k, kn, nbrs, dists)
        .with_context(|| format!("{}: invalid center graph", path.display()))?;
    ClusterModel::from_parts(centers, graph, norms, config, codes)
        .with_context(|| format!("{}: inconsistent model parts", path.display()))
}

fn expect_eof(r: &mut impl Read, path: &Path) -> Result<()> {
    let mut trailing = [0u8; 1];
    if r.read(&mut trailing)? != 0 {
        bail!("{}: trailing bytes after the model payload", path.display());
    }
    Ok(())
}

/// Parse the optional `codes <words>` section of a version-2 model
/// file. Absent section (EOF right after the graph distances) is fine —
/// codes rebuild lazily. A present section must pass three gates: the
/// tag's word count must match `ceil(d/64)`, the payload must be
/// exactly the promised length with nothing trailing, and the decoded
/// codes must be **bit-identical** to a rebuild from the loaded centers
/// (`μ` = column means) — the codes are derived data, so any mismatch
/// means the file was tampered with or corrupted.
fn read_codes_section(
    r: &mut (impl BufRead + Read),
    k: usize,
    d: usize,
    centers: &Matrix,
    path: &Path,
) -> Result<Option<QuantizedCodes>> {
    let mut tag = String::new();
    if r.read_line(&mut tag)? == 0 {
        return Ok(None);
    }
    let parts: Vec<&str> = tag.split_whitespace().collect();
    if parts.len() != 2 || parts[0] != "codes" {
        bail!("{}: bad codes section tag {tag:?}", path.display());
    }
    let words: usize = parts[1]
        .parse()
        .with_context(|| format!("{}: bad codes word count {:?}", path.display(), parts[1]))?;
    if words != quant::words_for(d) {
        bail!(
            "{}: codes section promises {words} words per row but dim {d} needs {}",
            path.display(),
            quant::words_for(d)
        );
    }
    let mu = read_f32s(r, 1, d, "model codes mu")?;
    let heads = read_f32s(r, k, 4, "model codes heads")?;
    let bits = read_u64s(r, k, words, "model codes bits")?;
    expect_eof(r, path)?;
    let loaded = QuantizedCodes::from_parts(d, mu, &heads, bits)
        .with_context(|| format!("{}: inconsistent codes section lengths", path.display()))?;
    let want = QuantizedCodes::pack(centers, &quant::column_means(centers));
    let f32_bits_eq = |a: &[f32], b: &[f32]| {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    };
    if !f32_bits_eq(loaded.mu(), want.mu())
        || !f32_bits_eq(&loaded.heads_flat(), &want.heads_flat())
        || loaded.bits() != want.bits()
    {
        bail!(
            "{}: codes section does not match a rebuild from the centers (tampered or \
             corrupt derived data)",
            path.display()
        );
    }
    Ok(Some(loaded))
}

fn parse_bool01(v: &str) -> Result<bool> {
    match v {
        "0" => Ok(false),
        "1" => Ok(true),
        _ => bail!("expected 0 or 1, got {v:?}"),
    }
}

/// Parse the `cfg k=… … numerics=…` provenance line. All 11 keys are
/// required (the format is versioned — a new key means a new version),
/// and unknown keys are an error rather than silently ignored.
///
/// [`Config::refresh`] is deliberately **absent**: the refresh mode is
/// an execution strategy with a bitwise-equality contract (Incremental
/// and Full produce identical labels/centers/energies — see
/// `cluster::common::Config`), so it is not result provenance and
/// persisting it would force a format version bump for a knob that
/// cannot change any saved number. Loaded models get the process
/// default (`K2M_REFRESH`, else Incremental).
fn parse_config_line(line: &str) -> Result<Config> {
    let mut toks = line.split_whitespace();
    if toks.next() != Some("cfg") {
        bail!("expected a 'cfg' line, got {line:?}");
    }
    let mut cfg = Config::default();
    let mut seen = 0u32;
    for tok in toks {
        let (key, val) = tok.split_once('=').with_context(|| format!("bad cfg token {tok:?}"))?;
        match key {
            "k" => cfg.k = val.parse().context("cfg k")?,
            "kn" => cfg.kn = val.parse().context("cfg kn")?,
            "m" => cfg.m = val.parse().context("cfg m")?,
            "batch" => cfg.batch = val.parse().context("cfg batch")?,
            "iters" => cfg.max_iters = val.parse().context("cfg iters")?,
            "seed" => cfg.seed = val.parse().context("cfg seed")?,
            "trace" => cfg.record_trace = parse_bool01(val).context("cfg trace")?,
            "target" => {
                cfg.target_energy = if val == "-" {
                    None
                } else {
                    Some(f64::from_bits(
                        u64::from_str_radix(val, 16).context("cfg target")?,
                    ))
                }
            }
            "bounds" => cfg.use_bounds = parse_bool01(val).context("cfg bounds")?,
            "threads" => cfg.threads = val.parse().context("cfg threads")?,
            "numerics" => {
                cfg.numerics = NumericsMode::parse(val)
                    .with_context(|| format!("unknown numerics tier {val:?}"))?
            }
            other => bail!("unknown cfg key {other:?}"),
        }
        seen += 1;
    }
    if seen != 11 {
        bail!("cfg line has {seen} keys, expected 11");
    }
    Ok(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("k2m_test_{}_{}", std::process::id(), name));
        p
    }

    #[test]
    fn bin_roundtrip() {
        let ds = crate::data::usps_like(0.01, 3);
        let p = tmpfile("roundtrip.k2b");
        save_bin(&ds, &p).unwrap();
        let back = load_bin(&p).unwrap();
        assert_eq!(back.name, ds.name);
        assert_eq!(back.x, ds.x);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_rejects_garbage() {
        let p = tmpfile("garbage.k2b");
        std::fs::write(&p, b"not a k2b file\n").unwrap();
        assert!(load_bin(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_parses_with_header() {
        let p = tmpfile("data.csv");
        std::fs::write(&p, "a,b,c\n1,2,3\n4.5,5,6\n").unwrap();
        let ds = load_csv(&p).unwrap();
        assert_eq!(ds.x.rows(), 2);
        assert_eq!(ds.x.cols(), 3);
        assert_eq!(ds.x.row(1)[0], 4.5);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmpfile("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bin_rejects_zero_dims_and_truncation() {
        let p = tmpfile("zerodim.k2b");
        std::fs::write(&p, b"k2b x 0 4\n").unwrap();
        let err = load_bin(&p).unwrap_err().to_string();
        assert!(err.contains("zero-dimension"), "{err}");
        // Truncated payload: header promises 2x2 but only one f32 follows.
        std::fs::write(&p, b"k2b x 2 2\n\x00\x00\x80\x3f").unwrap();
        let err = load_bin(&p).unwrap_err().to_string();
        assert!(err.contains("shorter than the header promises"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    fn sample_model() -> ClusterModel {
        let centers = crate::testing::random_matrix(9, 5, 21);
        let cfg = Config {
            k: 9,
            kn: 4,
            seed: 33,
            threads: 2,
            target_energy: Some(1.25),
            record_trace: false,
            ..Default::default()
        };
        ClusterModel::build(centers, &cfg)
    }

    #[test]
    fn model_roundtrip_is_bit_identical() {
        let m = sample_model();
        let p = tmpfile("model.k2mm");
        save_model(&m, &p).unwrap();
        let back = load_model(&p).unwrap();
        // Lossless: centers, norms, and the graph bit for bit.
        assert_eq!(back.centers(), m.centers());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(back.norms()), bits(m.norms()));
        assert_eq!(back.graph().nbrs_flat(), m.graph().nbrs_flat());
        assert_eq!(
            bits(back.graph().dists_flat()),
            bits(m.graph().dists_flat())
        );
        // Config provenance survives, including the hex-bits f64 target.
        let (a, b) = (back.config(), m.config());
        assert_eq!((a.k, a.kn, a.m, a.batch), (b.k, b.kn, b.m, b.batch));
        assert_eq!((a.max_iters, a.seed, a.threads), (b.max_iters, b.seed, b.threads));
        assert_eq!((a.record_trace, a.use_bounds), (b.record_trace, b.use_bounds));
        assert_eq!(a.numerics, b.numerics);
        assert_eq!(
            a.target_energy.map(f64::to_bits),
            b.target_energy.map(f64::to_bits)
        );
        std::fs::remove_file(&p).ok();
    }

    /// A Quantized-trained 9×5 model: eager codes, so [`save_model`]
    /// emits the codes section. Geometry of the written file's tail
    /// (d=5 → 1 word/row): tag `codes 1\n` = 8 bytes, then
    /// mu 5·4 + heads 9·16 + bits 9·8 = 236 payload bytes.
    fn quantized_model() -> ClusterModel {
        let centers = crate::testing::random_matrix(9, 5, 21);
        let cfg = Config {
            k: 9,
            kn: 4,
            seed: 33,
            threads: 2,
            numerics: NumericsMode::Quantized,
            ..Default::default()
        };
        ClusterModel::build(centers, &cfg)
    }

    /// Codes-section byte geometry of [`quantized_model`]'s file.
    const CODES_PAYLOAD: usize = 5 * 4 + 9 * 16 + 9 * 8;
    const CODES_SECTION: usize = 8 + CODES_PAYLOAD; // + "codes 1\n" tag

    /// Table-driven corruption corpus for the `.k2mm` loader: every
    /// entry mutates a freshly saved quantized-model file and names the
    /// error the loader must produce. Covers the version gate, both
    /// section-framing failures (truncation, trailing bytes), the codes
    /// tag grammar, and tampered derived data in each codes payload.
    #[test]
    fn model_loader_rejects_corruption_corpus() {
        type Mutate = fn(&mut Vec<u8>);
        let corpus: &[(&str, Mutate, &str)] = &[
            ("version skew to 9", |b| b[5] = b'9', "unsupported model version 9"),
            (
                "v1 header on a file that has a codes section",
                |b| b[5] = b'1',
                "trailing bytes",
            ),
            (
                "truncated inside the codes bits",
                |b| b.truncate(b.len() - 1),
                "shorter than the header promises",
            ),
            (
                "codes payload cut off right after the tag",
                |b| b.truncate(b.len() - CODES_PAYLOAD),
                "shorter than the header promises",
            ),
            (
                "bad section tag",
                |b| {
                    let off = b.len() - CODES_SECTION;
                    b[off..off + 5].copy_from_slice(b"goats");
                },
                "bad codes section tag",
            ),
            (
                "word count in the tag disagrees with the dim",
                |b| {
                    let off = b.len() - CODES_SECTION;
                    b[off + 6] = b'7'; // "codes 1" -> "codes 7"
                },
                "promises 7 words",
            ),
            (
                "tampered mu entry",
                |b| {
                    let off = b.len() - CODES_PAYLOAD;
                    b[off] ^= 0x40;
                },
                "does not match a rebuild",
            ),
            (
                "tampered sign bit in the codes",
                |b| {
                    let off = b.len() - 8; // last row's (only) code word
                    b[off] ^= 0x01;
                },
                "does not match a rebuild",
            ),
            (
                "trailing bytes after the codes section",
                |b| b.push(0),
                "trailing bytes",
            ),
        ];
        let m = quantized_model();
        let p = tmpfile("model_corpus.k2mm");
        save_model(&m, &p).unwrap();
        let pristine = std::fs::read(&p).unwrap();
        assert_eq!(&pristine[..6], b"k2mm 2");
        for (name, mutate, want) in corpus {
            let mut bytes = pristine.clone();
            mutate(&mut bytes);
            std::fs::write(&p, &bytes).unwrap();
            let err = load_model(&p).unwrap_err().to_string();
            assert!(err.contains(want), "{name}: expected {want:?} in {err:?}");
        }
        // The untouched file still loads — the corpus mutations, not the
        // fixture, are what the loader objects to.
        std::fs::write(&p, &pristine).unwrap();
        load_model(&p).unwrap();
        // And a file that is not a model at all.
        std::fs::write(&p, b"k2b x 2 2\n").unwrap();
        assert!(load_model(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn quantized_model_roundtrip_carries_codes() {
        let m = quantized_model();
        assert!(m.has_codes());
        let p = tmpfile("model_codes.k2mm");
        save_model(&m, &p).unwrap();
        let back = load_model(&p).unwrap();
        // The section was present, so the loaded model has codes without
        // a rebuild — and they are the same codes, bit for bit.
        assert!(back.has_codes());
        assert_eq!(back.quant_codes(), m.quant_codes());
        assert_eq!(back.centers(), m.centers());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn v1_files_without_codes_still_load_and_rebuild_lazily() {
        // A strict-trained model writes no codes section, so its body is
        // byte-for-byte a version-1 body; rewriting the version digit
        // yields a faithful v1 file.
        let m = sample_model();
        assert!(!m.has_codes());
        let p = tmpfile("model_v1.k2mm");
        save_model(&m, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..6], b"k2mm 2");
        bytes[5] = b'1';
        std::fs::write(&p, &bytes).unwrap();
        let back = load_model(&p).unwrap();
        assert!(!back.has_codes());
        // Lazy rebuild serves the same codes a quantized save would carry.
        assert_eq!(back.quant_codes(), m.quant_codes());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn model_rejects_corrupt_graph_payload() {
        let m = sample_model();
        let p = tmpfile("model_graph.k2mm");
        save_model(&m, &p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // The first graph index (row 0, slot 0 — the self index, value 0)
        // lives right after centers (9*5 f32) and norms (9 f32). Point it
        // at a non-self center: from_parts must refuse the row.
        let header_len = bytes.len() - (9 * 5 + 9 + 9 * 4 + 9 * 4) * 4;
        let off = header_len + (9 * 5 + 9) * 4;
        assert_eq!(&bytes[off..off + 4], &[0, 0, 0, 0]);
        bytes[off] = 7;
        std::fs::write(&p, &bytes).unwrap();
        let err = load_model(&p).unwrap_err().to_string();
        assert!(err.contains("invalid center graph"), "{err}");
        std::fs::remove_file(&p).ok();
    }
}
