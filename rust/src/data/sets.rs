//! The paper's evaluation datasets as seeded simulacra (DESIGN.md §3).
//!
//! Each generator matches the paper's (n, d) at `scale = 1.0` and scales
//! `n` down (never below 64 points) for the fast default experiment grids.
//! `mnist50_like` is literally a seeded gaussian random projection of
//! `mnist_like` to d=50, mirroring how the paper built mnist50 from mnist.

use super::gmm::{generate_gmm, GmmSpec};
use super::Dataset;
use crate::core::Matrix;
use crate::rng::Pcg32;

fn scaled_n(n: usize, scale: f64) -> usize {
    ((n as f64 * scale).round() as usize).max(64)
}

fn make(name: &str, spec: GmmSpec, seed: u64) -> Dataset {
    Dataset { name: name.to_string(), x: generate_gmm(&spec, seed), seed }
}

/// cifar (n=50000, d=3072): raw 32x32x3 images. Many visual modes, strong
/// low-rank structure (images live near low-dim manifolds), mild imbalance.
pub fn cifar_like(scale: f64, seed: u64) -> Dataset {
    make(
        "cifar",
        GmmSpec {
            n: scaled_n(50000, scale),
            d: 3072,
            modes: 60,
            spread: 4.0,
            imbalance: 0.7,
            rank: 12,
            rank_amp: 3.0,
            anisotropy: 2.0,
            tail_df: 0.0,
            noise_frac: 0.02,
        },
        seed,
    )
}

/// cnnvoc (n=15662, d=4096): CNN fc7 features of VOC boxes, 20 categories.
pub fn cnnvoc_like(scale: f64, seed: u64) -> Dataset {
    make(
        "cnnvoc",
        GmmSpec {
            n: scaled_n(15662, scale),
            d: 4096,
            modes: 20,
            spread: 5.0,
            imbalance: 1.2,
            rank: 10,
            rank_amp: 2.5,
            anisotropy: 2.5,
            tail_df: 0.0,
            noise_frac: 0.03,
        },
        seed,
    )
}

/// covtype (n=150000, d=54): cartographic features — 7 cover types, heavy
/// tails, strong imbalance, per-axis scale differences.
pub fn covtype_like(scale: f64, seed: u64) -> Dataset {
    make(
        "covtype",
        GmmSpec {
            n: scaled_n(150000, scale),
            d: 54,
            modes: 7,
            spread: 3.0,
            imbalance: 2.0,
            rank: 3,
            rank_amp: 2.0,
            anisotropy: 4.0,
            tail_df: 4.0,
            noise_frac: 0.0,
        },
        seed,
    )
}

/// mnist (n=60000, d=784): 10 digit prototypes + within-digit subspace
/// wobble (style variation).
pub fn mnist_like(scale: f64, seed: u64) -> Dataset {
    make(
        "mnist",
        GmmSpec {
            n: scaled_n(60000, scale),
            d: 784,
            modes: 10,
            spread: 5.0,
            imbalance: 0.3,
            rank: 8,
            rank_amp: 3.0,
            anisotropy: 1.5,
            tail_df: 0.0,
            noise_frac: 0.0,
        },
        seed,
    )
}

/// mnist50 (n=60000, d=50): the paper projects raw mnist pixels onto a
/// random 50-dim subspace; we do the same to `mnist_like`.
pub fn mnist50_like(scale: f64, seed: u64) -> Dataset {
    let base = mnist_like(scale, seed);
    let x = random_projection(&base.x, 50, seed ^ 0x50f7);
    Dataset { name: "mnist50".to_string(), x, seed }
}

/// tinygist10k (n=10000, d=384): gist descriptors of tiny images.
pub fn tinygist10k_like(scale: f64, seed: u64) -> Dataset {
    make(
        "tinygist10k",
        GmmSpec {
            n: scaled_n(10000, scale),
            d: 384,
            modes: 40,
            spread: 3.5,
            imbalance: 0.8,
            rank: 6,
            rank_amp: 2.0,
            anisotropy: 2.0,
            tail_df: 0.0,
            noise_frac: 0.05,
        },
        seed,
    )
}

/// tiny10k (n=10000, d=3072): raw tiny images (supplementary Table 10).
pub fn tiny10k_like(scale: f64, seed: u64) -> Dataset {
    make(
        "tiny10k",
        GmmSpec {
            n: scaled_n(10000, scale),
            d: 3072,
            modes: 50,
            spread: 3.5,
            imbalance: 0.8,
            rank: 12,
            rank_amp: 3.0,
            anisotropy: 2.0,
            tail_df: 0.0,
            noise_frac: 0.04,
        },
        seed,
    )
}

/// usps (n=7291, d=256): scanned digits, 10 modes, less style variation
/// than mnist.
pub fn usps_like(scale: f64, seed: u64) -> Dataset {
    make(
        "usps",
        GmmSpec {
            n: scaled_n(7291, scale),
            d: 256,
            modes: 10,
            spread: 4.5,
            imbalance: 0.5,
            rank: 5,
            rank_amp: 2.0,
            anisotropy: 1.5,
            tail_df: 0.0,
            noise_frac: 0.0,
        },
        seed,
    )
}

/// yale (n=2414, d=32256): cropped faces of 38 subjects under extreme
/// illumination — few samples, enormous d, strong low-rank structure
/// (illumination cones are ~9-dimensional).
pub fn yale_like(scale: f64, seed: u64) -> Dataset {
    make(
        "yale",
        GmmSpec {
            n: scaled_n(2414, scale),
            d: 32256,
            modes: 38,
            spread: 2.5,
            imbalance: 0.2,
            rank: 9,
            rank_amp: 4.0,
            anisotropy: 1.5,
            tail_df: 0.0,
            noise_frac: 0.0,
        },
        seed,
    )
}

/// Seeded gaussian random projection to `d_out` dims, scaled by
/// `1/sqrt(d_out)` (Johnson–Lindenstrauss normalization).
pub fn random_projection(x: &Matrix, d_out: usize, seed: u64) -> Matrix {
    let d_in = x.cols();
    let mut rng = Pcg32::new(seed, 0x7ea7);
    // Projection matrix (d_in x d_out), column-major access pattern is
    // fine here — this runs once per dataset build.
    let proj: Vec<f32> =
        (0..d_in * d_out).map(|_| rng.gaussian_f32() / (d_out as f32).sqrt()).collect();
    let mut out = Matrix::zeros(x.rows(), d_out);
    for i in 0..x.rows() {
        let xi = x.row(i);
        let oi = out.row_mut(i);
        for (jin, &v) in xi.iter().enumerate() {
            if v == 0.0 {
                continue;
            }
            let prow = &proj[jin * d_out..(jin + 1) * d_out];
            for (o, &p) in oi.iter_mut().zip(prow.iter()) {
                *o += v * p;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_shapes_at_full_scale_metadata() {
        // Don't generate full-size here (slow); check the scaled-n math.
        assert_eq!(scaled_n(50000, 1.0), 50000);
        assert_eq!(scaled_n(2414, 1.0), 2414);
        assert_eq!(scaled_n(150000, 0.01), 1500);
        assert_eq!(scaled_n(100, 0.0001), 64); // floor
    }

    #[test]
    fn small_scale_generators_shape() {
        for (ds, d) in [
            (covtype_like(0.005, 1), 54),
            (usps_like(0.05, 1), 256),
            (mnist50_like(0.01, 1), 50),
            (tinygist10k_like(0.05, 1), 384),
        ] {
            assert_eq!(ds.d(), d, "{}", ds.name);
            assert!(ds.n() >= 64);
        }
    }

    #[test]
    fn mnist50_is_projection_of_mnist() {
        let m = mnist_like(0.005, 7);
        let m50 = mnist50_like(0.005, 7);
        assert_eq!(m.n(), m50.n());
        assert_eq!(m50.d(), 50);
        // JL property: relative distances roughly preserved for a pair.
        let d_hi = crate::core::ops::sqdist_raw(m.x.row(0), m.x.row(1));
        let d_lo = crate::core::ops::sqdist_raw(m50.x.row(0), m50.x.row(1));
        assert!(d_lo > 0.0 && d_hi > 0.0);
        let ratio = d_lo / d_hi;
        assert!(ratio > 0.2 && ratio < 5.0, "ratio={ratio}");
    }

    #[test]
    fn determinism_across_calls() {
        let a = usps_like(0.02, 42);
        let b = usps_like(0.02, 42);
        assert_eq!(a.x, b.x);
        let c = usps_like(0.02, 43);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn random_projection_linearity() {
        // P(2x) = 2 P(x)
        let mut x = Matrix::zeros(2, 8);
        for j in 0..8 {
            x.row_mut(0)[j] = j as f32;
            x.row_mut(1)[j] = 2.0 * j as f32;
        }
        let p = random_projection(&x, 4, 5);
        for j in 0..4 {
            assert!((p.row(1)[j] - 2.0 * p.row(0)[j]).abs() < 1e-4);
        }
    }
}
