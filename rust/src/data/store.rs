//! Out-of-core dataset store: the `.k2c` chunked binary format and the
//! [`ChunkedMatrix`] reader that backs rows in fixed-size row-block
//! chunks loaded on demand, plus the [`DatasetSource`] abstraction that
//! lets every training surface point at either an in-RAM
//! [`Matrix`] or a chunked file.
//!
//! # The `.k2c` format (version 1)
//!
//! ```text
//! k2c 1 <name> <rows> <cols> <chunk_rows>\n   — magic, version, geometry
//! rows·cols f32le                             — row-major payload
//! ```
//!
//! The payload is byte-for-byte the `.k2b` payload: **chunking is a read
//! granularity, not a physical layout**. `chunk_rows` in the header is
//! the writer's suggested block size; readers may override it
//! (`K2M_CHUNK_ROWS`, [`OpenOptions`]) without any effect on the bytes a
//! row decodes to. That is the store's core contract: *chunked reads
//! reproduce the in-RAM rows bitwise*, for every chunk size and every
//! cache size (pinned by `rust/tests/bigmeans.rs` and the boundary sweep
//! in `rust/tests/properties.rs`).
//!
//! # Strict validation
//!
//! [`ChunkedMatrix::open`] follows the `.k2mm` loader discipline: the
//! magic/version gate refuses unknown versions by name, zero dimensions
//! and zero chunk sizes are rejected, the header's promised payload size
//! must not overflow, and the file length must equal header + payload
//! **exactly** — both truncated and oversized files are errors at open
//! time (table-driven corruption corpus in this module's tests). After
//! that gate, a mid-run short read can only mean the file changed
//! underneath the process, which panics with context rather than
//! returning garbage rows.
//!
//! # Caching
//!
//! Chunks decode into ordinary [`Matrix`] blocks held in a bounded
//! LRU cache (`K2M_CHUNK_CACHE` chunks, default
//! [`DEFAULT_CACHE_CHUNKS`]). The cache affects only IO traffic; it
//! cannot affect any decoded bit, which is what makes the big-means
//! determinism contract (`cluster::bigmeans`) trivially cache-size
//! invariant.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

use super::io::payload_bytes;
use super::Dataset;
use crate::core::{env, Matrix};

/// Magic tag of the chunked dataset format.
const STORE_MAGIC: &str = "k2c";
/// The format version this build writes and reads.
const STORE_VERSION: u32 = 1;

/// Default bound on resident decoded chunks when neither
/// [`OpenOptions::cache_chunks`] nor `K2M_CHUNK_CACHE` says otherwise.
pub const DEFAULT_CACHE_CHUNKS: usize = 16;

/// `K2M_CHUNK_ROWS`: process-wide override of the chunk size every
/// [`ChunkedMatrix::open`] resolves (the header value is only the
/// writer's suggestion). Resolved through the shared env-knob policy —
/// once per process, trimmed, garbage → no override, `0` clamped to 1.
/// CI runs the whole suite with `K2M_CHUNK_ROWS=7` to force tiny chunks
/// through every chunked code path.
fn env_chunk_rows() -> Option<usize> {
    static ROWS: OnceLock<Option<usize>> = OnceLock::new();
    env::knob(&ROWS, "K2M_CHUNK_ROWS", |s| s.parse::<usize>().ok().map(|n| Some(n.max(1))), || {
        None
    })
}

/// `K2M_CHUNK_CACHE`: process-wide default for the resident-chunk bound
/// (same policy; `0` clamped to 1 — an unbounded cache is spelled by a
/// large number, a zero cache cannot serve a read).
fn env_cache_chunks() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    env::knob(&CAP, "K2M_CHUNK_CACHE", |s| s.parse::<usize>().ok().map(|n| n.max(1)), || {
        DEFAULT_CACHE_CHUNKS
    })
}

/// Write `ds` as a `.k2c` chunked dataset file. `chunk_rows` is the
/// suggested read block size recorded in the header (clamped to `>= 1`);
/// the payload itself is the plain row-major f32le stream, so the choice
/// never affects a single payload byte.
pub fn save_chunked(ds: &Dataset, chunk_rows: usize, path: &Path) -> Result<()> {
    if ds.x.rows() == 0 || ds.x.cols() == 0 {
        bail!("refusing to save a zero-dimension dataset ({}x{})", ds.x.rows(), ds.x.cols());
    }
    let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(
        w,
        "{STORE_MAGIC} {STORE_VERSION} {} {} {} {}",
        ds.name.replace(' ', "_"),
        ds.x.rows(),
        ds.x.cols(),
        chunk_rows.max(1),
    )?;
    let bytes: Vec<u8> = ds.x.as_slice().iter().flat_map(|v| v.to_le_bytes()).collect();
    w.write_all(&bytes)?;
    Ok(())
}

/// Per-open knob overrides for [`ChunkedMatrix::open_with`]. `None`
/// fields resolve the corresponding env knob (then the header / the
/// built-in default) — [`ChunkedMatrix::open`] is `open_with` on an
/// all-`None` value. Tests sweep chunk and cache sizes through this
/// without touching process env (the env knobs are once-cached).
#[derive(Clone, Copy, Debug, Default)]
pub struct OpenOptions {
    /// Rows per decoded chunk (clamped to `>= 1`). `None`:
    /// `K2M_CHUNK_ROWS`, else the file header's value.
    pub chunk_rows: Option<usize>,
    /// Resident-chunk bound (clamped to `>= 1`). `None`:
    /// `K2M_CHUNK_CACHE`, else [`DEFAULT_CACHE_CHUNKS`].
    pub cache_chunks: Option<usize>,
}

/// The mutable half of a [`ChunkedMatrix`]: the file handle and the
/// bounded LRU cache, guarded by one mutex (reads seek + read under the
/// lock — portable, and chunk decode is the cheap part next to IO).
struct StoreInner {
    file: File,
    /// Decoded chunks in recency order, least-recent first. Bounded by
    /// `cache_chunks`; entries are `Arc`s so an evicted chunk stays
    /// valid for callers still holding it.
    cache: VecDeque<(usize, Arc<Matrix>)>,
}

/// An `n × d` matrix backed by a `.k2c` file, decoded chunk-by-chunk on
/// demand — the out-of-core counterpart of [`Matrix`]. Shared freely
/// across threads (`Arc<ChunkedMatrix>`); concurrent readers serialize
/// on the inner mutex.
pub struct ChunkedMatrix {
    path: PathBuf,
    name: String,
    rows: usize,
    cols: usize,
    /// Effective rows per chunk (option > env > header).
    chunk_rows: usize,
    /// Byte offset of row 0 (end of the header line).
    data_off: u64,
    cache_chunks: usize,
    inner: Mutex<StoreInner>,
    /// Lazily assembled full in-RAM copy ([`ChunkedMatrix::materialize`]).
    full: OnceLock<Arc<Matrix>>,
}

impl std::fmt::Debug for ChunkedMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChunkedMatrix")
            .field("path", &self.path)
            .field("name", &self.name)
            .field("rows", &self.rows)
            .field("cols", &self.cols)
            .field("chunk_rows", &self.chunk_rows)
            .field("cache_chunks", &self.cache_chunks)
            .finish_non_exhaustive()
    }
}

impl ChunkedMatrix {
    /// Open a `.k2c` file with the process-default knobs (env overrides,
    /// else the header's chunk size and [`DEFAULT_CACHE_CHUNKS`]).
    pub fn open(path: &Path) -> Result<ChunkedMatrix> {
        ChunkedMatrix::open_with(path, OpenOptions::default())
    }

    /// Open with explicit knob overrides — see [`OpenOptions`]. All
    /// validation happens here, up front: magic/version, nonzero
    /// geometry, overflow-checked payload size, and an **exact** file
    /// length check (truncated and oversized files are both refused, so
    /// every later in-bounds read is guaranteed to succeed on an
    /// untouched file).
    pub fn open_with(path: &Path, opts: OpenOptions) -> Result<ChunkedMatrix> {
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut header = String::new();
        r.read_line(&mut header)?;
        let parts: Vec<&str> = header.split_whitespace().collect();
        if parts.len() != 6 || parts[0] != STORE_MAGIC {
            bail!("{}: not a k2c chunked dataset (header {header:?})", path.display());
        }
        let version: u32 = parts[1]
            .parse()
            .with_context(|| format!("{}: bad k2c version field {:?}", path.display(), parts[1]))?;
        if version != STORE_VERSION {
            bail!(
                "{}: unsupported k2c version {version} (this build reads version \
                 {STORE_VERSION})",
                path.display()
            );
        }
        let name = parts[2].to_string();
        let rows: usize = parts[3].parse().context("k2c rows")?;
        let cols: usize = parts[4].parse().context("k2c cols")?;
        let header_chunk: usize = parts[5].parse().context("k2c chunk_rows")?;
        if rows == 0 || cols == 0 {
            bail!("{}: zero-dimension matrix ({rows}x{cols}) in k2c header", path.display());
        }
        if header_chunk == 0 {
            bail!("{}: zero chunk_rows in k2c header", path.display());
        }
        let payload = payload_bytes(rows, cols, 4, "k2c payload")? as u64;
        let data_off = header.len() as u64;
        let file = r.into_inner();
        let actual = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if actual != data_off + payload {
            bail!(
                "{}: file is {actual} bytes but the header promises {} (truncated or \
                 oversized payload)",
                path.display(),
                data_off + payload
            );
        }
        let chunk_rows = opts.chunk_rows.or_else(env_chunk_rows).unwrap_or(header_chunk).max(1);
        let cache_chunks = opts.cache_chunks.unwrap_or_else(env_cache_chunks).max(1);
        Ok(ChunkedMatrix {
            path: path.to_path_buf(),
            name,
            rows,
            cols,
            chunk_rows,
            data_off,
            cache_chunks,
            inner: Mutex::new(StoreInner { file, cache: VecDeque::new() }),
            full: OnceLock::new(),
        })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The effective chunk size this handle reads with (option > env >
    /// header — not necessarily the header's value).
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Number of row-block chunks (`ceil(rows / chunk_rows)`).
    pub fn num_chunks(&self) -> usize {
        self.rows.div_ceil(self.chunk_rows)
    }

    /// The row range `[start, end)` chunk `ci` covers.
    pub fn chunk_range(&self, ci: usize) -> (usize, usize) {
        let start = ci * self.chunk_rows;
        (start, (start + self.chunk_rows).min(self.rows))
    }

    /// Decoded chunks currently resident (tests pin the cache bound).
    pub fn resident_chunks(&self) -> usize {
        lock(&self.inner).cache.len()
    }

    /// Chunk `ci` as a decoded block (rows `chunk_range(ci)`), served
    /// from the LRU cache or read + decoded on miss. The returned `Arc`
    /// stays valid after eviction.
    ///
    /// # Panics
    ///
    /// If the backing file shrank or vanished after [`open`]'s exact
    /// length check — the file changed underneath the process, and
    /// returning fabricated rows would silently corrupt a training run.
    ///
    /// [`open`]: ChunkedMatrix::open
    pub fn chunk(&self, ci: usize) -> Arc<Matrix> {
        assert!(ci < self.num_chunks(), "chunk {ci} out of {}", self.num_chunks());
        let (start, end) = self.chunk_range(ci);
        let mut inner = lock(&self.inner);
        if let Some(pos) = inner.cache.iter().position(|(idx, _)| *idx == ci) {
            // Hit: refresh recency (move to the back) and serve.
            let entry = inner.cache.remove(pos).expect("position came from iter");
            inner.cache.push_back(entry.clone());
            return entry.1;
        }
        let nrows = end - start;
        let nbytes = nrows * self.cols * 4;
        let off = self.data_off + (start * self.cols * 4) as u64;
        let mut buf = vec![0u8; nbytes];
        inner
            .file
            .seek(SeekFrom::Start(off))
            .and_then(|_| inner.file.read_exact(&mut buf))
            .unwrap_or_else(|e| {
                panic!(
                    "{}: chunk {ci} read failed after open-time validation ({e}); \
                     the file changed underneath the process",
                    self.path.display()
                )
            });
        let data: Vec<f32> = buf
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let block = Arc::new(Matrix::from_vec(data, nrows, self.cols));
        inner.cache.push_back((ci, Arc::clone(&block)));
        while inner.cache.len() > self.cache_chunks {
            inner.cache.pop_front();
        }
        block
    }

    /// One row by global index, copied out of its chunk. Row-at-a-time
    /// access for tests and spot reads; bulk consumers use
    /// [`ChunkedMatrix::gather_rows`] / [`ChunkedMatrix::for_each_chunk`].
    pub fn row(&self, i: usize) -> Vec<f32> {
        assert!(i < self.rows, "row {i} out of {}", self.rows);
        let block = self.chunk(i / self.chunk_rows);
        block.row(i % self.chunk_rows).to_vec()
    }

    /// Gather `idx` (global row indices, any order, repeats allowed)
    /// into a dense matrix — the chunked twin of [`Matrix::gather`],
    /// bitwise equal to it on the same data. Sorted index lists visit
    /// each chunk once, which is why the big-means sampler sorts its
    /// draws before gathering.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (dst, &i) in idx.iter().enumerate() {
            assert!(i < self.rows, "row {i} out of {}", self.rows);
            let block = self.chunk(i / self.chunk_rows);
            out.row_mut(dst).copy_from_slice(block.row(i % self.chunk_rows));
        }
        out
    }

    /// Stream every chunk in order: `f(start_row, block)` for chunks
    /// `0..num_chunks()`. The streaming shape of the big-means final
    /// assignment pass — sequential, cache-friendly, never more than one
    /// decoded chunk needed at a time.
    pub fn for_each_chunk(&self, mut f: impl FnMut(usize, &Matrix)) {
        for ci in 0..self.num_chunks() {
            let (start, _) = self.chunk_range(ci);
            let block = self.chunk(ci);
            f(start, &block);
        }
    }

    /// Assemble (once) and return the full in-RAM matrix. For consumers
    /// that genuinely need all rows resident — e.g. a roster algorithm
    /// scheduled directly on a chunked source — not for the out-of-core
    /// paths. Cached, so repeated calls share one copy.
    pub fn materialize(&self) -> Arc<Matrix> {
        Arc::clone(self.full.get_or_init(|| {
            let mut m = Matrix::zeros(self.rows, self.cols);
            self.for_each_chunk(|start, block| {
                for r in 0..block.rows() {
                    m.row_mut(start + r).copy_from_slice(block.row(r));
                }
            });
            Arc::new(m)
        }))
    }
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Where a training surface's rows live: in RAM or in a `.k2c` file.
/// The jobs manifest, `load_dataset`, the CLI and the big-means driver
/// all speak this type, so "swap the dataset for one that does not fit
/// in RAM" is a constructor change, not a new code path.
#[derive(Clone, Debug)]
pub enum DatasetSource {
    /// A fully resident matrix, `Arc`-shared across jobs.
    InRam(Arc<Matrix>),
    /// A chunked on-disk matrix, loaded block-by-block on demand.
    Chunked(Arc<ChunkedMatrix>),
}

impl From<Arc<Matrix>> for DatasetSource {
    fn from(x: Arc<Matrix>) -> DatasetSource {
        DatasetSource::InRam(x)
    }
}

impl From<Matrix> for DatasetSource {
    fn from(x: Matrix) -> DatasetSource {
        DatasetSource::InRam(Arc::new(x))
    }
}

impl From<Arc<ChunkedMatrix>> for DatasetSource {
    fn from(x: Arc<ChunkedMatrix>) -> DatasetSource {
        DatasetSource::Chunked(x)
    }
}

impl From<ChunkedMatrix> for DatasetSource {
    fn from(x: ChunkedMatrix) -> DatasetSource {
        DatasetSource::Chunked(Arc::new(x))
    }
}

impl DatasetSource {
    pub fn rows(&self) -> usize {
        match self {
            DatasetSource::InRam(x) => x.rows(),
            DatasetSource::Chunked(c) => c.rows(),
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            DatasetSource::InRam(x) => x.cols(),
            DatasetSource::Chunked(c) => c.cols(),
        }
    }

    /// Gather global row indices into a dense matrix — bitwise identical
    /// between the two variants on the same data ([`Matrix::gather`] vs
    /// [`ChunkedMatrix::gather_rows`]).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        match self {
            DatasetSource::InRam(x) => Matrix::gather(x, idx),
            DatasetSource::Chunked(c) => c.gather_rows(idx),
        }
    }

    /// Stream the rows in order as `(start_row, block)` chunks. The
    /// in-RAM variant yields itself as one chunk; the chunked variant
    /// streams file blocks — same rows, same order, same bits.
    pub fn for_each_chunk(&self, mut f: impl FnMut(usize, &Matrix)) {
        match self {
            DatasetSource::InRam(x) => f(0, x),
            DatasetSource::Chunked(c) => c.for_each_chunk(f),
        }
    }

    /// The full matrix, resident: a free `Arc` clone for [`InRam`],
    /// a one-time assembly (cached on the store) for [`Chunked`].
    ///
    /// [`InRam`]: DatasetSource::InRam
    /// [`Chunked`]: DatasetSource::Chunked
    pub fn materialize(&self) -> Arc<Matrix> {
        match self {
            DatasetSource::InRam(x) => Arc::clone(x),
            DatasetSource::Chunked(c) => c.materialize(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::blobs;

    fn tmpfile(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("k2m_test_{}_{}", std::process::id(), name));
        p
    }

    fn fixture(n: usize, d: usize, seed: u64) -> Dataset {
        let (x, _) = blobs(n, 4, d, 10.0, seed);
        Dataset { name: "blobs".into(), x, seed }
    }

    /// Open with pinned knobs so the assertions hold under the CI job
    /// that forces `K2M_CHUNK_ROWS`/`K2M_CHUNK_CACHE` suite-wide.
    fn open_pinned(p: &Path, chunk_rows: usize, cache: usize) -> ChunkedMatrix {
        ChunkedMatrix::open_with(
            p,
            OpenOptions { chunk_rows: Some(chunk_rows), cache_chunks: Some(cache) },
        )
        .unwrap()
    }

    #[test]
    fn roundtrip_reads_are_bitwise() {
        let ds = fixture(53, 7, 11);
        let p = tmpfile("roundtrip.k2c");
        save_chunked(&ds, 8, &p).unwrap();
        // Chunk sizes spanning the boundary cases: 1, a non-divisor, an
        // exact divisor of 53? (none but 53), and > n.
        for chunk_rows in [1usize, 7, 8, 53, 100] {
            let cm = open_pinned(&p, chunk_rows, 3);
            assert_eq!((cm.rows(), cm.cols()), (53, 7));
            assert_eq!(cm.name(), "blobs");
            for i in 0..cm.rows() {
                let got = cm.row(i);
                let want = ds.x.row(i);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "row {i} chunk_rows={chunk_rows}");
                }
            }
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn gather_matches_matrix_gather_bitwise() {
        let ds = fixture(40, 5, 3);
        let p = tmpfile("gather.k2c");
        save_chunked(&ds, 6, &p).unwrap();
        let cm = open_pinned(&p, 6, 2);
        // Unsorted with a repeat and both edge rows.
        let idx = vec![39usize, 0, 13, 13, 27, 6];
        let got = cm.gather_rows(&idx);
        let want = Matrix::gather(&ds.x, &idx);
        assert_eq!(got, want);
        // And through the DatasetSource face, both variants agree.
        let src_ram: DatasetSource = Arc::new(ds.x.clone()).into();
        let src_chunk: DatasetSource = cm.into();
        assert_eq!(src_ram.gather_rows(&idx), src_chunk.gather_rows(&idx));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn streaming_and_materialize_reassemble_exactly() {
        let ds = fixture(29, 4, 8);
        let p = tmpfile("stream.k2c");
        save_chunked(&ds, 5, &p).unwrap();
        let cm = open_pinned(&p, 5, 1); // cache of 1: every chunk re-read
        assert_eq!(cm.num_chunks(), 6);
        assert_eq!(cm.chunk_range(5), (25, 29)); // ragged tail
        let mut seen = 0usize;
        cm.for_each_chunk(|start, block| {
            assert_eq!(start, seen);
            seen += block.rows();
        });
        assert_eq!(seen, 29);
        assert_eq!(*cm.materialize(), ds.x);
        // Materialization is cached: same Arc both times.
        assert!(Arc::ptr_eq(&cm.materialize(), &cm.materialize()));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn lru_cache_stays_bounded_and_serves_hits() {
        let ds = fixture(32, 3, 5);
        let p = tmpfile("lru.k2c");
        save_chunked(&ds, 4, &p).unwrap();
        let cm = open_pinned(&p, 4, 2);
        assert_eq!(cm.resident_chunks(), 0);
        let a = cm.chunk(0);
        let b = cm.chunk(1);
        assert_eq!(cm.resident_chunks(), 2);
        // A hit refreshes recency: touching 0 then loading 2 evicts 1.
        let a2 = cm.chunk(0);
        assert!(Arc::ptr_eq(&a, &a2));
        cm.chunk(2);
        assert_eq!(cm.resident_chunks(), 2);
        let b2 = cm.chunk(1); // re-read after eviction: same bits
        assert_eq!(*b, *b2);
        assert!(!Arc::ptr_eq(&b, &b2));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn open_resolution_prefers_options_over_header() {
        let ds = fixture(20, 3, 2);
        let p = tmpfile("knobs.k2c");
        save_chunked(&ds, 9, &p).unwrap();
        let cm = open_pinned(&p, 4, 2);
        assert_eq!(cm.chunk_rows(), 4); // explicit option wins
        // Without an explicit option the resolution is env > header; we
        // cannot assert which fired (env knobs are once-cached per
        // process), only that the result is a sane effective size.
        let cm = ChunkedMatrix::open(&p).unwrap();
        assert!(cm.chunk_rows() >= 1);
        std::fs::remove_file(&p).ok();
    }

    /// Table-driven corruption corpus for the `.k2c` loader, mirroring
    /// the `.k2mm` corpus in `data::io`: every entry mutates a freshly
    /// saved file and names the error `open` must produce.
    #[test]
    fn open_rejects_corruption_corpus() {
        type Mutate = fn(&mut Vec<u8>);
        let corpus: &[(&str, Mutate, &str)] = &[
            ("wrong magic", |b| b[..3].copy_from_slice(b"k2b"), "not a k2c"),
            ("version skew to 9", |b| b[4] = b'9', "unsupported k2c version 9"),
            (
                "zero rows",
                |b| {
                    // "k2c 1 blobs 12 3 5\n" -> rows field at offset 12.
                    b[12..14].copy_from_slice(b" 0");
                },
                "zero-dimension",
            ),
            (
                "zero chunk_rows",
                |b| {
                    let nl = b.iter().position(|&c| c == b'\n').unwrap();
                    b[nl - 1] = b'0';
                },
                "zero chunk_rows",
            ),
            ("truncated payload", |b| b.truncate(b.len() - 1), "truncated or oversized"),
            ("trailing bytes", |b| b.push(0), "truncated or oversized"),
            (
                "header/field-count skew",
                |b| {
                    // Drop the chunk_rows field entirely: 6 fields -> 5.
                    let nl = b.iter().position(|&c| c == b'\n').unwrap();
                    b.drain(nl - 2..nl);
                },
                "not a k2c",
            ),
        ];
        let ds = fixture(12, 3, 7);
        let p = tmpfile("corpus.k2c");
        save_chunked(&ds, 5, &p).unwrap();
        let pristine = std::fs::read(&p).unwrap();
        assert_eq!(&pristine[..12], b"k2c 1 blobs ");
        for (name, mutate, want) in corpus {
            let mut bytes = pristine.clone();
            mutate(&mut bytes);
            std::fs::write(&p, &bytes).unwrap();
            let err = ChunkedMatrix::open(&p).unwrap_err().to_string();
            assert!(err.contains(want), "{name}: expected {want:?} in {err:?}");
        }
        // The untouched file still loads — the corpus mutations, not the
        // fixture, are what the loader objects to.
        std::fs::write(&p, &pristine).unwrap();
        ChunkedMatrix::open(&p).unwrap();
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn zero_dimension_saves_are_refused() {
        let ds = Dataset { name: "empty".into(), x: Matrix::zeros(0, 0), seed: 0 };
        let p = tmpfile("empty.k2c");
        assert!(save_chunked(&ds, 4, &p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
