//! Dataset substrate: the paper's eight evaluation datasets as
//! deterministic synthetic simulacra, plus binary/CSV I/O and the
//! out-of-core chunked store ([`store`] — the `.k2c` format,
//! [`ChunkedMatrix`], and the [`DatasetSource`] in-RAM/chunked
//! abstraction every training surface accepts).
//!
//! The paper evaluates on real datasets (cifar, cnnvoc, covtype, mnist,
//! mnist50, tinygist10k, tiny10k, usps, yale) that we cannot ship.
//! Following the substitution rule in DESIGN.md §3, each is replaced by a
//! generator with the **same (n, d)** and a matched generative structure
//! (multi-modal, imbalanced, anisotropic, low-rank within modes — the
//! properties k-means-family algorithms are sensitive to). All generators
//! are seeded and bit-reproducible.

mod gmm;
pub mod io;
mod sets;
pub mod store;

pub use gmm::{generate_gmm, GmmSpec};
pub use io::{load_bin, load_csv, load_model, save_bin, save_model};
pub use sets::*;
pub use store::{save_chunked, ChunkedMatrix, DatasetSource};

use crate::core::Matrix;

/// A named dataset: flat row-major points plus provenance metadata.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Short name used in tables ("cifar", "mnist50", ...).
    pub name: String,
    /// `n x d` data points.
    pub x: Matrix,
    /// Generator seed (0 for loaded data).
    pub seed: u64,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }
    pub fn d(&self) -> usize {
        self.x.cols()
    }
}

/// Every paper dataset by name at a given scale factor (`scale` multiplies
/// n; 1.0 = the paper's size). Returns `None` for unknown names.
pub fn by_name(name: &str, scale: f64, seed: u64) -> Option<Dataset> {
    Some(match name {
        "cifar" => cifar_like(scale, seed),
        "cnnvoc" => cnnvoc_like(scale, seed),
        "covtype" => covtype_like(scale, seed),
        "mnist" => mnist_like(scale, seed),
        "mnist50" => mnist50_like(scale, seed),
        "tinygist10k" => tinygist10k_like(scale, seed),
        "tiny10k" => tiny10k_like(scale, seed),
        "usps" => usps_like(scale, seed),
        "yale" => yale_like(scale, seed),
        _ => return None,
    })
}

/// The dataset roster of the paper's main speedup tables (Tables 5/6 and
/// supplementary 8–11), in paper order.
pub const SPEEDUP_ROSTER: &[&str] = &[
    "cifar", "cnnvoc", "covtype", "mnist", "mnist50", "tinygist10k", "usps", "yale",
];

/// The roster of the initialization comparison (Tables 4/7) — the paper
/// excludes cifar and tiny10k there ("prohibitive cost of standard Lloyd").
pub const INIT_ROSTER: &[&str] =
    &["cnnvoc", "covtype", "mnist", "mnist50", "tinygist10k", "usps", "yale"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_known_and_unknown() {
        let ds = by_name("usps", 0.05, 1).unwrap();
        assert_eq!(ds.name, "usps");
        assert!(ds.n() > 0);
        assert_eq!(ds.d(), 256);
        assert!(by_name("nope", 1.0, 1).is_none());
    }

    #[test]
    fn rosters_resolve() {
        for name in SPEEDUP_ROSTER.iter().chain(INIT_ROSTER) {
            assert!(by_name(name, 0.01, 3).is_some(), "{name}");
        }
    }
}
