//! The generic generator behind every simulacrum: an anisotropic Gaussian
//! mixture with power-law component sizes, optional within-mode low-rank
//! structure, optional heavy tails and background noise.
//!
//! The knobs map to the properties that drive k-means behaviour:
//! * `modes` + `spread`      — how much true cluster structure exists;
//! * `imbalance`             — power-law component masses (real image/
//!                             category data is never balanced);
//! * `rank`                  — within-mode low-rank wobble (feature
//!                             embeddings live near low-dim manifolds);
//! * `tail`                  — Student-t-ish heavy tails (covtype-like
//!                             cartographic measurements);
//! * `noise_frac`            — uniform background points (clutter).

use crate::core::Matrix;
use crate::rng::Pcg32;

/// Specification for [`generate_gmm`].
#[derive(Clone, Debug)]
pub struct GmmSpec {
    pub n: usize,
    pub d: usize,
    /// Number of mixture components.
    pub modes: usize,
    /// Center scale relative to unit within-mode noise.
    pub spread: f64,
    /// Power-law exponent for component masses; 0 = balanced.
    pub imbalance: f64,
    /// Rank of within-mode subspace wobble (0 = isotropic only).
    pub rank: usize,
    /// Amplitude of the subspace wobble relative to the isotropic noise.
    pub rank_amp: f64,
    /// Per-axis anisotropy: noise std per axis drawn in [1/a, a].
    pub anisotropy: f64,
    /// Degrees-of-freedom-ish tail control; 0 disables (pure gaussian).
    /// Implemented as dividing each point's noise by sqrt(chi2/df).
    pub tail_df: f64,
    /// Fraction of points replaced by uniform background clutter.
    pub noise_frac: f64,
}

impl Default for GmmSpec {
    fn default() -> Self {
        GmmSpec {
            n: 1000,
            d: 16,
            modes: 10,
            spread: 6.0,
            imbalance: 1.0,
            rank: 4,
            rank_amp: 2.0,
            anisotropy: 2.0,
            tail_df: 0.0,
            noise_frac: 0.0,
        }
    }
}

/// Draw the component sizes: power-law masses, renormalized, with every
/// component getting at least one point.
fn component_sizes(spec: &GmmSpec, rng: &mut Pcg32) -> Vec<usize> {
    let m = spec.modes;
    let mut masses: Vec<f64> = (0..m)
        .map(|i| ((i + 1) as f64).powf(-spec.imbalance) * (0.5 + rng.f64()))
        .collect();
    let total: f64 = masses.iter().sum();
    for w in masses.iter_mut() {
        *w /= total;
    }
    let mut sizes: Vec<usize> =
        masses.iter().map(|w| ((w * spec.n as f64) as usize).max(1)).collect();
    // Fix rounding drift so sizes sum exactly to n.
    let mut diff = spec.n as i64 - sizes.iter().sum::<usize>() as i64;
    let mut i = 0;
    while diff != 0 {
        let j = i % m;
        if diff > 0 {
            sizes[j] += 1;
            diff -= 1;
        } else if sizes[j] > 1 {
            sizes[j] -= 1;
            diff += 1;
        }
        i += 1;
    }
    sizes
}

/// Generate a dataset from the spec. Deterministic in (spec, seed).
pub fn generate_gmm(spec: &GmmSpec, seed: u64) -> Matrix {
    assert!(spec.n > 0 && spec.d > 0 && spec.modes > 0);
    let mut rng = Pcg32::new(seed, 0x9e3779b97f4a7c15);
    let d = spec.d;
    let sizes = component_sizes(spec, &mut rng);

    let mut x = Matrix::zeros(spec.n, d);
    let mut row = 0usize;
    for (mode, &sz) in sizes.iter().enumerate() {
        // Mode center, per-axis noise scales, and subspace basis.
        let mut rmode = Pcg32::new(seed ^ 0xabcd, mode as u64 + 1);
        let center: Vec<f32> =
            (0..d).map(|_| (rmode.gaussian() * spec.spread) as f32).collect();
        let axis: Vec<f32> = (0..d)
            .map(|_| {
                let a = spec.anisotropy.max(1.0);
                let lo = 1.0 / a;
                (lo + (a - lo) * rmode.f64()) as f32
            })
            .collect();
        // Flat rank × d basis (stride indexing, no per-vector boxes) —
        // same draws, same normalization arithmetic as the old
        // Vec<Vec<f32>> staging buffer.
        let mut basis = Matrix::zeros(spec.rank, d);
        for br in 0..spec.rank {
            let bvec = basis.row_mut(br);
            for v in bvec.iter_mut() {
                *v = rmode.gaussian_f32();
            }
            let n2 = crate::core::ops::norm2_raw(bvec).sqrt().max(1e-6);
            for v in bvec.iter_mut() {
                *v /= n2;
            }
        }

        for _ in 0..sz {
            let r = x.row_mut(row);
            // Heavy-tail scale factor (approximate Student-t).
            let tail_scale = if spec.tail_df > 0.0 {
                let df = spec.tail_df;
                let chi: f64 = (0..df.round() as usize)
                    .map(|_| {
                        let g = rng.gaussian();
                        g * g
                    })
                    .sum::<f64>()
                    .max(1e-9);
                (df / chi).sqrt() as f32
            } else {
                1.0
            };
            for (j, v) in r.iter_mut().enumerate() {
                *v = center[j] + rng.gaussian_f32() * axis[j] * tail_scale;
            }
            // Low-rank wobble: r += sum_k z_k * amp * b_k
            for br in 0..spec.rank {
                let z = rng.gaussian_f32() * spec.rank_amp as f32 * tail_scale;
                for (v, &bj) in r.iter_mut().zip(basis.row(br)) {
                    *v += z * bj;
                }
            }
            row += 1;
        }
    }
    debug_assert_eq!(row, spec.n);

    // Background clutter: overwrite a random subset with broad uniforms.
    if spec.noise_frac > 0.0 {
        let n_noise = (spec.noise_frac * spec.n as f64) as usize;
        let half_range = (spec.spread * 2.0) as f32;
        let idx = rng.sample_distinct(spec.n, n_noise);
        for i in idx {
            for v in x.row_mut(i) {
                *v = (rng.f32() * 2.0 - 1.0) * half_range;
            }
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let spec = GmmSpec { n: 333, d: 7, modes: 5, ..Default::default() };
        let a = generate_gmm(&spec, 9);
        let b = generate_gmm(&spec, 9);
        assert_eq!(a.rows(), 333);
        assert_eq!(a.cols(), 7);
        assert_eq!(a, b);
        let c = generate_gmm(&spec, 10);
        assert_ne!(a, c);
    }

    #[test]
    fn component_sizes_sum_to_n() {
        let mut rng = Pcg32::seeded(0);
        for imb in [0.0, 1.0, 2.5] {
            let spec = GmmSpec { n: 997, modes: 13, imbalance: imb, ..Default::default() };
            let sizes = component_sizes(&spec, &mut rng);
            assert_eq!(sizes.iter().sum::<usize>(), 997);
            assert!(sizes.iter().all(|&s| s >= 1));
        }
    }

    #[test]
    fn imbalance_skews_masses() {
        let mut rng = Pcg32::seeded(1);
        let spec = GmmSpec { n: 10000, modes: 10, imbalance: 2.0, ..Default::default() };
        let sizes = component_sizes(&spec, &mut rng);
        let max = *sizes.iter().max().unwrap();
        let min = *sizes.iter().min().unwrap();
        assert!(max > 10 * min.max(1), "max={max} min={min}");
    }

    #[test]
    fn clusters_are_separated_when_spread_large() {
        // With huge spread, within-mode variance << between-mode distance,
        // so k-means on true centers would recover structure. We check the
        // raw data spans a much larger range than unit noise.
        let spec = GmmSpec {
            n: 500, d: 8, modes: 4, spread: 50.0, rank: 0, anisotropy: 1.0,
            ..Default::default()
        };
        let x = generate_gmm(&spec, 2);
        let flat = x.as_slice();
        let maxabs = flat.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(maxabs > 20.0);
    }

    #[test]
    fn noise_frac_injects_clutter() {
        let base = GmmSpec {
            n: 400,
            d: 4,
            modes: 2,
            spread: 0.0,
            noise_frac: 0.0,
            rank: 0,
            ..Default::default()
        };
        let noisy = GmmSpec { noise_frac: 0.5, ..base.clone() };
        let a = generate_gmm(&base, 3);
        let b = generate_gmm(&noisy, 3);
        assert_ne!(a, b);
    }
}
