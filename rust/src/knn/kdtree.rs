//! kd-tree with best-bin-first (BBF) bounded search — the approximate
//! nearest-neighbour structure behind AKM (Philbin et al., CVPR'07).
//!
//! AKM rebuilds the tree over the *centers* every iteration and answers
//! each point's assignment query with at most `m` distance checks; `m`
//! trades accuracy for speed exactly like the paper's Table 2 (`O(nmd)`
//! per iteration). Distance checks are counted through [`OpCounter`];
//! the tree build's comparison work is counted under the sort convention
//! (`k log2 k / d` per level-set, paper §2.2).
//!
//! The search is *exact* when `m >= k` (the priority queue eventually
//! visits every leaf), which the property tests exploit.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::core::{Matrix, NumericsMode, OpCounter};
use crate::rng::Pcg32;

/// Maximum points per leaf.
const LEAF_SIZE: usize = 8;
/// Dimensions sampled when picking the split axis (FLANN-style randomized
/// kd-tree: pick randomly among the top-RAND_DIM_CANDIDATES variance axes).
const RAND_DIM_CANDIDATES: usize = 5;

#[derive(Debug)]
enum Node {
    Leaf {
        /// Indices into the point table.
        idx: Vec<u32>,
    },
    Split {
        axis: u32,
        threshold: f32,
        left: Box<Node>,
        right: Box<Node>,
    },
}

/// A single randomized kd-tree over a borrowed point table.
pub struct KdTree<'a> {
    points: &'a Matrix,
    root: Node,
}

/// Max-heap entry ordered by *smallest* bound first (reverse ordering).
struct QueueEntry<'t> {
    bound: f32,
    node: &'t Node,
}

impl PartialEq for QueueEntry<'_> {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound
    }
}
impl Eq for QueueEntry<'_> {}
impl PartialOrd for QueueEntry<'_> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueueEntry<'_> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for min-bound-first.
        other.bound.partial_cmp(&self.bound).unwrap_or(Ordering::Equal)
    }
}

impl<'a> KdTree<'a> {
    /// Build over all rows of `points`. Counts the per-level comparison
    /// work under the paper's sort convention.
    pub fn build(points: &'a Matrix, seed: u64, counter: &mut OpCounter) -> Self {
        let mut rng = Pcg32::new(seed, 0x6b64);
        let idx: Vec<u32> = (0..points.rows() as u32).collect();
        // Each tree level partitions all k points: count log2(k) passes.
        counter.count_sort(points.rows(), points.cols());
        let root = Self::build_node(points, idx, &mut rng, 0);
        KdTree { points, root }
    }

    fn build_node(points: &Matrix, idx: Vec<u32>, rng: &mut Pcg32, depth: usize) -> Node {
        if idx.len() <= LEAF_SIZE || depth > 30 {
            return Node::Leaf { idx };
        }
        let d = points.cols();
        // Variance per axis over this subset (sampled for large subsets).
        let sample: Vec<u32> = if idx.len() > 128 {
            (0..128).map(|i| idx[i * idx.len() / 128]).collect()
        } else {
            idx.clone()
        };
        let m = sample.len() as f32;
        let mut mean = vec![0.0f32; d];
        for &i in &sample {
            for (a, &v) in mean.iter_mut().zip(points.row(i as usize)) {
                *a += v;
            }
        }
        for a in mean.iter_mut() {
            *a /= m;
        }
        let mut var = vec![0.0f32; d];
        for &i in &sample {
            for ((a, &v), &mu) in var.iter_mut().zip(points.row(i as usize)).zip(&mean) {
                let c = v - mu;
                *a += c * c;
            }
        }
        // Pick randomly among the top-variance axes (randomized forest).
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            var[b as usize].partial_cmp(&var[a as usize]).unwrap()
        });
        let cand = RAND_DIM_CANDIDATES.min(d);
        let axis = order[rng.gen_below(cand)];
        let threshold = mean[axis as usize];

        let (left, right): (Vec<u32>, Vec<u32>) = idx
            .iter()
            .partition(|&&i| points.row(i as usize)[axis as usize] < threshold);
        if left.is_empty() || right.is_empty() {
            return Node::Leaf { idx };
        }
        Node::Split {
            axis,
            threshold,
            left: Box::new(Self::build_node(points, left, rng, depth + 1)),
            right: Box::new(Self::build_node(points, right, rng, depth + 1)),
        }
    }

    /// Best-bin-first approximate NN: visit leaves in increasing
    /// bound order, checking at most `max_checks` point distances
    /// (each counted). Returns `(index, sqdist)`. Strict-tier entry —
    /// see [`KdTree::nearest_mode`].
    pub fn nearest(
        &self,
        query: &[f32],
        max_checks: usize,
        counter: &mut OpCounter,
    ) -> (u32, f32) {
        self.nearest_mode(query, max_checks, counter, NumericsMode::Strict)
    }

    /// [`KdTree::nearest`] with the leaf distance checks dispatched on
    /// `nm` (AKM's hot path rides `Config::numerics` through here). The
    /// BBF descent — axis-gap bound arithmetic and queue ordering — is
    /// scalar bookkeeping shared by both tiers, so the check budget and
    /// the counted bill are mode-independent whenever no leaf
    /// comparison lands inside the tiers' rounding gap.
    pub fn nearest_mode(
        &self,
        query: &[f32],
        max_checks: usize,
        counter: &mut OpCounter,
        nm: NumericsMode,
    ) -> (u32, f32) {
        let mut best = (u32::MAX, f32::INFINITY);
        let mut checks = 0usize;
        let mut queue: BinaryHeap<QueueEntry> = BinaryHeap::new();
        queue.push(QueueEntry { bound: 0.0, node: &self.root });

        while let Some(QueueEntry { bound, node }) = queue.pop() {
            if checks >= max_checks || bound >= best.1 {
                if bound >= best.1 {
                    continue; // this branch can't win; others might be closer
                }
                break;
            }
            let mut cur = node;
            let mut cur_bound = bound;
            loop {
                match cur {
                    Node::Leaf { idx } => {
                        for &i in idx {
                            if checks >= max_checks {
                                break;
                            }
                            let dist =
                                nm.sqdist_one(query, self.points.row(i as usize), counter);
                            checks += 1;
                            if dist < best.1 {
                                best = (i, dist);
                            }
                        }
                        break;
                    }
                    Node::Split { axis, threshold, left, right } => {
                        let diff = query[*axis as usize] - threshold;
                        let (near, far) =
                            if diff < 0.0 { (left, right) } else { (right, left) };
                        // The far child's bound grows by the axis gap.
                        let far_bound = cur_bound + diff * diff;
                        queue.push(QueueEntry { bound: far_bound, node: far });
                        cur = near;
                        let _ = cur_bound; // near child keeps the same bound
                        cur_bound = bound;
                    }
                }
            }
        }
        best
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.points.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.points.rows() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ops;

    fn random_points(n: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let mut m = Matrix::zeros(n, d);
        for i in 0..n {
            for v in m.row_mut(i) {
                *v = rng.gaussian_f32() * 3.0;
            }
        }
        m
    }

    fn brute_nearest(points: &Matrix, q: &[f32]) -> (u32, f32) {
        let mut best = (u32::MAX, f32::INFINITY);
        for i in 0..points.rows() {
            let d = ops::sqdist_raw(q, points.row(i));
            if d < best.1 {
                best = (i as u32, d);
            }
        }
        best
    }

    #[test]
    fn exact_when_unbounded() {
        let pts = random_points(200, 8, 1);
        let mut ctr = OpCounter::default();
        let tree = KdTree::build(&pts, 0, &mut ctr);
        let queries = random_points(50, 8, 2);
        for qi in 0..queries.rows() {
            let q = queries.row(qi);
            let (gi, gd) = tree.nearest(q, usize::MAX, &mut ctr);
            let (bi, bd) = brute_nearest(&pts, q);
            assert_eq!(gi, bi, "query {qi}");
            assert!((gd - bd).abs() < 1e-5);
        }
    }

    #[test]
    fn bounded_checks_respected_and_reasonable() {
        let pts = random_points(500, 16, 3);
        let mut ctr = OpCounter::default();
        let tree = KdTree::build(&pts, 0, &mut ctr);
        let q = random_points(1, 16, 4);
        let before = ctr.distances;
        let (_, d_bounded) = tree.nearest(q.row(0), 20, &mut ctr);
        assert!(ctr.distances - before <= 20, "checks not bounded");
        let (_, d_exact) = brute_nearest(&pts, q.row(0));
        // Approximate answer is valid (>= exact) and finite.
        assert!(d_bounded >= d_exact - 1e-5);
        assert!(d_bounded.is_finite());
    }

    #[test]
    fn approximation_improves_with_checks() {
        let pts = random_points(1000, 32, 5);
        let mut ctr = OpCounter::default();
        let tree = KdTree::build(&pts, 0, &mut ctr);
        let queries = random_points(30, 32, 6);
        let mut err_small = 0usize;
        let mut err_large = 0usize;
        for qi in 0..queries.rows() {
            let q = queries.row(qi);
            let (bi, _) = brute_nearest(&pts, q);
            let (s, _) = tree.nearest(q, 10, &mut ctr);
            let (l, _) = tree.nearest(q, 400, &mut ctr);
            err_small += (s != bi) as usize;
            err_large += (l != bi) as usize;
        }
        assert!(err_large <= err_small, "more checks should not hurt: {err_large} vs {err_small}");
    }

    #[test]
    fn single_point_tree() {
        let pts = random_points(1, 4, 7);
        let mut ctr = OpCounter::default();
        let tree = KdTree::build(&pts, 0, &mut ctr);
        let (i, d) = tree.nearest(pts.row(0), 10, &mut ctr);
        assert_eq!(i, 0);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn duplicate_points_handled() {
        let mut pts = Matrix::zeros(50, 3);
        for i in 0..50 {
            pts.row_mut(i).copy_from_slice(&[1.0, 2.0, 3.0]);
        }
        let mut ctr = OpCounter::default();
        let tree = KdTree::build(&pts, 0, &mut ctr);
        let (_, d) = tree.nearest(&[1.0, 2.0, 3.0], usize::MAX, &mut ctr);
        assert_eq!(d, 0.0);
    }
}
