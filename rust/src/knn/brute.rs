//! Exact brute-force kNN over the center table.
//!
//! k²-means rebuilds this graph every iteration: `k²` counted distances
//! plus a per-row partial sort counted under the paper's sort convention.
//! Neighbour lists always start with the center itself (distance 0),
//! matching the paper's `N_kn(c_l)` which includes `c_l`.
//!
//! The serial build fills the pairwise table by upper-triangle tiles
//! ([`crate::core::kernels::pairwise_block`] — each pair computed and
//! counted once); the sharded build runs row selection over center
//! shards with the blocked row kernel
//! ([`crate::core::kernels::sqdist_rows_raw`]). Every thread count
//! produces the identical graph (each row's computation is independent
//! and deterministic, and the blocked kernels are bit-identical to the
//! scalar path). [`knn_graph_mode`] additionally selects the numerics
//! tier ([`NumericsMode`]); the bare entry points stay Strict.

use anyhow::{bail, Result};

use crate::coordinator::pool;
use crate::core::{Matrix, NumericsMode, OpCounter, RefreshMode};

/// kn-nearest-neighbour graph over a set of centers, stored flat:
/// `k × kn` neighbour indices and distances at stride `kn`, so a row's
/// candidate list is one contiguous `&[u32]` — exactly the shape the
/// blocked kernels ([`crate::core::kernels`]) scan.
///
/// # Distance convention — **squared** distances
///
/// [`NeighborGraph::dists_row`] holds **squared** euclidean distances.
/// The k²-means bound arithmetic (`u`, `lb`) works in **plain**
/// distances; every crossing of that boundary must go through
/// [`NeighborGraph::plain_dist`] (the `.sqrt()` lives there and nowhere
/// else), so a refactor cannot silently mix the two conventions. See
/// the regression test `dists_are_squared_not_plain`.
#[derive(Clone, Debug)]
pub struct NeighborGraph {
    k: usize,
    kn: usize,
    /// Flat `k * kn` neighbour indices; row `l` = `N_kn(c_l)`,
    /// `nbrs_row(l)[0] == l`.
    nbrs: Vec<u32>,
    /// Flat **squared** distances aligned with `nbrs` (see struct docs).
    dists: Vec<f32>,
}

impl NeighborGraph {
    pub fn k(&self) -> usize {
        self.k
    }
    pub fn kn(&self) -> usize {
        self.kn
    }

    /// Center `l`'s neighbour list (length `kn`, self at slot 0) — a
    /// contiguous candidate list for the blocked kernels.
    #[inline(always)]
    pub fn nbrs_row(&self, l: usize) -> &[u32] {
        &self.nbrs[l * self.kn..(l + 1) * self.kn]
    }

    /// **Squared** distances aligned with [`NeighborGraph::nbrs_row`].
    #[inline(always)]
    pub fn dists_row(&self, l: usize) -> &[f32] {
        &self.dists[l * self.kn..(l + 1) * self.kn]
    }

    /// Plain (non-squared) distance from center `l` to its slot-`t`
    /// neighbour — the **only** sanctioned conversion from this graph's
    /// squared distances into the plain-distance domain of the k²-means
    /// bounds `u`/`lb` (Elkan-style triangle-inequality arithmetic is
    /// unsound on squared distances).
    ///
    /// ```
    /// use k2m::core::{Matrix, OpCounter};
    /// use k2m::knn::knn_graph;
    ///
    /// // Two centers 3.0 apart in one dimension. The graph row stores
    /// // the SQUARED distance (9.0); `plain_dist` is where the one
    /// // sanctioned sqrt lives.
    /// let centers = Matrix::from_vec(vec![0.0, 3.0], 2, 1);
    /// let g = knn_graph(&centers, 2, &mut OpCounter::default());
    /// assert_eq!(g.dists_row(0)[1], 9.0); // squared, straight from the row
    /// assert_eq!(g.plain_dist(0, 1), 3.0); // plain, for bound arithmetic
    /// ```
    #[inline]
    pub fn plain_dist(&self, l: usize, t: usize) -> f32 {
        self.dists[l * self.kn + t].sqrt()
    }

    /// Flat row-major neighbour indices (`k * kn`, stride `kn`) — the
    /// serialization view consumed by `data::io::save_model`.
    pub fn nbrs_flat(&self) -> &[u32] {
        &self.nbrs
    }

    /// Flat **squared** distances aligned with
    /// [`NeighborGraph::nbrs_flat`].
    pub fn dists_flat(&self) -> &[f32] {
        &self.dists
    }

    /// Rebuild a graph from its flat serialized parts (the
    /// `data::io::load_model` path), validating every structural
    /// invariant the bounded-scan consumers rely on: `1 <= kn <= k`,
    /// both flats exactly `k * kn` long, every neighbour index `< k`,
    /// self at slot 0 with distance exactly `0.0`, and each row's
    /// distances finite, non-negative, and non-decreasing after slot 0
    /// (the serving path reads slot `kn-1` as a coverage radius, which
    /// is only sound on sorted rows). A file that fails any of these
    /// is rejected with a descriptive error rather than producing a
    /// graph whose "exact" scans would silently be wrong.
    pub fn from_parts(
        k: usize,
        kn: usize,
        nbrs: Vec<u32>,
        dists: Vec<f32>,
    ) -> Result<NeighborGraph> {
        if k == 0 || kn == 0 || kn > k {
            bail!("neighbor graph: kn={kn} out of range for k={k} (need 1 <= kn <= k)");
        }
        let flat = k
            .checked_mul(kn)
            .filter(|&f| f == nbrs.len() && f == dists.len());
        if flat.is_none() {
            bail!(
                "neighbor graph: flats have {} indices / {} distances, expected k*kn = {}*{}",
                nbrs.len(),
                dists.len(),
                k,
                kn
            );
        }
        for l in 0..k {
            let ni = &nbrs[l * kn..(l + 1) * kn];
            let nd = &dists[l * kn..(l + 1) * kn];
            if ni[0] != l as u32 || nd[0] != 0.0 {
                bail!(
                    "neighbor graph row {l}: slot 0 must be self with distance 0 \
                     (got index {} dist {})",
                    ni[0],
                    nd[0]
                );
            }
            if let Some(&bad) = ni.iter().find(|&&j| j as usize >= k) {
                bail!("neighbor graph row {l}: neighbour index {bad} out of range (k={k})");
            }
            if nd.iter().any(|&v| !v.is_finite() || v < 0.0) {
                bail!("neighbor graph row {l}: non-finite or negative squared distance");
            }
            if nd.windows(2).skip(1).any(|w| w[0] > w[1]) {
                bail!("neighbor graph row {l}: distances not sorted ascending after slot 0");
            }
        }
        Ok(NeighborGraph { k, kn, nbrs, dists })
    }
}

/// Build the exact kn-NN graph of `centers` (self included as slot 0).
/// Serial **strict-tier** entry point — see [`knn_graph_threaded`] /
/// [`knn_graph_mode`].
pub fn knn_graph(centers: &Matrix, kn: usize, counter: &mut OpCounter) -> NeighborGraph {
    knn_graph_threaded(centers, kn, counter, 1)
}

/// Build the exact kn-NN graph with row selection sharded over `threads`
/// workers, on the **strict** numerics tier — the historical,
/// bit-pinned entry point. Mode-aware callers (the k²-means iteration
/// loop) go through [`knn_graph_mode`] instead.
pub fn knn_graph_threaded(
    centers: &Matrix,
    kn: usize,
    counter: &mut OpCounter,
    threads: usize,
) -> NeighborGraph {
    knn_graph_mode(centers, kn, counter, threads, NumericsMode::Strict)
}

/// Build the exact kn-NN graph with row selection sharded over `threads`
/// workers and distance arithmetic on the numerics tier `nm`.
///
/// Counts `k*(k-1)/2` distances (each unordered pair once — the paper's
/// accounting) plus one per-row selection under the sort convention.
/// The serial path fills the symmetric table by upper-triangle tiles
/// (`pairwise_block` — each pair computed once); the sharded path
/// instead recomputes each row's distances locally with the blocked row
/// kernel to avoid cross-shard writes — both tiers' kernels are bitwise
/// symmetric in their arguments, so serial and sharded paths emit the
/// identical graph *within a tier*, and the counted-op bill is the same
/// because symmetric recomputation is not a second "distance
/// computation" in the paper's sense.
pub fn knn_graph_mode(
    centers: &Matrix,
    kn: usize,
    counter: &mut OpCounter,
    threads: usize,
    nm: NumericsMode,
) -> NeighborGraph {
    let k = centers.rows();
    let kn = kn.min(k);
    assert!(kn >= 1, "kn must be >= 1");
    let d = centers.cols();
    let threads = pool::resolve_threads(threads, k);

    let mut nbrs = vec![0u32; k * kn];
    let mut dists = vec![0.0f32; k * kn];

    if threads <= 1 {
        // Serial: the tile-vs-tile pairwise table, each pair computed
        // (and counted) once, then per-row selection.
        let mut table = vec![0.0f32; k * k];
        nm.pairwise_block(centers, &mut table, counter);
        for ((i, ni), nd) in
            nbrs.chunks_exact_mut(kn).enumerate().zip(dists.chunks_exact_mut(kn))
        {
            select_row(&table[i * k..(i + 1) * k], i, ni, nd);
            counter.count_sort(k, d);
        }
    } else {
        // Sharded (rows over [`pool::sharded_reduce`]): each row
        // recomputes its full distance row with the blocked kernel
        // instead of reading a shared symmetric table — bitwise
        // symmetric, so the output is identical to the serial path
        // while no write crosses a shard. Pairs are still counted once
        // ((k-1-i) per row), matching the serial accounting.
        let chunk = pool::chunk_len(k, threads);
        pool::sharded_reduce(
            nbrs.chunks_mut(chunk * kn).zip(dists.chunks_mut(chunk * kn)),
            counter,
            |si, (nbrs_chunk, dists_chunk): (&mut [u32], &mut [f32]), ctr| {
                let mut row = vec![0.0f32; k];
                for ((off, ni), nd) in nbrs_chunk
                    .chunks_exact_mut(kn)
                    .enumerate()
                    .zip(dists_chunk.chunks_exact_mut(kn))
                {
                    let i = si * chunk + off;
                    nm.sqdist_rows_raw(centers.row(i), centers, 0, &mut row);
                    ctr.distances += (k - 1 - i) as u64;
                    select_row(&row, i, ni, nd);
                    ctr.count_sort(k, d);
                }
            },
        );
    }

    NeighborGraph { k, kn, nbrs, dists }
}

/// Partial selection of the `ni.len()` smallest entries of one distance
/// row into the flat output slots (self has distance 0 and sorts first;
/// ties broken by index for determinism; self forced into slot 0 even
/// under exact-tie pathologies). Shared by the serial and sharded graph
/// builds so they cannot drift.
fn select_row(row: &[f32], i: usize, ni: &mut [u32], nd: &mut [f32]) {
    let kn = ni.len();
    let mut idx: Vec<u32> = (0..row.len() as u32).collect();
    idx.sort_unstable_by(|&a, &b| {
        row[a as usize]
            .partial_cmp(&row[b as usize])
            .unwrap()
            .then(a.cmp(&b))
    });
    ni.copy_from_slice(&idx[..kn]);
    if ni[0] != i as u32 {
        if let Some(pos) = ni.iter().position(|&v| v == i as u32) {
            ni.swap(0, pos);
        } else {
            ni[0] = i as u32;
        }
    }
    for (slot, &j) in ni.iter().enumerate() {
        nd[slot] = row[j as usize];
    }
}

/// Center kNN graph with its full `k × k` squared-distance table kept
/// resident, so the per-iteration rebuild can be **incremental**: after
/// an update step, only the pairs touching a *moved* center are
/// recomputed; every unmoved pair reuses its cached distance bitwise.
///
/// # Incremental-update contract
///
/// Let `M` be the set of centers whose rows changed **bitwise** since
/// the last [`update`] (callers derive it from the drift vector the
/// update step already computes: `drift[j] != 0.0`). Then
/// [`update`] with [`RefreshMode::Incremental`] guarantees:
///
/// 1. **Bitwise equality.** The resulting [`NeighborGraph`] is bitwise
///    identical (`nbrs` and `dists` flats) to a from-scratch
///    [`knn_graph_mode`] build over the same centers on the same
///    numerics tier, at any thread count. This holds because (a) every
///    tier's pair kernel is bitwise symmetric in its arguments and
///    bit-identical across the tile/row/scalar paths, so a recomputed
///    moved-pair entry equals what the full build would produce, (b) an
///    unmoved pair's cached entry is byte-for-byte what a recompute
///    over bitwise-identical rows would emit, and (c) row selection
///    ([`select_row`]) is a deterministic function of the table.
///    The only numerically-equal-but-bitwise-different drift is
///    `-0.0`; squaring annihilates the sign in every tier, so treating
///    a `±0.0`-only change as "unmoved" is sound.
/// 2. **Bill ordering.** With `m = |M|`, the incremental update bills
///    `C(k,2) - C(k-m,2)` distances (each pair with at least one moved
///    endpoint, once) versus the full build's `C(k,2)`; the
///    `C(k-m,2)` unmoved-pair reuses are logged to
///    [`OpCounter::refresh_saved`], off the bill, so
///    `distances + refresh_saved` always equals the full-refresh bill
///    for the same maintenance. When `m == 0` the graph is provably
///    unchanged, so selection (and its sort charge) is skipped too.
/// 3. **Invariance.** The moved-row recompute runs serially inside the
///    cache (the mirrored column writes would race under sharding) and
///    the moved set itself is thread-invariant, so the update is
///    bit-identical run-to-run and thread-to-thread.
///
/// With [`RefreshMode::Full`] the cache degenerates to a per-call full
/// rebuild with the historical bill — the parity baseline that
/// `K2M_REFRESH=full` pins in `tests/refresh.rs`.
#[derive(Clone, Debug)]
pub struct KnnGraphCache {
    kn: usize,
    mode: RefreshMode,
    /// Full symmetric `k * k` **squared**-distance table over the
    /// centers as of the last build/update (diagonal exactly `0.0`).
    table: Vec<f32>,
    graph: NeighborGraph,
}

impl KnnGraphCache {
    /// Full build: fills the `k × k` table and selects all rows, with
    /// exactly [`knn_graph_mode`]'s bill (`C(k,2)` distances + one
    /// per-row sort charge) and a bitwise-identical graph.
    pub fn new(
        centers: &Matrix,
        kn: usize,
        counter: &mut OpCounter,
        threads: usize,
        nm: NumericsMode,
        mode: RefreshMode,
    ) -> KnnGraphCache {
        let k = centers.rows();
        let kn = kn.min(k);
        assert!(kn >= 1, "kn must be >= 1");
        let mut cache = KnnGraphCache {
            kn,
            mode,
            table: vec![0.0f32; k * k],
            graph: NeighborGraph {
                k,
                kn,
                nbrs: vec![0u32; k * kn],
                dists: vec![0.0f32; k * kn],
            },
        };
        cache.rebuild(centers, counter, threads, nm);
        cache
    }

    /// The current graph — matches the centers passed to the most
    /// recent [`KnnGraphCache::new`] / [`KnnGraphCache::update`].
    pub fn graph(&self) -> &NeighborGraph {
        &self.graph
    }

    /// Consume the cache, donating its graph (the k²-means fallthrough
    /// arm hands this to `ClusterModel` so no post-hoc rebuild runs).
    pub fn into_graph(self) -> NeighborGraph {
        self.graph
    }

    /// Refresh the cache against `centers` after an update step.
    /// `moved[j]` must be true iff center `j`'s row changed bitwise
    /// since the previous build/update; `None` means "unknown — treat
    /// every center as moved". See the struct docs for the contract.
    pub fn update(
        &mut self,
        centers: &Matrix,
        moved: Option<&[bool]>,
        counter: &mut OpCounter,
        threads: usize,
        nm: NumericsMode,
    ) {
        let k = self.graph.k;
        debug_assert_eq!(centers.rows(), k);
        let moved = match (self.mode, moved) {
            (RefreshMode::Full, _) | (RefreshMode::Incremental, None) => {
                self.rebuild(centers, counter, threads, nm);
                return;
            }
            (RefreshMode::Incremental, Some(m)) => m,
        };
        debug_assert_eq!(moved.len(), k);
        let m = moved.iter().filter(|&&b| b).count();
        let unmoved_pairs = ((k - m) * (k - m).saturating_sub(1) / 2) as u64;
        if m == 0 {
            // Table and graph are provably unchanged — no distances, no
            // selection, no sort charge. The entire full-refresh bill
            // is savings.
            counter.refresh_saved += unmoved_pairs;
            return;
        }
        // Recompute each moved center's full distance row and mirror it
        // into the (unmoved) column entries. Serial on purpose: the
        // column writes scatter across rows, and k×d work on |M| rows
        // is cheap; thread-invariance comes for free.
        let mut row = vec![0.0f32; k];
        let mut prior_moved = 0u64;
        for j in 0..k {
            if !moved[j] {
                continue;
            }
            nm.sqdist_rows_raw(centers.row(j), centers, 0, &mut row);
            // Each pair with >= 1 moved endpoint is billed once: row j
            // charges its pairs against every center except itself and
            // the moved centers already charged (they billed pair
            // (i, j) when their own row was recomputed). Summed over M
            // this is exactly C(k,2) - C(k-m,2).
            counter.distances += (k as u64 - 1) - prior_moved;
            prior_moved += 1;
            row[j] = 0.0;
            self.table[j * k..(j + 1) * k].copy_from_slice(&row);
            for (i, &v) in row.iter().enumerate() {
                if i != j {
                    self.table[i * k + j] = v;
                }
            }
        }
        counter.refresh_saved += unmoved_pairs;
        // A moved center can enter or leave *any* row's neighbour list,
        // so every row re-selects (deterministic function of the table
        // — bitwise equal to a full build's selection).
        self.select_all(centers, counter);
    }

    /// Full table fill + selection with [`knn_graph_mode`]'s exact
    /// structure and bill: serial tile-vs-tile `pairwise_block`, or
    /// sharded per-row recompute above the thread threshold.
    fn rebuild(
        &mut self,
        centers: &Matrix,
        counter: &mut OpCounter,
        threads: usize,
        nm: NumericsMode,
    ) {
        let k = self.graph.k;
        debug_assert_eq!(centers.rows(), k);
        let threads = pool::resolve_threads(threads, k);
        if threads <= 1 {
            nm.pairwise_block(centers, &mut self.table, counter);
        } else {
            // Shard rows of the table; each shard recomputes its rows
            // with the blocked row kernel (bitwise symmetric, so the
            // table matches the serial tile fill bit-for-bit) and pairs
            // are still counted once ((k-1-i) per row).
            let chunk = pool::chunk_len(k, threads);
            pool::sharded_reduce(
                self.table.chunks_mut(chunk * k),
                counter,
                |si, table_chunk: &mut [f32], ctr| {
                    for (off, row) in table_chunk.chunks_exact_mut(k).enumerate() {
                        let i = si * chunk + off;
                        nm.sqdist_rows_raw(centers.row(i), centers, 0, row);
                        row[i] = 0.0;
                        ctr.distances += (k - 1 - i) as u64;
                    }
                },
            );
        }
        self.select_all(centers, counter);
    }

    /// Re-select every neighbour row from the resident table (one sort
    /// charge per row, matching the full build's accounting).
    fn select_all(&mut self, centers: &Matrix, counter: &mut OpCounter) {
        let k = self.graph.k;
        let kn = self.kn;
        let d = centers.cols();
        for ((i, ni), nd) in self
            .graph
            .nbrs
            .chunks_exact_mut(kn)
            .enumerate()
            .zip(self.graph.dists.chunks_exact_mut(kn))
        {
            select_row(&self.table[i * k..(i + 1) * k], i, ni, nd);
            counter.count_sort(k, d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ops;
    use crate::rng::Pcg32;

    fn random_centers(k: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let mut m = Matrix::zeros(k, d);
        for i in 0..k {
            for v in m.row_mut(i) {
                *v = rng.gaussian_f32();
            }
        }
        m
    }

    #[test]
    fn self_is_first_neighbor() {
        let c = random_centers(20, 6, 1);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 5, &mut ctr);
        for i in 0..g.k() {
            assert_eq!(g.nbrs_row(i)[0], i as u32);
            assert_eq!(g.dists_row(i)[0], 0.0);
        }
    }

    #[test]
    fn neighbors_are_true_nearest() {
        let c = random_centers(30, 4, 2);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 4, &mut ctr);
        for i in 0..30 {
            // Brute-force check.
            let mut all: Vec<(f32, u32)> = (0..30)
                .map(|j| (ops::sqdist_raw(c.row(i), c.row(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want: std::collections::HashSet<u32> =
                all[..4].iter().map(|&(_, j)| j).collect();
            let got: std::collections::HashSet<u32> =
                g.nbrs_row(i).iter().copied().collect();
            assert_eq!(got, want, "row {i}");
        }
    }

    #[test]
    fn distance_count_is_k_choose_2() {
        let c = random_centers(16, 3, 3);
        let mut ctr = OpCounter::default();
        let _ = knn_graph(&c, 3, &mut ctr);
        assert_eq!(ctr.distances, 16 * 15 / 2);
        // The pair accounting must not depend on the shard layout.
        for threads in [2usize, 5, 16] {
            let mut ctr = OpCounter::default();
            let _ = knn_graph_threaded(&c, 3, &mut ctr, threads);
            assert_eq!(ctr.distances, 16 * 15 / 2, "threads={threads}");
        }
    }

    #[test]
    fn kn_clamped_to_k() {
        let c = random_centers(3, 2, 4);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 10, &mut ctr);
        assert_eq!(g.kn(), 3);
    }

    #[test]
    fn dists_sorted_ascending_after_slot0() {
        let c = random_centers(25, 5, 5);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 6, &mut ctr);
        for l in 0..g.k() {
            let row = g.dists_row(l);
            for w in row.windows(2).skip(1) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn threaded_graph_identical_to_serial() {
        let c = random_centers(37, 8, 6);
        let mut c1 = OpCounter::default();
        let want = knn_graph(&c, 7, &mut c1);
        for threads in [2usize, 3, 8, 37, 64] {
            let mut c2 = OpCounter::default();
            let got = knn_graph_threaded(&c, 7, &mut c2, threads);
            assert_eq!(got.nbrs, want.nbrs, "threads={threads}");
            assert_eq!(got.dists, want.dists, "threads={threads}");
            assert_eq!(c1.distances, c2.distances);
        }
    }

    #[test]
    fn from_parts_round_trips_a_built_graph() {
        let c = random_centers(14, 4, 8);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 5, &mut ctr);
        let back = NeighborGraph::from_parts(
            g.k(),
            g.kn(),
            g.nbrs_flat().to_vec(),
            g.dists_flat().to_vec(),
        )
        .unwrap();
        assert_eq!(back.nbrs_flat(), g.nbrs_flat());
        assert_eq!(back.dists_flat(), g.dists_flat());
        assert_eq!((back.k(), back.kn()), (g.k(), g.kn()));
    }

    #[test]
    fn from_parts_rejects_malformed_graphs() {
        let c = random_centers(6, 3, 9);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 3, &mut ctr);
        let (ni, nd) = (g.nbrs_flat().to_vec(), g.dists_flat().to_vec());
        // Length mismatch.
        assert!(NeighborGraph::from_parts(6, 3, ni[1..].to_vec(), nd.clone()).is_err());
        // kn out of range.
        assert!(NeighborGraph::from_parts(6, 0, ni.clone(), nd.clone()).is_err());
        assert!(NeighborGraph::from_parts(6, 7, ni.clone(), nd.clone()).is_err());
        // Self not at slot 0.
        let mut bad = ni.clone();
        bad[0] = 1;
        assert!(NeighborGraph::from_parts(6, 3, bad, nd.clone()).is_err());
        // Neighbour index out of range.
        let mut bad = ni.clone();
        bad[1] = 99;
        assert!(NeighborGraph::from_parts(6, 3, bad, nd.clone()).is_err());
        // Unsorted row tail.
        let mut bad = nd.clone();
        bad[1] = bad[2] + 1.0;
        assert!(NeighborGraph::from_parts(6, 3, ni.clone(), bad).is_err());
        // Negative / non-finite distance.
        let mut bad = nd.clone();
        bad[2] = f32::NAN;
        assert!(NeighborGraph::from_parts(6, 3, ni, bad).is_err());
    }

    /// The cache's full build must be indistinguishable from
    /// [`knn_graph_mode`] — bitwise graph, identical bill — at every
    /// thread count.
    #[test]
    fn cache_full_build_matches_knn_graph_mode() {
        let c = random_centers(23, 6, 11);
        for threads in [1usize, 4, 7] {
            let mut c1 = OpCounter::default();
            let want = knn_graph_mode(&c, 5, &mut c1, threads, NumericsMode::Strict);
            let mut c2 = OpCounter::default();
            let cache = KnnGraphCache::new(
                &c,
                5,
                &mut c2,
                threads,
                NumericsMode::Strict,
                RefreshMode::Incremental,
            );
            assert_eq!(cache.graph().nbrs, want.nbrs, "threads={threads}");
            assert_eq!(cache.graph().dists, want.dists, "threads={threads}");
            assert_eq!(c1, c2, "threads={threads}");
        }
    }

    /// Drift patterns (no-move / single-move / all-move): the
    /// incremental update is bitwise equal to a fresh full build over
    /// the new centers, bills exactly `C(k,2) - C(k-m,2)` distances,
    /// and logs the `C(k-m,2)` reuses to `refresh_saved`.
    #[test]
    fn cache_incremental_update_bitwise_and_billed_per_moved_set() {
        let k = 19usize;
        let c0 = random_centers(k, 5, 12);
        let pairs = (k * (k - 1) / 2) as u64;
        for moved_idx in [vec![], vec![7usize], (0..k).collect::<Vec<_>>()] {
            let mut c1 = random_centers(k, 5, 13);
            // Perturb exactly the moved rows; keep the rest bitwise.
            for i in 0..k {
                if !moved_idx.contains(&i) {
                    c1.row_mut(i).copy_from_slice(c0.row(i));
                }
            }
            let moved: Vec<bool> = (0..k).map(|i| moved_idx.contains(&i)).collect();
            let m = moved_idx.len();
            let unmoved_pairs = ((k - m) * (k - m).saturating_sub(1) / 2) as u64;

            let mut cache = KnnGraphCache::new(
                &c0,
                4,
                &mut OpCounter::default(),
                1,
                NumericsMode::Strict,
                RefreshMode::Incremental,
            );
            let mut inc = OpCounter::default();
            cache.update(&c1, Some(&moved), &mut inc, 1, NumericsMode::Strict);

            let want = knn_graph(&c1, 4, &mut OpCounter::default());
            assert_eq!(cache.graph().nbrs, want.nbrs, "m={m}");
            assert_eq!(cache.graph().dists, want.dists, "m={m}");
            assert_eq!(inc.distances, pairs - unmoved_pairs, "m={m}");
            assert_eq!(inc.refresh_saved, unmoved_pairs, "m={m}");
            // distances + refresh_saved always reconstructs the full
            // bill, and the no-move case skips the sort charge too.
            assert_eq!(inc.distances + inc.refresh_saved, pairs);
            if m == 0 {
                assert_eq!(inc.sort_scaled, 0.0);
            }
        }
    }

    /// Full mode ignores the moved set: every update pays the complete
    /// historical bill and saves nothing.
    #[test]
    fn cache_full_mode_rebuilds_with_full_bill() {
        let k = 15usize;
        let c0 = random_centers(k, 4, 14);
        let c1 = random_centers(k, 4, 15);
        let mut cache = KnnGraphCache::new(
            &c0,
            3,
            &mut OpCounter::default(),
            1,
            NumericsMode::Strict,
            RefreshMode::Full,
        );
        let mut ctr = OpCounter::default();
        let moved = vec![false; k]; // lies: everything actually moved
        cache.update(&c1, Some(&moved), &mut ctr, 1, NumericsMode::Strict);
        let want = knn_graph(&c1, 3, &mut OpCounter::default());
        assert_eq!(cache.graph().nbrs, want.nbrs);
        assert_eq!(cache.graph().dists, want.dists);
        assert_eq!(ctr.distances, (k * (k - 1) / 2) as u64);
        assert_eq!(ctr.refresh_saved, 0);
    }

    /// Regression guard for the distance-convention boundary: the graph
    /// stores **squared** distances; plain distances only exist via
    /// [`NeighborGraph::plain_dist`]. If a refactor made `dists` plain,
    /// the squared/plain comparison below would flip and this fails.
    #[test]
    fn dists_are_squared_not_plain() {
        let c = random_centers(12, 5, 7);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 4, &mut ctr);
        for l in 0..12 {
            for (t, &j) in g.nbrs_row(l).iter().enumerate() {
                let sq = ops::sqdist_raw(c.row(l), c.row(j as usize));
                let plain = ops::dist_raw(c.row(l), c.row(j as usize));
                assert!(
                    (g.dists_row(l)[t] - sq).abs() <= 1e-5 * (1.0 + sq),
                    "dists_row({l})[{t}] is not the squared distance"
                );
                assert!(
                    (g.plain_dist(l, t) - plain).abs() <= 1e-5 * (1.0 + plain),
                    "plain_dist({l}, {t}) is not the plain distance"
                );
                // The two conventions genuinely differ away from 0/1, so
                // the assertions above cannot both pass on mixed-up data.
                if sq > 1.5 {
                    assert!(g.dists_row(l)[t] > g.plain_dist(l, t));
                }
            }
        }
    }
}
