//! Exact brute-force kNN over the center table.
//!
//! k²-means rebuilds this graph every iteration: `k²` counted distances
//! plus a per-row partial sort counted under the paper's sort convention.
//! Neighbour lists always start with the center itself (distance 0),
//! matching the paper's `N_kn(c_l)` which includes `c_l`.

use crate::core::{ops, Matrix, OpCounter};

/// kn-nearest-neighbour graph over a set of centers.
#[derive(Clone, Debug)]
pub struct NeighborGraph {
    /// `k x kn` neighbour indices; row `l` = `N_kn(c_l)`, `nbrs[l][0] == l`.
    pub nbrs: Vec<Vec<u32>>,
    /// Squared distances aligned with `nbrs`.
    pub dists: Vec<Vec<f32>>,
}

impl NeighborGraph {
    pub fn k(&self) -> usize {
        self.nbrs.len()
    }
    pub fn kn(&self) -> usize {
        self.nbrs.first().map_or(0, |r| r.len())
    }
}

/// Build the exact kn-NN graph of `centers` (self included as slot 0).
///
/// Counts `k*(k-1)/2` distances (symmetric pairs computed once) plus the
/// per-row selection counted as a sort over k items.
pub fn knn_graph(centers: &Matrix, kn: usize, counter: &mut OpCounter) -> NeighborGraph {
    let k = centers.rows();
    let kn = kn.min(k);
    assert!(kn >= 1, "kn must be >= 1");
    let d = centers.cols();

    // Symmetric pairwise distances, each pair counted once.
    let mut dist = vec![0.0f32; k * k];
    for i in 0..k {
        for j in (i + 1)..k {
            let v = ops::sqdist(centers.row(i), centers.row(j), counter);
            dist[i * k + j] = v;
            dist[j * k + i] = v;
        }
    }

    let mut nbrs = Vec::with_capacity(k);
    let mut dists = Vec::with_capacity(k);
    let mut idx: Vec<u32> = (0..k as u32).collect();
    for i in 0..k {
        let row = &dist[i * k..(i + 1) * k];
        // Partial selection of the kn smallest (self has distance 0 and
        // sorts first; ties broken by index for determinism).
        idx.sort_unstable_by(|&a, &b| {
            row[a as usize]
                .partial_cmp(&row[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        counter.count_sort(k, d);
        let mut ni: Vec<u32> = idx[..kn].to_vec();
        // Guarantee self is slot 0 even under exact-tie pathologies.
        if ni[0] != i as u32 {
            if let Some(pos) = ni.iter().position(|&v| v == i as u32) {
                ni.swap(0, pos);
            } else {
                ni[0] = i as u32;
            }
        }
        let nd: Vec<f32> = ni.iter().map(|&j| row[j as usize]).collect();
        nbrs.push(ni);
        dists.push(nd);
    }
    NeighborGraph { nbrs, dists }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn random_centers(k: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Pcg32::seeded(seed);
        let mut m = Matrix::zeros(k, d);
        for i in 0..k {
            for v in m.row_mut(i) {
                *v = rng.gaussian_f32();
            }
        }
        m
    }

    #[test]
    fn self_is_first_neighbor() {
        let c = random_centers(20, 6, 1);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 5, &mut ctr);
        for (i, row) in g.nbrs.iter().enumerate() {
            assert_eq!(row[0], i as u32);
            assert_eq!(g.dists[i][0], 0.0);
        }
    }

    #[test]
    fn neighbors_are_true_nearest() {
        let c = random_centers(30, 4, 2);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 4, &mut ctr);
        for i in 0..30 {
            // Brute-force check.
            let mut all: Vec<(f32, u32)> = (0..30)
                .map(|j| (ops::sqdist_raw(c.row(i), c.row(j)), j as u32))
                .collect();
            all.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want: std::collections::HashSet<u32> =
                all[..4].iter().map(|&(_, j)| j).collect();
            let got: std::collections::HashSet<u32> = g.nbrs[i].iter().copied().collect();
            assert_eq!(got, want, "row {i}");
        }
    }

    #[test]
    fn distance_count_is_k_choose_2() {
        let c = random_centers(16, 3, 3);
        let mut ctr = OpCounter::default();
        let _ = knn_graph(&c, 3, &mut ctr);
        assert_eq!(ctr.distances, 16 * 15 / 2);
    }

    #[test]
    fn kn_clamped_to_k() {
        let c = random_centers(3, 2, 4);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 10, &mut ctr);
        assert_eq!(g.kn(), 3);
    }

    #[test]
    fn dists_sorted_ascending_after_slot0() {
        let c = random_centers(25, 5, 5);
        let mut ctr = OpCounter::default();
        let g = knn_graph(&c, 6, &mut ctr);
        for row in &g.dists {
            for w in row.windows(2).skip(1) {
                assert!(w[0] <= w[1]);
            }
        }
    }
}
