//! Nearest-neighbour substrates.
//!
//! * [`brute`] — exact kNN over the center table; builds the kn-NN center
//!   graph of k²-means (paper Alg. 1 line 6, `O(k²d)` counted distances).
//! * [`kdtree`] — kd-tree with best-bin-first bounded search; the
//!   approximate search structure AKM (Philbin et al.) uses for its
//!   assignment step.

pub mod brute;
pub mod kdtree;

pub use brute::{knn_graph, knn_graph_mode, knn_graph_threaded, KnnGraphCache, NeighborGraph};
pub use kdtree::KdTree;
