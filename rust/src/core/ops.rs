//! Counted scalar vector operations — the reference primitives the
//! blocked kernel layer ([`super::kernels`]) is defined against.
//!
//! The `*_raw` functions are the uncounted primitives (also used for
//! measurement-only work like energy traces); the plain names are the
//! counted scalar entry points. Algorithm hot paths scan candidates
//! through [`super::kernels`] (bit-identical per-pair arithmetic, better
//! locality); the scalar calls survive here as the reference, inside
//! kd-tree descent, and in tests. The squared-distance inner loop is the
//! whole system's hot path (the paper observes >95% of runtime is
//! distance computations) — it is written with four independent
//! accumulators so LLVM vectorizes it to wide FMA lanes; see
//! EXPERIMENTS.md §Perf for the measured effect.

use super::OpCounter;

/// Squared euclidean distance, uncounted.
///
/// `chunks_exact(8)` elides bounds checks and the four independent
/// accumulators break the add-reduce dependency chain, so LLVM emits
/// packed FMA lanes (see EXPERIMENTS.md §Perf for before/after).
#[inline]
pub fn sqdist_raw(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        let d0 = x[0] - y[0];
        let d1 = x[1] - y[1];
        let d2 = x[2] - y[2];
        let d3 = x[3] - y[3];
        let d4 = x[4] - y[4];
        let d5 = x[5] - y[5];
        let d6 = x[6] - y[6];
        let d7 = x[7] - y[7];
        s0 += d0 * d0 + d4 * d4;
        s1 += d1 * d1 + d5 * d5;
        s2 += d2 * d2 + d6 * d6;
        s3 += d3 * d3 + d7 * d7;
    }
    let mut s = s0 + s1 + s2 + s3;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Squared euclidean distance — counted as one "distance computation".
#[inline]
pub fn sqdist(a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
    c.distances += 1;
    sqdist_raw(a, b)
}

/// Inner product, uncounted (same vectorization strategy as
/// [`sqdist_raw`]).
#[inline]
pub fn dot_raw(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for (x, y) in (&mut ca).zip(&mut cb) {
        s0 += x[0] * y[0] + x[4] * y[4];
        s1 += x[1] * y[1] + x[5] * y[5];
        s2 += x[2] * y[2] + x[6] * y[6];
        s3 += x[3] * y[3] + x[7] * y[7];
    }
    let mut s = s0 + s1 + s2 + s3;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

/// Inner product — counted as one vector op.
#[inline]
pub fn dot(a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
    c.inner_products += 1;
    dot_raw(a, b)
}

/// `acc += x`, uncounted.
#[inline]
pub fn add_assign_raw(acc: &mut [f32], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x.iter()) {
        *a += b;
    }
}

/// `acc += x` — counted as one vector addition.
#[inline]
pub fn add_assign(acc: &mut [f32], x: &[f32], c: &mut OpCounter) {
    c.additions += 1;
    add_assign_raw(acc, x);
}

/// `acc -= x`, counted (used by incremental mean maintenance).
#[inline]
pub fn sub_assign(acc: &mut [f32], x: &[f32], c: &mut OpCounter) {
    c.additions += 1;
    debug_assert_eq!(acc.len(), x.len());
    for (a, &b) in acc.iter_mut().zip(x.iter()) {
        *a -= b;
    }
}

/// In-place scale.
#[inline]
pub fn scale(v: &mut [f32], s: f32) {
    for a in v.iter_mut() {
        *a *= s;
    }
}

/// Squared l2 norm, uncounted.
#[inline]
pub fn norm2_raw(a: &[f32]) -> f32 {
    dot_raw(a, a)
}

/// Euclidean distance (not squared), uncounted — for Elkan's bound
/// arithmetic which works in plain distances.
#[inline]
pub fn dist_raw(a: &[f32], b: &[f32]) -> f32 {
    sqdist_raw(a, b).sqrt()
}

/// Euclidean distance, counted.
#[inline]
pub fn dist(a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
    c.distances += 1;
    dist_raw(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_sqdist(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }

    #[test]
    fn sqdist_matches_naive_all_lengths() {
        // Cover remainder paths: lengths 0..40 cross the 8-wide boundary.
        for n in 0..40 {
            let a: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
            let b: Vec<f32> = (0..n).map(|i| (i as f32 * 1.3).cos()).collect();
            let got = sqdist_raw(&a, &b);
            let want = naive_sqdist(&a, &b);
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "n={n}");
        }
    }

    #[test]
    fn dot_matches_naive() {
        for n in [0, 1, 7, 8, 9, 31, 64, 100] {
            let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.01).collect();
            let b: Vec<f32> = (0..n).map(|i| 1.0 - i as f32 * 0.02).collect();
            let want: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot_raw(&a, &b) - want).abs() <= 1e-4 * (1.0 + want.abs()));
        }
    }

    #[test]
    fn counted_ops_tally() {
        let mut c = OpCounter::default();
        let a = [1.0f32, 2.0];
        let b = [0.0f32, 1.0];
        let _ = sqdist(&a, &b, &mut c);
        let _ = dot(&a, &b, &mut c);
        let mut acc = [0.0f32, 0.0];
        add_assign(&mut acc, &a, &mut c);
        sub_assign(&mut acc, &b, &mut c);
        let _ = dist(&a, &b, &mut c);
        assert_eq!(c.distances, 2);
        assert_eq!(c.inner_products, 1);
        assert_eq!(c.additions, 2);
    }

    #[test]
    fn dist_is_sqrt_of_sqdist() {
        let a = [3.0f32, 0.0];
        let b = [0.0f32, 4.0];
        assert!((dist_raw(&a, &b) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut c = OpCounter::default();
        let mut acc = [1.0f32, 2.0, 3.0];
        let x = [0.5f32, -1.0, 2.0];
        add_assign(&mut acc, &x, &mut c);
        sub_assign(&mut acc, &x, &mut c);
        assert_eq!(acc, [1.0, 2.0, 3.0]);
    }
}
