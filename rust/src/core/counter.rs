//! The paper's evaluation currency: counted vector operations.
//!
//! Paper §3: *"we use the number of vector operations as a measure of
//! complexity, i.e. distances, inner products and additions ... for
//! simplicity we count all vector operations equally and refer to them as
//! 'distance computations'"*, and §2.2: the `O(|Xj| log |Xj|)` sort inside
//! Projective Split is *"artificially counted as `|Xj| log2(|Xj|)/d`
//! vector operations"*.
//!
//! Every algorithm in [`crate::cluster`] and [`crate::init`] threads a
//! `&mut OpCounter` through the counted entry points in
//! [`crate::core::ops`]; measurement-only work (energy traces for the
//! figures) uses the uncounted `*_raw` variants.

/// Running tally of the paper's "distance computations".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpCounter {
    /// Full point-to-point / point-to-center distance evaluations.
    pub distances: u64,
    /// Inner products (projections in Projective Split).
    pub inner_products: u64,
    /// Vector additions (mean accumulation in update steps / GDI).
    pub additions: u64,
    /// Scaled comparison work from sorting: `|Xj| * log2(|Xj|) / d` per
    /// sort call (paper §2.2). Fractional, so kept as f64.
    pub sort_scaled: f64,
}

impl OpCounter {
    /// Total vector operations under the paper's equal-weight convention.
    pub fn total(&self) -> f64 {
        self.distances as f64
            + self.inner_products as f64
            + self.additions as f64
            + self.sort_scaled
    }

    /// Record a sort over `n` items in a `d`-dimensional context
    /// (counted as `n*log2(n)/d` vector ops, paper §2.2).
    pub fn count_sort(&mut self, n: usize, d: usize) {
        if n > 1 {
            self.sort_scaled += (n as f64) * (n as f64).log2() / (d as f64).max(1.0);
        }
    }

    /// Fold another counter into this one (used when joining parallel
    /// sub-runs or accumulating init + iteration phases).
    pub fn merge(&mut self, other: &OpCounter) {
        self.distances += other.distances;
        self.inner_products += other.inner_products;
        self.additions += other.additions;
        self.sort_scaled += other.sort_scaled;
    }

    /// Snapshot of `total()` — convenient for per-iteration trace points.
    pub fn mark(&self) -> f64 {
        self.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_sums_all_categories() {
        let c = OpCounter { distances: 3, inner_products: 2, additions: 1, sort_scaled: 0.5 };
        assert!((c.total() - 6.5).abs() < 1e-12);
    }

    #[test]
    fn sort_cost_matches_paper_formula() {
        let mut c = OpCounter::default();
        c.count_sort(1024, 64);
        // 1024 * log2(1024) / 64 = 1024*10/64 = 160
        assert!((c.sort_scaled - 160.0).abs() < 1e-9);
    }

    #[test]
    fn sort_of_one_item_free() {
        let mut c = OpCounter::default();
        c.count_sort(1, 10);
        c.count_sort(0, 10);
        assert_eq!(c.sort_scaled, 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = OpCounter { distances: 1, ..Default::default() };
        let b = OpCounter { distances: 2, additions: 3, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.distances, 3);
        assert_eq!(a.additions, 3);
    }
}
