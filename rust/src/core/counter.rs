//! The paper's evaluation currency: counted vector operations.
//!
//! Paper §3: *"we use the number of vector operations as a measure of
//! complexity, i.e. distances, inner products and additions ... for
//! simplicity we count all vector operations equally and refer to them as
//! 'distance computations'"*, and §2.2: the `O(|Xj| log |Xj|)` sort inside
//! Projective Split is *"artificially counted as `|Xj| log2(|Xj|)/d`
//! vector operations"*.
//!
//! Every algorithm in [`crate::cluster`] and [`crate::init`] threads a
//! `&mut OpCounter` through the counted entry points in
//! [`crate::core::ops`]; measurement-only work (energy traces for the
//! figures) uses the uncounted `*_raw` variants.

/// Running tally of the paper's "distance computations".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpCounter {
    /// Full point-to-point / point-to-center distance evaluations.
    pub distances: u64,
    /// Inner products (projections in Projective Split).
    pub inner_products: u64,
    /// Vector additions (mean accumulation in update steps / GDI).
    pub additions: u64,
    /// Scaled comparison work from sorting: `|Xj| * log2(|Xj|) / d` per
    /// sort call (paper §2.2). Fractional, so kept as f64.
    pub sort_scaled: f64,
    /// Quantized-tier estimated scores: one per (query, candidate) pair
    /// scored with the 1-bit popcount estimator
    /// ([`crate::core::kernels::quant`]). **Excluded from [`total`]** —
    /// an estimate is a prune decision, not one of the paper's vector
    /// operations, and keeping it off the bill keeps op counts
    /// comparable across numerics tiers (a Quantized run's `distances`
    /// can then be read directly against a Strict run's).
    ///
    /// [`total`]: OpCounter::total
    pub estimates: u64,
    /// Rows packed into 1-bit quantized codes (points, centers after an
    /// update, serve-time queries). **Excluded from [`total`]** for the
    /// same reason as [`estimates`] — packing is O(d) bookkeeping, not a
    /// counted distance computation.
    ///
    /// [`total`]: OpCounter::total
    /// [`estimates`]: OpCounter::estimates
    pub packs: u64,
    /// Distance evaluations *avoided* by the incremental moved-set
    /// refresh layer (`RefreshMode::Incremental`): pairs of bitwise
    /// stationary centers whose cached distances were reused instead of
    /// recomputed (center kNN graph, Elkan's cc table, Hamerly's
    /// s-table). **Excluded from [`total`]** — it is an audit trail of
    /// savings, not work performed; `distances + refresh_saved` of an
    /// incremental run equals the `distances` a full refresh would have
    /// billed for the same center-state maintenance.
    ///
    /// [`total`]: OpCounter::total
    pub refresh_saved: u64,
    /// Exact distance evaluations the batched scan mode
    /// (`ScanMode::Batched`) performed that the sequential gated loop
    /// would have skipped: candidates admitted into a tile under a
    /// not-yet-tightened upper bound that the per-candidate replay then
    /// pruned. At most `TILE − 1` per scan by construction (tile
    /// capacity drops to one after the first tile that produces an
    /// extra). **Excluded from [`total`]** — an audit trail keeping the
    /// paper-faithful sequential bill reconstructible:
    /// `distances − batch_extra ≤` the gated run's `distances`.
    ///
    /// [`total`]: OpCounter::total
    pub batch_extra: u64,
}

impl OpCounter {
    /// Total vector operations under the paper's equal-weight convention.
    /// [`estimates`] and [`packs`] are deliberately **not** included —
    /// see their field docs.
    ///
    /// [`estimates`]: OpCounter::estimates
    /// [`packs`]: OpCounter::packs
    pub fn total(&self) -> f64 {
        self.distances as f64
            + self.inner_products as f64
            + self.additions as f64
            + self.sort_scaled
    }

    /// Record a sort over `n` items in a `d`-dimensional context
    /// (counted as `n*log2(n)/d` vector ops, paper §2.2).
    pub fn count_sort(&mut self, n: usize, d: usize) {
        if n > 1 {
            self.sort_scaled += (n as f64) * (n as f64).log2() / (d as f64).max(1.0);
        }
    }

    /// Fold another counter into this one (used when joining parallel
    /// sub-runs or accumulating init + iteration phases).
    ///
    /// The integer fields are exact, so any merge order yields the same
    /// tallies; `sort_scaled` is an `f64` sum, so the sharded engine
    /// always merges **in fixed shard order** (see [`merge_shards`])
    /// to keep repeated runs bit-identical.
    ///
    /// [`merge_shards`]: OpCounter::merge_shards
    pub fn merge(&mut self, other: &OpCounter) {
        self.distances += other.distances;
        self.inner_products += other.inner_products;
        self.additions += other.additions;
        self.sort_scaled += other.sort_scaled;
        self.estimates += other.estimates;
        self.packs += other.packs;
        self.refresh_saved += other.refresh_saved;
        self.batch_extra += other.batch_extra;
    }

    /// Fold per-shard counters into this one **in shard order** — the
    /// join step of the sharded execution engine. Each shard counts its
    /// own ops without touching shared state (no `&mut` serialization
    /// through the inner loops); the deterministic left-to-right fold
    /// here makes the combined counter reproducible run to run.
    pub fn merge_shards<I: IntoIterator<Item = OpCounter>>(&mut self, shards: I) {
        for shard in shards {
            self.merge(&shard);
        }
    }

    /// Snapshot of `total()` — convenient for per-iteration trace points.
    pub fn mark(&self) -> f64 {
        self.total()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_sums_all_categories() {
        // estimates/packs/refresh_saved/batch_extra are deliberately off
        // the bill: huge values here must not move total().
        let c = OpCounter {
            distances: 3,
            inner_products: 2,
            additions: 1,
            sort_scaled: 0.5,
            estimates: 1 << 40,
            packs: 1 << 40,
            refresh_saved: 1 << 40,
            batch_extra: 1 << 40,
        };
        assert!((c.total() - 6.5).abs() < 1e-12);
    }

    #[test]
    fn estimates_and_packs_merge_but_stay_off_the_bill() {
        let mut a = OpCounter {
            estimates: 5,
            packs: 2,
            refresh_saved: 9,
            batch_extra: 3,
            ..Default::default()
        };
        let b = OpCounter {
            estimates: 7,
            packs: 1,
            refresh_saved: 4,
            batch_extra: 2,
            distances: 4,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.estimates, 12);
        assert_eq!(a.packs, 3);
        assert_eq!(a.refresh_saved, 13);
        assert_eq!(a.batch_extra, 5);
        assert_eq!(a.total(), 4.0);
    }

    #[test]
    fn sort_cost_matches_paper_formula() {
        let mut c = OpCounter::default();
        c.count_sort(1024, 64);
        // 1024 * log2(1024) / 64 = 1024*10/64 = 160
        assert!((c.sort_scaled - 160.0).abs() < 1e-9);
    }

    #[test]
    fn sort_of_one_item_free() {
        let mut c = OpCounter::default();
        c.count_sort(1, 10);
        c.count_sort(0, 10);
        assert_eq!(c.sort_scaled, 0.0);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = OpCounter { distances: 1, ..Default::default() };
        let b = OpCounter { distances: 2, additions: 3, ..Default::default() };
        a.merge(&b);
        assert_eq!(a.distances, 3);
        assert_eq!(a.additions, 3);
    }

    #[test]
    fn merge_identity() {
        let mut a = OpCounter {
            distances: 5,
            inner_products: 2,
            additions: 7,
            sort_scaled: 1.25,
            estimates: 3,
            packs: 1,
            refresh_saved: 2,
            batch_extra: 4,
        };
        let before = a.clone();
        a.merge(&OpCounter::default());
        assert_eq!(a, before);
        let mut zero = OpCounter::default();
        zero.merge(&before);
        assert_eq!(zero, before);
    }

    #[test]
    fn merge_associative() {
        // sort_scaled values are dyadic rationals so the f64 sums are
        // exact and the associativity check is meaningful.
        let a = OpCounter {
            distances: 1,
            inner_products: 2,
            additions: 3,
            sort_scaled: 0.5,
            estimates: 4,
            packs: 1,
            refresh_saved: 6,
            batch_extra: 2,
        };
        let b = OpCounter {
            distances: 10,
            inner_products: 0,
            additions: 4,
            sort_scaled: 0.25,
            estimates: 0,
            packs: 2,
            refresh_saved: 0,
            batch_extra: 1,
        };
        let c = OpCounter {
            distances: 7,
            inner_products: 9,
            additions: 0,
            sort_scaled: 2.0,
            estimates: 6,
            packs: 0,
            refresh_saved: 3,
            batch_extra: 0,
        };
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
    }

    #[test]
    fn merge_shards_folds_in_order() {
        let shards = vec![
            OpCounter { distances: 1, ..Default::default() },
            OpCounter { additions: 2, sort_scaled: 0.5, ..Default::default() },
            OpCounter { inner_products: 3, ..Default::default() },
        ];
        let mut total = OpCounter::default();
        total.merge_shards(shards.clone());
        assert_eq!(total.distances, 1);
        assert_eq!(total.additions, 2);
        assert_eq!(total.inner_products, 3);
        assert_eq!(total.sort_scaled, 0.5);
        // Same shards, same order => bit-identical result.
        let mut again = OpCounter::default();
        again.merge_shards(shards);
        assert_eq!(total, again);
    }
}
