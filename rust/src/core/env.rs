//! One home for the process-wide `K2M_*` environment knobs.
//!
//! Every runtime knob in this crate follows the same policy, historically
//! copy-pasted at each site (`K2M_THREADS`, `K2M_NUMERICS`, `K2M_REFRESH`,
//! `K2M_SCAN`, `K2M_SHARD_MIN`, and now the chunked-store and big-means
//! knobs):
//!
//! * **Read once per process** and cached in a `OnceLock` — the first
//!   read wins for the process lifetime, keeping `std::env` out of hot
//!   paths and making mid-run `set_var` games impossible by construction.
//! * **Trim, then parse.** Shell quoting artifacts (`"7 "`) must not
//!   silently disable a knob.
//! * **Unset or unparsable falls back to the default** — a typo'd value
//!   degrades to stock behavior instead of aborting a long run. (CLI
//!   flags are the opposite — typos fail loudly there; see
//!   `main::parse_numerics` — because a flag is always deliberate.)
//!
//! [`parse_knob`] is that policy as a pure function (unit-tested below
//! without touching process env); [`knob`] adds the `OnceLock` cache and
//! the actual `std::env` read. Call sites keep their own `static` cache
//! cell so each variable still resolves independently.

use std::sync::OnceLock;

/// The parse policy shared by every `K2M_*` knob, as a pure function:
/// trim the raw value, run the knob's parser, fall back to the default
/// when the variable is unset or the parser rejects it.
pub fn parse_knob<T>(
    raw: Option<&str>,
    parse: impl Fn(&str) -> Option<T>,
    default: impl FnOnce() -> T,
) -> T {
    raw.and_then(|s| parse(s.trim())).unwrap_or_else(default)
}

/// Resolve `var` through [`parse_knob`], caching the result in `cache`
/// so the variable is read **once per process** — the shared contract of
/// every `K2M_*` knob. The caller owns the `static` cell, so distinct
/// knobs cannot collide:
///
/// ```
/// use std::sync::OnceLock;
/// use k2m::core::env;
///
/// static DEMO: OnceLock<usize> = OnceLock::new();
/// let v = env::knob(&DEMO, "K2M_DOC_DEMO", |s| s.parse().ok(), || 42);
/// assert_eq!(v, 42); // unset in the test environment -> default
/// ```
pub fn knob<T: Copy + Send + Sync + 'static>(
    cache: &'static OnceLock<T>,
    var: &str,
    parse: impl Fn(&str) -> Option<T>,
    default: impl FnOnce() -> T,
) -> T {
    *cache.get_or_init(|| parse_knob(std::env::var(var).ok().as_deref(), parse, default))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_falls_back_to_default() {
        assert_eq!(parse_knob(None, |s: &str| s.parse::<usize>().ok(), || 9), 9);
    }

    #[test]
    fn valid_values_parse() {
        assert_eq!(parse_knob(Some("7"), |s| s.parse::<usize>().ok(), || 9), 7);
    }

    #[test]
    fn values_are_trimmed_before_parsing() {
        // Shell artifacts like `K2M_THREADS="7 "` must not disable the knob.
        assert_eq!(parse_knob(Some(" 7\n"), |s| s.parse::<usize>().ok(), || 9), 7);
    }

    #[test]
    fn garbage_falls_back_to_default() {
        assert_eq!(parse_knob(Some("seven"), |s| s.parse::<usize>().ok(), || 9), 9);
        assert_eq!(parse_knob(Some(""), |s| s.parse::<usize>().ok(), || 9), 9);
    }

    #[test]
    fn parser_level_clamps_apply() {
        // Knobs that clamp (e.g. K2M_SHARD_MIN's `.max(1)`) do so inside
        // their parser, after the trim.
        let parse = |s: &str| s.parse::<usize>().ok().map(|n| n.max(1));
        assert_eq!(parse_knob(Some("0"), parse, || 5), 1);
    }

    #[test]
    fn knob_caches_first_resolution() {
        static CACHE: OnceLock<usize> = OnceLock::new();
        // Variable is unset: the default is cached...
        assert_eq!(knob(&CACHE, "K2M_TEST_NOT_SET_EVER", |s| s.parse().ok(), || 3), 3);
        // ...and later calls return the cached value without re-reading.
        assert_eq!(knob(&CACHE, "K2M_TEST_NOT_SET_EVER", |s| s.parse().ok(), || 4), 3);
    }
}
