//! Dense row-major `f32` matrix — the storage type for datasets and
//! center tables. Deliberately minimal: the clustering algorithms only
//! need row views, and keeping the representation flat lets the hot
//! distance loop vectorize.

/// Dense row-major matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f32>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// A `rows x cols` matrix of zeros.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Build from a flat row-major buffer. Panics if the length mismatches.
    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length != rows*cols");
        Matrix { data, rows, cols }
    }

    /// Build by copying a set of rows (e.g. seed centers from data points).
    pub fn from_rows(rows: &[&[f32]]) -> Self {
        assert!(!rows.is_empty(), "from_rows needs at least one row");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "ragged rows");
            data.extend_from_slice(r);
        }
        Matrix { data, rows: rows.len(), cols }
    }

    /// Gather rows of `src` by index into a new matrix.
    pub fn gather(src: &Matrix, idx: &[usize]) -> Self {
        let mut m = Matrix::zeros(idx.len(), src.cols);
        for (out_i, &src_i) in idx.iter().enumerate() {
            m.row_mut(out_i).copy_from_slice(src.row(src_i));
        }
        m
    }

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` as a slice.
    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// The flat row-major buffer.
    #[inline(always)]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat buffer (used by the runtime's padding layer).
    #[inline(always)]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Iterate over rows.
    pub fn iter_rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_content() {
        let m = Matrix::zeros(3, 4);
        assert_eq!(m.rows(), 3);
        assert_eq!(m.cols(), 4);
        assert!(m.as_slice().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn row_views() {
        let m = Matrix::from_vec(vec![1., 2., 3., 4., 5., 6.], 2, 3);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn from_rows_copies() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let m = Matrix::from_rows(&[&a, &b]);
        assert_eq!(m.rows(), 2);
        assert_eq!(m.row(1), &[3., 4.]);
    }

    #[test]
    fn gather_rows() {
        let m = Matrix::from_vec((0..12).map(|v| v as f32).collect(), 4, 3);
        let g = Matrix::gather(&m, &[2, 0]);
        assert_eq!(g.row(0), &[6., 7., 8.]);
        assert_eq!(g.row(1), &[0., 1., 2.]);
    }

    #[test]
    #[should_panic(expected = "buffer length")]
    fn from_vec_length_checked() {
        let _ = Matrix::from_vec(vec![0.0; 5], 2, 3);
    }

    #[test]
    fn row_mut_writes_through() {
        let mut m = Matrix::zeros(2, 2);
        m.row_mut(1)[0] = 7.0;
        assert_eq!(m.as_slice(), &[0., 0., 7., 0.]);
    }
}
