//! Core math substrate: row-major matrices, counted vector operations,
//! and the blocked distance-kernel layer.
//!
//! Everything the clustering algorithms touch goes through this module so
//! that the paper's evaluation metric — *counted vector operations* — is
//! enforced in exactly one place (see [`OpCounter`]). The scalar
//! primitives live in [`ops`]; every algorithm hot path scans candidates
//! through the blocked kernels in [`kernels`] (bit-identical results,
//! identical op counts, better locality), on one of two numerics tiers
//! selected by [`NumericsMode`] (Strict — bit-identical, the default —
//! or Fast — lane-striped, deterministic, same op counts).

mod counter;
mod matrix;
pub mod env;
pub mod kernels;
pub mod ops;

pub use counter::OpCounter;
pub use kernels::{NumericsMode, RefreshMode, ScanMode};
pub use matrix::Matrix;
