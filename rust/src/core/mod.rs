//! Core math substrate: row-major matrices, counted vector operations.
//!
//! Everything the clustering algorithms touch goes through this module so
//! that the paper's evaluation metric — *counted vector operations* — is
//! enforced in exactly one place (see [`OpCounter`]).

mod counter;
mod matrix;
pub mod ops;

pub use counter::OpCounter;
pub use matrix::Matrix;
