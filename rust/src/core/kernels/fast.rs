//! The **fast-numerics tier**: lane-striped distance kernels selected by
//! [`NumericsMode::Fast`](super::NumericsMode).
//!
//! The strict kernels in the parent module are pinned to
//! [`ops::sqdist_raw`]'s accumulation order (four paired accumulators,
//! `s0+s1+s2+s3`) so that every blocked scan stays bit-identical to the
//! historical scalar loops. That pairing — `s[l] += d_l·d_l + d_{l+4}·
//! d_{l+4}` — chains two FMAs per 8-dim chunk into each accumulator, so
//! LLVM lowers it to 4-wide vectors with a 2-FMA dependency chain per
//! chunk. This module trades the bit pin for throughput: each pair
//! accumulates across [`LANES`]` = 8` **fixed dimension lanes**
//! (`s[l] += d_l·d_l`, one `[f32; 8]` array accumulator = one 8-wide
//! register, a single FMA per chunk), the lanes are reduced in a fixed
//! pairwise tree (`lane_sum`), and a tail loop handles `d % LANES` in
//! order. Stable Rust only — array accumulators that LLVM
//! autovectorizes, no nightly `portable_simd`.
//!
//! # The fast-tier contract
//!
//! *Deterministic, not bit-equal to strict.*
//!
//! * **One arithmetic, everywhere.** Every kernel here performs exactly
//!   the per-pair arithmetic of [`sqdist_raw`] (resp. [`dot_raw`]), the
//!   same way the strict tier is defined against `ops::sqdist_raw`.
//!   Blocked, rowwise, argmin and single-pair entry points therefore
//!   agree bit for bit *within the tier*, so bound maintenance
//!   (tighten-then-recompute patterns like Hamerly's rescan) keeps its
//!   exact-recomputation property in fast mode.
//! * **Thread-count invariant.** Lane order and the lane-sum tree are
//!   fixed per pair and independent of how a scan is sharded; argmin
//!   folds keep the serial lowest-index tie-break. Combined with the
//!   pool's fixed shard-merge order, fast-mode results are bit-identical
//!   at any thread count and across repeated runs — pinned by
//!   `rust/tests/numerics.rs`.
//! * **Identical op counts.** The counting contract is the parent
//!   module's, enforced in the [`NumericsMode`](super::NumericsMode)
//!   dispatch layer: the mode changes *how* a distance is summed, never
//!   *whether* it is counted.
//! * **Small-`d` coincidence.** For `d < LANES` there are no full
//!   chunks; the tail loop is the same in-order accumulation as the
//!   strict remainder, so fast and strict are bit-identical below one
//!   lane chunk (pinned by tests).

use super::super::{ops, Matrix};
use super::TILE;

/// Fixed dimension lanes per accumulator array — one 8-wide SIMD
/// register on x86-64/aarch64 baselines. The strict tier's chunk width
/// is the same 8, so the two tiers walk memory identically and differ
/// only in accumulation structure.
pub const LANES: usize = 8;

/// The fixed lane reduction: a pairwise tree, not a left fold. Chosen
/// once and pinned — changing it changes every fast-mode result.
#[inline(always)]
fn lane_sum(s: &[f32; LANES]) -> f32 {
    ((s[0] + s[1]) + (s[2] + s[3])) + ((s[4] + s[5]) + (s[6] + s[7]))
}

/// One 8-lane chunk of squared differences, accumulated vertically
/// (`s[l] += d_l²`) — the autovectorizable core of the tier.
#[inline(always)]
fn accum8(x: &[f32], y: &[f32], s: &mut [f32; LANES]) {
    for l in 0..LANES {
        let d = x[l] - y[l];
        s[l] += d * d;
    }
}

/// Dot-product companion of [`accum8`].
#[inline(always)]
fn accum8_dot(x: &[f32], y: &[f32], s: &mut [f32; LANES]) {
    for l in 0..LANES {
        s[l] += x[l] * y[l];
    }
}

/// Lane-striped squared euclidean distance — the fast tier's per-pair
/// reference. Every other kernel in this module is bit-identical to it
/// per (query, candidate) pair.
#[inline]
pub fn sqdist_raw(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    let mut s = [0.0f32; LANES];
    for (x, y) in (&mut ca).zip(&mut cb) {
        accum8(x, y, &mut s);
    }
    let mut acc = lane_sum(&s);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Lane-striped inner product (fast twin of [`ops::dot_raw`]).
#[inline]
pub fn dot_raw(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    let mut s = [0.0f32; LANES];
    for (x, y) in (&mut ca).zip(&mut cb) {
        accum8_dot(x, y, &mut s);
    }
    let mut acc = lane_sum(&s);
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc += x * y;
    }
    acc
}

/// Plain distance: the same single `sqrt` over [`sqdist_raw`] as the
/// strict tier applies over its own squared distance.
#[inline]
pub fn dist_raw(a: &[f32], b: &[f32]) -> f32 {
    sqdist_raw(a, b).sqrt()
}

/// Squared norm (for the engine backend's norm-trick assignment).
#[inline]
pub fn norm2_raw(a: &[f32]) -> f32 {
    dot_raw(a, a)
}

/// Four candidates per pass, each with its own `[f32; 8]` lane
/// accumulator (4 × one 8-wide register — the register budget of the
/// strict tile, half the instructions per chunk). Per lane slot the
/// result is bit-identical to [`sqdist_raw`].
#[inline]
fn sqdist_x4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; TILE] {
    let mut cx = x.chunks_exact(LANES);
    let mut k0 = c0.chunks_exact(LANES);
    let mut k1 = c1.chunks_exact(LANES);
    let mut k2 = c2.chunks_exact(LANES);
    let mut k3 = c3.chunks_exact(LANES);
    let mut s = [[0.0f32; LANES]; TILE];
    for ((((xx, y0), y1), y2), y3) in
        (&mut cx).zip(&mut k0).zip(&mut k1).zip(&mut k2).zip(&mut k3)
    {
        accum8(xx, y0, &mut s[0]);
        accum8(xx, y1, &mut s[1]);
        accum8(xx, y2, &mut s[2]);
        accum8(xx, y3, &mut s[3]);
    }
    let rx = cx.remainder();
    let rem = [k0.remainder(), k1.remainder(), k2.remainder(), k3.remainder()];
    let mut out = [0.0f32; TILE];
    for (t, o) in out.iter_mut().enumerate() {
        let mut acc = lane_sum(&s[t]);
        for (a, b) in rx.iter().zip(rem[t]) {
            let dv = a - b;
            acc += dv * dv;
        }
        *o = acc;
    }
    out
}

/// Dot-product tile (bit-identical per pair to [`dot_raw`]).
#[inline]
fn dot_x4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; TILE] {
    let mut cx = x.chunks_exact(LANES);
    let mut k0 = c0.chunks_exact(LANES);
    let mut k1 = c1.chunks_exact(LANES);
    let mut k2 = c2.chunks_exact(LANES);
    let mut k3 = c3.chunks_exact(LANES);
    let mut s = [[0.0f32; LANES]; TILE];
    for ((((xx, y0), y1), y2), y3) in
        (&mut cx).zip(&mut k0).zip(&mut k1).zip(&mut k2).zip(&mut k3)
    {
        accum8_dot(xx, y0, &mut s[0]);
        accum8_dot(xx, y1, &mut s[1]);
        accum8_dot(xx, y2, &mut s[2]);
        accum8_dot(xx, y3, &mut s[3]);
    }
    let rx = cx.remainder();
    let rem = [k0.remainder(), k1.remainder(), k2.remainder(), k3.remainder()];
    let mut out = [0.0f32; TILE];
    for (t, o) in out.iter_mut().enumerate() {
        let mut acc = lane_sum(&s[t]);
        for (a, b) in rx.iter().zip(rem[t]) {
            acc += a * b;
        }
        *o = acc;
    }
    out
}

/// Fast twin of [`super::sqdist_block_raw`]: `out[t]` is bit-identical
/// to `fast::sqdist_raw(x, rows.row(cand[t]))`.
pub fn sqdist_block_raw(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32]) {
    debug_assert_eq!(cand.len(), out.len());
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = sqdist_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        out[t..t + TILE].copy_from_slice(&d4);
        t += TILE;
    }
    while t < cand.len() {
        out[t] = sqdist_raw(x, rows.row(cand[t] as usize));
        t += 1;
    }
}

/// Fast twin of [`super::dot_block_raw`].
pub fn dot_block_raw(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32]) {
    debug_assert_eq!(cand.len(), out.len());
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = dot_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        out[t..t + TILE].copy_from_slice(&d4);
        t += TILE;
    }
    while t < cand.len() {
        out[t] = dot_raw(x, rows.row(cand[t] as usize));
        t += 1;
    }
}

/// Fast twin of [`super::sqdist_rows_raw`] (contiguous candidate rows).
pub fn sqdist_rows_raw(x: &[f32], rows: &Matrix, start: usize, out: &mut [f32]) {
    let nc = out.len();
    debug_assert!(start + nc <= rows.rows());
    let mut t = 0;
    while t + TILE <= nc {
        let j = start + t;
        let d4 = sqdist_x4(x, rows.row(j), rows.row(j + 1), rows.row(j + 2), rows.row(j + 3));
        out[t..t + TILE].copy_from_slice(&d4);
        t += TILE;
    }
    while t < nc {
        out[t] = sqdist_raw(x, rows.row(start + t));
        t += 1;
    }
}

/// Fast twin of [`super::nearest_in_block`]'s scan (uncounted — the
/// dispatch layer charges). Plain-distance argmin, lowest slot wins.
pub fn nearest_in_block_raw(x: &[f32], rows: &Matrix, cand: &[u32]) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = sqdist_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        for (off, &sq) in d4.iter().enumerate() {
            let dv = sq.sqrt();
            if dv < best.1 {
                best = (t + off, dv);
            }
        }
        t += TILE;
    }
    while t < cand.len() {
        let dv = dist_raw(x, rows.row(cand[t] as usize));
        if dv < best.1 {
            best = (t, dv);
        }
        t += 1;
    }
    best
}

/// Fast twin of [`super::nearest_sq_in_block`]'s scan (uncounted).
pub fn nearest_sq_in_block_raw(x: &[f32], rows: &Matrix, cand: &[u32]) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = sqdist_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        for (off, &sq) in d4.iter().enumerate() {
            if sq < best.1 {
                best = (t + off, sq);
            }
        }
        t += TILE;
    }
    while t < cand.len() {
        let sq = sqdist_raw(x, rows.row(cand[t] as usize));
        if sq < best.1 {
            best = (t, sq);
        }
        t += 1;
    }
    best
}

/// Fast twin of [`super::nearest_sq_rows_raw`].
pub fn nearest_sq_rows_raw(x: &[f32], rows: &Matrix) -> (u32, f32) {
    let k = rows.rows();
    let mut best = (0u32, f32::INFINITY);
    let mut j = 0;
    while j + TILE <= k {
        let d4 = sqdist_x4(x, rows.row(j), rows.row(j + 1), rows.row(j + 2), rows.row(j + 3));
        for (off, &sq) in d4.iter().enumerate() {
            if sq < best.1 {
                best = ((j + off) as u32, sq);
            }
        }
        j += TILE;
    }
    while j < k {
        let sq = sqdist_raw(x, rows.row(j));
        if sq < best.1 {
            best = (j as u32, sq);
        }
        j += 1;
    }
    best
}

/// Fast twin of [`super::nearest_rows`]'s scan (uncounted; plain
/// distances, compared after the sqrt like the strict tier).
pub fn nearest_rows_raw(x: &[f32], rows: &Matrix) -> (u32, f32) {
    let k = rows.rows();
    let mut best = (0u32, f32::INFINITY);
    let mut j = 0;
    while j + TILE <= k {
        let d4 = sqdist_x4(x, rows.row(j), rows.row(j + 1), rows.row(j + 2), rows.row(j + 3));
        for (off, &sq) in d4.iter().enumerate() {
            let dv = sq.sqrt();
            if dv < best.1 {
                best = ((j + off) as u32, dv);
            }
        }
        j += TILE;
    }
    while j < k {
        let dv = dist_raw(x, rows.row(j));
        if dv < best.1 {
            best = (j as u32, dv);
        }
        j += 1;
    }
    best
}

/// Fast twin of [`super::pairwise_block_raw`]: same upper-triangle tile
/// walk, lane-striped pair arithmetic, zero diagonal, mirrored writes.
pub fn pairwise_block_raw(rows: &Matrix, out: &mut [f32]) {
    let k = rows.rows();
    debug_assert_eq!(out.len(), k * k);
    let mut j0 = 0;
    while j0 < k {
        let je = (j0 + TILE).min(k);
        if je - j0 == TILE {
            for i in 0..j0 {
                let d4 = sqdist_x4(
                    rows.row(i),
                    rows.row(j0),
                    rows.row(j0 + 1),
                    rows.row(j0 + 2),
                    rows.row(j0 + 3),
                );
                for (t, &v) in d4.iter().enumerate() {
                    out[i * k + j0 + t] = v;
                    out[(j0 + t) * k + i] = v;
                }
            }
        } else {
            for i in 0..j0 {
                for j in j0..je {
                    let v = sqdist_raw(rows.row(i), rows.row(j));
                    out[i * k + j] = v;
                    out[j * k + i] = v;
                }
            }
        }
        for i in j0..je {
            out[i * k + i] = 0.0;
            for j in (i + 1)..je {
                let v = sqdist_raw(rows.row(i), rows.row(j));
                out[i * k + j] = v;
                out[j * k + i] = v;
            }
        }
        j0 = je;
    }
}

/// Fast twin of the [`super::dist_rowwise`] scan (uncounted).
pub fn dist_rowwise_raw(a: &Matrix, b: &Matrix, out: &mut [f32]) {
    debug_assert_eq!(a.rows(), b.rows());
    debug_assert_eq!(a.rows(), out.len());
    for (i, v) in out.iter_mut().enumerate() {
        *v = dist_raw(a.row(i), b.row(i));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, small_usize};
    use crate::testing::random_matrix;

    #[test]
    fn blocked_scans_bit_identical_to_fast_scalar_reference() {
        // The tier's own bit-identity contract: every blocked/argmin
        // kernel agrees with fast::sqdist_raw per pair, across dims
        // crossing the lane boundary and candidate counts crossing the
        // tile remainder.
        for d in 0..40 {
            let rows = random_matrix(13, d, d as u64 + 101);
            let x = random_matrix(1, d, 199);
            let q = x.row(0);
            let cand: Vec<u32> = (0..13u32).rev().collect();
            let mut sq = vec![0.0f32; 13];
            sqdist_block_raw(q, &rows, &cand, &mut sq);
            let mut dots = vec![0.0f32; 13];
            dot_block_raw(q, &rows, &cand, &mut dots);
            let mut by_rows = vec![0.0f32; 13];
            sqdist_rows_raw(q, &rows, 0, &mut by_rows);
            for (t, &j) in cand.iter().enumerate() {
                let j = j as usize;
                assert_eq!(sq[t].to_bits(), sqdist_raw(q, rows.row(j)).to_bits(), "d={d}");
                assert_eq!(dots[t].to_bits(), dot_raw(q, rows.row(j)).to_bits(), "d={d}");
                assert_eq!(
                    by_rows[j].to_bits(),
                    sqdist_raw(q, rows.row(j)).to_bits(),
                    "d={d}"
                );
            }
        }
    }

    #[test]
    fn matches_strict_below_one_lane_chunk() {
        // d < LANES: no full chunks, so the tail loop is the whole sum
        // and the two tiers coincide bitwise.
        for d in 0..LANES {
            let a = random_matrix(1, d, 7);
            let b = random_matrix(1, d, 8);
            assert_eq!(
                sqdist_raw(a.row(0), b.row(0)).to_bits(),
                ops::sqdist_raw(a.row(0), b.row(0)).to_bits(),
                "d={d}"
            );
            assert_eq!(
                dot_raw(a.row(0), b.row(0)).to_bits(),
                ops::dot_raw(a.row(0), b.row(0)).to_bits(),
                "d={d}"
            );
        }
    }

    #[test]
    fn differs_from_strict_somewhere_at_high_d() {
        // Sanity that Fast is a genuinely different summation order: at
        // d = 64 the lane tree and the strict pairing round differently
        // for essentially every random pair; require at least one
        // difference across many pairs (a blanket per-pair assert would
        // be wrong — individual pairs may coincide).
        let a = random_matrix(64, 64, 9);
        let b = random_matrix(64, 64, 10);
        let mut any_diff = false;
        for i in 0..64 {
            if sqdist_raw(a.row(i), b.row(i)).to_bits()
                != ops::sqdist_raw(a.row(i), b.row(i)).to_bits()
            {
                any_diff = true;
                break;
            }
        }
        assert!(any_diff, "fast tier unexpectedly bit-equal to strict at d=64");
    }

    #[test]
    fn close_to_strict_in_value() {
        // Different rounding, same quantity: relative agreement to f32
        // accumulation accuracy.
        for d in [8usize, 31, 64, 257, 1024] {
            let a = random_matrix(1, d, 11);
            let b = random_matrix(1, d, 12);
            let f = sqdist_raw(a.row(0), b.row(0));
            let s = ops::sqdist_raw(a.row(0), b.row(0));
            assert!((f - s).abs() <= 1e-5 * (1.0 + s.abs()), "d={d}: {f} vs {s}");
        }
    }

    #[test]
    fn ties_keep_lowest_slot() {
        let mut rows = random_matrix(5, 12, 13);
        let dup: Vec<f32> = rows.row(1).to_vec();
        rows.row_mut(3).copy_from_slice(&dup);
        let x: Vec<f32> = dup.iter().map(|v| v + 0.25).collect();
        let cand: Vec<u32> = (0..5).collect();
        let (slot_sq, _) = nearest_sq_in_block_raw(&x, &rows, &cand);
        let (slot_pl, _) = nearest_in_block_raw(&x, &rows, &cand);
        let (row_sq, _) = nearest_sq_rows_raw(&x, &rows);
        let (row_pl, _) = nearest_rows_raw(&x, &rows);
        assert!(slot_sq != 3 && slot_pl != 3 && row_sq != 3 && row_pl != 3);
    }

    #[test]
    fn pairwise_matches_fast_scalar_triangle() {
        for k in [0usize, 1, 3, 4, 5, 9, 16, 19] {
            let rows = random_matrix(k, 13, k as u64 + 121);
            let mut got = vec![f32::NAN; k * k];
            pairwise_block_raw(&rows, &mut got);
            for i in 0..k {
                for j in 0..k {
                    let want = if i == j { 0.0 } else { sqdist_raw(rows.row(i), rows.row(j)) };
                    assert_eq!(got[i * k + j].to_bits(), want.to_bits(), "k={k} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn prop_fast_block_scan_bit_identity() {
        check("fast kernels block == fast scalar", 60, |rng| {
            let d = small_usize(rng, 1, 41) - 1; // 0..40
            let k = small_usize(rng, 1, 22);
            let nc = small_usize(rng, 1, k + 1);
            let rows = random_matrix(k, d, rng.gen_below(1 << 20) as u64);
            let x = random_matrix(1, d, rng.gen_below(1 << 20) as u64);
            let cand: Vec<u32> = (0..nc).map(|_| rng.gen_below(k) as u32).collect();
            let mut out = vec![0.0f32; nc];
            sqdist_block_raw(x.row(0), &rows, &cand, &mut out);
            for (t, &got) in out.iter().enumerate() {
                let want = sqdist_raw(x.row(0), rows.row(cand[t] as usize));
                assert_eq!(got.to_bits(), want.to_bits(), "d={d} nc={nc} t={t}");
            }
        });
    }

    #[test]
    fn rowwise_matches_scalar_pairs() {
        let a = random_matrix(6, 21, 41);
        let b = random_matrix(6, 21, 42);
        let mut out = vec![0.0f32; 6];
        dist_rowwise_raw(&a, &b, &mut out);
        for i in 0..6 {
            assert_eq!(out[i].to_bits(), dist_raw(a.row(i), b.row(i)).to_bits());
        }
    }
}
