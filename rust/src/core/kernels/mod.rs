//! Blocked distance kernels — the candidate-scan layer every algorithm
//! hot path routes through.
//!
//! The paper observes that >95% of runtime is distance computations, and
//! once assignment is restricted to candidate lists (k²-means' `N_kn`
//! neighbourhoods, seeding sweeps, bound-failure rescans) the scan over
//! those lists *is* the algorithm. A per-pair [`ops::sqdist`] loop
//! reloads the query row from cache for every candidate; the kernels
//! here load the query row **once** and register-tile [`TILE`] candidate
//! rows per pass, so the query's 8-wide chunks are reused across the
//! tile and the candidate rows stream through cache linearly. The scalar
//! primitives in [`ops`] survive as the reference implementation, inside
//! kd-tree descent (whose per-leaf candidate sets are too small and
//! irregular to tile), and in the engine backend's norm-trick full
//! assignment (a measured-faster form at its batch shapes — see the
//! §Perf note in `runtime/engine.rs`); every other scan goes through
//! this module.
//!
//! # The bit-identity contract
//!
//! Every kernel performs **exactly the per-pair arithmetic of
//! [`ops::sqdist_raw`]** (8-wide chunks into four independent
//! accumulators, `s0+s1+s2+s3`, then the remainder terms in order), so a
//! blocked scan returns bit-identical `f32` results to the scalar loop
//! it replaces — interleaving independent pairs across a tile cannot
//! change any individual pair's rounding. Plain-distance variants apply
//! the same single `sqrt` as [`ops::dist_raw`]. `rust/tests/kernels.rs`
//! pins this for dims 0..40 and candidate counts crossing the tile
//! remainder boundary, and end-to-end for the full algorithm roster.
//!
//! # The counting contract
//!
//! Counted entry points charge **exactly one distance (or inner
//! product) per (query, candidate) pair** — the same bill as the scalar
//! loops they replace — in one bulk `+=` on the caller's counter.
//! Symmetric or self-distance recomputation that a caller performs for
//! layout reasons (see [`crate::knn::knn_graph_threaded`]) is charged by
//! the caller, not here.
//!
//! # The tie-break contract
//!
//! The argmin helpers ([`nearest_in_block`], [`nearest_sq_rows`], …)
//! compare with strict `<` in candidate order, so the **lowest slot
//! wins ties** — identical to the serial `for j { if dist < best }`
//! loops. The plain-distance variants compare *plain* distances (not
//! squared), because two distinct squared values can round to the same
//! `sqrt`, and the winner must match the scalar plain-distance loop
//! bit for bit.
//!
//! # When to use block vs scalar
//!
//! Use a blocked kernel whenever the set of candidate distances is
//! known before the scan (full assignments, bootstraps, seeding sweeps,
//! the center graph build). The bound-gated loops — Elkan/k²-means
//! bound pruning, Yinyang's group filter — decide per candidate
//! whether to compute at all; under [`ScanMode::Gated`] they keep the
//! scalar [`dist_one`]/[`sqdist_one`] shape, while [`ScanMode::Batched`]
//! (the default) filters on cached bounds first and drives the
//! survivors through [`tile_scan_gated`] in [`TILE`]-wide blocks,
//! replaying each gate at fold time so results stay bitwise equal and
//! every evaluation a tile admitted that the sequential loop would have
//! skipped is tallied on [`OpCounter::batch_extra`].
//!
//! # The three numerics tiers
//!
//! The kernels above are the **Strict** tier — the default everywhere.
//! The [`fast`] submodule is the **Fast** tier: lane-striped variants
//! that accumulate each pair across `W = 8` fixed dimension lanes
//! instead of `ops::sqdist_raw`'s four paired accumulators, trading the
//! bit pin against the historical scalar loops for ~2× fewer FMA chain
//! steps per chunk. The [`quant`] submodule is the **Quantized** tier:
//! 1-bit sign codes with a certified error radius that *prune*
//! candidates before a strict re-rank. Selection is explicit via
//! [`NumericsMode`], whose methods mirror the entry points here and
//! dispatch per mode:
//!
//! * **Strict guarantees**: bit-identical to the pre-kernel scalar
//!   loops (the contract above), so every historical pin holds.
//! * **Fast guarantees**: *deterministic, not bit-equal* — one fixed
//!   per-pair arithmetic shared by every fast kernel (so recompute
//!   patterns stay exact within the tier), bit-identical results at any
//!   thread count and across repeated runs, and **the same op-count
//!   bill** as Strict (counting lives in the dispatch methods, not the
//!   tiers). Final energies agree with Strict to f32 accumulation
//!   accuracy. Pinned by `rust/tests/numerics.rs`.
//! * **Quantized guarantees**: answers **bit-identical to Strict** —
//!   labels, centers, energies, serve answers. Every exact evaluation
//!   runs the strict arithmetic; the estimator only decides *which*
//!   candidates get one. Supported scans go through the `*_q` dispatch
//!   methods, which take an optional [`quant::QuantPair`] and prune
//!   when codes are supplied; every other dispatch method routes
//!   `Quantized` to the strict functions with an identical bill.
//!   Estimated scores bill [`OpCounter::estimates`], packing bills
//!   [`OpCounter::packs`] — both off `total()` — while exact
//!   `distances` on a pruned scan is the survivor count (≤ the Strict
//!   bill). Pinned by `rust/tests/quantized.rs`.
//! * **When each dispatches**: every `NumericsMode` method matches on
//!   `self` — `Strict` routes to the functions in this module, `Fast`
//!   to [`fast`], `Quantized` to the strict functions (exactness) or,
//!   in the `*_q` methods with codes present, to [`quant`]'s pruned
//!   scans. Callers thread the mode from `cluster::Config`
//!   (CLI `--numerics`, manifest `numerics=`, env `K2M_NUMERICS`);
//!   the bare functions in this module remain the Strict reference
//!   surface for code that predates the tiers.

pub mod fast;
pub mod quant;

use std::sync::OnceLock;

use super::{ops, Matrix, OpCounter};

/// Candidate rows processed per register tile. Four rows × four
/// accumulators each stays comfortably inside the 16 architectural
/// SIMD registers of x86-64/aarch64 baselines.
pub const TILE: usize = 4;

/// One 8-wide chunk of `x` against one chunk of `y`, accumulated into
/// `s` in exactly [`ops::sqdist_raw`]'s order.
#[inline(always)]
fn accum8(x: &[f32], y: &[f32], s: &mut [f32; 4]) {
    let d0 = x[0] - y[0];
    let d1 = x[1] - y[1];
    let d2 = x[2] - y[2];
    let d3 = x[3] - y[3];
    let d4 = x[4] - y[4];
    let d5 = x[5] - y[5];
    let d6 = x[6] - y[6];
    let d7 = x[7] - y[7];
    s[0] += d0 * d0 + d4 * d4;
    s[1] += d1 * d1 + d5 * d5;
    s[2] += d2 * d2 + d6 * d6;
    s[3] += d3 * d3 + d7 * d7;
}

/// Dot-product companion of [`accum8`] ([`ops::dot_raw`]'s order).
#[inline(always)]
fn accum8_dot(x: &[f32], y: &[f32], s: &mut [f32; 4]) {
    s[0] += x[0] * y[0] + x[4] * y[4];
    s[1] += x[1] * y[1] + x[5] * y[5];
    s[2] += x[2] * y[2] + x[6] * y[6];
    s[3] += x[3] * y[3] + x[7] * y[7];
}

/// Squared distances from one query row to four candidate rows. Each
/// pair's accumulation order is exactly [`ops::sqdist_raw`]'s, so every
/// lane is bit-identical to the scalar call — the tile only changes
/// *when* independent pairs are computed, not *how*.
#[inline]
fn sqdist_x4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; 4] {
    let mut cx = x.chunks_exact(8);
    let mut k0 = c0.chunks_exact(8);
    let mut k1 = c1.chunks_exact(8);
    let mut k2 = c2.chunks_exact(8);
    let mut k3 = c3.chunks_exact(8);
    let mut s = [[0.0f32; 4]; TILE];
    for ((((xx, y0), y1), y2), y3) in
        (&mut cx).zip(&mut k0).zip(&mut k1).zip(&mut k2).zip(&mut k3)
    {
        accum8(xx, y0, &mut s[0]);
        accum8(xx, y1, &mut s[1]);
        accum8(xx, y2, &mut s[2]);
        accum8(xx, y3, &mut s[3]);
    }
    let rx = cx.remainder();
    let rem = [k0.remainder(), k1.remainder(), k2.remainder(), k3.remainder()];
    let mut out = [0.0f32; TILE];
    for (t, o) in out.iter_mut().enumerate() {
        let mut acc = s[t][0] + s[t][1] + s[t][2] + s[t][3];
        for (a, b) in rx.iter().zip(rem[t]) {
            let dv = a - b;
            acc += dv * dv;
        }
        *o = acc;
    }
    out
}

/// Inner products of one query row with four candidate rows
/// (bit-identical per pair to [`ops::dot_raw`]).
#[inline]
fn dot_x4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; 4] {
    let mut cx = x.chunks_exact(8);
    let mut k0 = c0.chunks_exact(8);
    let mut k1 = c1.chunks_exact(8);
    let mut k2 = c2.chunks_exact(8);
    let mut k3 = c3.chunks_exact(8);
    let mut s = [[0.0f32; 4]; TILE];
    for ((((xx, y0), y1), y2), y3) in
        (&mut cx).zip(&mut k0).zip(&mut k1).zip(&mut k2).zip(&mut k3)
    {
        accum8_dot(xx, y0, &mut s[0]);
        accum8_dot(xx, y1, &mut s[1]);
        accum8_dot(xx, y2, &mut s[2]);
        accum8_dot(xx, y3, &mut s[3]);
    }
    let rx = cx.remainder();
    let rem = [k0.remainder(), k1.remainder(), k2.remainder(), k3.remainder()];
    let mut out = [0.0f32; TILE];
    for (t, o) in out.iter_mut().enumerate() {
        let mut acc = s[t][0] + s[t][1] + s[t][2] + s[t][3];
        for (a, b) in rx.iter().zip(rem[t]) {
            acc += a * b;
        }
        *o = acc;
    }
    out
}

// ---------------------------------------------------------------------------
// Candidate-list scans
// ---------------------------------------------------------------------------

/// Squared distances from `x` to the rows of `rows` named by `cand`,
/// uncounted. `out[t]` is bit-identical to
/// `ops::sqdist_raw(x, rows.row(cand[t]))`.
pub fn sqdist_block_raw(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32]) {
    debug_assert_eq!(cand.len(), out.len());
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = sqdist_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        out[t..t + TILE].copy_from_slice(&d4);
        t += TILE;
    }
    while t < cand.len() {
        out[t] = ops::sqdist_raw(x, rows.row(cand[t] as usize));
        t += 1;
    }
}

/// [`sqdist_block_raw`] — counted as one distance per candidate.
pub fn sqdist_block(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32], c: &mut OpCounter) {
    c.distances += cand.len() as u64;
    sqdist_block_raw(x, rows, cand, out);
}

/// Plain distances over a candidate list — the same single `sqrt` per
/// pair as [`ops::dist_raw`]. Counted as one distance per candidate.
pub fn dist_block(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32], c: &mut OpCounter) {
    sqdist_block(x, rows, cand, out, c);
    for v in out.iter_mut() {
        *v = v.sqrt();
    }
}

/// Inner products of `x` with the rows named by `cand`, uncounted.
/// `out[t]` is bit-identical to `ops::dot_raw(x, rows.row(cand[t]))`
/// (elementwise `f32` multiplication commutes bitwise, so either
/// argument order matches the scalar call).
pub fn dot_block_raw(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32]) {
    debug_assert_eq!(cand.len(), out.len());
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = dot_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        out[t..t + TILE].copy_from_slice(&d4);
        t += TILE;
    }
    while t < cand.len() {
        out[t] = ops::dot_raw(x, rows.row(cand[t] as usize));
        t += 1;
    }
}

/// [`dot_block_raw`] — counted as one inner product per candidate.
pub fn dot_block(x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32], c: &mut OpCounter) {
    c.inner_products += cand.len() as u64;
    dot_block_raw(x, rows, cand, out);
}

// ---------------------------------------------------------------------------
// Contiguous-row scans (candidates are `start..start + out.len()`)
// ---------------------------------------------------------------------------

/// Squared distances from `x` to the contiguous rows
/// `start..start + out.len()` of `rows`, uncounted. The row-range twin
/// of [`sqdist_block_raw`] for full scans and point shards, where
/// materializing an index list would be pure overhead.
pub fn sqdist_rows_raw(x: &[f32], rows: &Matrix, start: usize, out: &mut [f32]) {
    let nc = out.len();
    debug_assert!(start + nc <= rows.rows());
    let mut t = 0;
    while t + TILE <= nc {
        let j = start + t;
        let d4 = sqdist_x4(x, rows.row(j), rows.row(j + 1), rows.row(j + 2), rows.row(j + 3));
        out[t..t + TILE].copy_from_slice(&d4);
        t += TILE;
    }
    while t < nc {
        out[t] = ops::sqdist_raw(x, rows.row(start + t));
        t += 1;
    }
}

/// [`sqdist_rows_raw`] — counted as one distance per row scanned.
pub fn sqdist_rows(x: &[f32], rows: &Matrix, start: usize, out: &mut [f32], c: &mut OpCounter) {
    c.distances += out.len() as u64;
    sqdist_rows_raw(x, rows, start, out);
}

/// Plain distances over a contiguous row range (one `sqrt` per pair,
/// like [`ops::dist_raw`]). Counted as one distance per row scanned.
pub fn dist_rows(x: &[f32], rows: &Matrix, start: usize, out: &mut [f32], c: &mut OpCounter) {
    sqdist_rows(x, rows, start, out, c);
    for v in out.iter_mut() {
        *v = v.sqrt();
    }
}

// ---------------------------------------------------------------------------
// Argmin-over-block helpers
// ---------------------------------------------------------------------------

/// Earliest index of the strictly smallest value — the shared tie-break
/// of every assignment loop in the crate (`for j { if d < best }` keeps
/// the first winner). For buffer-based call sites that need the
/// distances *and* the argmin.
pub fn argmin(dists: &[f32]) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    for (t, &dv) in dists.iter().enumerate() {
        if dv < best.1 {
            best = (t, dv);
        }
    }
    best
}

/// Argmin by **plain** distance over a candidate list. Returns
/// `(slot, dist)` — `slot` indexes `cand`, ties keep the lowest slot.
/// Counted as one distance per candidate (all candidates are computed,
/// exactly like the serial loop this replaces).
pub fn nearest_in_block(x: &[f32], rows: &Matrix, cand: &[u32], c: &mut OpCounter) -> (usize, f32) {
    c.distances += cand.len() as u64;
    nearest_in_block_scan(x, rows, cand)
}

/// The uncounted scan behind [`nearest_in_block`] (the numerics
/// dispatch bills once and routes here or to the fast twin).
fn nearest_in_block_scan(x: &[f32], rows: &Matrix, cand: &[u32]) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = sqdist_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        for (off, &sq) in d4.iter().enumerate() {
            let dv = sq.sqrt();
            if dv < best.1 {
                best = (t + off, dv);
            }
        }
        t += TILE;
    }
    while t < cand.len() {
        let dv = ops::dist_raw(x, rows.row(cand[t] as usize));
        if dv < best.1 {
            best = (t, dv);
        }
        t += 1;
    }
    best
}

/// Argmin by **squared** distance over a candidate list — `(slot,
/// sqdist)`, lowest slot wins ties. Counted one distance per candidate.
pub fn nearest_sq_in_block(
    x: &[f32],
    rows: &Matrix,
    cand: &[u32],
    c: &mut OpCounter,
) -> (usize, f32) {
    c.distances += cand.len() as u64;
    nearest_sq_in_block_scan(x, rows, cand)
}

/// The uncounted scan behind [`nearest_sq_in_block`].
fn nearest_sq_in_block_scan(x: &[f32], rows: &Matrix, cand: &[u32]) -> (usize, f32) {
    let mut best = (0usize, f32::INFINITY);
    let mut t = 0;
    while t + TILE <= cand.len() {
        let d4 = sqdist_x4(
            x,
            rows.row(cand[t] as usize),
            rows.row(cand[t + 1] as usize),
            rows.row(cand[t + 2] as usize),
            rows.row(cand[t + 3] as usize),
        );
        for (off, &sq) in d4.iter().enumerate() {
            if sq < best.1 {
                best = (t + off, sq);
            }
        }
        t += TILE;
    }
    while t < cand.len() {
        let sq = ops::sqdist_raw(x, rows.row(cand[t] as usize));
        if sq < best.1 {
            best = (t, sq);
        }
        t += 1;
    }
    best
}

/// Argmin by **squared** distance over all rows, uncounted — the
/// measurement-only twin of [`nearest_sq_rows`] (energy evaluation,
/// MiniBatch's trace assignments).
pub fn nearest_sq_rows_raw(x: &[f32], rows: &Matrix) -> (u32, f32) {
    let k = rows.rows();
    let mut best = (0u32, f32::INFINITY);
    let mut j = 0;
    while j + TILE <= k {
        let d4 = sqdist_x4(x, rows.row(j), rows.row(j + 1), rows.row(j + 2), rows.row(j + 3));
        for (off, &sq) in d4.iter().enumerate() {
            if sq < best.1 {
                best = ((j + off) as u32, sq);
            }
        }
        j += TILE;
    }
    while j < k {
        let sq = ops::sqdist_raw(x, rows.row(j));
        if sq < best.1 {
            best = (j as u32, sq);
        }
        j += 1;
    }
    best
}

/// Argmin by **squared** distance over all rows — the full-assignment
/// kernel (Lloyd, MiniBatch). Counted one distance per row.
pub fn nearest_sq_rows(x: &[f32], rows: &Matrix, c: &mut OpCounter) -> (u32, f32) {
    c.distances += rows.rows() as u64;
    nearest_sq_rows_raw(x, rows)
}

/// Argmin by **plain** distance over all rows — the bound-establishing
/// full assignment (k²-means' unlabeled bootstrap). Counted one
/// distance per row.
pub fn nearest_rows(x: &[f32], rows: &Matrix, c: &mut OpCounter) -> (u32, f32) {
    c.distances += rows.rows() as u64;
    nearest_rows_scan(x, rows)
}

/// The uncounted scan behind [`nearest_rows`].
fn nearest_rows_scan(x: &[f32], rows: &Matrix) -> (u32, f32) {
    let k = rows.rows();
    let mut best = (0u32, f32::INFINITY);
    let mut j = 0;
    while j + TILE <= k {
        let d4 = sqdist_x4(x, rows.row(j), rows.row(j + 1), rows.row(j + 2), rows.row(j + 3));
        for (off, &sq) in d4.iter().enumerate() {
            let dv = sq.sqrt();
            if dv < best.1 {
                best = ((j + off) as u32, dv);
            }
        }
        j += TILE;
    }
    while j < k {
        let dv = ops::dist_raw(x, rows.row(j));
        if dv < best.1 {
            best = (j as u32, dv);
        }
        j += 1;
    }
    best
}

// ---------------------------------------------------------------------------
// Tile-vs-tile pairwise table
// ---------------------------------------------------------------------------

/// Full symmetric `k × k` **squared**-distance table of `rows`, built by
/// upper-triangle tiles: each [`TILE`]-wide block of candidate rows
/// stays hot in cache while every earlier query row streams past it,
/// instead of `k` independent row scans each reloading all of `rows`.
/// Every unordered pair is computed once and mirrored; the diagonal is
/// written as `0.0`. Uncounted — see [`pairwise_block`].
pub fn pairwise_block_raw(rows: &Matrix, out: &mut [f32]) {
    let k = rows.rows();
    debug_assert_eq!(out.len(), k * k);
    let mut j0 = 0;
    while j0 < k {
        let je = (j0 + TILE).min(k);
        if je - j0 == TILE {
            for i in 0..j0 {
                let d4 = sqdist_x4(
                    rows.row(i),
                    rows.row(j0),
                    rows.row(j0 + 1),
                    rows.row(j0 + 2),
                    rows.row(j0 + 3),
                );
                for (t, &v) in d4.iter().enumerate() {
                    out[i * k + j0 + t] = v;
                    out[(j0 + t) * k + i] = v;
                }
            }
        } else {
            for i in 0..j0 {
                for j in j0..je {
                    let v = ops::sqdist_raw(rows.row(i), rows.row(j));
                    out[i * k + j] = v;
                    out[j * k + i] = v;
                }
            }
        }
        // Pairs inside the tile, plus the zero diagonal.
        for i in j0..je {
            out[i * k + i] = 0.0;
            for j in (i + 1)..je {
                let v = ops::sqdist_raw(rows.row(i), rows.row(j));
                out[i * k + j] = v;
                out[j * k + i] = v;
            }
        }
        j0 = je;
    }
}

/// [`pairwise_block_raw`] — counted `k·(k−1)/2` distances (each
/// unordered pair once — the paper's accounting for the
/// `NeighborGraph` rebuild).
pub fn pairwise_block(rows: &Matrix, out: &mut [f32], c: &mut OpCounter) {
    let k = rows.rows();
    c.distances += (k * k.saturating_sub(1) / 2) as u64;
    pairwise_block_raw(rows, out);
}

/// [`pairwise_block`] in **plain** distances (one `sqrt` per entry, like
/// [`ops::dist_raw`]) — Elkan's center-center table. Counted
/// `k·(k−1)/2` distances.
pub fn pairwise_dist_block(rows: &Matrix, out: &mut [f32], c: &mut OpCounter) {
    pairwise_block(rows, out, c);
    for v in out.iter_mut() {
        *v = v.sqrt();
    }
}

// ---------------------------------------------------------------------------
// Row-wise and single-pair entry points
// ---------------------------------------------------------------------------

/// `out[i] = dist(a.row(i), b.row(i))` — the center-drift kernel shared
/// by every bound-maintaining algorithm. Counted one distance per row.
/// (Each pair has its own query, so there is nothing to tile; this
/// exists so drift loops need no scalar `ops` calls.)
pub fn dist_rowwise(a: &Matrix, b: &Matrix, out: &mut [f32], c: &mut OpCounter) {
    c.distances += a.rows() as u64;
    dist_rowwise_scan(a, b, out);
}

/// The uncounted scan behind [`dist_rowwise`].
fn dist_rowwise_scan(a: &Matrix, b: &Matrix, out: &mut [f32]) {
    debug_assert_eq!(a.rows(), b.rows());
    debug_assert_eq!(a.rows(), out.len());
    for (i, v) in out.iter_mut().enumerate() {
        *v = ops::dist_raw(a.row(i), b.row(i));
    }
}

/// One counted squared distance — the per-candidate evaluation of the
/// bound-gated loops under [`ScanMode::Gated`] (their batched twin
/// gathers survivors and evaluates through [`tile_scan_gated`] instead).
#[inline]
pub fn sqdist_one(a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
    c.distances += 1;
    ops::sqdist_raw(a, b)
}

/// One counted plain distance — see [`sqdist_one`].
#[inline]
pub fn dist_one(a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
    c.distances += 1;
    ops::dist_raw(a, b)
}

// ---------------------------------------------------------------------------
// Gather-then-tile driver (ScanMode::Batched)
// ---------------------------------------------------------------------------

/// Drive one bound-gated candidate scan in gather-then-tile form — the
/// [`ScanMode::Batched`] replacement for a sequential
/// `dist_one`-per-survivor loop.
///
/// `tags`/`ids` are the phase-1 survivors in candidate order: `tags[t]`
/// is the caller's handle for a candidate (a neighbour slot, a center
/// index, …) passed back to the closures, `ids[t]` the row of `rows` to
/// evaluate. The driver repeatedly **gathers** up to [`TILE`] candidates
/// whose `gate` passes under the caller's *current* state, evaluates the
/// gathered tile through the mode-dispatched block kernel (per-pair
/// arithmetic plus one `sqrt`, bitwise equal to
/// [`NumericsMode::dist_one`] on every tier), then **folds** the tile in
/// candidate order, replaying `gate` before each fold so the caller
/// observes exactly the sequential loop's decisions: a candidate whose
/// gate fails at fold time (an earlier fold in the same tile tightened
/// the bound) is billed on [`OpCounter::batch_extra`] as well as
/// `distances`, and **not** folded.
///
/// After the first tile that produces an extra, the gather capacity
/// drops to one — a lone gathered candidate is always folded under the
/// exact state it was gathered under — so one scan pays at most
/// `TILE − 1` extras total, all inside that first offending tile.
///
/// Contract: `gate` must be a pure read of `state`, `true` exactly when
/// the sequential loop would evaluate that candidate under the same
/// state; `fold` must perform the sequential loop's entire
/// post-evaluation bookkeeping. The driver then yields bitwise-identical
/// scan results with `distances` equal to the sequential bill plus
/// `batch_extra`.
#[allow(clippy::too_many_arguments)]
pub fn tile_scan_gated<S, G, F>(
    nm: NumericsMode,
    x: &[f32],
    rows: &Matrix,
    tags: &[u32],
    ids: &[u32],
    state: &mut S,
    c: &mut OpCounter,
    mut gate: G,
    mut fold: F,
) where
    G: FnMut(&S, u32) -> bool,
    F: FnMut(&mut S, u32, f32),
{
    debug_assert_eq!(tags.len(), ids.len());
    let mut cap = TILE;
    let mut cur = 0;
    let mut tile_tags = [0u32; TILE];
    let mut tile_ids = [0u32; TILE];
    let mut dists = [0.0f32; TILE];
    while cur < tags.len() {
        // Gather: admit up to `cap` candidates passing the gate under
        // the state every earlier fold has already tightened.
        let mut m = 0;
        while cur < tags.len() && m < cap {
            if gate(state, tags[cur]) {
                tile_tags[m] = tags[cur];
                tile_ids[m] = ids[cur];
                m += 1;
            }
            cur += 1;
        }
        if m == 0 {
            break;
        }
        c.distances += m as u64;
        nm.sqdist_block_raw(x, rows, &tile_ids[..m], &mut dists[..m]);
        let mut extra = false;
        for t in 0..m {
            let dv = dists[t].sqrt();
            if gate(state, tile_tags[t]) {
                fold(state, tile_tags[t], dv);
            } else {
                c.batch_extra += 1;
                extra = true;
            }
        }
        if extra {
            cap = 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Refresh-mode selection (incremental center-state maintenance)
// ---------------------------------------------------------------------------

/// How per-iteration center-derived state (the center kNN graph, Elkan's
/// cc table, Hamerly's s-table, the quantized center codes) is refreshed
/// after an update step moves the centers.
///
/// `Full` rebuilds everything from scratch each iteration — the
/// historical behavior, paying the `O(k²d)` iteration tax in full.
/// `Incremental` (the default) derives the set `M` of centers whose rows
/// actually changed (drift is already in hand and is exactly `0.0` for a
/// bitwise-stationary center) and recomputes only the pairs touching
/// `M`, reusing every unmoved-pair distance bitwise.
///
/// # Contract
///
/// Labels, centers, energies and iteration counts are **bitwise equal**
/// between the two modes at any thread count (the reused values are the
/// exact bits a recompute would produce — see
/// [`crate::knn::KnnGraphCache`] for the soundness argument). Only the
/// counted bill moves: an incremental run's `distances` is ≤ the full
/// run's, strictly < once any center freezes, with the avoided
/// evaluations tallied on [`OpCounter::refresh_saved`].
///
/// [`OpCounter::refresh_saved`]: crate::core::OpCounter::refresh_saved
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum RefreshMode {
    /// Rebuild all center-derived state from scratch every iteration.
    Full,
    /// Refresh only the state touching bitwise-moved centers. The
    /// default.
    #[default]
    Incremental,
}

impl RefreshMode {
    /// Parse the CLI/manifest/env spelling
    /// (`full` | `incremental`, case-insensitive).
    pub fn parse(s: &str) -> Option<RefreshMode> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(RefreshMode::Full),
            "incremental" => Some(RefreshMode::Incremental),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RefreshMode::Full => "full",
            RefreshMode::Incremental => "incremental",
        }
    }

    /// The process-wide default: `K2M_REFRESH` (`full` | `incremental`),
    /// resolved through [`crate::core::env::knob`] — read once per
    /// process, trimmed, unset/unrecognized falling back to
    /// [`RefreshMode::Incremental`]. `cluster::Config::default()` and
    /// the CLI's `--refresh` default resolve through this.
    pub fn from_env() -> RefreshMode {
        static MODE: OnceLock<RefreshMode> = OnceLock::new();
        crate::core::env::knob(&MODE, "K2M_REFRESH", RefreshMode::parse, || {
            RefreshMode::Incremental
        })
    }
}

// ---------------------------------------------------------------------------
// Scan-mode selection (sequential gated vs gather-then-tile loops)
// ---------------------------------------------------------------------------

/// How the bound-pruned candidate loops (k²-means' neighbourhood scan,
/// Elkan's step-2/3 pass, Yinyang's group filter, Hamerly's rescan, the
/// serve-time graph descent) execute their surviving evaluations.
///
/// `Gated` is the paper-literal shape: one scalar [`dist_one`] per
/// candidate, each evaluation gated on the bound state the previous one
/// tightened. `Batched` (the default) runs the same scan as a two-phase
/// filter → tile-evaluate pipeline: phase 1 walks the candidate list on
/// cached bounds alone (zero distance evaluations) and gathers the
/// survivors, phase 2 evaluates them in [`TILE`]-wide blocks through
/// [`tile_scan_gated`], re-checking the tightened bound between folds —
/// so the blocked kernels (and, under [`NumericsMode::Quantized`], the
/// in-loop estimator prune) finally reach the paper's O(n·kn·d) hot
/// path instead of only its bootstraps.
///
/// # Contract
///
/// Labels, centers, energies, iteration counts and center graphs are
/// **bitwise equal** between the two modes at any thread count and on
/// every numerics tier (same per-pair arithmetic, same lowest-index
/// tie-break, gate decisions replayed at fold time). Only the bill
/// moves: a batched scan bills at most `TILE − 1` evaluations beyond
/// the gated bill, each tallied on [`OpCounter::batch_extra`] (off
/// `total()`), so the paper-faithful sequential bill stays
/// reconstructible as
/// `batched.distances − batched.batch_extra ≤ gated.distances`; under
/// `Quantized` the in-loop prune can push `distances` strictly below
/// the gated bill.
///
/// [`OpCounter::batch_extra`]: crate::core::OpCounter::batch_extra
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ScanMode {
    /// Sequential scalar evaluations, one gate check per candidate —
    /// the historical loop shape.
    Gated,
    /// Filter on cached bounds, then gather-and-tile the survivors
    /// through the blocked kernels. The default.
    #[default]
    Batched,
}

impl ScanMode {
    /// Parse the CLI/manifest/env spelling
    /// (`gated` | `batched`, case-insensitive).
    pub fn parse(s: &str) -> Option<ScanMode> {
        match s.to_ascii_lowercase().as_str() {
            "gated" => Some(ScanMode::Gated),
            "batched" => Some(ScanMode::Batched),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ScanMode::Gated => "gated",
            ScanMode::Batched => "batched",
        }
    }

    /// The process-wide default: `K2M_SCAN` (`gated` | `batched`),
    /// resolved through [`crate::core::env::knob`] — read once per
    /// process, trimmed, unset/unrecognized falling back to
    /// [`ScanMode::Batched`]. `cluster::Config::default()` and the
    /// CLI's `--scan` default resolve through this.
    pub fn from_env() -> ScanMode {
        static MODE: OnceLock<ScanMode> = OnceLock::new();
        crate::core::env::knob(&MODE, "K2M_SCAN", ScanMode::parse, || ScanMode::Batched)
    }
}

// ---------------------------------------------------------------------------
// Numerics-mode dispatch
// ---------------------------------------------------------------------------

/// Which numerics tier a candidate scan runs on — see the module docs
/// ("The three numerics tiers") for the exact guarantees of each.
///
/// `Strict` (the `Default`) is bit-identical to the historical scalar
/// loops; `Fast` is the lane-striped tier in [`fast`]: deterministic
/// (same bits at any thread count and across runs, fixed lane order),
/// same op-count bill, but a different — faster — summation order.
/// `Quantized` is the estimate-prune-rerank tier in [`quant`]: answers
/// bit-identical to `Strict`, exact-distance bills ≤ `Strict`'s.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum NumericsMode {
    /// Bit-identical to the pre-kernel scalar path (`ops::sqdist_raw`
    /// accumulation order). The default.
    #[default]
    Strict,
    /// Lane-striped accumulation ([`fast`]; `W = 8` fixed lanes, fixed
    /// pairwise lane reduction). Deterministic, not bit-equal to Strict.
    Fast,
    /// 1-bit code estimate → certified prune → strict re-rank
    /// ([`quant`]). Bit-equal to Strict; scans without codes fall back
    /// to the strict functions with an identical bill.
    Quantized,
}

impl NumericsMode {
    /// Parse the CLI/manifest/env spelling
    /// (`strict` | `fast` | `quantized`, case-insensitive).
    pub fn parse(s: &str) -> Option<NumericsMode> {
        match s.to_ascii_lowercase().as_str() {
            "strict" => Some(NumericsMode::Strict),
            "fast" => Some(NumericsMode::Fast),
            "quantized" => Some(NumericsMode::Quantized),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            NumericsMode::Strict => "strict",
            NumericsMode::Fast => "fast",
            NumericsMode::Quantized => "quantized",
        }
    }

    /// The process-wide default: `K2M_NUMERICS` (`strict` | `fast` |
    /// `quantized`), resolved through [`crate::core::env::knob`] — read
    /// once per process so no hot path touches `std::env`, trimmed,
    /// unset/unrecognized falling back to [`NumericsMode::Strict`].
    /// `cluster::Config::default()` and the CLI's `--numerics` default
    /// resolve through this, so the env var reaches every entry point
    /// that does not explicitly pick a mode.
    pub fn from_env() -> NumericsMode {
        static MODE: OnceLock<NumericsMode> = OnceLock::new();
        crate::core::env::knob(&MODE, "K2M_NUMERICS", NumericsMode::parse, || {
            NumericsMode::Strict
        })
    }

    // -- dispatching twins of the module's entry points -----------------
    //
    // Counting happens HERE (identically for both tiers), so the two
    // modes cannot drift in the op-count bill: the tier only changes how
    // a distance is summed, never whether it is charged.

    /// Mode-dispatched [`fn@sqdist_block_raw`].
    #[inline]
    pub fn sqdist_block_raw(self, x: &[f32], rows: &Matrix, cand: &[u32], out: &mut [f32]) {
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => {
                sqdist_block_raw(x, rows, cand, out)
            }
            NumericsMode::Fast => fast::sqdist_block_raw(x, rows, cand, out),
        }
    }

    /// Mode-dispatched [`fn@sqdist_block`] (counted: one per candidate).
    #[inline]
    pub fn sqdist_block(
        self,
        x: &[f32],
        rows: &Matrix,
        cand: &[u32],
        out: &mut [f32],
        c: &mut OpCounter,
    ) {
        c.distances += cand.len() as u64;
        self.sqdist_block_raw(x, rows, cand, out);
    }

    /// Mode-dispatched [`fn@dot_block`] (counted: one per candidate).
    #[inline]
    pub fn dot_block(
        self,
        x: &[f32],
        rows: &Matrix,
        cand: &[u32],
        out: &mut [f32],
        c: &mut OpCounter,
    ) {
        c.inner_products += cand.len() as u64;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => dot_block_raw(x, rows, cand, out),
            NumericsMode::Fast => fast::dot_block_raw(x, rows, cand, out),
        }
    }

    /// Mode-dispatched [`fn@sqdist_rows_raw`].
    #[inline]
    pub fn sqdist_rows_raw(self, x: &[f32], rows: &Matrix, start: usize, out: &mut [f32]) {
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => {
                sqdist_rows_raw(x, rows, start, out)
            }
            NumericsMode::Fast => fast::sqdist_rows_raw(x, rows, start, out),
        }
    }

    /// Mode-dispatched [`fn@sqdist_rows`] (counted: one per row).
    #[inline]
    pub fn sqdist_rows(
        self,
        x: &[f32],
        rows: &Matrix,
        start: usize,
        out: &mut [f32],
        c: &mut OpCounter,
    ) {
        c.distances += out.len() as u64;
        self.sqdist_rows_raw(x, rows, start, out);
    }

    /// Mode-dispatched [`fn@dist_rows`] (counted: one per row).
    #[inline]
    pub fn dist_rows(
        self,
        x: &[f32],
        rows: &Matrix,
        start: usize,
        out: &mut [f32],
        c: &mut OpCounter,
    ) {
        self.sqdist_rows(x, rows, start, out, c);
        for v in out.iter_mut() {
            *v = v.sqrt();
        }
    }

    /// Mode-dispatched [`fn@nearest_in_block`] (counted).
    #[inline]
    pub fn nearest_in_block(
        self,
        x: &[f32],
        rows: &Matrix,
        cand: &[u32],
        c: &mut OpCounter,
    ) -> (usize, f32) {
        c.distances += cand.len() as u64;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => nearest_in_block_scan(x, rows, cand),
            NumericsMode::Fast => fast::nearest_in_block_raw(x, rows, cand),
        }
    }

    /// Mode-dispatched [`fn@nearest_sq_in_block`] (counted).
    #[inline]
    pub fn nearest_sq_in_block(
        self,
        x: &[f32],
        rows: &Matrix,
        cand: &[u32],
        c: &mut OpCounter,
    ) -> (usize, f32) {
        c.distances += cand.len() as u64;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => {
                nearest_sq_in_block_scan(x, rows, cand)
            }
            NumericsMode::Fast => fast::nearest_sq_in_block_raw(x, rows, cand),
        }
    }

    /// Mode-dispatched [`fn@nearest_sq_rows_raw`] (uncounted).
    #[inline]
    pub fn nearest_sq_rows_raw(self, x: &[f32], rows: &Matrix) -> (u32, f32) {
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => nearest_sq_rows_raw(x, rows),
            NumericsMode::Fast => fast::nearest_sq_rows_raw(x, rows),
        }
    }

    /// Mode-dispatched [`fn@nearest_sq_rows`] (counted: one per row).
    #[inline]
    pub fn nearest_sq_rows(self, x: &[f32], rows: &Matrix, c: &mut OpCounter) -> (u32, f32) {
        c.distances += rows.rows() as u64;
        self.nearest_sq_rows_raw(x, rows)
    }

    /// Mode-dispatched [`fn@nearest_rows`] (counted: one per row).
    #[inline]
    pub fn nearest_rows(self, x: &[f32], rows: &Matrix, c: &mut OpCounter) -> (u32, f32) {
        c.distances += rows.rows() as u64;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => nearest_rows_scan(x, rows),
            NumericsMode::Fast => fast::nearest_rows_raw(x, rows),
        }
    }

    /// Mode-dispatched [`fn@pairwise_block`] (counted `k·(k−1)/2`).
    #[inline]
    pub fn pairwise_block(self, rows: &Matrix, out: &mut [f32], c: &mut OpCounter) {
        let k = rows.rows();
        c.distances += (k * k.saturating_sub(1) / 2) as u64;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => pairwise_block_raw(rows, out),
            NumericsMode::Fast => fast::pairwise_block_raw(rows, out),
        }
    }

    /// Mode-dispatched [`fn@pairwise_dist_block`] (counted `k·(k−1)/2`).
    #[inline]
    pub fn pairwise_dist_block(self, rows: &Matrix, out: &mut [f32], c: &mut OpCounter) {
        self.pairwise_block(rows, out, c);
        for v in out.iter_mut() {
            *v = v.sqrt();
        }
    }

    /// Mode-dispatched [`fn@dist_rowwise`] (counted: one per row).
    #[inline]
    pub fn dist_rowwise(self, a: &Matrix, b: &Matrix, out: &mut [f32], c: &mut OpCounter) {
        c.distances += a.rows() as u64;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => dist_rowwise_scan(a, b, out),
            NumericsMode::Fast => fast::dist_rowwise_raw(a, b, out),
        }
    }

    /// Mode-dispatched [`fn@sqdist_one`] (counted).
    #[inline]
    pub fn sqdist_one(self, a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
        c.distances += 1;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => ops::sqdist_raw(a, b),
            NumericsMode::Fast => fast::sqdist_raw(a, b),
        }
    }

    /// Mode-dispatched [`fn@dist_one`] (counted).
    #[inline]
    pub fn dist_one(self, a: &[f32], b: &[f32], c: &mut OpCounter) -> f32 {
        c.distances += 1;
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => ops::dist_raw(a, b),
            NumericsMode::Fast => fast::dist_raw(a, b),
        }
    }

    /// Mode-dispatched uncounted inner product (the engine backend's
    /// norm-trick assignment).
    #[inline]
    pub fn dot_one_raw(self, a: &[f32], b: &[f32]) -> f32 {
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => ops::dot_raw(a, b),
            NumericsMode::Fast => fast::dot_raw(a, b),
        }
    }

    /// Mode-dispatched uncounted squared norm.
    #[inline]
    pub fn norm2_raw(self, a: &[f32]) -> f32 {
        match self {
            NumericsMode::Strict | NumericsMode::Quantized => ops::norm2_raw(a),
            NumericsMode::Fast => fast::norm2_raw(a),
        }
    }

    // -- quantized-capable twins ---------------------------------------
    //
    // The `*_q` methods take an optional [`quant::QuantPair`]. On the
    // Quantized tier with codes present they run the estimate → prune →
    // strict-re-rank scan (estimates billed, exact distances billed per
    // survivor); in every other combination they are exactly the
    // unsuffixed method — same result, same bill — so call sites can
    // thread `Option` unconditionally.

    /// [`Self::nearest_sq_rows`] with optional quantized pruning.
    #[inline]
    pub fn nearest_sq_rows_q(
        self,
        x: &[f32],
        rows: &Matrix,
        qp: Option<&quant::QuantPair<'_>>,
        c: &mut OpCounter,
    ) -> (u32, f32) {
        match (self, qp) {
            (NumericsMode::Quantized, Some(qp)) => quant::nearest_sq_rows_pruned(x, rows, qp, c),
            _ => self.nearest_sq_rows(x, rows, c),
        }
    }

    /// [`Self::nearest_rows`] with optional quantized pruning.
    #[inline]
    pub fn nearest_rows_q(
        self,
        x: &[f32],
        rows: &Matrix,
        qp: Option<&quant::QuantPair<'_>>,
        c: &mut OpCounter,
    ) -> (u32, f32) {
        match (self, qp) {
            (NumericsMode::Quantized, Some(qp)) => quant::nearest_rows_pruned(x, rows, qp, c),
            _ => self.nearest_rows(x, rows, c),
        }
    }

    /// [`Self::nearest_in_block`] with optional quantized pruning.
    #[inline]
    pub fn nearest_in_block_q(
        self,
        x: &[f32],
        rows: &Matrix,
        cand: &[u32],
        qp: Option<&quant::QuantPair<'_>>,
        c: &mut OpCounter,
    ) -> (usize, f32) {
        match (self, qp) {
            (NumericsMode::Quantized, Some(qp)) => {
                quant::nearest_in_block_pruned(x, rows, cand, qp, c)
            }
            _ => self.nearest_in_block(x, rows, cand, c),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{check, small_usize};
    use crate::testing::random_matrix;

    fn cand_list(k: usize) -> Vec<u32> {
        (0..k as u32).collect()
    }

    #[test]
    fn sqdist_block_bit_identical_to_scalar_all_dims() {
        // Dims 0..40 cross the 8-wide chunk boundary; 13 candidates
        // cross the TILE remainder boundary (13 = 3*4 + 1).
        for d in 0..40 {
            let rows = random_matrix(13, d, d as u64 + 1);
            let x = random_matrix(1, d, 99);
            let cand = cand_list(13);
            let mut out = vec![0.0f32; 13];
            sqdist_block_raw(x.row(0), &rows, &cand, &mut out);
            for (t, &got) in out.iter().enumerate() {
                let want = ops::sqdist_raw(x.row(0), rows.row(t));
                assert_eq!(got.to_bits(), want.to_bits(), "d={d} t={t}");
            }
        }
    }

    #[test]
    fn candidate_counts_cross_tile_remainder() {
        let d = 17;
        let rows = random_matrix(11, d, 3);
        let x = random_matrix(1, d, 4);
        for nc in 0..=11usize {
            let cand = cand_list(nc);
            let mut out = vec![0.0f32; nc];
            let mut c = OpCounter::default();
            sqdist_block(x.row(0), &rows, &cand, &mut out, &mut c);
            assert_eq!(c.distances, nc as u64, "nc={nc}");
            for (t, &got) in out.iter().enumerate() {
                let want = ops::sqdist_raw(x.row(0), rows.row(t));
                assert_eq!(got.to_bits(), want.to_bits(), "nc={nc} t={t}");
            }
        }
    }

    #[test]
    fn dist_block_applies_the_same_sqrt() {
        let rows = random_matrix(9, 21, 5);
        let x = random_matrix(1, 21, 6);
        let cand = cand_list(9);
        let mut out = vec![0.0f32; 9];
        let mut c = OpCounter::default();
        dist_block(x.row(0), &rows, &cand, &mut out, &mut c);
        for (t, &got) in out.iter().enumerate() {
            let want = ops::dist_raw(x.row(0), rows.row(t));
            assert_eq!(got.to_bits(), want.to_bits(), "t={t}");
        }
        assert_eq!(c.distances, 9);
    }

    #[test]
    fn dot_block_bit_identical_both_argument_orders() {
        for d in [0usize, 1, 7, 8, 9, 24, 33] {
            let rows = random_matrix(7, d, 7);
            let x = random_matrix(1, d, 8);
            let cand = cand_list(7);
            let mut out = vec![0.0f32; 7];
            let mut c = OpCounter::default();
            dot_block(x.row(0), &rows, &cand, &mut out, &mut c);
            for (t, &got) in out.iter().enumerate() {
                let want = ops::dot_raw(rows.row(t), x.row(0));
                assert_eq!(got.to_bits(), want.to_bits(), "d={d} t={t}");
            }
            assert_eq!(c.inner_products, 7);
        }
    }

    #[test]
    fn rows_scan_matches_block_scan_with_identity_candidates() {
        let rows = random_matrix(10, 19, 9);
        let x = random_matrix(1, 19, 10);
        let cand = cand_list(10);
        let mut a = vec![0.0f32; 10];
        let mut b = vec![0.0f32; 10];
        sqdist_block_raw(x.row(0), &rows, &cand, &mut a);
        sqdist_rows_raw(x.row(0), &rows, 0, &mut b);
        assert_eq!(a, b);
        // Offset ranges index from `start`.
        let mut tail = vec![0.0f32; 4];
        sqdist_rows_raw(x.row(0), &rows, 6, &mut tail);
        assert_eq!(tail[..], a[6..10]);
    }

    #[test]
    fn nearest_ties_keep_lowest_slot() {
        // Rows 1 and 3 are identical: the serial `<` loop keeps slot 1.
        let mut rows = random_matrix(5, 12, 11);
        let dup: Vec<f32> = rows.row(1).to_vec();
        rows.row_mut(3).copy_from_slice(&dup);
        let x: Vec<f32> = dup.iter().map(|v| v + 0.25).collect();
        let mut c = OpCounter::default();
        let cand = cand_list(5);
        let (slot_sq, _) = nearest_sq_in_block(&x, &rows, &cand, &mut c);
        let (slot_pl, _) = nearest_in_block(&x, &rows, &cand, &mut c);
        let (row_sq, _) = nearest_sq_rows(&x, &rows, &mut c);
        let (row_pl, _) = nearest_rows(&x, &rows, &mut c);
        // The duplicate pair ties exactly; whichever of {1, 3} is the
        // true argmin, the earliest must win in all four helpers.
        assert!(slot_sq != 3 && slot_pl != 3 && row_sq != 3 && row_pl != 3);
        assert_eq!(c.distances, 20);
    }

    #[test]
    fn nearest_matches_serial_argmin() {
        let rows = random_matrix(23, 15, 13);
        let x = random_matrix(1, 15, 14);
        let mut c = OpCounter::default();
        let (j, sq) = nearest_sq_rows(x.row(0), &rows, &mut c);
        let mut best = (0u32, f32::INFINITY);
        for t in 0..23 {
            let dv = ops::sqdist_raw(x.row(0), rows.row(t));
            if dv < best.1 {
                best = (t as u32, dv);
            }
        }
        assert_eq!((j, sq.to_bits()), (best.0, best.1.to_bits()));
        let (jp, pl) = nearest_rows(x.row(0), &rows, &mut c);
        assert_eq!(jp, best.0);
        assert_eq!(pl.to_bits(), best.1.sqrt().to_bits());
    }

    #[test]
    fn pairwise_block_matches_scalar_triangle() {
        for k in [0usize, 1, 2, 3, 4, 5, 9, 16, 19] {
            let rows = random_matrix(k, 13, k as u64 + 21);
            let mut got = vec![f32::NAN; k * k];
            let mut c = OpCounter::default();
            pairwise_block(&rows, &mut got, &mut c);
            assert_eq!(c.distances, (k * k.saturating_sub(1) / 2) as u64, "k={k}");
            for i in 0..k {
                for j in 0..k {
                    let want =
                        if i == j { 0.0 } else { ops::sqdist_raw(rows.row(i), rows.row(j)) };
                    assert_eq!(got[i * k + j].to_bits(), want.to_bits(), "k={k} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn pairwise_dist_block_is_sqrt_of_squared() {
        let rows = random_matrix(7, 9, 31);
        let mut sq = vec![0.0f32; 49];
        let mut pl = vec![0.0f32; 49];
        let mut c = OpCounter::default();
        pairwise_block(&rows, &mut sq, &mut c);
        pairwise_dist_block(&rows, &mut pl, &mut c);
        for (a, b) in sq.iter().zip(&pl) {
            assert_eq!(a.sqrt().to_bits(), b.to_bits());
        }
    }

    #[test]
    fn rowwise_and_single_pair_count_and_match() {
        let a = random_matrix(6, 11, 41);
        let b = random_matrix(6, 11, 42);
        let mut out = vec![0.0f32; 6];
        let mut c = OpCounter::default();
        dist_rowwise(&a, &b, &mut out, &mut c);
        assert_eq!(c.distances, 6);
        for i in 0..6 {
            assert_eq!(out[i].to_bits(), ops::dist_raw(a.row(i), b.row(i)).to_bits());
            assert_eq!(
                dist_one(a.row(i), b.row(i), &mut c).to_bits(),
                out[i].to_bits()
            );
            assert_eq!(
                sqdist_one(a.row(i), b.row(i), &mut c).to_bits(),
                ops::sqdist_raw(a.row(i), b.row(i)).to_bits()
            );
        }
        assert_eq!(c.distances, 6 + 12);
    }

    #[test]
    fn argmin_earliest_wins() {
        assert_eq!(argmin(&[3.0, 1.0, 1.0, 2.0]), (1, 1.0));
        assert_eq!(argmin(&[]), (0, f32::INFINITY));
        assert_eq!(argmin(&[f32::INFINITY]), (0, f32::INFINITY));
    }

    #[test]
    fn prop_block_scan_bit_identity() {
        // Random dims crossing the 8-chunk boundary and candidate
        // counts crossing the TILE remainder, per the seeded harness.
        check("kernels block == scalar", 60, |rng| {
            let d = small_usize(rng, 1, 41) - 1; // 0..40
            let k = small_usize(rng, 1, 22);
            let nc = small_usize(rng, 1, k + 1);
            let rows = random_matrix(k, d, rng.gen_below(1 << 20) as u64);
            let x = random_matrix(1, d, rng.gen_below(1 << 20) as u64);
            let cand: Vec<u32> =
                (0..nc).map(|_| rng.gen_below(k) as u32).collect();
            let mut out = vec![0.0f32; nc];
            sqdist_block_raw(x.row(0), &rows, &cand, &mut out);
            for (t, &got) in out.iter().enumerate() {
                let want = ops::sqdist_raw(x.row(0), rows.row(cand[t] as usize));
                assert_eq!(got.to_bits(), want.to_bits(), "d={d} nc={nc} t={t}");
            }
        });
    }
}
