//! The **Quantized** numerics tier: 1-bit sign codes with a certified
//! error radius, used to *prune* candidate scans before an exact strict
//! re-rank — final answers are **bit-identical to Strict**.
//!
//! # The code
//!
//! A row `x` is packed against a fixed centering vector `μ` (column
//! means of the candidate set): with `x' = x − μ` (each coordinate an
//! exact `f64` difference of two `f32`s), the code stores one sign bit
//! per dimension (`bit_j = x'_j ≥ 0`, packed little-endian into `u64`
//! words, tail bits zero) plus a 16-byte header
//! [`QuantHead`]`{norm2, sum_abs, scale, err}` where `norm2 = ‖x'‖²`,
//! `sum_abs = Σ|x'_j|`, `scale = sum_abs/d`, and
//! `err = √(norm2 − sum_abs²/d)`. That is the exact decomposition
//! `x' = scale·b_x + e_x` with `b_x` the ±1 sign vector (`‖b_x‖² = d`,
//! `⟨x', b_x⟩ = sum_abs`) and `e_x ⊥ b_x`, `‖e_x‖ = err`.
//!
//! # The certified estimate
//!
//! For a pair with signed sign-dot `t = ⟨b_x, b_y⟩ = d − 2·popcount(
//! words_x XOR words_y)`:
//!
//! ```text
//! ‖x' − y'‖² = norm2_x + norm2_y − 2⟨x', y'⟩
//! ⟨x', y'⟩   = s_x·s_y·t  +  s_x⟨b_x, e_y⟩ + s_y⟨e_x, b_y⟩ + ⟨e_x, e_y⟩
//! ```
//!
//! The first term is the estimate; the rest is bounded with
//! Cauchy–Schwarz *tightened by orthogonality*: `e_y ⊥ b_y`, so
//! `|⟨b_x, e_y⟩| ≤ ‖b_x − (t/d)·b_y‖·err_y = √(d − t²/d)·err_y` (and
//! symmetrically), plus `|⟨e_x, e_y⟩| ≤ err_x·err_y`. Centering cancels
//! in differences (`‖x − y‖² = ‖x' − y'‖²` in exact arithmetic), so the
//! bounds certify the *true* squared distance; a small multiplicative
//! slack then absorbs every float rounding in play — the `f32` header
//! storage, the `f64` estimator arithmetic, and the `f32` accumulation
//! of the strict kernel the bound is compared against. All bound
//! comparisons run in `f64`; bounds are never narrowed to `f32`.
//!
//! # The prune/re-rank contract
//!
//! [`nearest_sq_rows_pruned`] (and its plain/candidate-list twins) score
//! every candidate with [`estimate_bounds`], keep exactly those whose
//! lower bound does not exceed the smallest upper bound, and re-rank the
//! survivors with the **strict** scan functions of the parent module.
//! Soundness: a pruned `j` has `exact_sq(j) ≥ lb(j) > min_ub ≥
//! exact_sq(j_ub)` for the candidate `j_ub` achieving `min_ub`, so `j`
//! loses *strictly* — every argmin achiever survives, survivor order is
//! candidate order, and the strict re-rank's lowest-slot tie-break
//! therefore returns the exact full-scan winner, bit for bit (value
//! *and* index). For the plain-distance twins the pruning still happens
//! on squared bounds: the slack term guarantees a pruned candidate's
//! squared distance exceeds the survivor minimum by a relative margin
//! (~1e-5) that is orders of magnitude wider than an `f32` ulp, so the
//! two cannot round to the same `sqrt` — strict loss survives the root.
//!
//! # Billing
//!
//! Estimated scores are charged to [`OpCounter::estimates`] (one per
//! pair) and packing to [`OpCounter::packs`] (one per row) — both
//! **excluded** from `total()`. Exact work is charged one distance per
//! *survivor*, so a Quantized run's `distances` is directly comparable
//! to (and never exceeds) a Strict run's on the same scan.
//!
//! # When it wins, and when it can't prune
//!
//! `err` measures how far a row is from a pure sign pattern. On
//! sign-structured data (near-binary features, ± spreads with small
//! jitter) `err ≈ 0`, the radius collapses, and most candidates are
//! pruned after one popcount per word. On isotropic data `err ≈
//! 0.6·‖x'‖` *regardless of separation*, the certified radius is the
//! same order as typical squared distances, and the tier degrades
//! gracefully to scanning every candidate — the bill is then *equal* to
//! Strict (plus uncounted estimates), never worse, and answers are
//! unchanged.

use std::cell::RefCell;

use super::super::{Matrix, OpCounter};

/// Bits per code word.
pub const WORD_BITS: usize = 64;

/// Code words needed for `dim` sign bits.
#[inline]
pub fn words_for(dim: usize) -> usize {
    dim.div_ceil(WORD_BITS)
}

/// Per-row correction header — see the module docs for the exact
/// definitions. Stored as four `f32`s (16 bytes) both in memory and in
/// the `.k2mm` codes section; the estimator widens to `f64`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantHead {
    /// `‖x − μ‖²`.
    pub norm2: f32,
    /// `Σ_j |x_j − μ_j|`.
    pub sum_abs: f32,
    /// `sum_abs / d` — the projection coefficient onto the sign vector.
    pub scale: f32,
    /// `√(norm2 − sum_abs²/d)` — the residual norm off the sign axis.
    pub err: f32,
}

/// One packed row borrowed out of a [`QuantizedCodes`] (or packed on the
/// fly for a serve-time query): header plus its `words_for(dim)` code
/// words.
#[derive(Clone, Copy, Debug)]
pub struct QuantRow<'a> {
    pub head: QuantHead,
    pub bits: &'a [u64],
}

/// A (query, candidate-set) pairing handed to the pruned scans: the
/// query's packed row and the codes of the rows being scanned, packed
/// against the **same** `μ`.
#[derive(Clone, Copy, Debug)]
pub struct QuantPair<'a> {
    pub query: QuantRow<'a>,
    pub cands: &'a QuantizedCodes,
}

/// Packed 1-bit codes for a set of rows: the shared centering vector
/// `μ`, one [`QuantHead`] per row, and `rows × words_for(dim)` code
/// words (row-major, little-endian bit order, tail bits zero).
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedCodes {
    dim: usize,
    words: usize,
    mu: Vec<f32>,
    heads: Vec<QuantHead>,
    bits: Vec<u64>,
}

/// Pack one row against `μ` into `out_bits` (resized/overwritten) and
/// return its header. The math runs in `f64`: each centered coordinate
/// `x_j − μ_j` is an *exact* `f64`, and the `norm2`/`sum_abs`
/// accumulations round only at `2^-53` — negligible against the
/// estimator's slack.
pub fn pack_row(x: &[f32], mu: &[f32], out_bits: &mut Vec<u64>) -> QuantHead {
    debug_assert_eq!(x.len(), mu.len());
    let dim = x.len();
    let words = words_for(dim);
    out_bits.clear();
    out_bits.resize(words, 0u64);
    let mut norm2 = 0.0f64;
    let mut sum_abs = 0.0f64;
    for (j, (&xv, &mv)) in x.iter().zip(mu).enumerate() {
        let v = xv as f64 - mv as f64;
        norm2 += v * v;
        sum_abs += v.abs();
        if v >= 0.0 {
            out_bits[j / WORD_BITS] |= 1u64 << (j % WORD_BITS);
        }
    }
    let d = dim as f64;
    let scale = if dim == 0 { 0.0 } else { sum_abs / d };
    let err2 = if dim == 0 { 0.0 } else { norm2 - sum_abs * sum_abs / d };
    QuantHead {
        norm2: norm2 as f32,
        sum_abs: sum_abs as f32,
        scale: scale as f32,
        err: err2.max(0.0).sqrt() as f32,
    }
}

/// Column means of `rows` — the centering vector convention used
/// everywhere codes are built (training packs against the *initial*
/// centers' means; the serve model packs against its own centers'
/// means). Any fixed `μ` is sound — it only moves prune power — but a
/// deterministic convention keeps rebuilt codes bit-identical to saved
/// ones.
pub fn column_means(rows: &Matrix) -> Vec<f32> {
    let (n, d) = (rows.rows(), rows.cols());
    if n == 0 {
        return vec![0.0; d];
    }
    let mut acc = vec![0.0f64; d];
    for i in 0..n {
        for (a, &v) in acc.iter_mut().zip(rows.row(i)) {
            *a += v as f64;
        }
    }
    acc.iter().map(|&a| (a / n as f64) as f32).collect()
}

impl QuantizedCodes {
    /// Pack every row of `rows` against `mu`. Uncounted — callers with a
    /// live [`OpCounter`] bill `rows.rows()` to
    /// [`packs`](OpCounter::packs) themselves (the cluster-loop
    /// [`QuantState`](crate::cluster::common) does; the lazy serve-model
    /// rebuild is measurement-free like the model's norms).
    pub fn pack(rows: &Matrix, mu: &[f32]) -> QuantizedCodes {
        let dim = rows.cols();
        debug_assert_eq!(mu.len(), dim);
        let words = words_for(dim);
        let n = rows.rows();
        let mut heads = Vec::with_capacity(n);
        let mut bits = vec![0u64; n * words];
        let mut scratch = Vec::with_capacity(words);
        for i in 0..n {
            heads.push(pack_row(rows.row(i), mu, &mut scratch));
            bits[i * words..(i + 1) * words].copy_from_slice(&scratch);
        }
        QuantizedCodes { dim, words, mu: mu.to_vec(), heads, bits }
    }

    /// Reassemble codes from their serialized parts (`.k2mm` loader).
    /// Returns `None` on any length inconsistency; `heads_flat` is
    /// `4 × rows` values in `[norm2, sum_abs, scale, err]` order.
    pub fn from_parts(
        dim: usize,
        mu: Vec<f32>,
        heads_flat: &[f32],
        bits: Vec<u64>,
    ) -> Option<QuantizedCodes> {
        if mu.len() != dim || heads_flat.len() % 4 != 0 {
            return None;
        }
        let n = heads_flat.len() / 4;
        let words = words_for(dim);
        if bits.len() != n * words {
            return None;
        }
        let heads = heads_flat
            .chunks_exact(4)
            .map(|h| QuantHead { norm2: h[0], sum_abs: h[1], scale: h[2], err: h[3] })
            .collect();
        Some(QuantizedCodes { dim, words, mu, heads, bits })
    }

    pub fn rows(&self) -> usize {
        self.heads.len()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Code words per row (`words_for(dim)`).
    pub fn words(&self) -> usize {
        self.words
    }

    pub fn mu(&self) -> &[f32] {
        &self.mu
    }

    /// All code words, row-major — the `.k2mm` writer's payload.
    pub fn bits(&self) -> &[u64] {
        &self.bits
    }

    /// Headers flattened to `[norm2, sum_abs, scale, err]` per row — the
    /// `.k2mm` writer's payload.
    pub fn heads_flat(&self) -> Vec<f32> {
        self.heads
            .iter()
            .flat_map(|h| [h.norm2, h.sum_abs, h.scale, h.err])
            .collect()
    }

    /// Borrow row `i` as a [`QuantRow`].
    pub fn row_q(&self, i: usize) -> QuantRow<'_> {
        QuantRow { head: self.heads[i], bits: &self.bits[i * self.words..(i + 1) * self.words] }
    }

    /// Re-pack a single row in place against the codes' own `μ` — the
    /// incremental refresh path (`RefreshMode::Incremental`): after an
    /// update step only the *moved* centers' codes change, so the
    /// cluster loop repacks exactly those rows instead of rebuilding
    /// the whole table. Produces the identical bytes [`pack`] would for
    /// row `i` (same `pack_row`, same `μ`), so a moved-set repack is
    /// bitwise indistinguishable from a full one.
    ///
    /// [`pack`]: QuantizedCodes::pack
    pub fn repack_row(&mut self, i: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        let mut scratch = Vec::with_capacity(self.words);
        self.heads[i] = pack_row(row, &self.mu, &mut scratch);
        self.bits[i * self.words..(i + 1) * self.words].copy_from_slice(&scratch);
    }
}

/// XOR-popcount between two equal-length code-word slices — the Hamming
/// kernel at the heart of [`estimate_bounds`]. Unrolled 4-wide with
/// independent accumulators so the `popcnt` dependency chains overlap
/// (the naive fold serializes on one accumulator); integer addition is
/// associative, so the result — and every estimate derived from it —
/// is bit-identical to the naive fold. The before/after cost is pinned
/// in `benches/kernels.rs` ("Quantized tier" section).
#[inline]
pub fn xor_popcount(x: &[u64], y: &[u64]) -> u64 {
    debug_assert_eq!(x.len(), y.len());
    let split = x.len() & !3;
    let (xc, xr) = x.split_at(split);
    let (yc, yr) = y.split_at(split);
    let mut acc = [0u64; 4];
    for (a, b) in xc.chunks_exact(4).zip(yc.chunks_exact(4)) {
        acc[0] += (a[0] ^ b[0]).count_ones() as u64;
        acc[1] += (a[1] ^ b[1]).count_ones() as u64;
        acc[2] += (a[2] ^ b[2]).count_ones() as u64;
        acc[3] += (a[3] ^ b[3]).count_ones() as u64;
    }
    let mut h = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (a, b) in xr.iter().zip(yr) {
        h += (a ^ b).count_ones() as u64;
    }
    h
}

/// Certified `f64` bounds on the squared distance between two packed
/// rows (same `μ`, same `dim`): returns `(lb, ub)` with
/// `lb ≤ ‖x − y‖² ≤ ub` — where the middle term is the strict-kernel
/// `f32` value as well as the exact real — for every pair (pinned by
/// `tests/properties.rs`). See the module docs for the derivation; the
/// slack term covers all float rounding, including the `f32` header
/// storage and the strict kernel's own accumulation error.
pub fn estimate_bounds(x: QuantRow<'_>, y: QuantRow<'_>, dim: usize) -> (f64, f64) {
    debug_assert_eq!(x.bits.len(), y.bits.len());
    let d = dim as f64;
    let h = xor_popcount(x.bits, y.bits);
    let t = d - 2.0 * h as f64;
    let (nx2, sx, ex) = (x.head.norm2 as f64, x.head.scale as f64, x.head.err as f64);
    let (ny2, sy, ey) = (y.head.norm2 as f64, y.head.scale as f64, y.head.err as f64);
    let est = nx2 + ny2 - 2.0 * sx * sy * t;
    let cross = if dim == 0 { 0.0 } else { (d - t * t / d).max(0.0).sqrt() };
    let r = 2.0 * ((sx * ey + sy * ex) * cross + ex * ey);
    let slack = (nx2 + ny2 + 2.0 * (sx * sy * t).abs() + r) * (1e-5 + 1e-7 * d) + 1e-30;
    ((est - r - slack).max(0.0), est + r + slack)
}

/// Squared prune threshold for a scan whose running best is the plain
/// distance `u`: the certified-safe margin `(u·(1+1e-4))²`, in `f64`.
/// Any candidate whose [`estimate_bounds`] lower bound exceeds it has
/// true squared distance strictly above `u²`, so it cannot improve a
/// strict-`<` argmin (the margin absorbs the `f32` squaring of `u`
/// itself). Shared by the serve-time completion prune and the in-loop
/// batched-scan prunes ([`prune_survivors`]).
#[inline]
pub fn plain_threshold_sq(u: f32) -> f64 {
    let t = u as f64 * (1.0 + 1e-4);
    t * t
}

/// In-loop estimator prune of a gathered survivor list against a fixed
/// squared threshold (top-1 scans under `ScanMode::Batched`): drops
/// every candidate whose certified lower bound exceeds `thresh_sq` —
/// its true distance strictly exceeds the bound the threshold was
/// derived from (see [`plain_threshold_sq`]), so it can neither win a
/// strict-`<` argmin nor tighten the scan's running best. Compacts
/// `ids` (center rows, fed to the block kernel) and the optional
/// parallel `tags` (the caller's candidate handles) in place,
/// preserving candidate order; bills one estimate per candidate scored.
pub fn prune_survivors(
    query: QuantRow<'_>,
    codes: &QuantizedCodes,
    ids: &mut Vec<u32>,
    mut tags: Option<&mut Vec<u32>>,
    thresh_sq: f64,
    c: &mut OpCounter,
) {
    if let Some(tags) = tags.as_deref() {
        debug_assert_eq!(tags.len(), ids.len());
    }
    c.estimates += ids.len() as u64;
    let mut w = 0;
    for r in 0..ids.len() {
        let (lb, _) = estimate_bounds(query, codes.row_q(ids[r] as usize), codes.dim());
        if lb <= thresh_sq {
            ids[w] = ids[r];
            if let Some(tags) = tags.as_deref_mut() {
                tags[w] = tags[r];
            }
            w += 1;
        }
    }
    ids.truncate(w);
    if let Some(tags) = tags {
        tags.truncate(w);
    }
}

/// Top-2-safe estimator prune (Hamerly's rescan, Yinyang's group scans
/// — folds that need both the minimum and the second minimum): scores
/// every candidate, takes `ub2` = the second-smallest upper bound, and
/// drops candidates with `lb > ub2`. At least two candidates have true
/// distance ≤ `ub2` and strictly below a dropped one's, so a dropped
/// candidate can change neither the min nor the second-min of the fold
/// — not even their strict-`<` tie-breaks, since it sits strictly
/// above both values. With fewer than two candidates nothing is scored
/// or dropped. Compacts `ids`/`tags` like [`prune_survivors`]; bills
/// one estimate per candidate.
pub fn prune_survivors_top2(
    query: QuantRow<'_>,
    codes: &QuantizedCodes,
    ids: &mut Vec<u32>,
    mut tags: Option<&mut Vec<u32>>,
    c: &mut OpCounter,
) {
    if let Some(tags) = tags.as_deref() {
        debug_assert_eq!(tags.len(), ids.len());
    }
    if ids.len() < 2 {
        return;
    }
    c.estimates += ids.len() as u64;
    SCRATCH.with(|s| {
        let (lbs, _, _) = &mut *s.borrow_mut();
        lbs.clear();
        lbs.reserve(ids.len());
        let (mut ub1, mut ub2) = (f64::INFINITY, f64::INFINITY);
        for &id in ids.iter() {
            let (lb, ub) = estimate_bounds(query, codes.row_q(id as usize), codes.dim());
            lbs.push(lb);
            if ub < ub1 {
                ub2 = ub1;
                ub1 = ub;
            } else if ub < ub2 {
                ub2 = ub;
            }
        }
        let mut w = 0;
        for r in 0..ids.len() {
            if lbs[r] <= ub2 {
                ids[w] = ids[r];
                if let Some(tags) = tags.as_deref_mut() {
                    tags[w] = tags[r];
                }
                w += 1;
            }
        }
        ids.truncate(w);
        if let Some(tags) = tags {
            tags.truncate(w);
        }
    });
}

// Per-thread scan scratch: lower bounds, survivor slots, survivor
// candidate ids. Thread-local (not per-call allocation) for the same
// reason the serve scratch is: these scans sit inside the n-loop.
thread_local! {
    static SCRATCH: RefCell<(Vec<f64>, Vec<u32>, Vec<u32>)> =
        const { RefCell::new((Vec::new(), Vec::new(), Vec::new())) };
}

/// Score `0..k` candidates with the estimator, returning the survivor
/// ids (candidates whose `lb ≤ min_ub`, in candidate order) into `keep`.
/// `ids` maps slot → candidate id scored (identity for row scans).
fn prune_pass(
    query: QuantRow<'_>,
    codes: &QuantizedCodes,
    ids: Option<&[u32]>,
    lbs: &mut Vec<f64>,
    keep: &mut Vec<u32>,
) {
    let k = ids.map_or(codes.rows(), <[u32]>::len);
    lbs.clear();
    lbs.reserve(k);
    let mut min_ub = f64::INFINITY;
    for slot in 0..k {
        let j = ids.map_or(slot, |ids| ids[slot] as usize);
        let (lb, ub) = estimate_bounds(query, codes.row_q(j), codes.dim());
        lbs.push(lb);
        if ub < min_ub {
            min_ub = ub;
        }
    }
    keep.clear();
    for (slot, &lb) in lbs.iter().enumerate() {
        if lb <= min_ub {
            keep.push(slot as u32);
        }
    }
}

/// Pruned twin of [`nearest_sq_rows`](super::nearest_sq_rows): estimate
/// all `rows.rows()` candidates (billed to `estimates`), prune, then
/// strict-re-rank the survivors (billed one distance each). Returns the
/// full scan's exact `(argmin, sqdist)` — value and index bit-identical
/// to Strict.
pub fn nearest_sq_rows_pruned(
    x: &[f32],
    rows: &Matrix,
    qp: &QuantPair<'_>,
    c: &mut OpCounter,
) -> (u32, f32) {
    let k = rows.rows();
    debug_assert_eq!(qp.cands.rows(), k);
    c.estimates += k as u64;
    SCRATCH.with(|s| {
        let (lbs, keep, _) = &mut *s.borrow_mut();
        prune_pass(qp.query, qp.cands, None, lbs, keep);
        c.distances += keep.len() as u64;
        if keep.is_empty() {
            return (0, f32::INFINITY);
        }
        let (slot, sq) = super::nearest_sq_in_block_scan(x, rows, keep);
        (keep[slot], sq)
    })
}

/// Pruned twin of [`nearest_rows`](super::nearest_rows) — plain-distance
/// argmin; pruning happens on squared bounds (sound through the `sqrt`,
/// see the module docs).
pub fn nearest_rows_pruned(
    x: &[f32],
    rows: &Matrix,
    qp: &QuantPair<'_>,
    c: &mut OpCounter,
) -> (u32, f32) {
    let k = rows.rows();
    debug_assert_eq!(qp.cands.rows(), k);
    c.estimates += k as u64;
    SCRATCH.with(|s| {
        let (lbs, keep, _) = &mut *s.borrow_mut();
        prune_pass(qp.query, qp.cands, None, lbs, keep);
        c.distances += keep.len() as u64;
        if keep.is_empty() {
            return (0, f32::INFINITY);
        }
        let (slot, dv) = super::nearest_in_block_scan(x, rows, keep);
        (keep[slot], dv)
    })
}

/// Pruned twin of [`nearest_in_block`](super::nearest_in_block): the
/// candidate-list (plain-distance) scan — k²-means' `N_kn`
/// neighbourhood shape. Returns `(slot, dist)` with `slot` indexing
/// `cand`, exactly like the unpruned scan.
pub fn nearest_in_block_pruned(
    x: &[f32],
    rows: &Matrix,
    cand: &[u32],
    qp: &QuantPair<'_>,
    c: &mut OpCounter,
) -> (usize, f32) {
    c.estimates += cand.len() as u64;
    SCRATCH.with(|s| {
        let (lbs, keep, sub) = &mut *s.borrow_mut();
        prune_pass(qp.query, qp.cands, Some(cand), lbs, keep);
        c.distances += keep.len() as u64;
        if keep.is_empty() {
            return (0, f32::INFINITY);
        }
        sub.clear();
        sub.extend(keep.iter().map(|&slot| cand[slot as usize]));
        let (slot, dv) = super::nearest_in_block_scan(x, rows, sub);
        (keep[slot] as usize, dv)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::ops;
    use crate::testing::random_matrix;

    fn codes_for(rows: &Matrix) -> QuantizedCodes {
        QuantizedCodes::pack(rows, &column_means(rows))
    }

    #[test]
    fn pack_dims_cross_word_and_tail_boundaries() {
        for d in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            let m = random_matrix(5, d, d as u64 + 3);
            let codes = codes_for(&m);
            assert_eq!(codes.dim(), d);
            assert_eq!(codes.words(), d.div_ceil(64));
            assert_eq!(codes.bits().len(), 5 * codes.words());
            // Tail bits beyond `d` must be zero (both sides of an XOR
            // see the same padding, so popcounts count only real dims).
            if d % 64 != 0 && codes.words() > 0 {
                let mask = !0u64 << (d % 64);
                for i in 0..5 {
                    assert_eq!(codes.row_q(i).bits[codes.words() - 1] & mask, 0, "d={d} i={i}");
                }
            }
        }
    }

    #[test]
    fn head_decomposition_invariants() {
        let m = random_matrix(7, 33, 11);
        let codes = codes_for(&m);
        for i in 0..7 {
            let h = codes.row_q(i).head;
            // err² + sum_abs²/d == norm2 (the orthogonal decomposition),
            // up to f32 storage rounding.
            let lhs = h.err as f64 * h.err as f64
                + h.sum_abs as f64 * h.sum_abs as f64 / 33.0;
            assert!((lhs - h.norm2 as f64).abs() <= 1e-4 * (1.0 + h.norm2 as f64), "i={i}");
            assert!((h.scale - h.sum_abs / 33.0).abs() <= 1e-5 * (1.0 + h.scale.abs()));
        }
    }

    #[test]
    fn bounds_bracket_exact_sqdist() {
        for d in [1usize, 8, 63, 64, 65, 100] {
            let m = random_matrix(9, d, 17 + d as u64);
            let codes = codes_for(&m);
            for i in 0..9 {
                for j in 0..9 {
                    let (lb, ub) = estimate_bounds(codes.row_q(i), codes.row_q(j), d);
                    let exact = ops::sqdist_raw(m.row(i), m.row(j)) as f64;
                    assert!(lb <= exact && exact <= ub, "d={d} ({i},{j}) {lb} {exact} {ub}");
                }
            }
        }
    }

    #[test]
    fn self_pair_bounds_are_tight_at_zero() {
        let m = random_matrix(4, 40, 23);
        let codes = codes_for(&m);
        for i in 0..4 {
            let (lb, _) = estimate_bounds(codes.row_q(i), codes.row_q(i), 40);
            assert_eq!(lb, 0.0);
        }
    }

    #[test]
    fn pruned_scans_match_full_strict_scans() {
        let m = random_matrix(60, 21, 31);
        let q = random_matrix(8, 21, 32);
        let mu = column_means(&m);
        let codes = QuantizedCodes::pack(&m, &mu);
        let mut bits = Vec::new();
        for i in 0..8 {
            let head = pack_row(q.row(i), &mu, &mut bits);
            let qp = QuantPair { query: QuantRow { head, bits: &bits }, cands: &codes };
            let mut c = OpCounter::default();
            let got_sq = nearest_sq_rows_pruned(q.row(i), &m, &qp, &mut c);
            let want_sq = super::super::nearest_sq_rows_raw(q.row(i), &m);
            assert_eq!(got_sq.0, want_sq.0, "i={i}");
            assert_eq!(got_sq.1.to_bits(), want_sq.1.to_bits(), "i={i}");
            assert_eq!(c.estimates, 60);
            assert!(c.distances <= 60);

            let got_pl = nearest_rows_pruned(q.row(i), &m, &qp, &mut c);
            let mut want_c = OpCounter::default();
            let want_pl = super::super::nearest_rows(q.row(i), &m, &mut want_c);
            assert_eq!(got_pl.0, want_pl.0, "i={i}");
            assert_eq!(got_pl.1.to_bits(), want_pl.1.to_bits(), "i={i}");
        }
    }

    #[test]
    fn pruned_block_scan_matches_and_respects_candidate_list() {
        let m = random_matrix(30, 13, 41);
        let q = random_matrix(1, 13, 42);
        let mu = column_means(&m);
        let codes = QuantizedCodes::pack(&m, &mu);
        let mut bits = Vec::new();
        let head = pack_row(q.row(0), &mu, &mut bits);
        let qp = QuantPair { query: QuantRow { head, bits: &bits }, cands: &codes };
        let cand: Vec<u32> = vec![7, 3, 19, 3, 28, 0];
        let mut c = OpCounter::default();
        let got = nearest_in_block_pruned(q.row(0), &m, &cand, &qp, &mut c);
        let mut wc = OpCounter::default();
        let want = super::super::nearest_in_block(q.row(0), &m, &cand, &mut wc);
        assert_eq!(got.0, want.0);
        assert_eq!(got.1.to_bits(), want.1.to_bits());
        assert_eq!(c.estimates, cand.len() as u64);
        assert!(c.distances <= wc.distances);
    }

    #[test]
    fn near_binary_data_actually_prunes() {
        // ±1 patterns with tiny jitter: err ≈ 0, so the certified radius
        // collapses and far candidates must actually be pruned.
        let d = 64usize;
        let k = 32usize;
        let base = random_matrix(k, d, 7);
        let mut data = Matrix::zeros(k, d);
        for i in 0..k {
            for j in 0..d {
                let sign = if base.row(i)[j] >= 0.0 { 1.0 } else { -1.0 };
                data.row_mut(i)[j] = sign + 1e-4 * base.row(i)[j];
            }
        }
        let codes = codes_for(&data);
        let mu = column_means(&data);
        let mut bits = Vec::new();
        let head = pack_row(data.row(0), &mu, &mut bits);
        let qp = QuantPair { query: QuantRow { head, bits: &bits }, cands: &codes };
        let mut c = OpCounter::default();
        let (j, sq) = nearest_sq_rows_pruned(data.row(0), &data, &qp, &mut c);
        assert_eq!(j, 0);
        assert_eq!(sq, 0.0);
        assert!(c.distances < k as u64, "no pruning happened: {} exact", c.distances);
    }

    /// The unrolled popcount must equal the naive one-accumulator fold
    /// exactly (u64 addition is associative) across word counts that
    /// cover every remainder of the 4-wide unroll.
    #[test]
    fn xor_popcount_matches_naive_fold() {
        let mut state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for len in 0usize..=9 {
            let x: Vec<u64> = (0..len).map(|_| next()).collect();
            let y: Vec<u64> = (0..len).map(|_| next()).collect();
            let naive: u64 =
                x.iter().zip(&y).map(|(a, b)| (a ^ b).count_ones() as u64).sum();
            assert_eq!(xor_popcount(&x, &y), naive, "len={len}");
        }
    }

    /// `repack_row` over every row must reproduce `pack` byte for byte
    /// — the bitwise guarantee the moved-set refresh relies on.
    #[test]
    fn repack_row_matches_full_pack_bitwise() {
        let before = random_matrix(6, 70, 52);
        let after = random_matrix(6, 70, 53);
        let mu = column_means(&before);
        let mut incremental = QuantizedCodes::pack(&before, &mu);
        for i in [1usize, 4] {
            incremental.repack_row(i, after.row(i));
        }
        // Reference: full pack of the mixed matrix.
        let mut mixed = before.clone();
        for i in [1usize, 4] {
            mixed.row_mut(i).copy_from_slice(after.row(i));
        }
        assert_eq!(incremental, QuantizedCodes::pack(&mixed, &mu));
    }

    #[test]
    fn from_parts_round_trips_and_rejects_bad_lengths() {
        let m = random_matrix(6, 70, 51);
        let codes = codes_for(&m);
        let rebuilt = QuantizedCodes::from_parts(
            codes.dim(),
            codes.mu().to_vec(),
            &codes.heads_flat(),
            codes.bits().to_vec(),
        )
        .unwrap();
        assert_eq!(rebuilt, codes);
        let heads = codes.heads_flat();
        let bits = codes.bits().to_vec();
        let mu = codes.mu().to_vec();
        assert!(QuantizedCodes::from_parts(70, vec![0.0; 69], &heads, bits.clone()).is_none());
        assert!(QuantizedCodes::from_parts(70, mu.clone(), &heads[1..], bits).is_none());
        assert!(QuantizedCodes::from_parts(70, mu, &heads, vec![0; 5]).is_none());
    }

    #[test]
    fn zero_dim_degenerates_cleanly() {
        let m = Matrix::zeros(3, 0);
        let codes = codes_for(&m);
        assert_eq!(codes.words(), 0);
        let (lb, ub) = estimate_bounds(codes.row_q(0), codes.row_q(1), 0);
        assert_eq!(lb, 0.0);
        assert!(ub > 0.0 && ub < 1e-20);
        let qp = QuantPair { query: codes.row_q(0), cands: &codes };
        let mut c = OpCounter::default();
        let (j, sq) = nearest_sq_rows_pruned(&[], &m, &qp, &mut c);
        assert_eq!((j, sq), (0, 0.0));
    }
}
