//! `k2m` — the command-line laboratory for the k²-means reproduction.
//!
//! ```text
//! k2m cluster   --dataset mnist50 --k 200 --method k2means [--kn 30] [--threads N] [--numerics strict|fast|quantized] [--refresh full|incremental] [--scan gated|batched] [--engine rust|xla]
//! k2m train     --dataset mnist50 --k 200 --method k2means --save-model model.k2mm
//! k2m serve     --model model.k2mm --queries q.k2b [--m 5] [--threads N] [--numerics strict|fast|quantized] [--scan gated|batched] [--out labels.csv]
//! k2m table4    [--seeds 5] [--full] [--per-k]      # paper Tables 4/7
//! k2m table5    [--seeds 3] [--full]                # speedup @1% (Table 5/10)
//! k2m table6    [--seeds 3] [--full]                # speedup @0% (Table 6/8)
//! k2m table9    [--seeds 3] [--full]                # speedup @0.5% (Table 9)
//! k2m table11   [--seeds 3] [--full]                # speedup @2% (Table 11)
//! k2m fig2      [--full]                            # Figures 2/3 CSVs
//! k2m fig4      [--full]                            # Figure 4 CSVs
//! k2m gen-data  --dataset usps --out usps.k2b [--scale 0.1] [--chunk-rows 4096]
//! k2m engines                                       # XLA vs native cross-check
//! k2m jobs      --manifest runs.txt [--budget N]    # concurrent clustering jobs
//! k2m bigmeans  --data big.k2c --k 200 [--samples 8] [--sample-rows 2048] [--round 4] [--method k2means] [--no-assign]
//! ```
//!
//! `k2m train` / `k2m serve` are the train/serve split: `train` runs any
//! counted-path method and persists the resulting
//! [`k2m::cluster::ClusterModel`] (versioned `.k2mm` binary); `serve`
//! loads one and answers batched assignment queries with the bounded
//! graph scan of [`k2m::runtime::ServeService`] — exact, but typically
//! far below `k` distance evaluations per query. A jobs-manifest line
//! can also persist its model with `save_model=<path>`.
//!
//! `k2m jobs` executes a manifest of clustering runs concurrently on the
//! persistent worker pool — one job per line as space-separated
//! `key=value` pairs (`#` starts a comment):
//!
//! ```text
//! name=codebook method=k2means init=gdi dataset=mnist50 scale=0.05 k=200 kn=30
//! name=baseline method=lloyd dataset=usps scale=0.2 k=100 iters=50 seed=1
//! name=external method=elkan data=points.csv k=64 numerics=fast
//! name=oocore method=bigmeans data=big.k2c k=200 samples=8 sample_rows=2048 round=4
//! ```
//!
//! A `data=` path ending in `.k2c` is opened as an out-of-core
//! [`k2m::data::ChunkedMatrix`] (write one with
//! `k2m gen-data --chunk-rows`); roster methods materialize it once,
//! `method=bigmeans` streams it. `k2m bigmeans` is the standalone
//! front-end for the same driver ([`k2m::cluster::bigmeans`]).
//!
//! Experiment outputs land in `out/` (tables as .txt + .csv, figures as
//! .csv per (dataset, k)); see DESIGN.md §5 for the experiment index.

#![allow(clippy::type_complexity)] // fn-pointer algorithm rosters

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use k2m::cli::Args;
use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, ClusterModel, Config, KmeansResult,
    MiniBatchOpts,
};
use k2m::coordinator::datasets::{init_set, speedup_set};
use k2m::coordinator::figures::{emit_fig2, emit_fig4};
use k2m::coordinator::inits::init_table;
use k2m::coordinator::speedup::{speedup_table, SpeedupConfig};
use k2m::coordinator::tablefmt::{render_init, render_speedup, speedup_csv};
use k2m::core::{NumericsMode, OpCounter, RefreshMode, ScanMode};
use k2m::data;
use k2m::init::{gdi, kmeans_pp, random_init, GdiOpts};
use k2m::runtime::{k2means_engine, lloyd_engine, Engine, RustEngine, XlaEngine};

const USAGE: &str = "k2m <cluster|train|serve|jobs|bigmeans|table4|table5|table6|table9|table11|fig2|fig4|gen-data|engines|help> [flags]
run `k2m help` or see rust/src/main.rs for the flag surface";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    match argv[0].as_str() {
        "cluster" => cmd_cluster(argv),
        "train" => cmd_train(argv),
        "serve" => cmd_serve(argv),
        "table4" | "table7" => cmd_table4(argv),
        "table5" => cmd_speedup(argv, 0.01, "table5"),
        "table6" => cmd_speedup(argv, 0.0, "table6"),
        "table9" => cmd_speedup(argv, 0.005, "table9"),
        "table11" => cmd_speedup(argv, 0.02, "table11"),
        "fig2" | "fig3" => cmd_fig(argv, true),
        "fig4" => cmd_fig(argv, false),
        "gen-data" => cmd_gen_data(argv),
        "engines" => cmd_engines(argv),
        "ablation" => cmd_ablation(argv),
        "jobs" => cmd_jobs(argv),
        "bigmeans" => cmd_bigmeans(argv),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

fn out_dir() -> Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from("out");
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// Load a dataset either from an explicit file path (`.csv`, `.k2c`
/// chunked — materialized resident — else the `.k2b` binary format) or
/// by simulacrum name + scale (generator seed 0xD5, the experiment
/// convention). `name`/`scale` are ignored when `data_path` is given.
/// Shared by `cluster` and `jobs` so the two surfaces cannot drift.
fn load_dataset(data_path: Option<&str>, name: &str, scale: f64) -> Result<data::Dataset> {
    if let Some(path) = data_path {
        let p = Path::new(path);
        if path.ends_with(".k2c") {
            let store = data::ChunkedMatrix::open(p)?;
            let x = store.materialize();
            return Ok(data::Dataset {
                name: store.name().to_string(),
                x: (*x).clone(),
                seed: 0,
            });
        }
        return if path.ends_with(".csv") { data::load_csv(p) } else { data::load_bin(p) };
    }
    data::by_name(name, scale, 0xD5).with_context(|| format!("unknown dataset {name}"))
}

/// Load a dataset as a [`k2m::data::DatasetSource`]: a `.k2c` path
/// stays **out of core** (chunked, streamed on demand); anything else
/// resolves through [`load_dataset`] and rides in RAM. This is the
/// loader for surfaces that can stream (`jobs`, `bigmeans`).
fn load_source(
    data_path: Option<&str>,
    name: &str,
    scale: f64,
) -> Result<(k2m::data::DatasetSource, String)> {
    if let Some(path) = data_path {
        if path.ends_with(".k2c") {
            let store = data::ChunkedMatrix::open(Path::new(path))?;
            let label = store.name().to_string();
            return Ok((k2m::data::DatasetSource::from(store), label));
        }
    }
    let ds = load_dataset(data_path, name, scale)?;
    Ok((k2m::data::DatasetSource::from(ds.x), ds.name))
}

/// Resolve a `--numerics` / `numerics=` spelling: absent falls back to
/// the once-cached `K2M_NUMERICS` resolution (else Strict); typos fail
/// loudly, same policy as unknown flags.
fn parse_numerics(raw: Option<&str>) -> Result<NumericsMode> {
    match raw {
        None => Ok(NumericsMode::from_env()),
        Some(s) => NumericsMode::parse(s)
            .ok_or_else(|| anyhow!("numerics must be strict|fast|quantized, got {s:?}")),
    }
}

/// Resolve a `--refresh` / `refresh=` spelling: absent falls back to the
/// once-cached `K2M_REFRESH` resolution (else Incremental); typos fail
/// loudly, same policy as unknown flags.
fn parse_refresh(raw: Option<&str>) -> Result<RefreshMode> {
    match raw {
        None => Ok(RefreshMode::from_env()),
        Some(s) => RefreshMode::parse(s)
            .ok_or_else(|| anyhow!("refresh must be full|incremental, got {s:?}")),
    }
}

/// Resolve a `--scan` / `scan=` spelling: absent falls back to the
/// once-cached `K2M_SCAN` resolution (else Batched); typos fail loudly,
/// same policy as unknown flags.
fn parse_scan(raw: Option<&str>) -> Result<ScanMode> {
    match raw {
        None => Ok(ScanMode::from_env()),
        Some(s) => {
            ScanMode::parse(s).ok_or_else(|| anyhow!("scan must be gated|batched, got {s:?}"))
        }
    }
}

fn cmd_cluster(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "dataset", "data", "k", "kn", "m", "method", "iters", "seed", "scale", "engine",
            "threads", "numerics", "refresh", "scan",
        ],
        &[],
    )?;
    let k = args.get_parse("k", 100usize)?;
    if k == 0 {
        bail!("--k must be >= 1");
    }
    let seed = args.get_parse("seed", 0u64)?;
    let scale = args.get_parse("scale", 0.05f64)?;
    let method = args.get("method").unwrap_or("k2means").to_string();
    let max_iters = args.get_parse("iters", 100usize)?;
    let numerics = parse_numerics(args.get("numerics"))?;
    let refresh = parse_refresh(args.get("refresh"))?;
    let scan = parse_scan(args.get("scan"))?;

    let ds = load_dataset(args.get("data"), args.get("dataset").unwrap_or("mnist50"), scale)?;
    eprintln!("dataset {} (n={}, d={}), k={k}, method={method}", ds.name, ds.n(), ds.d());

    // Engine path (batched; demonstrates the AOT artifacts end-to-end).
    if let Some(engine_name) = args.get("engine") {
        let kn = args.get_parse("kn", 30usize)?;
        let mut counter = OpCounter::default();
        // GDI rides the same --threads/--numerics knobs as the counted
        // path below.
        let gopts = GdiOpts {
            threads: args.get_parse("threads", 0usize)?,
            numerics,
            ..Default::default()
        };
        let init = gdi(&ds.x, k, &mut counter, seed, &gopts);
        let mut engine: Box<dyn Engine> = match engine_name {
            "rust" => Box::new(RustEngine::with_numerics(numerics)),
            // The XLA backend's arithmetic is fixed by its AOT
            // artifacts; --numerics only governs native scans.
            "xla" => Box::new(XlaEngine::new(&k2m::runtime::default_artifact_dir())?),
            other => bail!("unknown engine {other:?} (rust|xla)"),
        };
        let t0 = std::time::Instant::now();
        let r = if method == "lloyd" {
            lloyd_engine(&ds.x, &init.centers, max_iters, engine.as_mut())?
        } else {
            k2means_engine(
                &ds.x, &init.centers, init.labels.as_deref(), kn, max_iters,
                engine.as_mut(),
            )?
        };
        println!(
            "engine={} method={method} energy={:.6e} iters={} converged={} wall={:?}",
            engine.name(), r.energy, r.iters, r.converged, t0.elapsed()
        );
        return Ok(());
    }

    // Counted algorithm path (the paper's op-accounting methodology).
    let mut counter = OpCounter::default();
    let cfg = Config {
        k,
        kn: args.get_parse("kn", 30usize)?.clamp(1, k),
        m: args.get_parse("m", 30usize)?,
        max_iters,
        seed,
        // 0 = auto: K2M_THREADS, else available parallelism (scaled for
        // small workloads). Any value gives bit-identical labels.
        threads: args.get_parse("threads", 0usize)?,
        numerics,
        refresh,
        scan,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = run_counted_method(&ds.x, &method, &cfg, &mut counter)?;
    println!(
        "method={method} energy={:.6e} iters={} converged={} vector_ops={:.3e} wall={:?}",
        result.energy,
        result.iters,
        result.converged,
        counter.total(),
        t0.elapsed()
    );
    Ok(())
}

/// Dispatch one counted-path method by its CLI spelling — the single
/// roster behind `k2m cluster` and `k2m train`, so the two surfaces
/// cannot drift. The `++` variants seed from k-means++ instead of the
/// method's default init (random for everything but k²-means, which
/// always seeds from GDI per the paper's pairing).
fn run_counted_method(
    x: &k2m::core::Matrix,
    method: &str,
    cfg: &Config,
    counter: &mut OpCounter,
) -> Result<KmeansResult> {
    let (k, seed) = (cfg.k, cfg.seed);
    Ok(match method {
        "lloyd" => lloyd(x, &random_init(x, k, seed), cfg, counter),
        "lloyd++" => {
            let init = kmeans_pp(x, k, counter, seed);
            lloyd(x, &init, cfg, counter)
        }
        "elkan" => elkan(x, &random_init(x, k, seed), cfg, counter),
        "elkan++" => {
            let init = kmeans_pp(x, k, counter, seed);
            elkan(x, &init, cfg, counter)
        }
        "hamerly" => hamerly(x, &random_init(x, k, seed), cfg, counter),
        "yinyang" => yinyang(x, &random_init(x, k, seed), cfg, counter),
        "minibatch" => {
            minibatch(x, &random_init(x, k, seed), cfg, &MiniBatchOpts::default(), counter)
        }
        "akm" => akm(x, &random_init(x, k, seed), cfg, counter),
        "k2means" => {
            // GDI rides the same --threads/--numerics knobs as the
            // iteration phase.
            let gopts =
                GdiOpts { threads: cfg.threads, numerics: cfg.numerics, ..Default::default() };
            let init = gdi(x, k, counter, seed, &gopts);
            k2means(x, &init, cfg, counter)
        }
        other => bail!("unknown method {other:?}"),
    })
}

/// `k2m train`: run a counted-path method and persist the trained
/// [`ClusterModel`] — the write side of the train/serve split. Flags
/// mirror `k2m cluster`'s counted path plus `--save-model <path>`.
fn cmd_train(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &[
            "dataset", "data", "k", "kn", "m", "method", "iters", "seed", "scale", "threads",
            "numerics", "refresh", "scan", "save-model",
        ],
        &[],
    )?;
    let k = args.get_parse("k", 100usize)?;
    if k == 0 {
        bail!("--k must be >= 1");
    }
    let seed = args.get_parse("seed", 0u64)?;
    let scale = args.get_parse("scale", 0.05f64)?;
    let method = args.get("method").unwrap_or("k2means").to_string();
    let numerics = parse_numerics(args.get("numerics"))?;
    let refresh = parse_refresh(args.get("refresh"))?;
    let scan = parse_scan(args.get("scan"))?;
    let save = args.require("save-model")?;

    let ds = load_dataset(args.get("data"), args.get("dataset").unwrap_or("mnist50"), scale)?;
    eprintln!("dataset {} (n={}, d={}), k={k}, method={method}", ds.name, ds.n(), ds.d());

    let mut counter = OpCounter::default();
    let cfg = Config {
        k,
        kn: args.get_parse("kn", 30usize)?.clamp(1, k),
        m: args.get_parse("m", 30usize)?,
        max_iters: args.get_parse("iters", 100usize)?,
        seed,
        threads: args.get_parse("threads", 0usize)?,
        numerics,
        refresh,
        scan,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = run_counted_method(&ds.x, &method, &cfg, &mut counter)?;
    println!(
        "method={method} energy={:.6e} iters={} converged={} vector_ops={:.3e} wall={:?}",
        result.energy,
        result.iters,
        result.converged,
        counter.total(),
        t0.elapsed()
    );
    let model = &result.model;
    model.save(Path::new(save)).with_context(|| format!("save model to {save}"))?;
    println!("model saved to {save} (k={}, d={}, kn={})", model.k(), model.d(), model.kn());
    Ok(())
}

/// `k2m serve`: load a saved [`ClusterModel`] and answer a batch of
/// queries with the bounded graph scan ([`k2m::runtime::ServeService`])
/// — exact against a full scan on the serving tier, but typically far
/// fewer than `k` distance evaluations per query (the summary line
/// reports the savings). `--queries` takes a `.csv`/`.k2b` file;
/// without it `--dataset`/`--scale` generate the simulacrum queries.
/// `--m N` additionally reports the exact top-N centers; `--out` writes
/// per-query `label,distance` CSV rows.
fn cmd_serve(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &["model", "queries", "dataset", "scale", "m", "threads", "numerics", "scan", "out"],
        &[],
    )?;
    let model_path = args.require("model")?;
    let model = ClusterModel::load(Path::new(model_path))
        .with_context(|| format!("load model {model_path}"))?;
    let trained = model.config();
    eprintln!(
        "model {model_path}: k={}, d={}, kn={} (trained with threads={}, numerics={})",
        model.k(),
        model.d(),
        model.kn(),
        trained.threads,
        trained.numerics.name()
    );

    let scale = args.get_parse("scale", 0.05f64)?;
    let ds = load_dataset(args.get("queries"), args.get("dataset").unwrap_or("mnist50"), scale)?;
    if ds.d() != model.d() {
        bail!(
            "query dimensionality {} does not match the model's {} (queries {})",
            ds.d(),
            model.d(),
            ds.name
        );
    }

    // Serving defaults come from the model's training provenance; both
    // are overridable per serve run.
    let threads = args.get_parse("threads", trained.threads)?;
    let numerics = match args.get("numerics") {
        None => trained.numerics,
        Some(s) => NumericsMode::parse(s)
            .ok_or_else(|| anyhow!("numerics must be strict|fast|quantized, got {s:?}"))?,
    };
    let m = args.get_parse("m", 0usize)?;
    let k = model.k();
    let mut svc = k2m::runtime::ServeService::with_options(model, threads, numerics);
    // Serving is bitwise identical under either scan mode; the flag (or
    // K2M_SCAN) only picks the loop shape.
    svc.set_scan(parse_scan(args.get("scan"))?);

    let n = ds.n();
    let mut counter = OpCounter::default();
    let t0 = std::time::Instant::now();
    let (labels, dists) = svc.assign(&ds.x, &mut counter);
    let wall = t0.elapsed();
    let full_bill = (n as u64) * (k as u64);
    println!(
        "served {n} queries in {wall:?} ({:.0} queries/s) numerics={}",
        n as f64 / wall.as_secs_f64().max(1e-9),
        svc.numerics().name()
    );
    println!(
        "distance evals: {} vs full-scan {} ({:.1}% saved)",
        counter.distances,
        full_bill,
        (1.0 - counter.distances as f64 / full_bill.max(1) as f64) * 100.0
    );

    if m >= 1 {
        let mut ctr_m = OpCounter::default();
        let t0 = std::time::Instant::now();
        let (idx, _md) = svc.nearest_centers(&ds.x, m, &mut ctr_m);
        let mm = idx.len() / n.max(1);
        println!(
            "top-{mm} ranking in {:?}: {} distance evals ({:.1}% of full scan)",
            t0.elapsed(),
            ctr_m.distances,
            ctr_m.distances as f64 / full_bill.max(1) as f64 * 100.0
        );
    }

    if let Some(out) = args.get("out") {
        let mut text = String::with_capacity(n * 12);
        for (l, dv) in labels.iter().zip(&dists) {
            text.push_str(&format!("{l},{dv:.7e}\n"));
        }
        std::fs::write(out, text).with_context(|| format!("write labels to {out}"))?;
        println!("wrote {n} label,distance rows to {out}");
    }
    Ok(())
}

fn cmd_table4(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["seeds", "iters"], &["full", "per-k"])?;
    let full = args.switch("full");
    let seeds = args.get_parse("seeds", if full { 20 } else { 3 })?;
    let iters = args.get_parse("iters", 100usize)?;
    let set = init_set(full, seeds);
    eprintln!(
        "[table4] {} datasets x {:?} x {} seeds (full={full})",
        set.workloads.len(), set.ks, seeds
    );
    let rows = init_table(&set, iters, true);
    let text = render_init(&rows, args.switch("per-k"));
    println!("{text}");
    let dir = out_dir()?;
    let name = if args.switch("per-k") { "table7" } else { "table4" };
    std::fs::write(dir.join(format!("{name}.txt")), &text)?;
    eprintln!("[table4] wrote out/{name}.txt");
    Ok(())
}

fn cmd_speedup(argv: &[String], band: f64, name: &str) -> Result<()> {
    let args = Args::parse(argv, &["seeds", "iters"], &["full"])?;
    let full = args.switch("full");
    let seeds = args.get_parse("seeds", 3usize)?;
    let iters = args.get_parse("iters", 100usize)?;
    let cfg = SpeedupConfig {
        band,
        max_iters: iters,
        set: speedup_set(full, seeds),
        verbose: true,
    };
    eprintln!(
        "[{name}] band={:.1}% {} datasets x {:?} x {} seeds (full={full})",
        band * 100.0,
        cfg.set.workloads.len(),
        cfg.set.ks,
        seeds
    );
    let table = speedup_table(&cfg);
    let text = render_speedup(&table);
    println!("{text}");
    let dir = out_dir()?;
    std::fs::write(dir.join(format!("{name}.txt")), &text)?;
    std::fs::write(dir.join(format!("{name}.csv")), speedup_csv(&table))?;
    eprintln!("[{name}] wrote out/{name}.txt and out/{name}.csv");
    Ok(())
}

fn cmd_fig(argv: &[String], fig2: bool) -> Result<()> {
    let args = Args::parse(argv, &["iters"], &["full"])?;
    let full = args.switch("full");
    let iters = args.get_parse("iters", 100usize)?;
    let dir = out_dir()?;
    let files = if fig2 {
        emit_fig2(&dir, full, iters)?
    } else {
        emit_fig4(&dir, full, iters)?
    };
    println!("wrote {} files under out/", files.len());
    Ok(())
}

/// `k2m jobs`: execute a manifest of clustering runs concurrently on the
/// persistent worker pool via [`run_cluster_jobs`] — the CLI face of the
/// `coordinator::jobs` scheduler. One job per manifest line,
/// space-separated `key=value` pairs; datasets are loaded once per
/// distinct source and `Arc`-shared across jobs.
fn cmd_jobs(argv: &[String]) -> Result<()> {
    use std::collections::HashMap;

    use k2m::cluster::BigMeansOpts;
    use k2m::coordinator::jobs::{JobAlgo, JobInit, JobSpec};
    use k2m::data::DatasetSource;

    let args = Args::parse(argv, &["manifest", "budget"], &[])?;
    let path = args.require("manifest")?;
    let budget = args.get_parse("budget", 0usize)?; // 0 = one job per pool worker
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read jobs manifest {path}"))?;

    // The accepted manifest surface; typos fail loudly (same policy as
    // `cli::Args` for flags). The `samples`/`sample_rows`/`round`/
    // `assign`/`sample_method` keys only apply to `method=bigmeans`
    // lines (`sample_method` picks the inner roster solver, default
    // k2means).
    const KNOWN_KEYS: [&str; 22] = [
        "name", "method", "init", "data", "dataset", "scale", "k", "kn", "m", "batch", "iters",
        "seed", "threads", "numerics", "refresh", "scan", "save_model", "samples", "sample_rows",
        "round", "assign", "sample_method",
    ];
    let mut datasets: HashMap<String, DatasetSource> = HashMap::new();
    let mut dims: Vec<(usize, usize)> = Vec::new();
    let mut submissions: Vec<(DatasetSource, JobSpec)> = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let lineno = lineno + 1;
        let mut kv: HashMap<&str, &str> = HashMap::new();
        for field in line.split_whitespace() {
            let Some((key, value)) = field.split_once('=') else {
                bail!("jobs manifest line {lineno}: bad field {field:?} (want key=value)");
            };
            if !KNOWN_KEYS.contains(&key) {
                bail!("jobs manifest line {lineno}: unknown key {key:?} (known: {KNOWN_KEYS:?})");
            }
            kv.insert(key, value);
        }
        let num = |key: &str, default: usize| -> Result<usize> {
            match kv.get(key) {
                None => Ok(default),
                Some(s) => s
                    .parse()
                    .map_err(|_| anyhow!("jobs manifest line {lineno}: bad {key}={s}")),
            }
        };

        let method = kv.get("method").copied().unwrap_or("k2means");
        let big_method = method == "bigmeans";
        // A bigmeans line's inner solver is `sample_method=` (default
        // k²-means); any roster spelling works for either role.
        let algo = if big_method {
            let inner = kv.get("sample_method").copied().unwrap_or("k2means");
            JobAlgo::parse(inner).ok_or_else(|| {
                anyhow!("jobs manifest line {lineno}: unknown sample_method {inner:?}")
            })?
        } else {
            JobAlgo::parse(method).ok_or_else(|| {
                anyhow!("jobs manifest line {lineno}: unknown method {method:?}")
            })?
        };
        let init = match kv.get("init") {
            None => JobInit::default_for(algo),
            Some(s) => JobInit::parse(s)
                .ok_or_else(|| anyhow!("jobs manifest line {lineno}: unknown init {s:?}"))?,
        };
        if !big_method {
            for key in ["samples", "sample_rows", "round", "assign", "sample_method"] {
                if kv.contains_key(key) {
                    bail!("jobs manifest line {lineno}: {key}= needs method=bigmeans");
                }
            }
        }

        // Load each distinct dataset source once; share it across jobs
        // (an `Arc` clone either way — resident matrix or chunk store).
        let cache_key: String;
        let loader: Box<dyn FnOnce() -> Result<DatasetSource>>;
        if let Some(&p) = kv.get("data") {
            let p = p.to_string();
            cache_key = format!("file:{p}");
            loader = Box::new(move || Ok(load_source(Some(&p), "", 0.0)?.0));
        } else {
            let name = kv.get("dataset").copied().unwrap_or("mnist50").to_string();
            let scale = match kv.get("scale") {
                None => 0.05f64,
                Some(s) => s
                    .parse()
                    .map_err(|_| anyhow!("jobs manifest line {lineno}: bad scale={s}"))?,
            };
            cache_key = format!("{name}@{scale}");
            loader = Box::new(move || Ok(load_source(None, &name, scale)?.0));
        }
        let x = match datasets.entry(cache_key) {
            std::collections::hash_map::Entry::Occupied(e) => e.get().clone(),
            std::collections::hash_map::Entry::Vacant(e) => {
                let x = loader().with_context(|| format!("jobs manifest line {lineno}"))?;
                e.insert(x.clone());
                x
            }
        };

        let k = num("k", 100)?;
        if k == 0 {
            bail!("jobs manifest line {lineno}: k must be >= 1");
        }
        let numerics = parse_numerics(kv.get("numerics").copied())
            .with_context(|| format!("jobs manifest line {lineno}"))?;
        let refresh = parse_refresh(kv.get("refresh").copied())
            .with_context(|| format!("jobs manifest line {lineno}"))?;
        let scan = parse_scan(kv.get("scan").copied())
            .with_context(|| format!("jobs manifest line {lineno}"))?;
        let cfg = Config {
            k,
            kn: num("kn", 30)?.clamp(1, k),
            m: num("m", 30)?,
            batch: num("batch", 100)?,
            max_iters: num("iters", 100)?,
            seed: num("seed", 0)? as u64,
            threads: num("threads", 0)?,
            numerics,
            refresh,
            scan,
            record_trace: false,
            ..Default::default()
        };
        let name = kv
            .get("name")
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("job{}", submissions.len()));
        let save_model = kv.get("save_model").map(|s| s.to_string());
        let big = if big_method {
            let sample_rows = num("sample_rows", 2048)?.min(x.rows());
            if sample_rows < cfg.k {
                bail!("jobs manifest line {lineno}: sample_rows must be >= k");
            }
            let assign = match kv.get("assign").copied().unwrap_or("yes") {
                "yes" | "true" | "1" => true,
                "no" | "false" | "0" => false,
                s => bail!("jobs manifest line {lineno}: bad assign={s} (yes|no)"),
            };
            Some(BigMeansOpts {
                samples: num("samples", 8)?.max(1),
                sample_rows,
                round: num("round", 4)?,
                algo,
                init,
                assign,
                budget: 0,
            })
        } else {
            None
        };
        dims.push((x.rows(), x.cols()));
        submissions.push((x, JobSpec { name, algo, init, cfg, save_model, big }));
    }
    if submissions.is_empty() {
        bail!("jobs manifest {path} contains no jobs");
    }

    eprintln!(
        "[jobs] {} jobs, {} distinct datasets, budget={}",
        submissions.len(),
        datasets.len(),
        if budget == 0 { "pool-width".to_string() } else { budget.to_string() }
    );
    let t0 = std::time::Instant::now();
    let outcomes = k2m::runtime::run_cluster_jobs(&submissions, budget);
    let batch_wall = t0.elapsed();

    println!(
        "{:<14}{:<11}{:<10}{:>8}{:>6}{:>6}{:>14}{:>7}{:>6}{:>12}{:>10}",
        "name", "method", "init", "n", "d", "k", "energy", "iters", "conv", "vector_ops", "wall_ms"
    );
    let mut serial_wall = std::time::Duration::ZERO;
    let mut save_failures = 0usize;
    for (outcome, &(n, d)) in outcomes.iter().zip(&dims) {
        serial_wall += outcome.wall;
        println!(
            "{:<14}{:<11}{:<10}{:>8}{:>6}{:>6}{:>14.6e}{:>7}{:>6}{:>12.3e}{:>10.1}",
            outcome.name,
            outcome.algo.name(),
            outcome.init.name(),
            n,
            d,
            outcome.result.centers.rows(),
            outcome.result.energy,
            outcome.result.iters,
            if outcome.result.converged { "yes" } else { "no" },
            outcome.counter.total(),
            outcome.wall.as_secs_f64() * 1e3,
        );
        match &outcome.saved {
            None => {}
            Some(Ok(path)) => println!("  model saved to {path}"),
            Some(Err(msg)) => {
                save_failures += 1;
                eprintln!("  [jobs] {}: model save FAILED: {msg}", outcome.name);
            }
        }
    }
    println!(
        "batch wall {:?} vs summed job wall {:?} ({:.2}x overlap)",
        batch_wall,
        serial_wall,
        serial_wall.as_secs_f64() / batch_wall.as_secs_f64().max(1e-9)
    );
    if save_failures > 0 {
        bail!("{save_failures} model save(s) failed");
    }
    Ok(())
}

/// `k2m bigmeans`: the big-means global search over an in-RAM or
/// out-of-core dataset ([`k2m::cluster::bigmeans`]) — fixed-size sample
/// subproblems solved by any roster method (`--method`, default
/// k²-means), warm-started from the shared incumbent, plus a streamed
/// full-data assignment pass unless `--no-assign`.
fn cmd_bigmeans(argv: &[String]) -> Result<()> {
    use k2m::cluster::{bigmeans, BigMeansOpts};
    use k2m::coordinator::jobs::{JobAlgo, JobInit};

    let args = Args::parse(
        argv,
        &[
            "dataset", "data", "scale", "k", "kn", "m", "batch", "method", "init", "samples",
            "sample-rows", "round", "iters", "seed", "threads", "numerics", "refresh", "scan",
            "budget", "save-model",
        ],
        &["no-assign"],
    )?;
    let k = args.get_parse("k", 100usize)?;
    if k == 0 {
        bail!("--k must be >= 1");
    }
    let method = args.get("method").unwrap_or("k2means");
    let algo = JobAlgo::parse(method)
        .ok_or_else(|| anyhow!("unknown --method {method:?} (roster spelling)"))?;
    let init = match args.get("init") {
        None => JobInit::default_for(algo),
        Some(s) => JobInit::parse(s).ok_or_else(|| anyhow!("unknown --init {s:?}"))?,
    };
    let scale = args.get_parse("scale", 0.05f64)?;
    let (src, label) =
        load_source(args.get("data"), args.get("dataset").unwrap_or("mnist50"), scale)?;
    let sample_rows = args.get_parse("sample-rows", 2048usize)?.min(src.rows());
    if sample_rows < k {
        bail!("--sample-rows must be >= --k (got {sample_rows} < {k})");
    }
    let opts = BigMeansOpts {
        samples: args.get_parse("samples", 8usize)?.max(1),
        sample_rows,
        round: args.get_parse("round", 4usize)?,
        algo,
        init,
        assign: !args.switch("no-assign"),
        budget: args.get_parse("budget", 0usize)?,
    };
    let cfg = Config {
        k,
        kn: args.get_parse("kn", 30usize)?.clamp(1, k),
        m: args.get_parse("m", 30usize)?,
        batch: args.get_parse("batch", 100usize)?,
        max_iters: args.get_parse("iters", 100usize)?,
        seed: args.get_parse("seed", 0u64)?,
        threads: args.get_parse("threads", 0usize)?,
        numerics: parse_numerics(args.get("numerics"))?,
        refresh: parse_refresh(args.get("refresh"))?,
        scan: parse_scan(args.get("scan"))?,
        record_trace: false,
        ..Default::default()
    };
    eprintln!(
        "[bigmeans] {} (n={}, d={}), k={k}, {} samples x {} rows, round={}, inner={}",
        label,
        src.rows(),
        src.cols(),
        opts.samples,
        opts.sample_rows,
        opts.round,
        algo.name(),
    );

    let mut counter = OpCounter::default();
    let t0 = std::time::Instant::now();
    let out = bigmeans(&src, &cfg, &opts, &mut counter);
    let wall = t0.elapsed();

    println!(
        "{:<8}{:<7}{:<6}{:>14}{:>7}{:>12}{:>6}",
        "sample", "round", "warm", "energy", "iters", "vector_ops", "best"
    );
    for j in &out.jobs {
        println!(
            "{:<8}{:<7}{:<6}{:>14.6e}{:>7}{:>12.3e}{:>6}",
            j.sample,
            j.round,
            if j.warm { "yes" } else { "no" },
            j.energy,
            j.iters,
            j.counter.total(),
            if j.improved { "*" } else { "" },
        );
    }
    println!(
        "incumbent sample={} sample_energy={:.6e}{} vector_ops={:.3e} wall={:?}",
        out.best_sample,
        out.sample_energy,
        if opts.assign {
            format!(" full_energy={:.6e}", out.result.energy)
        } else {
            String::new()
        },
        counter.total(),
        wall,
    );
    if let Some(path) = args.get("save-model") {
        out.result.model.save(Path::new(path))?;
        println!("model saved to {path}");
    }
    Ok(())
}

fn cmd_gen_data(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &["dataset", "out", "scale", "seed", "chunk-rows"], &[])?;
    let name = args.require("dataset")?;
    let out = args.require("out")?;
    let scale = args.get_parse("scale", 1.0f64)?;
    let seed = args.get_parse("seed", 0xD5u64)?;
    let ds = data::by_name(name, scale, seed).with_context(|| format!("unknown dataset {name}"))?;
    // `--chunk-rows` switches to the out-of-core `.k2c` chunked format
    // (same payload bits as `.k2b`, read block-by-block on demand).
    match args.get("chunk-rows") {
        Some(_) => {
            let chunk_rows = args.get_parse("chunk-rows", 4096usize)?;
            data::save_chunked(&ds, chunk_rows, Path::new(out))?;
            println!(
                "wrote {} (n={}, d={}, chunk_rows={}) to {out}",
                ds.name,
                ds.n(),
                ds.d(),
                chunk_rows.max(1)
            );
        }
        None => {
            data::save_bin(&ds, Path::new(out))?;
            println!("wrote {} (n={}, d={}) to {out}", ds.name, ds.n(), ds.d());
        }
    }
    Ok(())
}

/// Design-choice ablations (DESIGN.md §5 calls these out):
/// (a) k²-means' two ideas separated — kn-restriction alone vs + bounds;
/// (b) the exact-accelerator family (Lloyd/Elkan/Hamerly/Yinyang) in ops;
/// (c) GDI's Projective-Split iteration count;
/// (d) the init family including k-means||.
fn cmd_ablation(argv: &[String]) -> Result<()> {
    use k2m::init::{kmeans_par, KmeansParOpts};

    let args = Args::parse(argv, &["k", "scale", "seed"], &[])?;
    let k = args.get_parse("k", 100usize)?;
    let scale = args.get_parse("scale", 0.033f64)?;
    let seed = args.get_parse("seed", 0u64)?;
    let ds = data::mnist50_like(scale, 0xD5);
    println!("ablations on {} n={} d={} k={k}\n", ds.name, ds.n(), ds.d());

    // (a) k2-means: kn-restriction alone vs restriction + bounds.
    println!("(a) k2-means triangle-inequality contribution (GDI init):");
    println!(
        "{:<8}{:>16}{:>16}{:>10}{:>14}",
        "kn", "ops(no bounds)", "ops(bounds)", "saved", "energy"
    );
    for kn in [5usize, 10, 30] {
        let run = |bounds: bool| {
            let mut c = OpCounter::default();
            let init = gdi(&ds.x, k, &mut c, seed, &GdiOpts::default());
            let cfg = Config { k, kn, use_bounds: bounds, ..Default::default() };
            let r = k2means(&ds.x, &init, &cfg, &mut c);
            (c.total(), r.energy)
        };
        let (ops_nb, _) = run(false);
        let (ops_b, e) = run(true);
        println!(
            "{:<8}{:>16.3e}{:>16.3e}{:>9.1}%{:>14.4e}",
            kn,
            ops_nb,
            ops_b,
            (1.0 - ops_b / ops_nb) * 100.0,
            e
        );
    }

    // (b) exact accelerators: identical trajectories, different op bills.
    println!("\n(b) exact accelerator family (random init, identical labels):");
    let init = random_init(&ds.x, k, seed);
    let cfg = Config { k, ..Default::default() };
    type Algo = fn(
        &k2m::core::Matrix,
        &k2m::init::InitResult,
        &Config,
        &mut OpCounter,
    ) -> k2m::cluster::KmeansResult;
    let family: [(&str, Algo); 4] = [
        ("Lloyd", lloyd as Algo),
        ("Elkan", elkan as Algo),
        ("Hamerly", hamerly as Algo),
        ("Yinyang", yinyang as Algo),
    ];
    let mut reference_labels: Option<Vec<u32>> = None;
    for (name, algo) in family {
        let mut c = OpCounter::default();
        let r = algo(&ds.x, &init, &cfg, &mut c);
        let same = match &reference_labels {
            None => {
                reference_labels = Some(r.labels.clone());
                true
            }
            Some(want) => *want == r.labels,
        };
        println!(
            "  {:<10} ops {:>12.3e}  iters {:>3}  labels==Lloyd: {}",
            name,
            c.total(),
            r.iters,
            same
        );
    }

    // (c) GDI split iterations.
    println!("\n(c) GDI Projective-Split iterations (paper uses 2):");
    for iters in [1usize, 2, 4] {
        let mut c = OpCounter::default();
        let gopts = GdiOpts { split_iters: iters, ..Default::default() };
        let init = gdi(&ds.x, k, &mut c, seed, &gopts);
        let init_ops = c.total();
        let r = lloyd(&ds.x, &init, &Config { k, ..Default::default() }, &mut c);
        println!(
            "  split_iters={iters}: init ops {:>10.3e}  converged energy {:.5e}",
            init_ops, r.energy
        );
    }

    // (d) init family including k-means||.
    println!("\n(d) init family (converged Lloyd energy / init op cost):");
    for name in ["random", "k-means++", "k-means||", "GDI"] {
        let mut c = OpCounter::default();
        let init = match name {
            "random" => random_init(&ds.x, k, seed),
            "k-means++" => kmeans_pp(&ds.x, k, &mut c, seed),
            "k-means||" => kmeans_par(&ds.x, k, &KmeansParOpts::default(), &mut c, seed),
            _ => gdi(&ds.x, k, &mut c, seed, &GdiOpts::default()),
        };
        let init_ops = c.total();
        let r = lloyd(&ds.x, &init, &Config { k, ..Default::default() }, &mut c);
        println!("  {:<10} init ops {:>11.3e}   energy {:.5e}", name, init_ops, r.energy);
    }
    Ok(())
}

/// Cross-check the XLA engine against the native engine on a small
/// workload — the quick proof that the three-layer stack composes.
fn cmd_engines(argv: &[String]) -> Result<()> {
    let _ = Args::parse(argv, &[], &[])?;
    let ds = data::mnist50_like(0.01, 0xD5);
    let k = 64;
    let mut counter = OpCounter::default();
    let init = gdi(&ds.x, k, &mut counter, 1, &GdiOpts::default());

    let mut rust = RustEngine::default();
    let t0 = std::time::Instant::now();
    let r_rust = k2means_engine(&ds.x, &init.centers, init.labels.as_deref(), 16, 50, &mut rust)?;
    let t_rust = t0.elapsed();

    let mut xla = XlaEngine::new(&k2m::runtime::default_artifact_dir())?;
    eprintln!("PJRT platform: {}", xla.platform());
    let t0 = std::time::Instant::now();
    let r_xla = k2means_engine(&ds.x, &init.centers, init.labels.as_deref(), 16, 50, &mut xla)?;
    let t_xla = t0.elapsed();

    println!(
        "native: energy={:.6e} iters={} wall={t_rust:?}",
        r_rust.energy, r_rust.iters
    );
    println!(
        "xla:    energy={:.6e} iters={} wall={t_xla:?}",
        r_xla.energy, r_xla.iters
    );
    let rel = (r_rust.energy - r_xla.energy).abs() / r_rust.energy.max(1e-12);
    println!("relative energy gap: {rel:.2e}");
    if rel > 1e-3 {
        bail!("engines disagree beyond tolerance");
    }
    println!("engines agree ✓");
    Ok(())
}
