//! Deterministic PRNG substrate (PCG32) — the offline vendor set has no
//! `rand`, so we carry the standard PCG-XSH-RR 64/32 generator plus the
//! few distributions the experiments need (uniform ranges, gaussians,
//! shuffles, weighted choice for k-means++ D² sampling).
//!
//! Determinism matters here: every table/figure in EXPERIMENTS.md is
//! regenerated from (dataset seed, method seed) pairs, so runs are
//! bit-reproducible across machines.

/// PCG-XSH-RR 64/32 (Melissa O'Neill's PCG32).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// Cached second Box–Muller gaussian.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with a (seed, stream) pair. Different streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Pcg32 { state: 0, inc: (stream << 1) | 1, gauss_spare: None };
        let _ = r.next_u32();
        r.state = r.state.wrapping_add(seed);
        let _ = r.next_u32();
        r
    }

    /// Convenience single-seed constructor (stream 0xda3e39cb94b95bdb).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn gen_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_below(0)");
        let bound = bound as u64;
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return (r % bound) as usize;
            }
        }
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    #[inline]
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_below(i + 1);
            v.swap(i, j);
        }
    }

    /// `count` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n, "sample_distinct: count > n");
        // For small count relative to n, rejection is cheaper than a full
        // index vector; for dense draws do partial Fisher–Yates.
        if count * 8 < n {
            let mut seen = std::collections::HashSet::with_capacity(count * 2);
            let mut out = Vec::with_capacity(count);
            while out.len() < count {
                let i = self.gen_below(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            for i in 0..count {
                let j = i + self.gen_below(n - i);
                idx.swap(i, j);
            }
            idx.truncate(count);
            idx
        }
    }

    /// Index drawn with probability proportional to `weights` (the
    /// k-means++ D² sampler). Zero-total weight falls back to uniform.
    pub fn choose_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return self.gen_below(weights.len());
        }
        let mut t = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::seeded(8);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| c.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_below_in_range_and_covers() {
        let mut r = Pcg32::seeded(1);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut r = Pcg32::seeded(2);
        let mean: f64 = (0..20000).map(|_| r.f64()).sum::<f64>() / 20000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::seeded(3);
        let xs: Vec<f64> = (0..50000).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Pcg32::seeded(4);
        for (n, c) in [(100, 5), (50, 50), (1000, 10), (10, 9)] {
            let s = r.sample_distinct(n, c);
            assert_eq!(s.len(), c);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), c, "duplicates for n={n} c={c}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn choose_weighted_respects_mass() {
        let mut r = Pcg32::seeded(5);
        let w = [0.0, 0.0, 1.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.choose_weighted(&w), 2);
        }
        // Rough proportionality check.
        let w = [1.0, 3.0];
        let mut counts = [0usize; 2];
        for _ in 0..40000 {
            counts[r.choose_weighted(&w)] += 1;
        }
        let frac = counts[1] as f64 / 40000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(6);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }
}
