//! Contract suite for the fast-numerics kernel tier and the
//! [`NumericsMode`] dispatch layer (`core::kernels`, "The three
//! numerics tiers"; the Quantized tier has its own suite in
//! `tests/quantized.rs`).
//!
//! Three rungs, mirroring `tests/kernels.rs`'s structure for the strict
//! tier:
//!
//! 1. **Dispatch correctness** — every `NumericsMode` method routes to
//!    the right tier (Strict bit-identical to the bare strict kernels,
//!    Fast bit-identical to `kernels::fast`'s per-pair reference) and
//!    charges the identical op bill in both modes.
//! 2. **Strict-vs-Fast parity** — the all-inits × all-algorithms roster
//!    run end to end in both modes: final energies within 1e-5
//!    relative, and the integer `OpCounter` categories **equal** (the
//!    tier changes how a distance is summed, never whether it is
//!    counted). A near-tie pruning decision falling inside the two
//!    tiers' rounding gap could move a count by O(1) — on these pinned
//!    seeds none does; if this ever fires after an unrelated change,
//!    suspect an ulp-tie in a bound comparison, not a counting bug.
//! 3. **Fast-mode determinism** — the fast tier's own contract:
//!    bit-identical labels/centers/energies and exact integer op counts
//!    at 1 vs 4 vs 7 threads, and bitwise run-to-run stability on the
//!    reused process-wide pool.

use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, Config, KmeansResult, MiniBatchOpts,
};
use k2m::core::kernels::{self, fast};
use k2m::core::{Matrix, NumericsMode, OpCounter, RefreshMode};
use k2m::init::{
    gdi, kmeans_par, kmeans_pp_numerics, random_init, GdiOpts, InitResult, KmeansParOpts,
};
use k2m::knn::{knn_graph, knn_graph_mode};
use k2m::runtime::{Engine, RustEngine};
use k2m::testing::{blobs, random_matrix};

// -------------------------------------------------------------------------
// 1. Dispatch correctness + op-bill equality at the kernel level
// -------------------------------------------------------------------------

#[test]
fn dispatch_routes_each_mode_to_its_tier() {
    let d = 37;
    let k = 11;
    let rows = random_matrix(k, d, 1);
    let x = random_matrix(1, d, 2);
    let q = x.row(0);
    let cand: Vec<u32> = (0..k as u32).rev().collect();

    let mut want_strict = vec![0.0f32; k];
    kernels::sqdist_block_raw(q, &rows, &cand, &mut want_strict);
    let mut want_fast = vec![0.0f32; k];
    fast::sqdist_block_raw(q, &rows, &cand, &mut want_fast);

    for (nm, want) in [(NumericsMode::Strict, &want_strict), (NumericsMode::Fast, &want_fast)] {
        let mut c = OpCounter::default();
        let mut out = vec![0.0f32; k];
        nm.sqdist_block(q, &rows, &cand, &mut out, &mut c);
        assert_eq!(c.distances, k as u64, "{nm:?}");
        for (got, want) in out.iter().zip(want.iter()) {
            assert_eq!(got.to_bits(), want.to_bits(), "{nm:?}");
        }
        // Single-pair entry agrees with the tier's blocked scan.
        for (t, &j) in cand.iter().enumerate() {
            let one = nm.sqdist_one(q, rows.row(j as usize), &mut c);
            assert_eq!(one.to_bits(), out[t].to_bits(), "{nm:?} t={t}");
            let pl = nm.dist_one(q, rows.row(j as usize), &mut c);
            assert_eq!(pl.to_bits(), out[t].sqrt().to_bits(), "{nm:?} t={t}");
        }
    }
}

#[test]
fn every_dispatch_method_bills_identically_in_both_modes() {
    let k = 13;
    let d = 29;
    let rows = random_matrix(k, d, 3);
    let rows_b = random_matrix(k, d, 4);
    let x = random_matrix(1, d, 5);
    let q = x.row(0);
    let cand: Vec<u32> = (0..k as u32).collect();
    let bill = |nm: NumericsMode| {
        let mut c = OpCounter::default();
        let mut out = vec![0.0f32; k];
        nm.sqdist_block(q, &rows, &cand, &mut out, &mut c);
        nm.dot_block(q, &rows, &cand, &mut out, &mut c);
        nm.sqdist_rows(q, &rows, 0, &mut out, &mut c);
        nm.dist_rows(q, &rows, 0, &mut out, &mut c);
        let _ = nm.nearest_in_block(q, &rows, &cand, &mut c);
        let _ = nm.nearest_sq_in_block(q, &rows, &cand, &mut c);
        let _ = nm.nearest_sq_rows(q, &rows, &mut c);
        let _ = nm.nearest_rows(q, &rows, &mut c);
        let mut table = vec![0.0f32; k * k];
        nm.pairwise_block(&rows, &mut table, &mut c);
        nm.pairwise_dist_block(&rows, &mut table, &mut c);
        nm.dist_rowwise(&rows, &rows_b, &mut out, &mut c);
        let _ = nm.sqdist_one(q, rows.row(0), &mut c);
        let _ = nm.dist_one(q, rows.row(0), &mut c);
        c
    };
    let s = bill(NumericsMode::Strict);
    let f = bill(NumericsMode::Fast);
    assert_eq!(s.distances, f.distances);
    assert_eq!(s.inner_products, f.inner_products);
    assert_eq!(s.additions, f.additions);
    // The analytic expectation, so neither tier can be silently wrong:
    // eight k-sized scans (sqdist_block, sqdist_rows, dist_rows, the
    // four argmins, dist_rowwise), two k-choose-2 pairwise tables, two
    // single-pair calls; dot_block bills k inner products.
    let expect = 8 * k as u64 + 2 * (k * (k - 1) / 2) as u64 + 2;
    assert_eq!(s.distances, expect);
    assert_eq!(s.inner_products, k as u64);
}

#[test]
fn parse_env_and_defaults() {
    assert_eq!(NumericsMode::parse("strict"), Some(NumericsMode::Strict));
    assert_eq!(NumericsMode::parse("FAST"), Some(NumericsMode::Fast));
    assert_eq!(NumericsMode::parse("Fast"), Some(NumericsMode::Fast));
    assert_eq!(NumericsMode::parse("quantized"), Some(NumericsMode::Quantized));
    assert_eq!(NumericsMode::parse("Quantized"), Some(NumericsMode::Quantized));
    assert_eq!(NumericsMode::parse("fastest"), None);
    assert_eq!(NumericsMode::parse("quant"), None);
    assert_eq!(NumericsMode::parse(""), None);
    assert_eq!(NumericsMode::Strict.name(), "strict");
    assert_eq!(NumericsMode::Fast.name(), "fast");
    assert_eq!(NumericsMode::Quantized.name(), "quantized");
    // The pure Default is Strict; the process default honors
    // K2M_NUMERICS (this suite runs under both CI matrices).
    assert_eq!(NumericsMode::default(), NumericsMode::Strict);
    let expect_env = std::env::var("K2M_NUMERICS")
        .ok()
        .and_then(|v| NumericsMode::parse(&v))
        .unwrap_or(NumericsMode::Strict);
    assert_eq!(NumericsMode::from_env(), expect_env);
    assert_eq!(NumericsMode::from_env(), NumericsMode::from_env()); // cached
    assert_eq!(Config::default().numerics, expect_env);
    assert_eq!(GdiOpts::default().numerics, expect_env);
    assert_eq!(KmeansParOpts::default().numerics, expect_env);
}

#[test]
fn knn_graph_mode_strict_is_the_bare_entry_and_fast_is_thread_invariant() {
    let c = random_matrix(37, 16, 6);
    let mut c1 = OpCounter::default();
    let bare = knn_graph(&c, 7, &mut c1);
    let mut c2 = OpCounter::default();
    let strict = knn_graph_mode(&c, 7, &mut c2, 1, NumericsMode::Strict);
    for l in 0..37 {
        assert_eq!(bare.nbrs_row(l), strict.nbrs_row(l), "row {l}");
        for (a, b) in bare.dists_row(l).iter().zip(strict.dists_row(l)) {
            assert_eq!(a.to_bits(), b.to_bits(), "row {l}");
        }
    }
    // Fast graph: serial == sharded, same k-choose-2 bill, values close
    // to strict.
    let mut cf1 = OpCounter::default();
    let want = knn_graph_mode(&c, 7, &mut cf1, 1, NumericsMode::Fast);
    assert_eq!(cf1.distances, 37 * 36 / 2);
    for threads in [4usize, 7] {
        let mut cf = OpCounter::default();
        let got = knn_graph_mode(&c, 7, &mut cf, threads, NumericsMode::Fast);
        assert_eq!(cf.distances, cf1.distances, "threads={threads}");
        for l in 0..37 {
            assert_eq!(got.nbrs_row(l), want.nbrs_row(l), "threads={threads} row {l}");
            for (a, b) in got.dists_row(l).iter().zip(want.dists_row(l)) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads} row {l}");
            }
        }
    }
    for l in 0..37 {
        for (a, b) in want.dists_row(l).iter().zip(bare.dists_row(l)) {
            assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "row {l}: {a} vs {b}");
        }
    }
}

#[test]
fn engine_backend_tiers_agree_within_tolerance() {
    // The engine's norm-trick assignment amplifies the tiers' rounding
    // gap via cancellation, so this asserts *quality*, not label bits:
    // whichever center each tier picks, the other tier's distance to it
    // must be within tolerance of its own minimum (a label may only
    // differ at a genuine near-tie), and the achieved minima agree.
    let x = random_matrix(300, 24, 7);
    let c = random_matrix(16, 24, 8);
    let tol = |a: f32| 1e-3 * (1.0 + a.abs());
    let (ls, ds) = RustEngine::with_numerics(NumericsMode::Strict).assign_full(&x, &c).unwrap();
    let (lf, df) = RustEngine::with_numerics(NumericsMode::Fast).assign_full(&x, &c).unwrap();
    for i in 0..300 {
        assert!((ds[i] - df[i]).abs() <= tol(ds[i]), "point {i}: minima diverged");
        if ls[i] != lf[i] {
            // Near-tie: the strict distance to fast's pick must match
            // the strict minimum (and vice versa by symmetry of ds/df).
            let cross = k2m::core::ops::sqdist_raw(x.row(i), c.row(lf[i] as usize));
            assert!(
                (cross - ds[i]).abs() <= tol(ds[i]),
                "point {i}: tiers picked non-tied centers {} vs {}",
                ls[i],
                lf[i]
            );
        }
    }
    // center_knn: the neighbour *distance multisets* must agree within
    // tolerance (index order may swap at near-equal center distances).
    let (ns, dss) = RustEngine::with_numerics(NumericsMode::Strict).center_knn(&c, 5).unwrap();
    let (nf, dsf) = RustEngine::with_numerics(NumericsMode::Fast).center_knn(&c, 5).unwrap();
    for i in 0..16 {
        assert_eq!(ns[i * 5], i as u32, "strict self-first");
        assert_eq!(nf[i * 5], i as u32, "fast self-first");
        let mut a: Vec<f32> = dss[i * 5..(i + 1) * 5].to_vec();
        let mut b: Vec<f32> = dsf[i * 5..(i + 1) * 5].to_vec();
        a.sort_by(|x, y| x.partial_cmp(y).unwrap());
        b.sort_by(|x, y| x.partial_cmp(y).unwrap());
        for (av, bv) in a.iter().zip(&b) {
            assert!((av - bv).abs() <= tol(*av), "row {i} knn distances diverged");
        }
    }
}

// -------------------------------------------------------------------------
// 2 + 3. Roster parity and fast-mode determinism
// -------------------------------------------------------------------------

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

const ALGOS: [(&str, Algo); 6] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
    ("akm", akm as Algo),
];

/// The four init families, each built **on the given tier** (serial) so
/// a mode's roster is end-to-end in that mode, with the init's own op
/// bill returned for the parity checks.
fn inits(x: &Matrix, k: usize, nm: NumericsMode) -> Vec<(&'static str, InitResult, OpCounter)> {
    let mut out = Vec::new();
    out.push(("random", random_init(x, k, 5), OpCounter::default()));
    let mut c = OpCounter::default();
    let pp = kmeans_pp_numerics(x, k, &mut c, 6, 1, nm);
    out.push(("kmeans_pp", pp, c));
    let mut c = OpCounter::default();
    let par = kmeans_par(
        x,
        k,
        &KmeansParOpts { threads: 1, numerics: nm, ..Default::default() },
        &mut c,
        7,
    );
    out.push(("kmeans_par", par, c));
    let mut c = OpCounter::default();
    let g = gdi(x, k, &mut c, 8, &GdiOpts { threads: 1, numerics: nm, ..Default::default() });
    out.push(("gdi", g, c));
    out
}

fn run(
    algo: Algo,
    x: &Matrix,
    init: &InitResult,
    threads: usize,
    nm: NumericsMode,
) -> (KmeansResult, OpCounter) {
    let cfg = Config {
        k: init.k(),
        kn: 4,
        m: 8,
        max_iters: 12,
        threads,
        numerics: nm,
        record_trace: false,
        // Pinned Full: these tests compare op bills *across tiers*
        // (Strict vs Fast), whose trajectories — and therefore moved
        // sets — legitimately differ; the incremental refresh would make
        // the center-maintenance bill trajectory-dependent and the
        // cross-tier equality pins meaningless. Incremental-vs-Full
        // equivalence has its own suite (tests/refresh.rs).
        refresh: RefreshMode::Full,
        ..Default::default()
    };
    let mut c = OpCounter::default();
    let r = algo(x, init, &cfg, &mut c);
    (r, c)
}

#[test]
fn roster_strict_vs_fast_energy_and_op_count_parity() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    let strict_inits = inits(&x, 12, NumericsMode::Strict);
    let fast_inits = inits(&x, 12, NumericsMode::Fast);
    for ((iname, si, sc), (_, fi, fc)) in strict_inits.iter().zip(&fast_inits) {
        // The init phase itself bills identically across tiers.
        assert_eq!(sc.distances, fc.distances, "{iname} init distances");
        assert_eq!(sc.inner_products, fc.inner_products, "{iname} init inner products");
        assert_eq!(sc.additions, fc.additions, "{iname} init additions");
        for (aname, algo) in ALGOS {
            let (rs, cs) = run(algo, &x, si, 1, NumericsMode::Strict);
            let (rf, cf) = run(algo, &x, fi, 1, NumericsMode::Fast);
            let tag = format!("{aname}/{iname}");
            assert!(rf.energy.is_finite(), "{tag}");
            let rel = (rs.energy - rf.energy).abs() / (1.0 + rs.energy.abs());
            assert!(
                rel <= 1e-5,
                "{tag}: strict energy {} vs fast {} (rel {rel:.2e})",
                rs.energy,
                rf.energy
            );
            assert_eq!(cs.distances, cf.distances, "{tag}: distance bill");
            assert_eq!(cs.inner_products, cf.inner_products, "{tag}: inner-product bill");
            assert_eq!(cs.additions, cf.additions, "{tag}: addition bill");
        }
    }
}

#[test]
fn roster_fast_mode_bit_identical_at_1_4_7_threads() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    for (iname, init, _) in inits(&x, 12, NumericsMode::Fast) {
        for (aname, algo) in ALGOS {
            let (want, c1) = run(algo, &x, &init, 1, NumericsMode::Fast);
            for threads in [4usize, 7] {
                let (got, ct) = run(algo, &x, &init, threads, NumericsMode::Fast);
                let tag = format!("{aname}/{iname}/t{threads}");
                assert_eq!(got.labels, want.labels, "{tag}");
                assert_eq!(got.centers, want.centers, "{tag}");
                assert_eq!(got.energy.to_bits(), want.energy.to_bits(), "{tag}");
                assert_eq!(got.iters, want.iters, "{tag}");
                assert_eq!(ct.distances, c1.distances, "{tag}");
                assert_eq!(ct.inner_products, c1.inner_products, "{tag}");
                assert_eq!(ct.additions, c1.additions, "{tag}");
            }
        }
    }
}

#[test]
fn fast_mode_run_to_run_bitwise_stable_on_reused_pool() {
    // Two identical fast-mode sweeps over the roster at 4 threads; the
    // second reuses the process-wide pool the first warmed up. Every
    // bit — including the full OpCounter with its f64 sort term — must
    // match (fixed lane order × fixed shard merge order).
    let (x, _) = blobs(420, 10, 12, 8.0, 91);
    let init = gdi(
        &x,
        12,
        &mut OpCounter::default(),
        9,
        &GdiOpts { threads: 1, numerics: NumericsMode::Fast, ..Default::default() },
    );
    let sweep = || {
        ALGOS
            .iter()
            .map(|&(_, algo)| run(algo, &x, &init, 4, NumericsMode::Fast))
            .collect::<Vec<_>>()
    };
    let a = sweep();
    let b = sweep();
    for (((ra, ca), (rb, cb)), (name, _)) in a.iter().zip(&b).zip(ALGOS.iter()) {
        assert_eq!(ra.labels, rb.labels, "{name}");
        assert_eq!(ra.centers, rb.centers, "{name}");
        assert_eq!(ra.energy.to_bits(), rb.energy.to_bits(), "{name}");
        assert_eq!(ca, cb, "{name}: counters diverged run to run");
    }
}

#[test]
fn minibatch_fast_mode_parity_and_thread_invariance() {
    let (x, _) = blobs(900, 12, 10, 8.0, 92);
    let init = random_init(&x, 12, 93);
    let opts = MiniBatchOpts { iterations: Some(30), eval_every: Some(10) };
    let run_mb = |threads: usize, nm: NumericsMode| {
        let cfg = Config {
            k: 12,
            batch: 300,
            seed: 13,
            threads,
            numerics: nm,
            ..Default::default()
        };
        let mut c = OpCounter::default();
        let r = minibatch(&x, &init, &cfg, &opts, &mut c);
        (r, c)
    };
    // Parity: the sample stream is seed-driven and the bill is the
    // analytic t*b*k + t*b in both modes.
    let (rs, cs) = run_mb(1, NumericsMode::Strict);
    let (rf, cf) = run_mb(1, NumericsMode::Fast);
    assert_eq!(cs.distances, 30 * 300 * 12);
    assert_eq!(cs.distances, cf.distances);
    assert_eq!(cs.additions, cf.additions);
    let rel = (rs.energy - rf.energy).abs() / (1.0 + rs.energy.abs());
    assert!(rel <= 1e-5, "minibatch strict {} vs fast {}", rs.energy, rf.energy);
    // Fast-mode thread invariance.
    for threads in [4usize, 7] {
        let (got, ct) = run_mb(threads, NumericsMode::Fast);
        assert_eq!(got.centers, rf.centers, "t{threads}");
        assert_eq!(got.labels, rf.labels, "t{threads}");
        assert_eq!(got.energy.to_bits(), rf.energy.to_bits(), "t{threads}");
        assert_eq!(ct.distances, cf.distances, "t{threads}");
        assert_eq!(ct.additions, cf.additions, "t{threads}");
    }
}

#[test]
fn strict_default_keeps_historical_bits() {
    // Belt and braces next to tests/kernels.rs: an explicitly-Strict
    // run and a default-config run agree bitwise when the process
    // default resolves to Strict (i.e. K2M_NUMERICS unset) — the
    // "existing pins survive untouched" guarantee in one assertion.
    if NumericsMode::from_env() != NumericsMode::Strict {
        eprintln!("SKIP: K2M_NUMERICS overrides the default; pin not applicable");
        return;
    }
    let (x, _) = blobs(300, 8, 10, 8.0, 94);
    let init = random_init(&x, 10, 95);
    let mut c1 = OpCounter::default();
    let dflt = lloyd(&x, &init, &Config { k: 10, max_iters: 8, ..Default::default() }, &mut c1);
    let mut c2 = OpCounter::default();
    let strict = lloyd(
        &x,
        &init,
        &Config { k: 10, max_iters: 8, numerics: NumericsMode::Strict, ..Default::default() },
        &mut c2,
    );
    assert_eq!(dflt.labels, strict.labels);
    assert_eq!(dflt.centers, strict.centers);
    assert_eq!(c1, c2);
}
