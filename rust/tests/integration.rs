//! Integration tests across the whole stack: the XLA/PJRT engine against
//! the native engine on every artifact-menu shape, the engine-path
//! clustering loops, and the experiment coordinator end to end.
//!
//! The XLA tests need `make artifacts`; when the artifacts are missing
//! they skip with a loud message rather than fail (CI runs `make test`,
//! which builds them first).

use k2m::core::{Matrix, NumericsMode};
use k2m::coordinator::datasets::Workload;
use k2m::coordinator::speedup::{speedup_table, SpeedupConfig};
use k2m::coordinator::WorkloadSet;
use k2m::init::{gdi, GdiOpts};
use k2m::rng::Pcg32;
use k2m::runtime::{
    default_artifact_dir, k2means_engine, lloyd_engine, Engine, RustEngine, XlaEngine,
};

fn artifacts_available() -> bool {
    let ok = default_artifact_dir().join("manifest.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts/manifest.txt missing — run `make artifacts`");
    }
    ok
}

fn random_matrix(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Pcg32::seeded(seed);
    let mut m = Matrix::zeros(rows, cols);
    for i in 0..rows {
        for v in m.row_mut(i) {
            *v = rng.gaussian_f32() * 2.0;
        }
    }
    m
}

/// labels must match exactly; distances to ~1e-3 relative (the XLA path
/// computes ||x||²+||c||²−2xc, the native path (x−c)² — different
/// association order).
fn assert_assignments_match(
    (l1, d1): &(Vec<u32>, Vec<f32>),
    (l2, d2): &(Vec<u32>, Vec<f32>),
    ctx: &str,
) {
    assert_eq!(l1, l2, "labels diverged: {ctx}");
    for (i, (a, b)) in d1.iter().zip(d2.iter()).enumerate() {
        assert!(
            (a - b).abs() <= 2e-3 * (1.0 + a.abs()),
            "{ctx}: dist[{i}] {a} vs {b}"
        );
    }
}

#[test]
fn xla_assign_full_matches_native_across_shapes() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaEngine::new(&default_artifact_dir()).unwrap();
    // The XLA backend's AOT arithmetic is fixed (strict-shaped); pin
    // the native reference to the strict tier so a K2M_NUMERICS=fast
    // environment cannot skew these exact cross-checks.
    let mut native = RustEngine::with_numerics(NumericsMode::Strict);
    // Shapes probing the padding paths: under/at/over block boundaries.
    for &(n, k, d) in
        &[(100usize, 10usize, 7usize), (2048, 256, 64), (2049, 200, 50), (4100, 300, 100)]
    {
        let x = random_matrix(n, d, 1);
        let c = random_matrix(k, d, 2);
        let got = xla.assign_full(&x, &c).unwrap();
        let want = native.assign_full(&x, &c).unwrap();
        assert_assignments_match(&got, &want, &format!("assign_full n={n} k={k} d={d}"));
    }
}

#[test]
fn xla_assign_candidates_matches_native() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaEngine::new(&default_artifact_dir()).unwrap();
    let mut native = RustEngine::with_numerics(NumericsMode::Strict);
    let mut rng = Pcg32::seeded(3);
    for &(n, k, kn, d) in &[(500usize, 40usize, 8usize, 30usize), (2100, 256, 32, 64)] {
        let x = random_matrix(n, d, 4);
        let c = random_matrix(k, d, 5);
        let cand: Vec<u32> = (0..n * kn).map(|_| rng.gen_below(k) as u32).collect();
        let got = xla.assign_candidates(&x, &c, &cand, kn).unwrap();
        let want = native.assign_candidates(&x, &c, &cand, kn).unwrap();
        assert_assignments_match(&got, &want, &format!("cand n={n} k={k} kn={kn} d={d}"));
    }
}

#[test]
fn xla_center_knn_matches_native() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaEngine::new(&default_artifact_dir()).unwrap();
    let mut native = RustEngine::with_numerics(NumericsMode::Strict);
    for &(k, kn, d) in &[(64usize, 8usize, 20usize), (256, 32, 64), (100, 16, 33)] {
        let c = random_matrix(k, d, 6);
        let (gn, gd) = xla.center_knn(&c, kn).unwrap();
        let (wn, wd) = native.center_knn(&c, kn).unwrap();
        // Self must be slot 0 everywhere; distance multisets must agree
        // (index ties can reorder).
        for i in 0..k {
            assert_eq!(gn[i * kn], i as u32, "self not first (k={k} kn={kn})");
            let mut a: Vec<f32> = gd[i * kn..(i + 1) * kn].to_vec();
            let mut b: Vec<f32> = wd[i * kn..(i + 1) * kn].to_vec();
            a.sort_by(|x, y| x.partial_cmp(y).unwrap());
            b.sort_by(|x, y| x.partial_cmp(y).unwrap());
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() <= 2e-3 * (1.0 + x.abs()), "knn dist k={k} kn={kn}");
            }
        }
        let _ = wn;
    }
}

#[test]
fn xla_update_stats_matches_native() {
    if !artifacts_available() {
        return;
    }
    let mut xla = XlaEngine::new(&default_artifact_dir()).unwrap();
    let mut native = RustEngine::with_numerics(NumericsMode::Strict);
    let mut rng = Pcg32::seeded(7);
    for &(n, k, d) in &[(333usize, 12usize, 9usize), (2500, 200, 64)] {
        let x = random_matrix(n, d, 8);
        let labels: Vec<u32> = (0..n).map(|_| rng.gen_below(k) as u32).collect();
        let (gs, gc) = xla.update_stats(&x, &labels, k).unwrap();
        let (ws, wc) = native.update_stats(&x, &labels, k).unwrap();
        for j in 0..k {
            assert_eq!(gc[j], wc[j], "count[{j}] n={n}");
            for (a, b) in gs.row(j).iter().zip(ws.row(j)) {
                assert!((a - b).abs() <= 2e-3 * (1.0 + a.abs()), "sums j={j}");
            }
        }
    }
}

#[test]
fn full_k2means_identical_trajectories_across_engines() {
    if !artifacts_available() {
        return;
    }
    let ds = k2m::data::mnist50_like(0.02, 0xD5);
    let k = 100;
    let init = gdi(&ds.x, k, &mut Default::default(), 1, &GdiOpts::default());
    let mut native = RustEngine::with_numerics(NumericsMode::Strict);
    let mut xla = XlaEngine::new(&default_artifact_dir()).unwrap();
    let a = k2means_engine(&ds.x, &init.centers, init.labels.as_deref(), 16, 60, &mut native)
        .unwrap();
    let b =
        k2means_engine(&ds.x, &init.centers, init.labels.as_deref(), 16, 60, &mut xla).unwrap();
    assert_eq!(a.labels, b.labels, "engine trajectories diverged");
    assert!((a.energy - b.energy).abs() <= 1e-4 * (1.0 + a.energy));
}

#[test]
fn full_lloyd_engine_cross_check() {
    if !artifacts_available() {
        return;
    }
    let ds = k2m::data::usps_like(0.05, 0xD5);
    let seeds = k2m::init::random_init(&ds.x, 40, 3).centers;
    let mut native = RustEngine::with_numerics(NumericsMode::Strict);
    let mut xla = XlaEngine::new(&default_artifact_dir()).unwrap();
    let a = lloyd_engine(&ds.x, &seeds, 40, &mut native).unwrap();
    let b = lloyd_engine(&ds.x, &seeds, 40, &mut xla).unwrap();
    assert_eq!(a.labels, b.labels);
}

#[test]
fn coordinator_speedup_protocol_end_to_end() {
    // Pure-rust path: no artifacts needed. Small but complete: oracle,
    // bands, per-method aggregation, rendering.
    let set = WorkloadSet {
        workloads: vec![Workload { name: "mnist50", scale: 0.008, d_cap: 50 }],
        ks: vec![24],
        seeds: vec![0, 1],
    };
    let cfg = SpeedupConfig { band: 0.02, max_iters: 30, set, verbose: false };
    let table = speedup_table(&cfg);
    let text = k2m::coordinator::tablefmt::render_speedup(&table);
    assert!(text.contains("mnist50"));
    assert!(text.contains("avg. speedup"));
    // Lloyd++ must be exactly 1.0.
    let row = &table.rows[0];
    let lpp = row
        .cells
        .iter()
        .find(|(m, _, _)| *m == k2m::coordinator::Method::LloydPp)
        .unwrap();
    assert_eq!(lpp.1, Some(1.0));
}

#[test]
fn figures_emit_csv() {
    // Tiny trace emission through the real figure code path, into a temp
    // dir (the default rosters are too slow for a unit test, so this
    // exercises emit_fig4's core via a small custom run).
    let dir = std::env::temp_dir().join(format!("k2m_figs_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // Use run_method directly to produce a curve and write it like
    // figures.rs does.
    let ds = k2m::data::usps_like(0.03, 0xD5);
    let run = k2m::coordinator::run_method(
        &ds.x,
        16,
        k2m::coordinator::Method::K2Means,
        5,
        0,
        20,
        None,
    );
    assert!(!run.trace.points.is_empty());
    let mut csv = String::from("method,param,iter,ops,energy_rel\n");
    for p in &run.trace.points {
        csv.push_str(&format!("k2-means,5,{},{:.1},{:.6}\n", p.iter, p.ops, p.energy));
    }
    let f = dir.join("curve.csv");
    std::fs::write(&f, &csv).unwrap();
    let back = std::fs::read_to_string(&f).unwrap();
    assert!(back.lines().count() >= 2);
    std::fs::remove_dir_all(&dir).ok();
}
