//! Determinism suite for the sharded execution engine.
//!
//! The engine's contract: for every algorithm it powers — k²-means,
//! Lloyd, Elkan, Hamerly, Yinyang, MiniBatch, AKM, the k-means++ /
//! k-means|| seedings, and GDI's projective splits — any thread count
//! produces **bit-identical** labels, centers, energy and iteration
//! count. Per-point (and per-member) passes are independent given
//! shared immutable state, and every floating-point reduction (the
//! update step's per-cluster f64 sums, the split sweep's sufficient
//! statistics) runs in a thread-count-invariant order. The integer
//! [`OpCounter`] categories (distances, inner products, additions)
//! survive sharding exactly.
//!
//! All multi-shard passes dispatch onto the **persistent worker pool**
//! (`k2m::coordinator::pool`): the 4- and 7-thread runs here queue
//! their shards on the same resident process-wide workers, and the
//! pool-reuse test below pins that repeated passes on those workers
//! stay bit-identical.
//!
//! These tests pin that contract at the integration level; unit-level
//! versions live next to each algorithm. The engine itself is
//! `k2m::coordinator::pool::sharded_reduce`.

use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, Config, KmeansResult, MiniBatchOpts,
};
use k2m::core::{Matrix, OpCounter};
use k2m::init::{
    gdi, kmeans_par, kmeans_pp_threaded, random_init, GdiOpts, InitResult, KmeansParOpts,
};
use k2m::testing::blobs;

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

/// Every Lloyd-family algorithm with the shared signature; the sharded
/// paths of MiniBatch (extra opts) and GDI (an init, not an iteration
/// scheme) get their own tests below.
const ALGOS: [(&str, Algo); 5] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
];

/// Workload big enough that explicit thread counts genuinely shard
/// (hundreds of points per shard at 8 threads) while staying unit-test
/// fast.
fn workload() -> (Matrix, InitResult, InitResult) {
    let (x, _) = blobs(4000, 40, 16, 9.0, 77);
    let seeded = gdi(&x, 50, &mut OpCounter::default(), 78, &GdiOpts::default());
    let unseeded = random_init(&x, 50, 79);
    (x, seeded, unseeded)
}

fn assert_identical(name: &str, threads: usize, got: &KmeansResult, want: &KmeansResult) {
    assert_eq!(got.labels, want.labels, "{name}: labels diverged at threads={threads}");
    assert_eq!(got.centers, want.centers, "{name}: centers diverged at threads={threads}");
    assert_eq!(
        got.energy.to_bits(),
        want.energy.to_bits(),
        "{name}: energy diverged at threads={threads}"
    );
    assert_eq!(got.iters, want.iters, "{name}: iteration count diverged at threads={threads}");
    assert_eq!(got.converged, want.converged, "{name}: convergence flag at threads={threads}");
}

#[test]
fn one_vs_n_threads_bit_identical_all_algorithms() {
    let (x, seeded, unseeded) = workload();
    for (name, algo) in ALGOS {
        // k²-means exercises its seeded bootstrap; the exact
        // accelerators take the unseeded path too.
        for (init_name, init) in [("seeded", &seeded), ("unseeded", &unseeded)] {
            let mut cfg = Config { k: 50, kn: 10, max_iters: 40, ..Default::default() };
            cfg.threads = 1;
            let mut c1 = OpCounter::default();
            let want = algo(&x, init, &cfg, &mut c1);
            for threads in [4usize, 7] {
                cfg.threads = threads;
                let mut c = OpCounter::default();
                let got = algo(&x, init, &cfg, &mut c);
                assert_identical(&format!("{name}/{init_name}"), threads, &got, &want);
                // The counted-op methodology survives sharding exactly
                // for the integer categories.
                assert_eq!(
                    c.distances, c1.distances,
                    "{name}/{init_name}: distance count at threads={threads}"
                );
                assert_eq!(
                    c.additions, c1.additions,
                    "{name}/{init_name}: addition count at threads={threads}"
                );
            }
        }
    }
}

#[test]
fn more_threads_than_points_all_algorithms() {
    // n < threads: shards of at most one point each, some workers idle.
    let (x, _) = blobs(6, 3, 4, 20.0, 91);
    let init = random_init(&x, 3, 92);
    for (name, algo) in ALGOS {
        let mut c1 = OpCounter::default();
        let serial = algo(
            &x,
            &init,
            &Config { k: 3, kn: 2, max_iters: 20, threads: 1, ..Default::default() },
            &mut c1,
        );
        let mut c2 = OpCounter::default();
        let wide = algo(
            &x,
            &init,
            &Config { k: 3, kn: 2, max_iters: 20, threads: 64, ..Default::default() },
            &mut c2,
        );
        assert_identical(name, 64, &wide, &serial);
    }
}

#[test]
fn auto_threads_matches_explicit_serial() {
    // Auto mode (threads = 0) may pick any worker count; the result must
    // still be bit-identical to serial.
    let (x, seeded, _) = workload();
    for (name, algo) in ALGOS {
        let mut c1 = OpCounter::default();
        let serial = algo(
            &x,
            &seeded,
            &Config { k: 50, kn: 10, max_iters: 30, threads: 1, ..Default::default() },
            &mut c1,
        );
        let mut c2 = OpCounter::default();
        let auto = algo(
            &x,
            &seeded,
            &Config { k: 50, kn: 10, max_iters: 30, threads: 0, ..Default::default() },
            &mut c2,
        );
        assert_identical(name, 0, &auto, &serial);
    }
}

#[test]
fn minibatch_one_vs_four_vs_seven_threads_bit_identical() {
    // MiniBatch's sharded batch assignment: same seed, same sample
    // stream, bit-identical centers/labels/energy at any thread count,
    // and the integer op categories survive sharding exactly. The batch
    // is large enough that explicit thread counts genuinely shard it.
    let (x, _) = blobs(3000, 24, 12, 9.0, 81);
    let init = random_init(&x, 40, 82);
    let opts = MiniBatchOpts { iterations: Some(200), eval_every: Some(50) };
    let run = |threads: usize| {
        let cfg = Config { k: 40, batch: 600, seed: 5, threads, ..Default::default() };
        let mut c = OpCounter::default();
        let r = minibatch(&x, &init, &cfg, &opts, &mut c);
        (r, c)
    };
    let (want, c1) = run(1);
    for threads in [4usize, 7] {
        let (got, c) = run(threads);
        assert_identical("minibatch", threads, &got, &want);
        assert_eq!(c.distances, c1.distances, "minibatch: distances at threads={threads}");
        assert_eq!(c.additions, c1.additions, "minibatch: additions at threads={threads}");
    }
}

#[test]
fn akm_one_vs_four_vs_seven_threads_bit_identical() {
    // AKM's sharded kd-tree query pass: every point asks the shared
    // immutable tree, writing only its own label slot — bit-identical
    // labels/centers/energy and exact integer op counts at any thread
    // count. (The tree build itself is serial and counted on the
    // caller's counter, so even `sort_scaled` is layout-independent.)
    let (x, _) = blobs(4000, 40, 16, 9.0, 87);
    let init = random_init(&x, 50, 88);
    let run = |threads: usize| {
        let cfg = Config { k: 50, m: 16, max_iters: 20, threads, ..Default::default() };
        let mut c = OpCounter::default();
        let r = akm(&x, &init, &cfg, &mut c);
        (r, c)
    };
    let (want, c1) = run(1);
    for threads in [4usize, 7] {
        let (got, c) = run(threads);
        assert_identical("akm", threads, &got, &want);
        assert_eq!(c.distances, c1.distances, "akm: distance count at threads={threads}");
        assert_eq!(c.additions, c1.additions, "akm: addition count at threads={threads}");
        assert_eq!(
            c.sort_scaled.to_bits(),
            c1.sort_scaled.to_bits(),
            "akm: tree-build sort cost at threads={threads}"
        );
    }
}

#[test]
fn kmeanspp_one_vs_four_vs_seven_threads_bit_identical() {
    // k-means++'s sharded distance scans: the D² draws are sequential
    // on the caller's thread, the n-point scans between them shard —
    // same chosen centers, same owner labels, exactly n*k distances at
    // any thread count.
    let (x, _) = blobs(4000, 40, 16, 9.0, 89);
    let run = |threads: usize| {
        let mut c = OpCounter::default();
        let init = kmeans_pp_threaded(&x, 50, &mut c, 90, threads);
        (init, c)
    };
    let (want, c1) = run(1);
    assert_eq!(c1.distances, 4000 * 50, "the paper's n*k distance bill");
    for threads in [4usize, 7] {
        let (got, c) = run(threads);
        assert_eq!(got.centers, want.centers, "kmeanspp: centers diverged at threads={threads}");
        assert_eq!(got.labels, want.labels, "kmeanspp: labels diverged at threads={threads}");
        assert_eq!(c.distances, c1.distances, "kmeanspp: distances at threads={threads}");
        assert_eq!(c.additions, c1.additions, "kmeanspp: additions at threads={threads}");
    }
}

#[test]
fn kmeanspar_one_vs_four_vs_seven_threads_bit_identical() {
    // k-means||'s sharded scans (round-0 seeding, per-round tightening,
    // attraction weights): the sampling stream and the candidate
    // reduction are serial on the caller's thread, so the whole init is
    // bit-identical — centers and integer op counts — at any thread
    // count.
    let (x, _) = blobs(4000, 40, 16, 9.0, 93);
    let run = |threads: usize| {
        let opts = KmeansParOpts { threads, ..Default::default() };
        let mut c = OpCounter::default();
        let init = kmeans_par(&x, 50, &opts, &mut c, 94);
        (init, c)
    };
    let (want, c1) = run(1);
    for threads in [4usize, 7] {
        let (got, c) = run(threads);
        assert_eq!(got.centers, want.centers, "kmeanspar: centers diverged at threads={threads}");
        assert_eq!(c.distances, c1.distances, "kmeanspar: distances at threads={threads}");
        assert_eq!(c.additions, c1.additions, "kmeanspar: additions at threads={threads}");
    }
}

#[test]
fn default_pool_reuse_is_bit_identical_across_runs() {
    // The persistent-pool regression: the full roster twice on the same
    // process-wide default pool (4 threads forces real dispatches both
    // times). Run 2 reuses workers that already executed thousands of
    // shard tasks — labels, centers and energy must not move by a bit.
    let (x, seeded, unseeded) = workload();
    let cfg = Config { k: 50, kn: 10, max_iters: 25, threads: 4, ..Default::default() };
    let mut first: Vec<(String, KmeansResult)> = Vec::new();
    for (name, algo) in ALGOS {
        for (init_name, init) in [("seeded", &seeded), ("unseeded", &unseeded)] {
            let mut c = OpCounter::default();
            first.push((format!("{name}/{init_name}"), algo(&x, init, &cfg, &mut c)));
        }
    }
    let mut idx = 0usize;
    for (_, algo) in ALGOS {
        for (_, init) in [("seeded", &seeded), ("unseeded", &unseeded)] {
            let mut c = OpCounter::default();
            let got = algo(&x, init, &cfg, &mut c);
            let (name, want) = &first[idx];
            assert_identical(&format!("{name}/pool-reuse"), 4, &got, want);
            idx += 1;
        }
    }
}

#[test]
fn gdi_one_vs_four_vs_seven_threads_bit_identical() {
    // GDI's sharded projective-split scans: identical partition, centers
    // and op counts at any thread count (including auto). The first
    // splits run over thousands of members, so explicit thread counts
    // genuinely shard the projection passes.
    let (x, _) = blobs(4000, 40, 16, 9.0, 83);
    let run = |threads: usize| {
        let mut c = OpCounter::default();
        let r = gdi(&x, 50, &mut c, 84, &GdiOpts { threads, ..Default::default() });
        (r, c)
    };
    let (want, c1) = run(1);
    for threads in [4usize, 7, 0] {
        let (got, c) = run(threads);
        assert_eq!(got.centers, want.centers, "gdi: centers diverged at threads={threads}");
        assert_eq!(got.labels, want.labels, "gdi: labels diverged at threads={threads}");
        assert_eq!(c.distances, c1.distances, "gdi: distances at threads={threads}");
        assert_eq!(
            c.inner_products, c1.inner_products,
            "gdi: inner products at threads={threads}"
        );
        assert_eq!(c.additions, c1.additions, "gdi: additions at threads={threads}");
    }
}
