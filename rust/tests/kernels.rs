//! Contract suite for the blocked distance-kernel layer
//! (`k2m::core::kernels`).
//!
//! Three rungs:
//!
//! 1. **Kernel-level bit-identity** — every blocked scan returns
//!    bit-identical `f32`s to the scalar `ops` primitives it replaces,
//!    across dims 0..40 (crossing the 8-wide chunk boundary) and
//!    candidate counts crossing the `TILE` remainder boundary, with the
//!    op counter charged exactly one distance per pair (property tests
//!    on the in-repo seeded harness).
//! 2. **Scalar mirrors** — full runs of the representative blocked hot
//!    paths (Lloyd assignment, the kNN center graph) compared against
//!    from-scratch scalar reimplementations written with per-pair
//!    `ops::sqdist_raw`: labels, centers and op counts must match the
//!    pre-refactor scalar path bit for bit.
//! 3. **Roster invariance** — every init × algorithm pair runs at 1, 4
//!    and 7 threads: bit-identical labels/centers/energies and equal
//!    integer op counts, proving the kernel layer composes with the
//!    sharded engine without perturbing any trajectory.

use k2m::cluster::{
    akm, elkan, hamerly, k2means, lloyd, minibatch, yinyang, Config, KmeansResult, MiniBatchOpts,
};
use k2m::core::{kernels, ops, Matrix, OpCounter};
use k2m::init::{gdi, kmeans_par, kmeans_pp, random_init, GdiOpts, InitResult, KmeansParOpts};
use k2m::knn::knn_graph;
use k2m::testing::prop::{check, small_usize};
use k2m::testing::{blobs, random_matrix};

// -------------------------------------------------------------------------
// 1. Kernel-level bit-identity (property tests, seeded harness)
// -------------------------------------------------------------------------

#[test]
fn prop_block_scans_bit_identical_across_dims_0_to_40() {
    // Every public blocked scan against its scalar reference, all dims
    // 0..40 — the 8-chunk remainder in every phase.
    check("kernels dims sweep", 41, |rng| {
        let d = rng.gen_below(41);
        let k = kernels::TILE * 3 + 1; // crosses the tile remainder (3 tiles + 1)
        let rows = random_matrix(k, d, rng.gen_below(1 << 30) as u64);
        let x = random_matrix(1, d, rng.gen_below(1 << 30) as u64);
        let q = x.row(0);
        let cand: Vec<u32> = (0..k as u32).rev().collect(); // non-identity order
        let mut c = OpCounter::default();

        let mut sq = vec![0.0f32; k];
        kernels::sqdist_block(q, &rows, &cand, &mut sq, &mut c);
        let mut pl = vec![0.0f32; k];
        kernels::dist_block(q, &rows, &cand, &mut pl, &mut c);
        let mut dots = vec![0.0f32; k];
        kernels::dot_block(q, &rows, &cand, &mut dots, &mut c);
        let mut rng_rows = vec![0.0f32; k];
        kernels::sqdist_rows(q, &rows, 0, &mut rng_rows, &mut c);
        for (t, &j) in cand.iter().enumerate() {
            let j = j as usize;
            assert_eq!(sq[t].to_bits(), ops::sqdist_raw(q, rows.row(j)).to_bits(), "d={d}");
            assert_eq!(pl[t].to_bits(), ops::dist_raw(q, rows.row(j)).to_bits(), "d={d}");
            assert_eq!(dots[t].to_bits(), ops::dot_raw(q, rows.row(j)).to_bits(), "d={d}");
            assert_eq!(
                rng_rows[j].to_bits(),
                ops::sqdist_raw(q, rows.row(j)).to_bits(),
                "d={d}"
            );
        }
        assert_eq!(c.distances, 3 * k as u64);
        assert_eq!(c.inner_products, k as u64);
    });
}

#[test]
fn prop_candidate_counts_cross_tile_remainder() {
    // Candidate counts 0..=2*TILE+1 hit every remainder class on both
    // sides of a full tile; argmin helpers agree with the serial loop.
    check("kernels cand sweep", 50, |rng| {
        let d = small_usize(rng, 1, 40);
        let k = small_usize(rng, 2, 30);
        let nc = rng.gen_below(2 * kernels::TILE + 2);
        let rows = random_matrix(k, d, rng.gen_below(1 << 30) as u64);
        let x = random_matrix(1, d, rng.gen_below(1 << 30) as u64);
        let q = x.row(0);
        let cand: Vec<u32> = (0..nc).map(|_| rng.gen_below(k) as u32).collect();

        let mut c = OpCounter::default();
        let mut out = vec![0.0f32; nc];
        kernels::sqdist_block(q, &rows, &cand, &mut out, &mut c);
        assert_eq!(c.distances, nc as u64);
        let mut serial_best = (0usize, f32::INFINITY);
        for (t, &j) in cand.iter().enumerate() {
            let want = ops::sqdist_raw(q, rows.row(j as usize));
            assert_eq!(out[t].to_bits(), want.to_bits(), "nc={nc} t={t}");
            if want < serial_best.1 {
                serial_best = (t, want);
            }
        }
        if nc > 0 {
            let (slot, sq) = kernels::nearest_sq_in_block(q, &rows, &cand, &mut c);
            assert_eq!((slot, sq.to_bits()), (serial_best.0, serial_best.1.to_bits()));
            let (pslot, pd) = kernels::nearest_in_block(q, &rows, &cand, &mut c);
            // The plain argmin compares after sqrt — recompute the
            // serial plain winner independently.
            let mut plain_best = (0usize, f32::INFINITY);
            for (t, &j) in cand.iter().enumerate() {
                let dv = ops::dist_raw(q, rows.row(j as usize));
                if dv < plain_best.1 {
                    plain_best = (t, dv);
                }
            }
            assert_eq!((pslot, pd.to_bits()), (plain_best.0, plain_best.1.to_bits()));
        }
    });
}

#[test]
fn prop_pairwise_block_matches_scalar_pairs() {
    check("kernels pairwise", 30, |rng| {
        let k = small_usize(rng, 1, 20);
        let d = small_usize(rng, 1, 40);
        let rows = random_matrix(k, d, rng.gen_below(1 << 30) as u64);
        let mut sq = vec![f32::NAN; k * k];
        let mut c = OpCounter::default();
        kernels::pairwise_block(&rows, &mut sq, &mut c);
        assert_eq!(c.distances, (k * (k - 1) / 2) as u64);
        for i in 0..k {
            for j in 0..k {
                let want = if i == j {
                    0.0
                } else {
                    ops::sqdist_raw(rows.row(i), rows.row(j))
                };
                assert_eq!(sq[i * k + j].to_bits(), want.to_bits(), "({i},{j})");
            }
        }
    });
}

// -------------------------------------------------------------------------
// 2. Scalar mirrors of migrated hot paths
// -------------------------------------------------------------------------

/// The pre-refactor Lloyd: per-pair `ops::sqdist` argmin and the serial
/// mean update, written from scratch so the comparison cannot share
/// code with the blocked implementation.
fn scalar_lloyd(x: &Matrix, init: &InitResult, max_iters: usize) -> (Vec<u32>, Matrix, u64) {
    let (n, k, d) = (x.rows(), init.k(), x.cols());
    let mut centers = init.centers.clone();
    let mut labels = vec![u32::MAX; n];
    let mut ctr = OpCounter::default();
    for _ in 0..max_iters {
        let mut changed = 0usize;
        for i in 0..n {
            let mut best = (0u32, f32::INFINITY);
            for j in 0..k {
                let dist = ops::sqdist(x.row(i), centers.row(j), &mut ctr);
                if dist < best.1 {
                    best = (j as u32, dist);
                }
            }
            if labels[i] != best.0 {
                labels[i] = best.0;
                changed += 1;
            }
        }
        if changed == 0 {
            break;
        }
        let mut sums = vec![0.0f64; k * d];
        let mut counts = vec![0u32; k];
        for (i, &l) in labels.iter().enumerate() {
            let l = l as usize;
            counts[l] += 1;
            ctr.additions += 1;
            for (a, &v) in sums[l * d..(l + 1) * d].iter_mut().zip(x.row(i)) {
                *a += v as f64;
            }
        }
        for j in 0..k {
            if counts[j] > 0 {
                let inv = 1.0 / counts[j] as f64;
                for (cv, &s) in centers.row_mut(j).iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                    *cv = (s * inv) as f32;
                }
            }
        }
    }
    (labels, centers, ctr.distances)
}

#[test]
fn blocked_lloyd_matches_scalar_mirror_bit_for_bit() {
    let (x, _) = blobs(500, 10, 12, 9.0, 77);
    let init = random_init(&x, 12, 78);
    let (want_labels, want_centers, want_dists) = scalar_lloyd(&x, &init, 100);
    let mut c = OpCounter::default();
    let cfg = Config { k: 12, threads: 1, record_trace: false, ..Default::default() };
    let got = lloyd(&x, &init, &cfg, &mut c);
    assert_eq!(got.labels, want_labels);
    assert_eq!(got.centers, want_centers);
    // The mirror stops on the converged pass; lloyd runs the same
    // passes (its `changed == 0` break mirrors the scalar loop), so the
    // distance bill must agree exactly.
    assert_eq!(c.distances, want_dists);
}

#[test]
fn blocked_knn_graph_matches_scalar_mirror() {
    let c = random_matrix(41, 17, 79); // odd k: tile remainder in play
    let kn = 7;
    let mut ctr = OpCounter::default();
    let g = knn_graph(&c, kn, &mut ctr);
    assert_eq!(ctr.distances, 41 * 40 / 2);
    // Scalar mirror of the pre-refactor build: full pairwise table via
    // per-pair sqdist_raw, per-row sort with the same tie-break.
    for i in 0..41 {
        let mut all: Vec<(f32, u32)> = (0..41u32)
            .map(|j| (ops::sqdist_raw(c.row(i), c.row(j as usize)), j))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let want_n: Vec<u32> = all[..kn].iter().map(|&(_, j)| j).collect();
        let want_d: Vec<f32> = all[..kn].iter().map(|&(dv, _)| dv).collect();
        assert_eq!(g.nbrs_row(i), &want_n[..], "row {i}");
        for (t, (&gd, &wd)) in g.dists_row(i).iter().zip(&want_d).enumerate() {
            assert_eq!(gd.to_bits(), wd.to_bits(), "row {i} slot {t}");
        }
    }
}

#[test]
fn k2means_ablation_path_matches_scalar_candidate_scan() {
    // One iteration of the no-bounds candidate scan, mirrored with
    // per-pair plain distances over the same graph rows.
    let (x, _) = blobs(300, 12, 10, 8.0, 80);
    let mut c0 = OpCounter::default();
    let init = gdi(&x, 16, &mut c0, 81, &GdiOpts::default());
    let cfg = Config {
        k: 16,
        kn: 5,
        max_iters: 1,
        use_bounds: false,
        threads: 1,
        record_trace: false,
        ..Default::default()
    };
    let mut c1 = OpCounter::default();
    let got = k2means(&x, &init, &cfg, &mut c1);
    // Mirror: rebuild the same graph, rescan candidates serially.
    let mut cg = OpCounter::default();
    let g = knn_graph(&init.centers, 5, &mut cg);
    let labels0 = init.labels.clone().unwrap();
    for i in 0..300 {
        let l = labels0[i] as usize;
        let mut best = (l as u32, f32::INFINITY);
        for &j in g.nbrs_row(l) {
            let dist = ops::dist_raw(x.row(i), init.centers.row(j as usize));
            if dist < best.1 {
                best = (j, dist);
            }
        }
        assert_eq!(got.labels[i], best.0, "point {i}");
    }
}

// -------------------------------------------------------------------------
// 3. Roster invariance: every init × algorithm, 1 vs 4 vs 7 threads
// -------------------------------------------------------------------------

type Algo = fn(&Matrix, &InitResult, &Config, &mut OpCounter) -> KmeansResult;

const ALGOS: [(&str, Algo); 6] = [
    ("k2means", k2means as Algo),
    ("lloyd", lloyd as Algo),
    ("elkan", elkan as Algo),
    ("hamerly", hamerly as Algo),
    ("yinyang", yinyang as Algo),
    ("akm", akm as Algo),
];

fn inits(x: &Matrix, k: usize) -> Vec<(&'static str, InitResult)> {
    let mut c = OpCounter::default();
    vec![
        ("random", random_init(x, k, 5)),
        ("kmeans_pp", kmeans_pp(x, k, &mut c, 6)),
        ("kmeans_par", kmeans_par(x, k, &KmeansParOpts::default(), &mut c, 7)),
        ("gdi", gdi(x, k, &mut c, 8, &GdiOpts::default())),
    ]
}

fn run(algo: Algo, x: &Matrix, init: &InitResult, threads: usize) -> (KmeansResult, OpCounter) {
    let cfg = Config {
        k: init.k(),
        kn: 4,
        m: 8,
        max_iters: 12,
        threads,
        record_trace: false,
        ..Default::default()
    };
    let mut c = OpCounter::default();
    let r = algo(x, init, &cfg, &mut c);
    (r, c)
}

#[test]
fn roster_all_inits_bit_identical_at_1_4_7_threads() {
    let (x, _) = blobs(420, 10, 12, 8.0, 90);
    for (iname, init) in inits(&x, 12) {
        for (aname, algo) in ALGOS {
            let (want, c1) = run(algo, &x, &init, 1);
            for threads in [4usize, 7] {
                let (got, ct) = run(algo, &x, &init, threads);
                let tag = format!("{aname}/{iname}/t{threads}");
                assert_eq!(got.labels, want.labels, "{tag}");
                assert_eq!(got.centers, want.centers, "{tag}");
                assert_eq!(got.energy.to_bits(), want.energy.to_bits(), "{tag}");
                assert_eq!(got.iters, want.iters, "{tag}");
                assert_eq!(ct.distances, c1.distances, "{tag}");
                assert_eq!(ct.inner_products, c1.inner_products, "{tag}");
                assert_eq!(ct.additions, c1.additions, "{tag}");
            }
        }
        // MiniBatch rides its own signature.
        let opts = MiniBatchOpts { iterations: Some(20), eval_every: Some(10) };
        let base = Config { k: 12, batch: 64, seed: 13, threads: 1, ..Default::default() };
        let mut c1 = OpCounter::default();
        let want = minibatch(&x, &init, &base, &opts, &mut c1);
        for threads in [4usize, 7] {
            let cfg = Config { threads, ..base.clone() };
            let mut ct = OpCounter::default();
            let got = minibatch(&x, &init, &cfg, &opts, &mut ct);
            let tag = format!("minibatch/{iname}/t{threads}");
            assert_eq!(got.labels, want.labels, "{tag}");
            assert_eq!(got.centers, want.centers, "{tag}");
            assert_eq!(ct.distances, c1.distances, "{tag}");
            assert_eq!(ct.additions, c1.additions, "{tag}");
        }
    }
}

// -------------------------------------------------------------------------
// Analytic op-count pins (the paper's accounting survives the kernels)
// -------------------------------------------------------------------------

#[test]
fn analytic_counts_pinned() {
    let x = random_matrix(60, 6, 91);
    // Lloyd: n*k distances per iteration.
    let init = random_init(&x, 5, 92);
    let mut c = OpCounter::default();
    let cfg = Config { k: 5, max_iters: 1, record_trace: false, ..Default::default() };
    let _ = lloyd(&x, &init, &cfg, &mut c);
    assert_eq!(c.distances, 60 * 5);
    // k-means++: exactly n*k distances.
    let mut c = OpCounter::default();
    let _ = kmeans_pp(&x, 7, &mut c, 93);
    assert_eq!(c.distances, 60 * 7);
    // kNN center graph: k choose 2.
    let mut c = OpCounter::default();
    let _ = knn_graph(&x, 4, &mut c);
    assert_eq!(c.distances, 60 * 59 / 2);
    // MiniBatch: t*(b*k) distances + t*b additions.
    let init = random_init(&x, 5, 94);
    let mut c = OpCounter::default();
    let cfg = Config { k: 5, batch: 10, seed: 95, ..Default::default() };
    let opts = MiniBatchOpts { iterations: Some(7), eval_every: Some(100) };
    let _ = minibatch(&x, &init, &cfg, &opts, &mut c);
    assert_eq!(c.distances, 7 * 10 * 5);
    assert_eq!(c.additions, 7 * 10);
    // Elkan bootstrap (first pass) is a full n*k scan; iteration 1 adds
    // the k(k-1)/2 center table — a lower bound on the total.
    let mut c = OpCounter::default();
    let cfg = Config { k: 5, max_iters: 1, record_trace: false, ..Default::default() };
    let _ = elkan(&x, &init, &cfg, &mut c);
    assert!(c.distances >= 60 * 5 + 5 * 4 / 2);
}
