//! Acceptance suite for the train/serve split (`k2m::runtime::serve`).
//!
//! The serving contract under test: for a [`ClusterModel`] trained by
//! **any** of the seven algorithms, batched `assign` answers are **bit
//! identical** to a full strict scan over all `k` centers on the same
//! numerics tier — at 1, 4, and 7 threads, on both tiers — while the
//! counted distance bill never exceeds the full scan's `n × k` (and is
//! `≤ k` for every individual query). A model that round-trips through
//! `save`/`load` serves identically to the in-memory original.

use std::sync::Arc;

use k2m::cluster::{ClusterModel, Config};
use k2m::coordinator::jobs::{run_job, JobAlgo, JobSpec};
use k2m::core::{Matrix, NumericsMode, OpCounter};
use k2m::runtime::ServeService;
use k2m::testing::{blobs, random_matrix};

const K: usize = 32;
const D: usize = 12;

/// Train one model per algorithm on a shared seeded roster workload
/// (each algorithm's default init pairing: GDI for k²-means, random
/// sampling for the rest).
fn trained_models() -> Vec<(&'static str, ClusterModel)> {
    let (x, _) = blobs(1500, K, D, 10.0, 77);
    let x = Arc::new(x);
    [
        JobAlgo::K2Means,
        JobAlgo::Lloyd,
        JobAlgo::Elkan,
        JobAlgo::Hamerly,
        JobAlgo::Yinyang,
        JobAlgo::MiniBatch,
        JobAlgo::Akm,
    ]
    .into_iter()
    .map(|algo| {
        let cfg = Config {
            k: K,
            kn: 8,
            m: 12,
            batch: 100,
            max_iters: 12,
            seed: 13,
            ..Default::default()
        };
        let out = run_job(&x, &JobSpec::new(algo.name(), algo, cfg));
        (algo.name(), out.result.model)
    })
    .collect()
}

/// Two query mixtures: in-distribution points (the descent's accept
/// path fires often) and unrelated gaussian noise (frequent completion
/// fallbacks). Exactness must hold on both.
fn query_sets() -> Vec<(&'static str, Matrix)> {
    vec![
        ("in-distribution", blobs(220, K, D, 10.0, 78).0),
        ("noise", random_matrix(180, D, 79)),
    ]
}

/// Reference: the strict full scan every answer is pinned against —
/// `nearest_rows` over all `k` centers per query, same tier.
fn full_scan(q: &Matrix, centers: &Matrix, nm: NumericsMode) -> (Vec<u32>, Vec<f32>, OpCounter) {
    let mut ctr = OpCounter::default();
    let mut labels = Vec::with_capacity(q.rows());
    let mut dists = Vec::with_capacity(q.rows());
    for i in 0..q.rows() {
        let (j, dist) = nm.nearest_rows(q.row(i), centers, &mut ctr);
        labels.push(j);
        dists.push(dist);
    }
    (labels, dists, ctr)
}

#[test]
fn every_algorithms_model_serves_bit_identically_to_the_full_scan() {
    for (algo, model) in trained_models() {
        for (qname, q) in query_sets() {
            for nm in [NumericsMode::Strict, NumericsMode::Fast] {
                let (want_l, want_d, want_ctr) = full_scan(&q, model.centers(), nm);
                let mut per_thread: Vec<(Vec<u32>, Vec<f32>, OpCounter)> = Vec::new();
                for threads in [1usize, 4, 7] {
                    let svc = ServeService::with_options(model.clone(), threads, nm);
                    let mut ctr = OpCounter::default();
                    let (labels, dists) = svc.assign(&q, &mut ctr);
                    let tag = format!("{algo}/{qname}/{}/t{threads}", nm.name());
                    assert_eq!(labels, want_l, "{tag}: labels");
                    for (i, (a, b)) in dists.iter().zip(&want_d).enumerate() {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: dist[{i}]");
                    }
                    assert!(
                        ctr.distances <= want_ctr.distances,
                        "{tag}: bill {} exceeds full scan {}",
                        ctr.distances,
                        want_ctr.distances
                    );
                    per_thread.push((labels, dists, ctr));
                }
                // Thread invariance: answers AND op bills identical at
                // any worker count.
                for got in &per_thread[1..] {
                    assert_eq!(got.0, per_thread[0].0, "{algo}/{qname}: labels vs t1");
                    assert_eq!(
                        got.1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        per_thread[0].1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{algo}/{qname}: dists vs t1"
                    );
                    assert_eq!(got.2, per_thread[0].2, "{algo}/{qname}: counter vs t1");
                }
            }
        }
    }
}

#[test]
fn per_query_bill_is_at_most_k() {
    // Serve queries one at a time: the scratch cache guarantees each
    // center is evaluated at most once per query, descent or fallback.
    let (_, model) = trained_models().remove(0);
    let q = random_matrix(50, D, 80);
    for nm in [NumericsMode::Strict, NumericsMode::Fast] {
        let svc = ServeService::with_options(model.clone(), 1, nm);
        for i in 0..q.rows() {
            let one = Matrix::from_vec(q.row(i).to_vec(), 1, D);
            let mut ctr = OpCounter::default();
            svc.assign(&one, &mut ctr);
            assert!(
                ctr.distances <= K as u64,
                "query {i} on {} billed {} > k={K}",
                nm.name(),
                ctr.distances
            );
        }
    }
}

#[test]
fn nearest_centers_matches_the_sorted_reference() {
    let models = trained_models();
    let q = blobs(90, K, D, 10.0, 81).0;
    let m = 5;
    for (algo, model) in &models[..2] {
        for nm in [NumericsMode::Strict, NumericsMode::Fast] {
            let svc = ServeService::with_options(model.clone(), 4, nm);
            let mut ctr = OpCounter::default();
            let (idx, dists) = svc.nearest_centers(&q, m, &mut ctr);
            assert!(ctr.distances <= (q.rows() * K) as u64, "{algo}: top-m bill");
            for i in 0..q.rows() {
                // Reference ranking: every center's plain distance,
                // sorted by (distance, index).
                let mut scratch = OpCounter::default();
                let ctrs = model.centers();
                let mut want: Vec<(f32, u32)> = (0..K)
                    .map(|j| (nm.dist_one(q.row(i), ctrs.row(j), &mut scratch), j as u32))
                    .collect();
                want.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
                for t in 0..m {
                    assert_eq!(
                        idx[i * m + t],
                        want[t].1,
                        "{algo}/{}: query {i} slot {t}",
                        nm.name()
                    );
                    assert_eq!(
                        dists[i * m + t].to_bits(),
                        want[t].0.to_bits(),
                        "{algo}/{}: query {i} slot {t} dist",
                        nm.name()
                    );
                }
            }
        }
    }
}

#[test]
fn saved_model_serves_identically_to_the_in_memory_one() {
    let q = blobs(120, K, D, 10.0, 82).0;
    for (algo, model) in trained_models() {
        let mut path = std::env::temp_dir();
        path.push(format!("k2m_test_{}_serve_{algo}.k2mm", std::process::id()));
        model.save(&path).unwrap();
        let loaded = ClusterModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();

        let nm = model.config().numerics;
        let live = ServeService::with_options(model, 3, nm);
        let disk = ServeService::with_options(loaded, 3, nm);
        let mut c1 = OpCounter::default();
        let mut c2 = OpCounter::default();
        let (l1, d1) = live.assign(&q, &mut c1);
        let (l2, d2) = disk.assign(&q, &mut c2);
        assert_eq!(l1, l2, "{algo}: labels");
        for (a, b) in d1.iter().zip(&d2) {
            assert_eq!(a.to_bits(), b.to_bits(), "{algo}: dists");
        }
        assert_eq!(c1, c2, "{algo}: op bill");
    }
}
