//! Acceptance suite for the out-of-core chunked store + big-means
//! global search (the ISSUE 10 contract):
//!
//! 1. chunked reads reproduce in-RAM rows **bitwise** (gather, stream,
//!    materialize — any chunk size, any cache size);
//! 2. the big-means incumbent trajectory is **bitwise identical** at
//!    1/4/7 inner threads, any concurrency budget, and any chunk-cache
//!    size for a fixed seed + schedule;
//! 3. the incumbent energy is ≤ the energy of a single sample-sized
//!    run of the same inner method (job 0 *is* that run — the incumbent
//!    is a strict min over it and every later sample);
//! 4. per-job op bills plus the final streamed assignment bill
//!    reconstruct the driver's counter exactly, and the assignment
//!    pass is billed like one Lloyd pass (`k` distances per row).

use std::path::PathBuf;
use std::sync::Arc;

use k2m::cluster::{bigmeans, job_seed, sample_indices, BigMeansOpts, BigMeansOutcome, Config};
use k2m::coordinator::jobs::{run_algo, run_init, JobAlgo, JobInit, JobQueue, JobSpec};
use k2m::core::{Matrix, OpCounter};
use k2m::data::store::OpenOptions;
use k2m::data::{save_chunked, ChunkedMatrix, Dataset, DatasetSource};
use k2m::init::InitResult;
use k2m::testing::blobs;

fn tmpfile(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("k2m_itest_{}_{}", std::process::id(), name));
    p
}

/// A multi-modal fixture big enough that samples see every mode.
fn fixture(n: usize, seed: u64) -> Matrix {
    let (x, _) = blobs(n, 6, 8, 16.0, seed);
    x
}

/// Write the fixture as a `.k2c` and open it with pinned chunk/cache
/// knobs (pinning keeps assertions valid under the CI job that forces
/// `K2M_CHUNK_ROWS`/`K2M_CHUNK_CACHE` suite-wide).
fn chunked(x: &Matrix, file: &str, chunk_rows: usize, cache: usize) -> ChunkedMatrix {
    let ds = Dataset { name: "fixture".into(), x: x.clone(), seed: 0 };
    let p = tmpfile(file);
    save_chunked(&ds, chunk_rows, &p).unwrap();
    ChunkedMatrix::open_with(
        &p,
        OpenOptions { chunk_rows: Some(chunk_rows), cache_chunks: Some(cache) },
    )
    .unwrap()
}

fn cfg(k: usize, threads: usize) -> Config {
    let seed = 0xB16;
    Config { k, kn: k, max_iters: 15, seed, threads, record_trace: false, ..Config::default() }
}

fn opts(samples: usize, sample_rows: usize, round: usize, budget: usize) -> BigMeansOpts {
    BigMeansOpts { samples, sample_rows, round, budget, ..BigMeansOpts::default() }
}

/// The full observable surface two equal runs must share, bit for bit.
fn assert_same_outcome(name: &str, a: &BigMeansOutcome, b: &BigMeansOutcome) {
    assert_eq!(a.result.centers, b.result.centers, "{name}: centers");
    assert_eq!(a.result.labels, b.result.labels, "{name}: labels");
    assert_eq!(a.result.energy.to_bits(), b.result.energy.to_bits(), "{name}: energy");
    assert_eq!(a.sample_energy.to_bits(), b.sample_energy.to_bits(), "{name}: sample energy");
    assert_eq!(a.best_sample, b.best_sample, "{name}: best sample");
    assert_eq!(a.jobs.len(), b.jobs.len(), "{name}: job count");
    for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
        assert_eq!(ja.energy.to_bits(), jb.energy.to_bits(), "{name}: job {} energy", ja.sample);
        assert_eq!(ja.counter, jb.counter, "{name}: job {} bill", ja.sample);
        assert_eq!(
            (ja.round, ja.warm, ja.iters, ja.improved),
            (jb.round, jb.warm, jb.iters, jb.improved),
            "{name}: job {} shape",
            ja.sample
        );
    }
    let pa: Vec<_> = a.result.trace.points.iter().map(|p| (p.energy.to_bits(), p.iter)).collect();
    let pb: Vec<_> = b.result.trace.points.iter().map(|p| (p.energy.to_bits(), p.iter)).collect();
    assert_eq!(pa, pb, "{name}: incumbent trajectory");
}

#[test]
fn chunked_reads_match_in_ram_bitwise() {
    let x = fixture(211, 5);
    // Chunk sizes across the boundary cases: 1, a non-divisor, a tail
    // fragment, > n; cache sizes down to a single resident chunk.
    for (chunk_rows, cache) in [(1usize, 1usize), (7, 2), (50, 1), (64, 3), (300, 1)] {
        let cm = chunked(&x, &format!("bitwise_{chunk_rows}_{cache}.k2c"), chunk_rows, cache);
        assert_eq!((cm.rows(), cm.cols()), (x.rows(), x.cols()));
        for i in [0usize, 1, 6, 7, 49, 50, 117, 210] {
            assert_eq!(cm.row(i), x.row(i), "row {i} at chunk_rows={chunk_rows}");
        }
        let idx: Vec<usize> = (0..x.rows()).rev().collect();
        assert_eq!(
            cm.gather_rows(&idx).as_slice(),
            Matrix::gather(&x, &idx).as_slice(),
            "gather at chunk_rows={chunk_rows}"
        );
        assert_eq!(
            cm.materialize().as_slice(),
            x.as_slice(),
            "materialize at chunk_rows={chunk_rows}"
        );
        let mut streamed = Vec::new();
        cm.for_each_chunk(|start, block| {
            assert_eq!(streamed.len(), start * x.cols(), "chunks arrive in row order");
            streamed.extend_from_slice(block.as_slice());
        });
        assert_eq!(streamed, x.as_slice(), "stream at chunk_rows={chunk_rows}");
    }
}

#[test]
fn trajectory_invariant_across_threads_budgets_sources_and_caches() {
    let x = fixture(900, 9);
    let src_ram = DatasetSource::from(x.clone());
    let c = cfg(6, 1);
    let o = opts(6, 150, 2, 0);
    let mut counter = OpCounter::default();
    let want = bigmeans(&src_ram, &c, &o, &mut counter);

    // Inner-solver thread sweep (the house 1/4/7 convention) and driver
    // concurrency budgets, on the in-RAM source.
    for threads in [4usize, 7] {
        let got = bigmeans(&src_ram, &cfg(6, threads), &o, &mut OpCounter::default());
        assert_same_outcome(&format!("threads={threads}"), &got, &want);
    }
    for budget in [1usize, 2, 5] {
        let ob = opts(6, 150, 2, budget);
        let got = bigmeans(&src_ram, &c, &ob, &mut OpCounter::default());
        assert_same_outcome(&format!("budget={budget}"), &got, &want);
    }

    // Chunked sources at several (chunk size, cache size) points — the
    // store must be invisible to the trajectory, including a cache of a
    // single resident chunk (maximum eviction pressure).
    for (chunk_rows, cache) in [(64usize, 1usize), (64, 4), (7, 2), (900, 1)] {
        let cm = chunked(&x, &format!("traj_{chunk_rows}_{cache}.k2c"), chunk_rows, cache);
        let src = DatasetSource::from(cm);
        let mut cc = OpCounter::default();
        let got = bigmeans(&src, &c, &o, &mut cc);
        assert_same_outcome(&format!("chunk={chunk_rows} cache={cache}"), &got, &want);
        assert_eq!(cc, counter, "driver bill differs on chunked source");
    }
}

#[test]
fn incumbent_is_no_worse_than_a_single_sample_sized_run() {
    let x = fixture(800, 21);
    let src = DatasetSource::from(x.clone());
    let c = cfg(6, 0);
    // MiniBatch inner solver: job 0 *is* "a single sample-sized
    // minibatch run" (cold init, one sample), reconstructed below.
    let o = BigMeansOpts { algo: JobAlgo::MiniBatch, init: JobInit::Random, ..opts(6, 200, 3, 0) };
    let out = bigmeans(&src, &c, &o, &mut OpCounter::default());

    // Reconstruct job 0 independently from the published schedule: the
    // per-sample outcome must be that run, bit for bit.
    let idx = sample_indices(c.seed, 0, x.rows(), o.sample_rows);
    let xs = Matrix::gather(&x, &idx);
    let mut jcfg = c.clone();
    jcfg.seed = job_seed(c.seed, 0);
    jcfg.record_trace = false;
    let mut jc = OpCounter::default();
    let init = run_init(&xs, o.init, &jcfg, &mut jc);
    let single = run_algo(&xs, o.algo, &init, &jcfg, &mut jc);
    assert_eq!(out.jobs[0].energy.to_bits(), single.energy.to_bits());
    assert_eq!(out.jobs[0].counter, jc);

    // The acceptance inequality: incumbent ≤ that single run (strict
    // min over all samples, job 0 included).
    assert!(out.sample_energy <= single.energy);
    // Same guarantee with the default k²-means inner solver.
    let out_k2 = bigmeans(&src, &c, &opts(6, 200, 3, 0), &mut OpCounter::default());
    assert!(out_k2.sample_energy <= out_k2.jobs[0].energy);
}

#[test]
fn op_bills_reconstruct_exactly_on_a_chunked_source() {
    let x = fixture(500, 33);
    let cm = chunked(&x, "bills.k2c", 48, 2);
    let src = DatasetSource::from(cm);
    let c = cfg(5, 1);
    let o = opts(5, 120, 2, 0);
    let mut counter = OpCounter::default();
    let out = bigmeans(&src, &c, &o, &mut counter);

    let mut rebuilt = OpCounter::default();
    for j in &out.jobs {
        rebuilt.merge(&j.counter);
    }
    rebuilt.merge(&out.assign_counter);
    assert_eq!(rebuilt, counter, "Σ jobs + assign != driver bill");
    // The final pass is billed like one Lloyd iteration over the full
    // data: k distances per row, streamed chunk-by-chunk.
    assert_eq!(out.assign_counter.distances, (x.rows() * c.k) as u64);
    assert_eq!(out.result.labels.len(), x.rows());
    // Warm starts are free; cold starts bill their seeding.
    let cold_ops: f64 = out.jobs.iter().filter(|j| !j.warm).map(|j| j.init_ops).sum();
    assert_eq!(out.init_ops, cold_ops);
}

#[test]
fn scheduler_routes_bigmeans_specs_like_the_direct_driver() {
    let x = fixture(600, 7);
    let cm = chunked(&x, "queue.k2c", 64, 2);
    let c = cfg(5, 1);
    let o = opts(4, 130, 2, 0);

    let mut counter = OpCounter::default();
    let direct = bigmeans(&DatasetSource::from(x.clone()), &c, &o, &mut counter);

    // One spec over the chunked store, one over the in-RAM matrix —
    // both must reproduce the direct driver run exactly.
    let spec = JobSpec::new("big", JobAlgo::K2Means, c.clone()).as_bigmeans(o);
    let mut q = JobQueue::new();
    q.submit(Arc::new(cm), spec.clone());
    q.submit(Arc::new(x), spec);
    let outcomes = q.run();
    for out in &outcomes {
        assert_eq!(out.result.centers, direct.result.centers);
        assert_eq!(out.result.labels, direct.result.labels);
        assert_eq!(out.result.energy.to_bits(), direct.result.energy.to_bits());
        assert_eq!(out.counter, counter);
        assert_eq!(out.init_ops, direct.init_ops);
        assert_eq!(out.algo, JobAlgo::K2Means);
    }
}

#[test]
fn warm_start_feeds_the_frozen_incumbent_forward() {
    let x = fixture(700, 13);
    let src = DatasetSource::from(x.clone());
    let c = cfg(6, 1);
    let o = BigMeansOpts { assign: false, ..opts(4, 140, 2, 0) };
    let out = bigmeans(&src, &c, &o, &mut OpCounter::default());

    // Round 1's jobs warm-start from the round-0 incumbent: reconstruct
    // job 2 (first job of round 1) with that incumbent's centers and it
    // must match bit for bit.
    let r0_best = out.jobs[..2]
        .iter()
        .min_by(|a, b| a.energy.partial_cmp(&b.energy).unwrap())
        .unwrap()
        .sample;
    // Recompute the round-0 incumbent centers the same way the driver
    // did: rerun that cold job.
    let idx = sample_indices(c.seed, r0_best, x.rows(), o.sample_rows);
    let xs = Matrix::gather(&x, &idx);
    let mut jcfg = c.clone();
    jcfg.seed = job_seed(c.seed, r0_best);
    jcfg.record_trace = false;
    let mut jc = OpCounter::default();
    let init = run_init(&xs, o.init, &jcfg, &mut jc);
    let incumbent = run_algo(&xs, o.algo, &init, &jcfg, &mut jc).centers;

    let idx2 = sample_indices(c.seed, 2, x.rows(), o.sample_rows);
    let xs2 = Matrix::gather(&x, &idx2);
    let mut jcfg2 = c.clone();
    jcfg2.seed = job_seed(c.seed, 2);
    jcfg2.record_trace = false;
    let mut jc2 = OpCounter::default();
    let warm = InitResult { centers: incumbent, labels: None };
    let redo = run_algo(&xs2, o.algo, &warm, &jcfg2, &mut jc2);
    assert_eq!(out.jobs[2].energy.to_bits(), redo.energy.to_bits());
    assert_eq!(out.jobs[2].counter, jc2);
    assert!(out.jobs[2].warm);
}
