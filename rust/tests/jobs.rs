//! Integration suite for the concurrent job scheduler
//! (`k2m::coordinator::jobs` on the persistent worker pool).
//!
//! The scheduler's contract: outcomes are **bit-identical to running
//! each spec serially, one at a time** — scheduling (budget, worker
//! interleaving, nested-inline passes) moves only the wall clock. These
//! tests run the full roster as one concurrent batch and diff it
//! against serial reference runs, counters included.

use std::sync::Arc;

use k2m::cluster::Config;
use k2m::coordinator::jobs::{run_job, JobAlgo, JobInit, JobQueue, JobSpec, JobStream};
use k2m::coordinator::pool::WorkerPool;
use k2m::runtime::run_cluster_jobs;
use k2m::testing::blobs;

/// A batch covering every algorithm (≥ 4 concurrent jobs) over one
/// shared dataset, with per-method knobs exercised.
fn roster_batch() -> Vec<(Arc<k2m::core::Matrix>, JobSpec)> {
    let (x, _) = blobs(3000, 24, 12, 9.0, 41);
    let x = Arc::new(x);
    let algos = [
        JobAlgo::K2Means,
        JobAlgo::Lloyd,
        JobAlgo::Elkan,
        JobAlgo::Hamerly,
        JobAlgo::Yinyang,
        JobAlgo::MiniBatch,
        JobAlgo::Akm,
    ];
    algos
        .into_iter()
        .enumerate()
        .map(|(i, algo)| {
            let cfg = Config {
                k: 30,
                kn: 8,
                m: 12,
                batch: 100, // MiniBatch's paper default; only it reads this
                max_iters: 15,
                seed: 7,
                ..Default::default()
            };
            (Arc::clone(&x), JobSpec::new(format!("{}-{i}", algo.name()), algo, cfg))
        })
        .collect()
}

#[test]
fn concurrent_jobs_match_serial_one_at_a_time() {
    let batch = roster_batch();
    assert!(batch.len() >= 4, "the contract wants >= 4 concurrent jobs");

    // Serial reference: each job alone on the calling thread.
    let reference: Vec<_> = batch.iter().map(|(x, spec)| run_job(x, spec)).collect();

    // The real thing: all jobs in flight at once on the default pool.
    let concurrent = run_cluster_jobs(&batch, 0);

    assert_eq!(concurrent.len(), reference.len());
    for (got, want) in concurrent.iter().zip(&reference) {
        assert_eq!(got.name, want.name, "submission order must be preserved");
        assert_eq!(got.result.labels, want.result.labels, "{}: labels", got.name);
        assert_eq!(got.result.centers, want.result.centers, "{}: centers", got.name);
        assert_eq!(
            got.result.energy.to_bits(),
            want.result.energy.to_bits(),
            "{}: energy",
            got.name
        );
        assert_eq!(got.result.iters, want.result.iters, "{}: iters", got.name);
        assert_eq!(got.counter, want.counter, "{}: op counter", got.name);
        assert_eq!(got.init_ops.to_bits(), want.init_ops.to_bits(), "{}: init ops", got.name);
    }
}

#[test]
fn budgets_do_not_change_outcomes() {
    // Any budget — serial (1), constrained (2), pool-wide (0) — yields
    // the same outcomes on the same isolated pool.
    let batch = roster_batch();
    let pool = WorkerPool::new(4);
    let run = |budget: usize| {
        let mut queue = JobQueue::with_budget(budget);
        for (x, spec) in &batch {
            queue.submit(Arc::clone(x), spec.clone());
        }
        queue.run_on(&pool)
    };
    let want = run(1);
    for budget in [2usize, 0] {
        let got = run(budget);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.result.labels, w.result.labels, "{}: budget={budget}", g.name);
            assert_eq!(g.result.centers, w.result.centers, "{}: budget={budget}", g.name);
            assert_eq!(g.counter, w.counter, "{}: budget={budget}", g.name);
        }
    }
}

#[test]
fn streaming_submission_matches_the_batch_queue() {
    // The submit-while-running path (JobStream) and the collect-then-run
    // path (JobQueue) must produce identical outcomes: the stream only
    // changes *when* work starts, never what it computes.
    let batch = roster_batch();
    let pool = WorkerPool::new(4);

    let stream = JobStream::start_on(&pool, 2);
    for (x, spec) in &batch {
        stream.submit(Arc::clone(x), spec.clone());
    }
    let streamed = stream.finish();

    let mut queue = JobQueue::with_budget(2);
    for (x, spec) in &batch {
        queue.submit(Arc::clone(x), spec.clone());
    }
    let queued = queue.run_on(&pool);

    assert_eq!(streamed.len(), queued.len());
    for (s, q) in streamed.iter().zip(&queued) {
        assert_eq!(s.name, q.name, "submission order must be preserved");
        assert_eq!(s.result.labels, q.result.labels, "{}: labels", s.name);
        assert_eq!(s.result.centers, q.result.centers, "{}: centers", s.name);
        assert_eq!(s.result.energy.to_bits(), q.result.energy.to_bits(), "{}: energy", s.name);
        assert_eq!(s.counter, q.counter, "{}: op counter", s.name);
    }
}

#[test]
fn mixed_inits_and_datasets_run_concurrently() {
    // Two datasets, every init family, one batch — exercises the Arc
    // sharing and the init dispatch inside run_job.
    let (xa, _) = blobs(1500, 10, 8, 12.0, 51);
    let (xb, _) = blobs(1200, 8, 6, 18.0, 52);
    let (xa, xb) = (Arc::new(xa), Arc::new(xb));
    let inits = [JobInit::Random, JobInit::KmeansPp, JobInit::KmeansPar, JobInit::Gdi];
    let mut batch = Vec::new();
    for (i, init) in inits.into_iter().enumerate() {
        let cfg = Config { k: 12, kn: 6, max_iters: 10, seed: 9, ..Default::default() };
        let x = if i % 2 == 0 { &xa } else { &xb };
        let spec = JobSpec {
            name: format!("{}-{i}", init.name()),
            algo: JobAlgo::K2Means,
            init,
            cfg,
            save_model: None,
        };
        batch.push((Arc::clone(x), spec));
    }
    let reference: Vec<_> = batch.iter().map(|(x, spec)| run_job(x, spec)).collect();
    let concurrent = run_cluster_jobs(&batch, 0);
    for (got, want) in concurrent.iter().zip(&reference) {
        assert_eq!(got.result.labels, want.result.labels, "{}", got.name);
        assert_eq!(got.result.centers, want.result.centers, "{}", got.name);
        assert_eq!(got.counter, want.counter, "{}", got.name);
    }
}
